"""Paper Fig. 7: load-imbalance (Eq. 10, normalised) comparison."""
from __future__ import annotations

import numpy as np

from benchmarks import common as C
from repro.core import normalized_load_imbalance
from repro.graph import stream as gstream
from repro.runtime.sweep import SweepRun

DATASETS = ("3elt", "grqc", "wiki-vote", "astroph", "email-enron")


def run(quick: bool = True) -> list:
    rows = []
    for ds in DATASETS:
        g = C.bench_graph(ds, quick)
        s = gstream.dynamic_schedule(g, n_intervals=4, seed=0)
        runs = [SweepRun(policy, C.default_cfg(k=4))
                for policy in ("sdp",) + C.BASELINES]
        for (st, _, m) in C.run_sweep_rows(s, runs):
            imb = normalized_load_imbalance(np.asarray(st.edge_load),
                                            np.asarray(st.active))
            rows.append({"dataset": ds, "policy": m["policy"],
                         "norm_load_imbalance": imb,
                         "load_imbalance": m["load_imbalance"],
                         "seconds": m["seconds"]})
    C.save_rows("fig7_imbalance", rows)
    return rows


def summarize(rows) -> list[str]:
    out = []
    for ds in DATASETS:
        d = {r["policy"]: r["norm_load_imbalance"] for r in rows
             if r["dataset"] == ds}
        worst = max(v for k, v in d.items() if k != "sdp")
        red = 100 * (1 - d["sdp"] / max(worst, 1e-9))
        out.append(f"fig7/{ds},{d['sdp']:.4f},"
                   f"reduction_vs_worst_baseline={red:.0f}%")
    return out
