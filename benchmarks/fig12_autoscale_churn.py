"""Beyond-paper Fig. 12: dynamic-autoscale sweep lanes on a delete-heavy
churn stream — the incremental O(K²) cut_matrix scale-in vs the old
per-event ``recompute_cut`` baseline.

Under vmap the scale-in cond computes both branches for every event of
every lane, so the baseline pays a full O(n·max_deg) adjacency pass per
event; the incremental path reads the merged cut off the pairwise matrix
(transition.py module docstring). Both variants ride the SAME production
kernel (``repro.runtime.sweep.sweep_events``) with only the static
``cut_fn`` knob flipped, and the integer counters are exact, so their
final states must be bit-identical — asserted per run and reported in the
rows. Writes BENCH_autoscale_churn.json (mirrored to the repo root).
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.core import EngineConfig
from repro.core import transition as tx
from repro.core.state import init_state
from repro.graph import stream as gstream
from repro.runtime import sweep as S

SEEDS = (0, 1, 2, 3)


def _cut_from_scratch(assignment, present, adj):
    """The pre-cut_matrix scale-in baseline: exact cut via a full
    O(n·max_deg) adjacency pass (each undirected edge stored twice).
    Deliberate copy of ``transition.recompute_cut`` (kept in sync) so no
    runtime path references the engine-layer from-scratch recompute."""
    valid = adj >= 0
    safe = jnp.where(valid, adj, 0)
    both = (valid & present[safe]) & present[:, None]
    diff = assignment[:, None] != assignment[safe]
    return (jnp.sum(both & diff, dtype=jnp.int32) // 2).astype(jnp.int32)


def _stacked_lanes(quick: bool):
    g = C.bench_graph("grqc", quick)
    streams = [
        gstream.interleaved_churn(g, warmup_frac=0.2, del_every=3,
                                  edge_del_every=5, seed=s)
        for s in SEEDS
    ]
    cfg = EngineConfig(k_max=16, k_init=1, max_cap=max(g.num_edges // 6, 30),
                       tolerance_param=60.0, dest_param=5.0, autoscale=True)
    T = max(s.num_events for s in streams)
    et, vx, nb, n, max_deg = S._stack_streams(streams, T)
    states = S._stack([
        init_state(n, max_deg, cfg.k_max, cfg.k_init, s) for s in SEEDS
    ])
    kns = S._stack([tx.knobs_arrays(cfg, n) for _ in SEEDS])
    pidx = jnp.full((len(SEEDS),), tx.POLICY_INDEX["sdp"], jnp.int32)
    auto = jnp.ones((len(SEEDS),), bool)
    events = sum(s.num_events for s in streams)
    return (states, kns, pidx, auto, et, vx, nb), cfg, events


def run(quick: bool = True) -> list:
    args, cfg, events = _stacked_lanes(quick)
    call = functools.partial(S.sweep_events, balance_guard=cfg.balance_guard,
                             autoscale_mode="dynamic", shared_stream=False)
    variants = {
        "scan_recompute": lambda: call(*args, jnp.int32(0),
                                       cut_fn=_cut_from_scratch),
        "scan_incremental": lambda: call(*args, jnp.int32(0)),
    }
    rows, finals = [], {}
    for name, fn in variants.items():
        out = jax.block_until_ready(fn())  # warm compile
        t0 = time.perf_counter()
        out = jax.block_until_ready(fn())
        dt = time.perf_counter() - t0
        finals[name] = out[0]
        rows.append({"variant": name, "seconds": dt, "events": events,
                     "lanes": len(SEEDS),
                     "scale_events": [int(x) for x in
                                      np.asarray(out[0].scale_events)],
                     "events_per_s": events / max(dt, 1e-9)})
    # exact counters: both scale-in implementations must agree bit-for-bit
    match = all(
        bool(jnp.all(a == b))
        for a, b in zip(jax.tree_util.tree_leaves(finals["scan_recompute"]),
                        jax.tree_util.tree_leaves(finals["scan_incremental"])))
    if not match:
        raise AssertionError(
            "incremental cut_matrix scale-in diverged from the recompute "
            "baseline — final sweep states are not bit-identical")
    base = next(r for r in rows if r["variant"] == "scan_recompute")
    for r in rows:
        r["states_match_baseline"] = match
        r["speedup_vs_recompute"] = base["seconds"] / max(r["seconds"], 1e-9)
    C.save_rows("fig12_autoscale_churn", rows)
    C.save_rows("BENCH_autoscale_churn", rows)
    return rows


def summarize(rows) -> list[str]:
    d = {r["variant"]: r for r in rows}
    inc = d["scan_incremental"]
    return [
        f"fig12/autoscale_churn,{inc['seconds']:.3f},"
        f"incremental_vs_recompute={inc['speedup_vs_recompute']:.1f}x"
        f";events_per_s={inc['events_per_s']:.0f}"
        f";states_match={inc['states_match_baseline']}"
    ]
