"""Fig. 16 (repo-native): partition quality over time on adversarial
streams — SDP vs SDP + online rebalancing vs the offline stand-in.

Each stream is fed interval-by-interval through the ``Partitioner``
facade; the rebalanced lane runs one ``rebalance()`` (greedy migration +
LPA refinement, repro.rebalance) between intervals — the between-windows
placement the subsystem is built for. Rows record the Eq. 9 cut ratio
and the normalised Eq. 10 imbalance at every interval boundary, plus a
``halo_bytes_per_layer`` row per lane showing that a better cut is also
fewer collective bytes for a GNN layer over the final partition. Every
rebalanced state is recount-gated against ``recompute_counters`` before
it is recorded.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common as C
from repro.api import Partitioner
from repro.core import recompute_counters
from repro.core.metrics import normalized_load_imbalance
from repro.core.offline import cut_of, offline_partition
from repro.graph import stream as gstream
from repro.graph.halo import build_halo_spec

K = 4
FEAT_DIM = 64


def _streams(quick: bool):
    g = C.bench_graph("wiki-vote", quick)
    block = 200 if quick else 600
    crowd = max(g.n // 8, 16)
    return [
        ("hub_arrivals", gstream.hub_arrivals(g, del_frac=0.1, seed=0)),
        ("community_merge", gstream.community_merge(block=block, seed=0)),
        ("flash_crowd", gstream.flash_crowd(g, crowd=crowd, seed=0)),
    ]


def _checkpoints(s) -> list[int]:
    pts = sorted({int(c) for c in s.intervals} | {s.num_events})
    return [c for c in pts if c > 0]


def _imbalance(part) -> float:
    st = part.state
    return float(normalized_load_imbalance(np.asarray(st.edge_load),
                                           np.asarray(st.active)))


def _recount_gate(part):
    st = part.state
    rec = recompute_counters(np.asarray(st.assignment),
                             np.asarray(st.present),
                             np.asarray(st.adj), part.cfg.k_max)
    assert int(st.cut_edges) == rec["cut_edges"], \
        "rebalance broke the cut counter"
    np.testing.assert_array_equal(np.asarray(st.cut_matrix),
                                  rec["cut_matrix"])


def _halo_bytes(g, assignment) -> tuple[int, int]:
    """(allgather bytes per device, total boundary bytes on the wire)
    for one GNN layer over the final partition — the measure_halo /
    gnn_halo_train cost model. The per-device figure is B_max-based (one
    padded all-gather); the total sums every shard's real publish set,
    which is the volume the cut actually controls."""
    a = np.asarray(assignment)[:g.n].copy()
    a[a < 0] = 0
    spec = build_halo_spec(g, a, K)
    total_rows = int((spec.publish_idx >= 0).sum())
    return (int(spec.collective_bytes_per_layer(FEAT_DIM)),
            total_rows * (K - 1) * FEAT_DIM * 4)


def _run_lane(name, s, rebalance: bool, quick: bool) -> list[dict]:
    cfg = C.default_cfg(k=K)
    m = 32 if quick else 128
    part = Partitioner.from_stream(s, cfg, policy="sdp", seed=0)
    rows, t0, prev = [], time.perf_counter(), 0
    for cur in _checkpoints(s):
        part.feed((s.etype[prev:cur], s.vertex[prev:cur],
                   s.nbrs[prev:cur])).sync()
        prev = cur
        if rebalance:
            part.rebalance(m=m, passes=2)
            _recount_gate(part)
        mm = part.metrics()
        rows.append({"stream": name,
                     "policy": "sdp+rebalance" if rebalance else "sdp",
                     "cursor": cur,
                     "edge_cut_ratio": mm["edge_cut_ratio"],
                     "imbalance": _imbalance(part),
                     "seconds": time.perf_counter() - t0})
    gm = gstream.materialize_graph(s)
    dev, tot = _halo_bytes(gm, part.state.assignment)
    rows[-1]["halo_bytes_per_layer"] = dev
    rows[-1]["halo_total_bytes_per_layer"] = tot
    return rows


def run(quick: bool = True) -> list:
    rows = []
    for name, s in _streams(quick):
        rows += _run_lane(name, s, rebalance=False, quick=quick)
        rows += _run_lane(name, s, rebalance=True, quick=quick)
        gm = gstream.materialize_graph(s)
        a, dt = C.timed(offline_partition, gm, K)
        deg = np.diff(gm.indptr)
        load = np.bincount(np.asarray(a), weights=deg, minlength=K)
        imb = float(load.std() / max(load.mean(), 1e-9))
        dev, tot = _halo_bytes(gm, a)
        rows.append({"stream": name, "policy": "offline(metis-standin)",
                     "cursor": s.num_events,
                     "edge_cut_ratio": cut_of(gm, a) / max(gm.num_edges, 1),
                     "imbalance": imb, "seconds": dt,
                     "halo_bytes_per_layer": dev,
                     "halo_total_bytes_per_layer": tot})
    C.save_rows("BENCH_quality", rows)
    return rows


def summarize(rows) -> list[str]:
    out = []
    for name in ("hub_arrivals", "community_merge", "flash_crowd"):
        fin = {r["policy"]: r for r in rows if r["stream"] == name}
        out.append(
            f"fig16/{name},{fin['sdp+rebalance']['edge_cut_ratio']:.4f},"
            f"sdp={fin['sdp']['edge_cut_ratio']:.4f}"
            f";offline={fin['offline(metis-standin)']['edge_cut_ratio']:.4f}"
            f";halo={fin['sdp+rebalance'].get('halo_total_bytes_per_layer', 0)}"
            f"vs{fin['sdp'].get('halo_total_bytes_per_layer', 0)}")
    return out
