"""Beyond-paper Fig. 13: elastic-geometry growth overhead.

Sessions seeded at 1/16, 1/4, and the full power-of-two tier of the
stream's geometry ingest the same growing stream (ids ordered by first
appearance, so the id universe expands with the cursor — the
serving regime where nobody knows the final size). Auto-grow doubles
the exceeded dimension per regeometry (repro.core.geometry), so the
undersized sessions pay O(log n) grow_state copies + kernel re-jits;
this benchmark reports that overhead against the presized baseline.
Growth is a semantics no-op, so all variants must end bit-identical —
asserted per run. Writes BENCH_growth.json (mirrored to the repo root;
CI bench-smoke runs and uploads it like fig12).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks import common as C
from repro.api import Partitioner
from repro.core import EngineConfig, Geometry, next_pow2
from repro.graph import stream as gstream

CHUNK = 512      # events per feed() call (the arrival granularity)
WINDOW = 256


def _stream(quick: bool) -> gstream.VertexStream:
    g = C.bench_graph("3elt", quick)
    # feed in ascending-id order: the mesh's id locality makes the
    # required universe grow with the cursor instead of jumping to n at
    # the first event
    order = np.arange(g.n, dtype=np.int32)
    return gstream.build_stream(g, seed=0, order=order)


def run(quick: bool = True) -> list:
    s = _stream(quick)
    full = Geometry(next_pow2(s.n), next_pow2(s.max_deg))
    cfg = EngineConfig(k_max=16, k_init=1,
                       max_cap=max(s.num_events // 6, 30), autoscale=True)
    variants = {
        "presized": full,
        "quarter": Geometry(max(full.n // 4, 1), max(full.max_deg // 4, 1)),
        "sixteenth": Geometry(max(full.n // 16, 1),
                              max(full.max_deg // 16, 1)),
    }
    rows, finals = [], {}
    for name, g0 in variants.items():

        def feed_all():
            part = Partitioner(cfg, n=g0.n, max_deg=g0.max_deg, seed=0,
                               engine="windowed", window=WINDOW)
            t0 = time.perf_counter()
            t = 0
            while t < s.num_events:
                e = min(t + CHUNK, s.num_events)
                part.feed((s.etype[t:e], s.vertex[t:e], s.nbrs[t:e]))
                t = e
            np.asarray(part.state.cut_edges)  # sync before stopping clock
            return part, time.perf_counter() - t0

        # the jit cache is shared across variants (they all end at the
        # same final tier and would reuse each other's compiles), so each
        # variant's cold pass starts from a cleared cache: cold includes
        # ALL of that variant's tier compiles, warm isolates the
        # grow_state copies + extra dispatches
        jax.clear_caches()
        part, cold = feed_all()
        _, warm = feed_all()
        finals[name] = part.state
        rows.append({
            "variant": name, "seconds_cold": cold, "seconds_warm": warm,
            "events": s.num_events,
            "start_n": g0.n, "start_max_deg": g0.max_deg,
            "final_n": part.n, "final_max_deg": part.max_deg,
            "regeometries": part.regeometries,
            "events_per_s_warm": s.num_events / max(warm, 1e-9),
        })
    # doubling tiers from a pow2 start land every variant on the same
    # final geometry, and growth is a semantics no-op — so the final
    # states must be bit-identical to the presized run
    base = finals["presized"]
    for name, st in finals.items():
        match = all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(base, st))
        if not match:
            raise AssertionError(
                f"elastic variant {name!r} diverged from the presized "
                "baseline — growth must be a semantics no-op")
    base = next(r for r in rows if r["variant"] == "presized")
    for r in rows:
        r["states_match_presized"] = True
        r["rejit_seconds"] = max(r["seconds_cold"] - r["seconds_warm"], 0.0)
        r["overhead_warm_vs_presized"] = (
            r["seconds_warm"] / max(base["seconds_warm"], 1e-9))
    for r in rows:
        # re-jit cost elasticity adds on top of the one compile a
        # presized session pays anyway
        r["marginal_rejit_vs_presized"] = max(
            r["rejit_seconds"] - base["rejit_seconds"], 0.0)
    C.save_rows("fig13_growth", rows)
    C.save_rows("BENCH_growth", rows)
    return rows


def summarize(rows) -> list[str]:
    d = {r["variant"]: r for r in rows}
    six = d["sixteenth"]
    return [
        f"fig13/growth,{six['seconds_warm']:.3f},"
        f"warm_overhead_vs_presized={six['overhead_warm_vs_presized']:.2f}x"
        f";marginal_rejit_s={six['marginal_rejit_vs_presized']:.3f}"
        f";regeometries={six['regeometries']}"
        f";final_n={six['final_n']}"
        f";states_match={six['states_match_presized']}"
    ]
