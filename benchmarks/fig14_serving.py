"""Beyond-paper Fig. 14: serving throughput and latency under Poisson
arrivals — the ROADMAP's "heavy traffic" number.

Event batches arrive on a Poisson process (``stream.poisson_arrivals``:
Poisson-sized bursts, exponential gaps, long-run rate λ events/s). Two
drivers ingest the identical workload:

* **sync_feed** — the naive request loop: per arrival, ``feed()`` then
  ``sync()`` (block) before touching the next batch. The host idles
  while the device runs and vice versa, and every ~mean_batch-event
  arrival occupies a full engine window.
* **service** — ``repro.api.serve.PartitionService``: submits are cheap
  enqueues; the double-buffered ingest thread coerces batch *t+1* while
  the device runs batch *t* and coalesces everything queued into full
  windows (continuous batching).

Both sessions pin ``engine="windowed"`` so every dispatch is the same
``(window,)`` shape — one compile each for the adds/mixed kernels,
warmed by the reference run, so the measurement is steady-state serving,
not recompiles. λ is calibrated to 2× the sync driver's unthrottled
capacity: the sync driver saturates (its p99 explodes — the point) while
the service has headroom to show its sustained rate.

``feed`` is bit-identical under any chopping, so both drivers — and the
service's coalesced batches — must land exactly on the whole-stream
reference state; asserted per run. Writes BENCH_serving.json (mirrored
to the repo root; CI bench-smoke runs fig14 and uploads it).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common as C
from repro.api import Partitioner, PartitionService
from repro.core import EngineConfig
from repro.graph import stream as gstream

WINDOW = 128
MEAN_BATCH = 24.0
OVERLOAD = 4.0          # λ = OVERLOAD × sync capacity: firm saturation
MAX_PENDING = 64


def _stream(quick: bool) -> gstream.VertexStream:
    # deliberately larger than the usual quick scale (0.25): serving runs
    # must be long enough (≥ ~0.5 s) that 1-core thread-scheduling noise
    # does not swamp the throughput signal
    from repro.graph.datasets import load_dataset
    g = load_dataset("3elt", scale=0.75 if quick else 1.0)
    return gstream.interleaved_churn(g, warmup_frac=0.25, del_every=3,
                                     edge_del_every=7, seed=0)


def _cfg(s: gstream.VertexStream) -> EngineConfig:
    return EngineConfig(k_max=16, k_init=1, autoscale=True,
                        max_cap=max(s.num_events // 6, 30))


def _session(s, cfg) -> Partitioner:
    return Partitioner.from_stream(s, cfg, seed=0, engine="windowed",
                                   window=WINDOW)


def _batches(s, bounds):
    return [(s.etype[a:b], s.vertex[a:b], s.nbrs[a:b])
            for a, b in zip(bounds[:-1], bounds[1:])]


def _percentiles(lat: np.ndarray) -> dict:
    return {"p50_ms": float(np.percentile(lat, 50) * 1e3),
            "p99_ms": float(np.percentile(lat, 99) * 1e3)}


def _assert_match(ref, got, who: str) -> None:
    if not all(np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(ref, got)):
        raise AssertionError(
            f"{who} final state diverged from the synchronous whole-stream "
            "reference — feed() chop-invariance must hold under serving")


def _run_sync(s, cfg, batches, due):
    """The naive per-arrival loop: sleep to the due time, feed, block."""
    part = _session(s, cfg)
    lat = np.empty(len(batches))
    t0 = time.perf_counter()
    for i, chunk in enumerate(batches):
        ahead = due[i] - (time.perf_counter() - t0)
        if ahead > 0:
            time.sleep(ahead)
        part.feed(chunk).sync()
        lat[i] = (time.perf_counter() - t0) - due[i]
    return part, time.perf_counter() - t0, lat


def _run_service(s, cfg, batches, due):
    part = _session(s, cfg)
    svc = PartitionService(part, max_pending_chunks=MAX_PENDING,
                           policy="block")
    t0 = time.perf_counter()
    for i, chunk in enumerate(batches):
        ahead = due[i] - (time.perf_counter() - t0)
        if ahead > 0:
            time.sleep(ahead)
        svc.submit(chunk, arrival=t0 + due[i])
    svc.flush()
    dur = time.perf_counter() - t0
    lat = svc.latencies()
    m = svc.metrics()
    svc.close()
    return part, dur, lat, m


def run(quick: bool = True) -> list:
    s = _stream(quick)
    cfg = _cfg(s)
    T = s.num_events

    # reference: one synchronous whole-stream feed — the bit-identity
    # anchor AND the compile warmup (every serving dispatch below reuses
    # these (WINDOW,)-shaped kernels)
    ref = _session(s, cfg).feed(s).sync().state

    # calibrate: unthrottled sync capacity (everything due at t=0).
    # Run twice and keep the second — the first pays one-off process
    # warmup (kernel-cache population for the per-arrival chunking) that
    # would understate capacity and leave λ below saturation.
    bounds, _ = gstream.poisson_arrivals(s, rate=1.0,
                                         mean_batch=MEAN_BATCH, seed=1)
    batches = _batches(s, bounds)
    _run_sync(s, cfg, batches, np.zeros(len(batches)))
    part, dur0, lat0 = _run_sync(s, cfg, batches, np.zeros(len(batches)))
    _assert_match(ref, part.state, "unthrottled sync")
    cap_sync = T / max(dur0, 1e-9)
    lam = OVERLOAD * cap_sync
    _, due = gstream.poisson_arrivals(s, rate=lam, mean_batch=MEAN_BATCH,
                                      seed=1)

    part, dur_sync, lat_sync = _run_sync(s, cfg, batches, due)
    _assert_match(ref, part.state, "sync_feed")
    eps_sync = T / max(dur_sync, 1e-9)

    part, dur_svc, lat_svc, svc_m = _run_service(s, cfg, batches, due)
    _assert_match(ref, part.state, "service")
    eps_svc = T / max(dur_svc, 1e-9)

    base = {"events": T, "chunks": len(batches), "mean_batch": MEAN_BATCH,
            "window": WINDOW, "arrival_rate_eps": lam,
            "states_match_reference": True}
    rows = [
        dict(base, variant="sync_unthrottled", seconds=dur0,
             events_per_s=cap_sync, **_percentiles(lat0)),
        dict(base, variant="sync_feed", seconds=dur_sync,
             events_per_s=eps_sync, **_percentiles(lat_sync)),
        dict(base, variant="service", seconds=dur_svc, events_per_s=eps_svc,
             speedup_vs_sync=eps_svc / max(eps_sync, 1e-9),
             batches_dispatched=svc_m["batches_dispatched"],
             device_busy_fraction=svc_m["device_busy_fraction"],
             coercion_s=svc_m["coercion_s"],
             device_wait_s=svc_m["device_wait_s"],
             submit_blocked_s=svc_m["submit_blocked_s"],
             max_queue_depth=svc_m["max_queue_depth"],
             **_percentiles(lat_svc)),
    ]
    C.save_rows("fig14_serving", rows)
    C.save_rows("BENCH_serving", rows)
    return rows


def summarize(rows) -> list[str]:
    d = {r["variant"]: r for r in rows}
    svc, sync = d["service"], d["sync_feed"]
    return [
        f"fig14/serving,{svc['seconds']:.3f},"
        f"events_per_s={svc['events_per_s']:.0f}"
        f";speedup_vs_sync={svc['speedup_vs_sync']:.2f}x"
        f";p99_ms={svc['p99_ms']:.1f}(sync={sync['p99_ms']:.1f})"
        f";busy={svc['device_busy_fraction']:.2f}"
        f";states_match={svc['states_match_reference']}"
    ]
