"""Paper Fig. 6: impact of dynamic addition/deletion — edge-cut captured
after each add/delete interval (25% add, 5% delete per interval)."""
from __future__ import annotations

from benchmarks import common as C
from repro.core import trace_at
from repro.graph import stream as gstream
from repro.runtime.sweep import SweepRun

DATASETS = ("email-enron", "grqc", "3elt", "wiki-vote")


def run(quick: bool = True) -> list:
    rows = []
    for ds in DATASETS:
        g = C.bench_graph(ds, quick)
        s = gstream.dynamic_schedule(g, add_pct=25.0, del_pct=5.0,
                                     n_intervals=4, seed=0,
                                     del_edges_per_interval=10)
        (_, trace, m), = C.run_sweep_rows(
            s, [SweepRun("sdp", C.default_cfg(k=4))])
        at = trace_at(trace, s.intervals)
        for i, (ratio, tot) in enumerate(zip(at["edge_cut_ratio"],
                                             at["total_edges"])):
            rows.append({"dataset": ds, "interval": i + 1,
                         "edge_cut_ratio": float(ratio),
                         "total_edges": int(tot),
                         "seconds": m["seconds"]})
    C.save_rows("fig6_dynamics", rows)
    return rows


def summarize(rows) -> list[str]:
    out = []
    for ds in DATASETS:
        rs = [r for r in rows if r["dataset"] == ds]
        trend = "->".join(f"{r['edge_cut_ratio']:.3f}" for r in rs)
        out.append(f"fig6/{ds},{rs[-1]['edge_cut_ratio']:.4f},"
                   f"trend={trend}")
    return out
