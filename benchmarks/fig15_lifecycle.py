"""Beyond-paper Fig. 15: the long-lived session lifecycle.

fig13 priced the way UP (elastic growth); this is the way DOWN and back
from the dead: a session grows to its peak tier, churn deletes most of
the graph, ``compact()`` hands the peak buffers back (dense re-pack +
tier drop, relabeling absorbed by the id map), and a crash is recovered
from snapshot + journal replay (repro.runtime.recovery). Three questions
priced per phase:

* steady-state step time — the same update batches, measured at the
  peak tier vs after the shrink (the post-shrink state is the same
  graph, so any delta is pure geometry);
* state footprint — device bytes at peak vs after compaction;
* recovery — wall seconds from dead process to a caught-up session
  (restore + replay of the journaled tail), vs re-feeding from scratch.

Writes BENCH_lifecycle.json (mirrored to the repo root; CI bench-smoke
runs and uploads it like fig12/fig13/fig14).
"""
from __future__ import annotations

import tempfile
import time

import numpy as np

from benchmarks import common as C
from repro.api import Partitioner
from repro.core import EngineConfig
from repro.graph.stream import EVENT_ADD, EVENT_DEL_VERTEX
from repro.runtime.recovery import CrashError, RecoverableSession

WINDOW = 256
CHUNK = 256          # events per measured feed
STEADY_BATCHES = 12  # measured update batches per phase


def _ring(lo, hi):
    ids = np.arange(lo, hi, dtype=np.int32)
    et = np.full(len(ids), EVENT_ADD, np.int32)
    nb = np.stack([ids - 1, ids + 1], 1).astype(np.int32)
    nb[0, 0], nb[-1, 1] = hi - 1, lo
    return et, ids, nb


def _dels(lo, hi):
    ids = np.arange(lo, hi, dtype=np.int32)
    return (np.full(len(ids), EVENT_DEL_VERTEX, np.int32), ids,
            np.full((len(ids), 2), -1, np.int32))


def _steady_batch(b, lo, hi):
    """CHUNK re-adds of existing ring vertices over [lo, hi) — the
    steady-state "update a vertex's neighbourhood" serving traffic."""
    ids = np.arange(lo, hi, dtype=np.int32)
    vx = np.resize(np.roll(ids, b), CHUNK).astype(np.int32)
    nb = np.stack([vx - 1, vx + 1], 1).astype(np.int32)
    nb[vx == lo, 0] = hi - 1
    nb[vx == hi - 1, 1] = lo
    return np.full(CHUNK, EVENT_ADD, np.int32), vx, nb


def _steady(feed, sync, lo, hi) -> tuple[float, float]:
    """Median / p90 seconds per steady-state batch."""
    times = []
    for b in range(STEADY_BATCHES + 2):     # +2 warmup (re-jit at new tier)
        chunk = _steady_batch(b, lo, hi)
        t0 = time.perf_counter()
        feed(chunk)
        sync()
        times.append(time.perf_counter() - t0)
    times = np.asarray(times[2:])
    return float(np.median(times)), float(np.percentile(times, 90))


def run(quick: bool = True) -> list:
    peak = 2048 if quick else 8192
    live_lo = peak - (128 if quick else 512)
    cfg = EngineConfig(k_max=8, k_init=2, max_cap=10**9)
    rows = []

    with tempfile.TemporaryDirectory() as d:
        part = Partitioner(cfg, seed=0, engine="windowed", window=WINDOW)
        sess = RecoverableSession(part, d, snapshot_every=10**9)
        # host-side log of everything fed, so the divergence check below
        # can replay the EXACT event sequence (RNG is cursor-keyed)
        log: list = []

        def feed(chunk):
            log.append(chunk)
            sess.feed(chunk)

        # -- grow to the peak tier ----------------------------------------
        t0 = time.perf_counter()
        feed(_ring(0, peak))
        sess.sync()
        grow_s = time.perf_counter() - t0
        med, p90 = _steady(feed, sess.sync, live_lo, peak)
        m = sess.metrics()
        rows.append({"phase": "peak", "n": m["n"], "max_deg": m["max_deg"],
                     "state_bytes": m["state_bytes"],
                     "step_median_s": med, "step_p90_s": p90,
                     "events_per_s": CHUNK / max(med, 1e-9),
                     "phase_seconds": grow_s, "cursor": sess.cursor})

        # -- churn away everything below live_lo, then reclaim ------------
        t0 = time.perf_counter()
        feed(_dels(0, live_lo))
        sess.sync()
        del_s = time.perf_counter() - t0
        bytes_before = sess.metrics()["state_bytes"]
        t0 = time.perf_counter()
        sess.compact()                       # journaled; drops the tier
        log.append("compact")
        compact_s = time.perf_counter() - t0
        med, p90 = _steady(feed, sess.sync, live_lo, peak)
        m = sess.metrics()
        assert m["n"] < peak, "compaction must drop the tier"
        rows.append({"phase": "post_shrink", "n": m["n"],
                     "max_deg": m["max_deg"],
                     "state_bytes": m["state_bytes"],
                     "step_median_s": med, "step_p90_s": p90,
                     "events_per_s": CHUNK / max(med, 1e-9),
                     "phase_seconds": del_s + compact_s,
                     "compact_seconds": compact_s,
                     "bytes_reclaimed": bytes_before - m["state_bytes"],
                     "cursor": sess.cursor})

        # -- crash + recover ----------------------------------------------
        sess.checkpoint(blocking=True)
        pre_crash_cursor = sess.cursor
        # journal a tail past the snapshot, then die mid-feed (the
        # crashing chunk is journaled but never executed — recovery must
        # replay both)
        feed(_ring(live_lo, peak))
        sess.inject_crash_after = sess.cursor
        try:
            feed(_ring(live_lo, peak))
        except CrashError:
            pass
        t0 = time.perf_counter()
        sess2 = RecoverableSession.recover(d, cfg, seed=0,
                                           engine="windowed", window=WINDOW)
        sess2.sync()
        recover_s = time.perf_counter() - t0
        replayed = sess2.cursor - pre_crash_cursor
        # the recovered session must match an uninterrupted replay of the
        # logged event sequence — spot-check via the cut counter
        ref = Partitioner(cfg, seed=0, engine="windowed", window=WINDOW)
        t0 = time.perf_counter()
        for item in log:
            ref.compact() if item == "compact" else ref.feed(item)
        ref.sync()
        refeed_s = time.perf_counter() - t0
        final_cut = int(np.asarray(sess2.state.cut_edges))
        if final_cut != int(np.asarray(ref.state.cut_edges)):
            raise AssertionError(
                "recovered session diverged from the uninterrupted replay "
                f"({final_cut} != {int(np.asarray(ref.state.cut_edges))})")
        m = sess2.metrics()
        rows.append({"phase": "recover", "n": m["n"],
                     "max_deg": m["max_deg"],
                     "state_bytes": m["state_bytes"],
                     "recover_seconds": recover_s,
                     "replayed_events": int(replayed),
                     "refeed_from_scratch_seconds": refeed_s,
                     "speedup_vs_refeed": refeed_s / max(recover_s, 1e-9),
                     "matches_uninterrupted": True,
                     "cursor": sess2.cursor})

    C.save_rows("fig15_lifecycle", rows)
    C.save_rows("BENCH_lifecycle", rows)
    return rows


def summarize(rows) -> list[str]:
    d = {r["phase"]: r for r in rows}
    pk, sh, rc = d["peak"], d["post_shrink"], d["recover"]
    return [
        f"fig15/lifecycle,{sh['step_median_s']:.4f},"
        f"peak_step_s={pk['step_median_s']:.4f}"
        f";bytes_peak={pk['state_bytes']};bytes_post_shrink="
        f"{sh['state_bytes']}"
        f";tier={pk['n']}->{sh['n']}"
        f";recover_s={rc['recover_seconds']:.2f}"
        f";recover_speedup_vs_refeed={rc['speedup_vs_refeed']:.1f}x"
        f";replayed={rc['replayed_events']}"
    ]
