"""Benchmark harness entry: one module per paper figure/table.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only fig4,...]

Prints ``name,value,derived`` CSV per row-group and writes JSON artifacts
to artifacts/bench/. The roofline table additionally needs dry-run
artifacts (repro.launch.dryrun --all).

Policy/config comparisons (fig4/6/7/8) run through the sweep runtime
(repro.runtime.sweep): all lanes of a comparison execute as ONE device
program (lane axis sharded across devices when more than one exists)
instead of a host loop re-scanning the stream per policy. fig10 times
the mixed-event window engine against the legacy delete-splitting driver
on an interleaved churn stream (BENCH_mixed_window.json); fig9 runs one
vertex-sharded session over mesh widths 1/2/4/8 at fixed n — events/s
and per-device peak state bytes (BENCH_shard_scaling.json; multi-width
rows need XLA_FLAGS=--xla_force_host_platform_device_count=8); fig11 times
host-loop vs vmapped vs sharded vs windowed-lane sweeps
(BENCH_sweep_scaling.json); fig12 times incremental vs recompute
autoscale lanes (BENCH_autoscale_churn.json); fig13 times elastic
geometry growth against a presized session (BENCH_growth.json); fig14
times the double-buffered PartitionService against a synchronous
per-arrival feed loop under Poisson arrivals (BENCH_serving.json);
fig16 tracks partition quality over time on adversarial streams with
and without the online rebalancing subsystem (BENCH_quality.json).
See docs/BENCHMARKS.md for every artifact's provenance and how to
regenerate it.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--full", action="store_true",
                   help="paper-scale datasets (slow on CPU); default quick")
    p.add_argument("--only", type=str, default="")
    args = p.parse_args()

    from benchmarks import (fig4_edgecut, fig5_vs_offline, fig6_dynamics,
                            fig7_imbalance, fig8_npartitions, fig9_scaling,
                            fig10_time, fig11_sweep_scaling,
                            fig12_autoscale_churn, fig13_growth,
                            fig14_serving, fig15_lifecycle, fig16_quality,
                            roofline)
    mods = {
        "fig4": fig4_edgecut, "fig5": fig5_vs_offline,
        "fig6": fig6_dynamics, "fig7": fig7_imbalance,
        "fig8": fig8_npartitions, "fig9": fig9_scaling,
        "fig10": fig10_time, "fig11": fig11_sweep_scaling,
        "fig12": fig12_autoscale_churn, "fig13": fig13_growth,
        "fig14": fig14_serving, "fig15": fig15_lifecycle,
        "fig16": fig16_quality,
        "roofline": roofline,
    }
    only = [s for s in args.only.split(",") if s]
    failures = 0
    print("name,value,derived")
    for name, mod in mods.items():
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            rows = mod.run(quick=not args.full)
            for line in mod.summarize(rows):
                print(line, flush=True)
            print(f"#{name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception as e:  # noqa: BLE001
            failures += 1
            import traceback
            traceback.print_exc()
            print(f"{name},ERROR,{type(e).__name__}:{e}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
