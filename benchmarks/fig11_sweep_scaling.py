"""Beyond-paper Fig. 11: sweep-runtime scaling — lanes/sec for the
host-loop, vmapped, and device-sharded sweep paths at L ∈ {4, 16, 64}
lanes, plus windowed-lane vs per-event-lane sweeps, all on a delete-heavy
interleaved churn stream. Writes BENCH_sweep_scaling.json.

The host loop re-dispatches ``run_stream`` per lane (the pre-sweep
benchmark pattern; its per-event branch switch also copies the written
adjacency each step — the cost the masked lane step avoids, see
transition.make_masked_step). The vmapped path runs all lanes in one
jitted program (``shard=False``); the sharded path additionally
shard_maps the lane axis across local devices (with one device the row
is omitted — run under ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
to exercise it on CPU). ``windowed_lanes`` rides the mixed-event window
kernel. Every path is bit-identical per lane, so the comparison is pure
throughput.

In quick mode the host loop is measured only for L ≤ 16 (it is 15-20×
slower than the device paths; a 64-lane host loop is minutes of
wall-clock that measures nothing new).

``PALLAS=1`` adds ``windowed_fused_lanes``: the same windowed lanes
through the fused Pallas chooser (``Sweep...kernel()``, vmapped over the
pallas_call). Off TPU the kernel runs in interpret mode, so the row
gates wiring, not Mosaic throughput; in quick mode it is measured at
L ≤ 16 only (interpret mode is host-speed).
"""
from __future__ import annotations

import os
import time

import jax

from benchmarks import common as C
from repro.api import Sweep, SweepRun
from repro.core import run_stream
from repro.graph import stream as gstream

LANE_COUNTS = (4, 16, 64)

PALLAS = os.environ.get("PALLAS", "").strip().lower() in (
    "1", "true", "yes", "on")


def _lanes(n_lanes: int):
    """sdp lanes, seeds vary (the fig4/8 sweep shape, autoscale off so the
    off-mode traced path — no per-event scale-in cond — is what's timed)."""
    return [SweepRun("sdp", C.default_cfg(k=4, k_max=8), seed)
            for seed in range(n_lanes)]


def _timed_round_robin(modes: dict) -> dict:
    """Best-of-reps per mode, modes interleaved round-robin so slow drift
    (shared-CPU contention) hits every mode equally instead of whichever
    mode happened to run during a noisy window."""
    for fn, _ in modes.values():
        jax.block_until_ready(fn())  # warm compile
    best = {m: float("inf") for m in modes}
    max_reps = max(reps for _, reps in modes.values())
    for i in range(max_reps):
        for m, (fn, reps) in modes.items():
            if i >= reps:
                continue
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            best[m] = min(best[m], time.perf_counter() - t0)
    return best


def run(quick: bool = True) -> list:
    g = C.bench_graph("grqc", quick)
    s = gstream.interleaved_churn(g, warmup_frac=0.2, del_every=3,
                                  edge_del_every=5, seed=0)
    ndev = jax.device_count()
    rows = []

    for L in LANE_COUNTS:
        runs = _lanes(L)

        def host_loop():
            return [run_stream(s, policy=r.policy, cfg=r.cfg, seed=r.seed)[0]
                    for r in runs]

        modes = {}
        if not quick or L <= 16:
            modes["host_loop"] = (host_loop, 1)
        modes["vmapped"] = (
            lambda: [r.state for r in
                     Sweep(s).lanes(runs).sharded(False).run()], 5)
        modes["windowed_lanes"] = (
            lambda: [r.state for r in
                     Sweep(s).lanes(runs).sharded(False).windowed().run()], 5)
        if PALLAS and (not quick or L <= 16):
            modes["windowed_fused_lanes"] = (
                lambda: [r.state for r in
                         Sweep(s).lanes(runs).sharded(False).windowed()
                         .kernel().run()], 2)
        if ndev > 1:
            modes["sharded"] = (
                lambda: [r.state for r in
                         Sweep(s).lanes(runs).sharded().run()], 5)
        for mode, dt in _timed_round_robin(modes).items():
            rows.append({
                "mode": mode, "lanes": L, "devices": ndev,
                "events": s.num_events, "seconds": dt,
                "lanes_per_s": L / max(dt, 1e-9),
                "lane_events_per_s": L * s.num_events / max(dt, 1e-9),
            })
    C.save_rows("fig11_sweep_scaling", rows)
    C.save_rows("BENCH_sweep_scaling", rows)
    return rows


def summarize(rows) -> list[str]:
    out = []
    for L in sorted({r["lanes"] for r in rows}):
        d = {r["mode"]: r for r in rows if r["lanes"] == L}
        vm, win = d["vmapped"], d["windowed_lanes"]
        parts = [f"windowed_vs_scan="
                 f"{win['lanes_per_s']/max(vm['lanes_per_s'],1e-9):.2f}x"]
        if "host_loop" in d:
            host = d["host_loop"]
            parts.insert(0, f"vmapped_vs_host="
                         f"{vm['lanes_per_s']/max(host['lanes_per_s'],1e-9):.1f}x")
        if "windowed_fused_lanes" in d:
            fused = d["windowed_fused_lanes"]
            parts.append(
                f"fused_vs_windowed="
                f"{fused['lanes_per_s']/max(win['lanes_per_s'],1e-9):.2f}x")
        if "sharded" in d:
            sh = d["sharded"]
            parts.append(
                f"sharded_vs_vmapped="
                f"{sh['lanes_per_s']/max(vm['lanes_per_s'],1e-9):.2f}x"
                f"@{sh['devices']}dev")
        out.append(f"fig11/L{L},{vm['lanes_per_s']:.2f}," + ";".join(parts))
    return out
