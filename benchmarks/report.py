"""Render EXPERIMENTS.md §Dry-run + §Roofline tables from artifacts.

    PYTHONPATH=src python -m benchmarks.report > /tmp/tables.md
"""
from __future__ import annotations

import glob
import json
import os

DRY_DIR = os.environ.get("REPRO_DRYRUN_DIR", "artifacts/dryrun")


def load(scheme_filter=None):
    recs = []
    for p in sorted(glob.glob(os.path.join(DRY_DIR, "*.json"))):
        with open(p) as f:
            r = json.load(f)
        r.setdefault("scheme", "baseline")
        if scheme_filter and r["scheme"] not in scheme_filter:
            continue
        recs.append(r)
    return recs


def dryrun_table(recs) -> str:
    out = ["| arch | shape | mesh | scheme | status | compile s | "
           "GiB/dev | fits 16G | HLO GFLOP/dev | coll GiB/dev |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                       f"| {r['scheme']} | **{r['status'].upper()}** "
                       f"| — | — | — | — | — |")
            continue
        gib = r["memory"]["live_bytes_per_device"] / 2**30
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['scheme']} "
            f"| ok | {r['compile_s']:.0f} | {gib:.2f} "
            f"| {'yes' if gib < 16 else 'NO'} "
            f"| {r['cost']['flops_per_device']/1e9:.1f} "
            f"| {r['collectives']['wire_bytes_per_device']/2**30:.2f} |")
    return "\n".join(out)


def roofline_table(recs) -> str:
    out = ["| arch | shape | mesh | scheme | compute s | memory s | "
           "collective s | dominant | useful-FLOP ratio | bound s |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["status"] != "ok":
            continue
        rl = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['scheme']} "
            f"| {rl['compute_s']:.3f} | {rl['memory_s']:.3f} "
            f"| {rl['collective_s']:.3f} | **{rl['dominant']}** "
            f"| {rl['useful_flops_ratio']:.2f} "
            f"| {rl['step_time_bound_s']:.3f} |")
    return "\n".join(out)


def skips_table(recs) -> str:
    out = ["| arch | shape | reason |", "|---|---|---|"]
    seen = set()
    for r in recs:
        if r["status"] == "skip" and (r["arch"], r["shape"]) not in seen:
            seen.add((r["arch"], r["shape"]))
            out.append(f"| {r['arch']} | {r['shape']} "
                       f"| {r.get('reason', '')} |")
    return "\n".join(out)


if __name__ == "__main__":
    recs = load()
    print("## Dry-run (all cells, both meshes)\n")
    print(dryrun_table(recs))
    print("\n## Skips\n")
    print(skips_table(recs))
    print("\n## Roofline\n")
    print(roofline_table(recs))
