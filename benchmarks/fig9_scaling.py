"""Paper Fig. 9: machines added/removed over time under the §4.2.3
auto-scaling policy (scale-out via Eq. 5, scale-in via Eqs. 6-8)."""
from __future__ import annotations

import numpy as np

from benchmarks import common as C
from repro.core import EngineConfig
from repro.graph import stream as gstream

DATASETS = ("3elt", "astroph", "grqc")


def run(quick: bool = True) -> list:
    rows = []
    for ds in DATASETS:
        g = C.bench_graph(ds, quick)
        s = gstream.dynamic_schedule(g, add_pct=25.0, del_pct=10.0,
                                     n_intervals=4, seed=0)
        # MAXCAP sized so the stream needs ~6 machines at peak
        cap = max(60, int(1.6 * g.num_edges / 6))
        cfg = EngineConfig(k_max=16, k_init=1, max_cap=cap,
                           tolerance_param=35.0, dest_param=5.0)
        st, trace, m = C.run_policy_stream(s, "sdp", cfg)
        parts = np.asarray(trace.num_partitions)
        marks = list(s.intervals)
        for i, t in enumerate(marks):
            rows.append({"dataset": ds, "interval": i + 1,
                         "num_partitions": int(parts[t - 1]),
                         "peak": int(parts.max()),
                         "scale_events": m["scale_events"],
                         "seconds": m["seconds"]})
    C.save_rows("fig9_scaling", rows)
    return rows


def summarize(rows) -> list[str]:
    out = []
    for ds in DATASETS:
        rs = [r for r in rows if r["dataset"] == ds]
        traj = "->".join(str(r["num_partitions"]) for r in rs)
        out.append(f"fig9/{ds},{rs[-1]['scale_events']},machines={traj}"
                   f";peak={rs[-1]['peak']}")
    return out
