"""Paper Fig. 9 (revived): partition-parallel scaling of ONE session.

The original Fig. 9 machine-count trajectory now rides fig12's autoscale
churn benchmark; this module measures the PR-10 distributed runtime
instead: one vertex-sharded session (repro.runtime.shard_session) run
over vertices-mesh widths 1, 2, 4, 8, ... at FIXED n, reporting

  * events/s — the windowed throughput at each width (on a forced-host
    CPU mesh the devices share one socket, so this shows the protocol
    overhead, not speedup; on real accelerators it shows scaling), and
  * per-device peak state bytes — the memory-capacity story: each device
    holds ~1/P of the O(n·max_deg) state, which is what lets a session
    outgrow a single device.

Every width computes the SAME partition (bit-identity is the runtime's
contract, gated by tests/test_shard_session.py), so quality columns are
recorded once per width as a cross-check. Multi-width rows need multiple
local devices — CI runs this under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``. Artifact:
BENCH_shard_scaling.json (mirrored to the repo root).
"""
from __future__ import annotations

import jax

from benchmarks import common as C
from repro.core import EngineConfig, state_metrics
from repro.core.geometry import resolve_geometry
from repro.core.sharded_state import (
    pad_rows, per_device_state_bytes, shard_state,
)
from repro.core.state import init_state
from repro.graph import stream as gstream
from repro.launch.mesh import make_vertices_mesh
from repro.runtime.shard_session import run_stream_sharded

DATASET = "3elt"
WINDOW = 256


def _widths() -> list[int]:
    n = jax.device_count()
    return [w for w in (1, 2, 4, 8, 16, 32) if w <= n]


def run(quick: bool = True) -> list:
    g = C.bench_graph(DATASET, quick)
    s = gstream.dynamic_schedule(g, add_pct=15.0, del_pct=10.0,
                                 n_intervals=3, seed=0)
    cfg = EngineConfig(k_max=16, k_init=4, autoscale=False)
    geom = resolve_geometry(s, cfg, None)
    rows = []
    for w in _widths():
        mesh = make_vertices_mesh(w)
        bytes_dev = per_device_state_bytes(shard_state(
            init_state(geom.n, geom.max_deg, geom.k_max, cfg.k_init, 0),
            mesh))
        # warm once (per-mesh jit cache), then time the steady run
        run_stream_sharded(s, policy="sdp", cfg=cfg, window=WINDOW,
                           geometry=geom, mesh=mesh)
        state, dt = C.timed(run_stream_sharded, s, policy="sdp", cfg=cfg,
                            window=WINDOW, geometry=geom, mesh=mesh)
        m = state_metrics(state)
        rows.append({"dataset": DATASET, "devices": w,
                     "n": geom.n,
                     "rows_per_device": pad_rows(geom.n, w) // w,
                     "events": s.num_events,
                     "seconds": dt,
                     "events_per_s": s.num_events / max(dt, 1e-9),
                     "per_device_state_bytes": bytes_dev,
                     "edge_cut_ratio": m["edge_cut_ratio"],
                     "load_imbalance": m["load_imbalance"]})
    C.save_rows("BENCH_shard_scaling", rows)
    return rows


def summarize(rows) -> list[str]:
    out = []
    for r in rows:
        out.append(
            f"fig9/shard_w{r['devices']},{r['events_per_s']:.0f},"
            f"bytes_per_dev={r['per_device_state_bytes']}"
            f";rows_per_dev={r['rows_per_device']}"
            f";cut={r['edge_cut_ratio']:.3f}")
    return out
