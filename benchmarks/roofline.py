"""Roofline table from dry-run artifacts (EXPERIMENTS.md §Roofline source).

Reads artifacts/dryrun/*.json (written by repro.launch.dryrun) and emits
per (arch × shape × mesh): the three roofline terms in seconds, dominant
bottleneck, MODEL_FLOPS / HLO_FLOPs ratio, and bytes/device.
"""
from __future__ import annotations

import glob
import json
import os

DRY_DIR = os.environ.get("REPRO_DRYRUN_DIR", "artifacts/dryrun")


def load_records() -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(DRY_DIR, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def run(quick: bool = True) -> list:
    rows = []
    for r in load_records():
        base = {"arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
                "scheme": r.get("scheme", "baseline"),
                "status": r["status"]}
        if r["status"] != "ok":
            base["reason"] = r.get("reason", "")[:60]
            rows.append(base)
            continue
        rl = r["roofline"]
        base.update({
            "compute_s": rl["compute_s"], "memory_s": rl["memory_s"],
            "collective_s": rl["collective_s"], "dominant": rl["dominant"],
            "useful_flops_ratio": rl["useful_flops_ratio"],
            "mem_gib_per_device": r["memory"]["live_bytes_per_device"] / 2**30,
            "fits_16g_hbm": r["memory"]["live_bytes_per_device"] < 16 * 2**30,
            "step_bound_s": rl["step_time_bound_s"],
        })
        rows.append(base)
    return rows


def summarize(rows) -> list[str]:
    out = []
    for r in rows:
        key = f"roofline/{r['arch']}:{r['shape']}:{r['mesh']}:{r['scheme']}"
        if r["status"] == "skip":
            out.append(f"{key},SKIP,{r.get('reason','')}")
        elif r["status"] != "ok":
            out.append(f"{key},ERROR,")
        else:
            out.append(
                f"{key},{r['step_bound_s']*1e3:.1f}ms,"
                f"dom={r['dominant']};mem={r['mem_gib_per_device']:.1f}GiB"
                f";useful={r['useful_flops_ratio']:.2f}")
    return out


def markdown_table(rows) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s |"
           " dominant | useful FLOP ratio | GiB/dev | fits HBM |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        if r["status"] == "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                f"| {r['compute_s']:.3f} | {r['memory_s']:.3f} "
                f"| {r['collective_s']:.3f} | **{r['dominant']}** "
                f"| {r['useful_flops_ratio']:.2f} "
                f"| {r['mem_gib_per_device']:.2f} "
                f"| {'yes' if r['fits_16g_hbm'] else 'NO'} |")
        else:
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                         f"| — | — | — | {r['status'].upper()} | — | — | — |")
    return hdr + "\n".join(lines)


if __name__ == "__main__":
    rows = run()
    print(markdown_table(rows))
