"""Paper Fig. 4: edge-cut ratio captured at 25%-of-dataset intervals,
SDP vs streaming baselines, across datasets."""
from __future__ import annotations


from benchmarks import common as C
from repro.core import trace_at
from repro.graph import stream as gstream
from repro.runtime.sweep import SweepRun

DATASETS = ("3elt", "grqc", "wiki-vote", "astroph")


def run(quick: bool = True) -> list:
    rows = []
    for ds in DATASETS:
        g = C.bench_graph(ds, quick)
        s = gstream.build_stream(g, seed=0)
        # capture at every 25% of the stream (paper protocol)
        t = s.num_events
        marks = [max(1, t * i // 4) for i in (1, 2, 3, 4)]
        # all policies in one vmapped device program
        runs = [SweepRun(policy, C.default_cfg(k=4))
                for policy in ("sdp",) + C.BASELINES]
        for (_, trace, m) in C.run_sweep_rows(s, runs):
            at = trace_at(trace, marks)
            for frac, ratio in zip((25, 50, 75, 100),
                                   at["edge_cut_ratio"]):
                rows.append({"dataset": ds, "policy": m["policy"],
                             "pct_streamed": frac,
                             "edge_cut_ratio": float(ratio),
                             "seconds": m["seconds"]})
    C.save_rows("fig4_edgecut", rows)
    return rows


def summarize(rows) -> list[str]:
    out = []
    for ds in DATASETS:
        final = {r["policy"]: r["edge_cut_ratio"] for r in rows
                 if r["dataset"] == ds and r["pct_streamed"] == 100}
        best_base = min(v for k, v in final.items() if k != "sdp")
        red = 100 * (1 - final["sdp"] / max(best_base, 1e-9))
        out.append(f"fig4/{ds},{final['sdp']:.4f},"
                   f"reduction_vs_best_baseline={red:.0f}%")
    return out
