"""Paper Fig. 8: edge-cut ratio vs number of partitions (communication
cost grows with k)."""
from __future__ import annotations

from benchmarks import common as C
from repro.graph import stream as gstream
from repro.runtime.sweep import SweepRun

DATASETS = ("3elt", "grqc")
KS = (2, 4, 8, 16)


def run(quick: bool = True) -> list:
    rows = []
    for ds in DATASETS:
        g = C.bench_graph(ds, quick)
        s = gstream.build_stream(g, seed=0)
        # one vmapped program sweeps every k (k_init varies, k_max shared)
        runs = [SweepRun("sdp", C.default_cfg(k=k)) for k in KS]
        for k, (_, _, m) in zip(KS, C.run_sweep_rows(s, runs)):
            rows.append({"dataset": ds, "k": k,
                         "edge_cut_ratio": m["edge_cut_ratio"],
                         "seconds": m["seconds"]})
    C.save_rows("fig8_npartitions", rows)
    return rows


def summarize(rows) -> list[str]:
    out = []
    for ds in DATASETS:
        rs = sorted((r for r in rows if r["dataset"] == ds),
                    key=lambda r: r["k"])
        mono = all(a["edge_cut_ratio"] <= b["edge_cut_ratio"] + 0.05
                   for a, b in zip(rs, rs[1:]))
        out.append(f"fig8/{ds},{rs[-1]['edge_cut_ratio']:.4f},"
                   f"monotone_in_k={mono}")
    return out
