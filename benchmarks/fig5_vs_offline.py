"""Paper Fig. 5: final edge-cut, streaming methods vs the offline
partitioner (METIS stand-in: BFS-grow + FM refinement).

Runs through the ``Partitioner`` facade (the supported entry since the
sweep/facade split) instead of the legacy ``run_policy_stream`` helper,
and adds an ``sdp+rebalance`` lane: the same SDP stream with the online
rebalancing subsystem (repro.rebalance) firing on an event cadence plus
one final repair pass — the gap toward the offline cut that between-
windows migration recovers on a static stream."""
from __future__ import annotations

import time

from benchmarks import common as C
from repro.api import Partitioner
from repro.core.offline import cut_of, offline_partition
from repro.graph import stream as gstream

DATASETS = ("3elt", "grqc", "wiki-vote", "4elt", "astroph")


def _run_part(s, policy, cfg, **kw):
    t0 = time.perf_counter()
    part = Partitioner.from_stream(s, cfg, policy=policy, seed=0, **kw)
    part.feed(s).sync()
    return part, time.perf_counter() - t0


def run(quick: bool = True) -> list:
    rows = []
    for ds in DATASETS:
        g = C.bench_graph(ds, quick)
        s = gstream.build_stream(g, seed=0)
        for policy in ("sdp",) + C.BASELINES:
            part, dt = _run_part(s, policy, C.default_cfg(k=4))
            m = part.metrics()
            rows.append({"dataset": ds, "policy": policy,
                         "edge_cut_ratio": m["edge_cut_ratio"],
                         "seconds": dt})
        every = max(s.num_events // 4, 1)
        m_budget = 32 if quick else 128
        part, dt = _run_part(s, "sdp", C.default_cfg(k=4),
                             auto_rebalance=True, rebalance_every=every,
                             rebalance_m=m_budget, rebalance_passes=2)
        part.rebalance()  # final repair pass before measuring
        m = part.metrics()
        rows.append({"dataset": ds, "policy": "sdp+rebalance",
                     "edge_cut_ratio": m["edge_cut_ratio"],
                     "seconds": dt})
        a, dt = C.timed(offline_partition, g, 4)
        rows.append({"dataset": ds, "policy": "offline(metis-standin)",
                     "edge_cut_ratio": cut_of(g, a) / max(g.num_edges, 1),
                     "seconds": dt})
    C.save_rows("BENCH_vs_offline", rows)
    return rows


def summarize(rows) -> list[str]:
    out = []
    for ds in DATASETS:
        d = {r["policy"]: r["edge_cut_ratio"] for r in rows
             if r["dataset"] == ds}
        out.append(
            f"fig5/{ds},{d['sdp']:.4f},"
            f"rebalance={d['sdp+rebalance']:.4f}"
            f";offline={d['offline(metis-standin)']:.4f}"
            f";hash={d['hash']:.4f}")
    return out
