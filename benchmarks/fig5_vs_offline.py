"""Paper Fig. 5: final edge-cut, streaming methods vs the offline
partitioner (METIS stand-in: BFS-grow + FM refinement)."""
from __future__ import annotations

from benchmarks import common as C
from repro.core.offline import cut_of, offline_partition
from repro.graph import stream as gstream

DATASETS = ("3elt", "grqc", "wiki-vote", "4elt", "astroph")


def run(quick: bool = True) -> list:
    rows = []
    for ds in DATASETS:
        g = C.bench_graph(ds, quick)
        s = gstream.build_stream(g, seed=0)
        for policy in ("sdp",) + C.BASELINES:
            _, _, m = C.run_policy_stream(s, policy, C.default_cfg(k=4))
            rows.append({"dataset": ds, "policy": policy,
                         "edge_cut_ratio": m["edge_cut_ratio"],
                         "seconds": m["seconds"]})
        a, dt = C.timed(offline_partition, g, 4)
        rows.append({"dataset": ds, "policy": "offline(metis-standin)",
                     "edge_cut_ratio": cut_of(g, a) / max(g.num_edges, 1),
                     "seconds": dt})
    C.save_rows("fig5_vs_offline", rows)
    return rows


def summarize(rows) -> list[str]:
    out = []
    for ds in DATASETS:
        d = {r["policy"]: r["edge_cut_ratio"] for r in rows
             if r["dataset"] == ds}
        out.append(
            f"fig5/{ds},{d['sdp']:.4f},"
            f"offline={d['offline(metis-standin)']:.4f}"
            f";hash={d['hash']:.4f}")
    return out
