"""Paper Fig. 10: execution time. Compares the paper-faithful scan engine,
the windowed TPU engine (beyond-paper), the windowed+Pallas-kernel path,
and the pure-Python oracle (the paper's Java-artifact analogue).

Also benchmarks the mixed-event window engine on a delete-heavy
*interleaved* churn stream — the regime where the legacy driver split
windows at every deletion boundary and degenerated to window-size-1
chunks — and writes the comparison to BENCH_mixed_window.json.

``PALLAS=1`` adds the fused-chooser rows: the full churn stream through
``use_kernel=True`` (``windowed_fused``) plus a per-window *step split*
(``stream="churn_step"``) timing one mixed window through the XLA step,
the fused Pallas kernel, and the two scoring paths in isolation — the
kernel-vs-XLA scoring breakdown. Off TPU these run the kernels in
interpret mode (see repro.kernels.common.default_interpret), so the
numbers gate wiring and shape-handling, not Mosaic throughput.
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.api import Partitioner
from repro.core import run_reference, run_stream
from repro.core.engine import run_events
from repro.core.state import init_state
from repro.core.windowed import (_pad_to, committed_scores, run_window_adds,
                                 run_window_mixed)
from repro.graph import stream as gstream
from repro.kernels.fused_chooser.ops import run_window_mixed_fused
from repro.kernels.partition_affinity.ops import scores_for_state

DATASETS = ("3elt", "grqc", "wiki-vote")
CHURN_DATASETS = ("grqc",)

PALLAS = os.environ.get("PALLAS", "").strip().lower() in (
    "1", "true", "yes", "on")


def _windowed_session(s, cfg, *, window=256, use_kernel=False):
    """The windowed engine behind the public session facade: one
    Partitioner over the whole stream (init + feed, same work the old
    run_stream_windowed driver did)."""
    return Partitioner.from_stream(
        s, cfg, policy="sdp", engine="windowed", window=window,
        use_kernel=use_kernel,
    ).feed(s).state


def _windowed_legacy(s, cfg, *, window=256):
    """The PR-1 delete-splitting driver, preserved here — fig10 is its
    only consumer — purely as the benchmark baseline: ADD runs go through
    run_window_adds, any other event through the faithful scan, windows
    split at every deletion boundary (so delete-heavy interleaved streams
    degenerate to window-size-1 chunks)."""
    state = init_state(s.n, s.max_deg, cfg.k_max, cfg.k_init, 0)
    et = np.asarray(s.etype)
    vx = jnp.asarray(s.vertex)
    nb = jnp.asarray(s.nbrs)
    t, T = 0, s.num_events
    while t < T:
        if et[t] == gstream.EVENT_ADD:
            end = t
            while end < T and et[end] == gstream.EVENT_ADD \
                    and end - t < window:
                end += 1
            state = run_window_adds(
                state, _pad_to(vx[t:end], window, -1),
                _pad_to(nb[t:end], window, -1), jnp.int32(t),
                policy="sdp", cfg=cfg)
        else:
            end = t
            while end < T and et[end] != gstream.EVENT_ADD:
                end += 1
            state, _ = run_events(
                state, jnp.asarray(et[t:end]), vx[t:end], nb[t:end],
                jnp.int32(t), policy="sdp", cfg=cfg)
        t = end
    return state


def _step_split(s, cfg, ds, *, window=256):
    """Per-window step-time split on one representative mixed window:
    the whole step through XLA (gather/score/choose/commit as separate
    ops) vs through the fused Pallas chooser, plus the scoring stage in
    isolation (``committed_scores`` vs the ``partition_affinity``
    kernel) — so the non-scoring share of the step is the difference."""
    state = init_state(s.n, s.max_deg, cfg.k_max, cfg.k_init, 0)
    w = min(window, s.num_events)
    et = jnp.asarray(s.etype[:w])
    vx = jnp.asarray(s.vertex[:w])
    nb = jnp.asarray(s.nbrs[:w])
    t0 = jnp.int32(0)
    steps = {
        "window_step_xla": lambda: run_window_mixed(
            state, et, vx, nb, t0, policy="sdp", cfg=cfg),
        "window_step_kernel": lambda: run_window_mixed_fused(
            state, et, vx, nb, t0, policy="sdp", cfg=cfg),
        "window_score_xla": lambda: committed_scores(state, nb),
        "window_score_kernel": lambda: scores_for_state(state, nb),
    }
    return _time_engines(steps, w,
                         {"dataset": ds, "stream": "churn_step",
                          "window": w})


def _time_engines(engines, num_events, extra):
    rows = []
    for name, fn in engines.items():
        jax.block_until_ready(fn())  # warm compile
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        dt = time.perf_counter() - t0
        rows.append({**extra, "engine": name, "seconds": dt,
                     "events": num_events,
                     "events_per_s": num_events / max(dt, 1e-9)})
    return rows


def run(quick: bool = True) -> list:
    rows = []
    for ds in DATASETS:
        g = C.bench_graph(ds, quick)
        s = gstream.build_stream(g, seed=0)
        cfg = C.default_cfg(k=4)

        engines = {
            "python_oracle": lambda: run_reference(s, policy="sdp", cfg=cfg),
            "faithful_scan": lambda: run_stream(s, policy="sdp", cfg=cfg),
            "windowed_256": lambda: _windowed_session(s, cfg, window=256),
            "windowed_kernel": lambda: _windowed_session(
                s, cfg, window=256, use_kernel=True),
        }
        if not quick:
            engines.pop("python_oracle")  # O(minutes) at full scale
        rows += _time_engines(engines, s.num_events,
                              {"dataset": ds, "stream": "static"})

    churn_rows = []
    for ds in CHURN_DATASETS:
        g = C.bench_graph(ds, quick)
        cs = gstream.interleaved_churn(g, warmup_frac=0.2, del_every=3,
                                       edge_del_every=5, seed=0)
        cfg = C.default_cfg(k=4)
        engines = {
            "faithful_scan": lambda: run_stream(cs, policy="sdp", cfg=cfg),
            "windowed_legacy": lambda: _windowed_legacy(
                cs, cfg, window=256),
            "windowed_mixed": lambda: _windowed_session(
                cs, cfg, window=256),
        }
        if PALLAS:
            engines["windowed_fused"] = lambda: _windowed_session(
                cs, cfg, window=256, use_kernel=True)
        churn_rows += _time_engines(engines, cs.num_events,
                                    {"dataset": ds, "stream": "churn"})
        if PALLAS:
            churn_rows += _step_split(cs, cfg, ds)

    rows += churn_rows
    C.save_rows("fig10_time", rows)
    C.save_rows("BENCH_mixed_window", churn_rows)
    return rows


def summarize(rows) -> list[str]:
    out = []
    for ds in DATASETS:
        d = {r["engine"]: r for r in rows
             if r["dataset"] == ds and r.get("stream") == "static"}
        base = d.get("python_oracle") or d["faithful_scan"]
        win = d["windowed_256"]
        speed = base["seconds"] / max(win["seconds"], 1e-9)
        out.append(f"fig10/{ds},{win['seconds']*1e6/win['events']:.1f},"
                   f"windowed_speedup_vs_{'oracle' if 'python_oracle' in d else 'faithful'}={speed:.1f}x"
                   f";events_per_s={win['events_per_s']:.0f}")
    for ds in CHURN_DATASETS:
        d = {r["engine"]: r for r in rows
             if r["dataset"] == ds and r.get("stream") == "churn"}
        if not d:
            continue
        mixed = d["windowed_mixed"]
        legacy = d["windowed_legacy"]
        speed = legacy["seconds"] / max(mixed["seconds"], 1e-9)
        line = (f"fig10/churn/{ds},{mixed['seconds']:.3f},"
                f"mixed_vs_legacy_windowed={speed:.1f}x"
                f";events_per_s={mixed['events_per_s']:.0f}")
        if "windowed_fused" in d:
            fused = d["windowed_fused"]
            line += (f";fused_vs_mixed="
                     f"{mixed['seconds']/max(fused['seconds'],1e-9):.2f}x")
        out.append(line)
    for ds in CHURN_DATASETS:
        d = {r["engine"]: r for r in rows
             if r["dataset"] == ds and r.get("stream") == "churn_step"}
        if not d:
            continue
        sx, sk = d["window_step_xla"], d["window_step_kernel"]
        cx, ck = d["window_score_xla"], d["window_score_kernel"]
        out.append(
            f"fig10/step/{ds},{sx['seconds']*1e6:.0f},"
            f"step_xla_us={sx['seconds']*1e6:.0f}"
            f";step_kernel_us={sk['seconds']*1e6:.0f}"
            f";score_xla_us={cx['seconds']*1e6:.0f}"
            f";score_kernel_us={ck['seconds']*1e6:.0f}"
            f";score_share_xla={cx['seconds']/max(sx['seconds'],1e-9):.2f}")
    return out
