"""Paper Fig. 10: execution time. Compares the paper-faithful scan engine,
the windowed TPU engine (beyond-paper), the windowed+Pallas-kernel path,
and the pure-Python oracle (the paper's Java-artifact analogue)."""
from __future__ import annotations

import time

from benchmarks import common as C
from repro.core import run_reference, run_stream, run_stream_windowed
from repro.graph import stream as gstream

DATASETS = ("3elt", "grqc", "wiki-vote")


def run(quick: bool = True) -> list:
    rows = []
    for ds in DATASETS:
        g = C.bench_graph(ds, quick)
        s = gstream.build_stream(g, seed=0)
        cfg = C.default_cfg(k=4)

        engines = {
            "python_oracle": lambda: run_reference(s, policy="sdp", cfg=cfg),
            "faithful_scan": lambda: run_stream(s, policy="sdp", cfg=cfg),
            "windowed_256": lambda: run_stream_windowed(
                s, policy="sdp", cfg=cfg, window=256),
            "windowed_kernel": lambda: run_stream_windowed(
                s, policy="sdp", cfg=cfg, window=256, use_kernel=True),
        }
        if not quick:
            engines.pop("python_oracle")  # O(minutes) at full scale
        for name, fn in engines.items():
            fn()  # warm compile
            t0 = time.perf_counter()
            fn()
            dt = time.perf_counter() - t0
            rows.append({"dataset": ds, "engine": name, "seconds": dt,
                         "events": s.num_events,
                         "events_per_s": s.num_events / max(dt, 1e-9)})
    C.save_rows("fig10_time", rows)
    return rows


def summarize(rows) -> list[str]:
    out = []
    for ds in DATASETS:
        d = {r["engine"]: r for r in rows if r["dataset"] == ds}
        base = d.get("python_oracle") or d["faithful_scan"]
        win = d["windowed_256"]
        speed = base["seconds"] / max(win["seconds"], 1e-9)
        out.append(f"fig10/{ds},{win['seconds']*1e6/win['events']:.1f},"
                   f"windowed_speedup_vs_{'oracle' if 'python_oracle' in d else 'faithful'}={speed:.1f}x"
                   f";events_per_s={win['events_per_s']:.0f}")
    return out
