"""Shared benchmark plumbing: dataset prep, interval capture, CSV/JSON out.

Every figure benchmark exposes ``run(quick: bool) -> list[dict]`` and is
registered in benchmarks.run. Results go to artifacts/bench/<name>.json and
a ``name,us_per_call,derived`` CSV line is printed per row.
"""
from __future__ import annotations

import json
import os
import time

import jax

from repro.core import EngineConfig, run_stream, state_metrics
from repro.graph.csr import cap_degree
from repro.graph.datasets import load_dataset

ART_DIR = os.environ.get("REPRO_BENCH_DIR", "artifacts/bench")
# BENCH_*-named artifacts are the repo's perf trajectory: they are mirrored
# next to the repo root's tracked BENCH_*.json files (artifacts/ is
# gitignored, so writing them only under ART_DIR silently froze the
# committed trajectory — the original sin this fixes)
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# Degree caps keep the padded (n, max_deg) adjacency bounded for the
# heavy-tailed stand-ins (twitter). Exact for the mesh/collab graphs.
DEG_CAP = {"twitter": 192, "wiki-vote": 192, "astroph": 192,
           "email-enron": 192}

QUICK_SCALE = {"3elt": 0.25, "grqc": 0.25, "wiki-vote": 0.15, "4elt": 0.1,
               "astroph": 0.08, "email-enron": 0.05, "twitter": 0.02}
FULL_SCALE = {"3elt": 1.0, "grqc": 1.0, "wiki-vote": 1.0, "4elt": 1.0,
              "astroph": 1.0, "email-enron": 1.0, "twitter": 0.25}

BASELINES = ("ldg", "fennel", "hash", "random", "greedy")


def bench_graph(name: str, quick: bool):
    scale = (QUICK_SCALE if quick else FULL_SCALE)[name]
    g = load_dataset(name, scale=scale)
    cap = DEG_CAP.get(name)
    if cap is not None:
        g = cap_degree(g, cap)
    return g


def default_cfg(k: int = 4, autoscale: bool = False,
                max_cap: int = 1 << 30, k_max: int = 16) -> EngineConfig:
    return EngineConfig(k_max=k_max, k_init=1 if autoscale else k,
                        max_cap=max_cap, autoscale=autoscale)


def save_rows(name: str, rows: list[dict]):
    os.makedirs(ART_DIR, exist_ok=True)
    payload = json.dumps(rows, indent=1, default=float)
    with open(os.path.join(ART_DIR, f"{name}.json"), "w") as f:
        f.write(payload)
    if name.startswith("BENCH_"):
        with open(os.path.join(REPO_ROOT, f"{name}.json"), "w") as f:
            f.write(payload)


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    jax.block_until_ready(out)  # async dispatch would under-report
    return out, time.perf_counter() - t0


def run_policy_stream(stream, policy, cfg, seed=0):
    (state, trace), dt = timed(run_stream, stream, policy=policy, cfg=cfg,
                               seed=seed)
    m = state_metrics(state)
    m["policy"] = policy
    m["seconds"] = dt
    m["events_per_s"] = stream.num_events / max(dt, 1e-9)
    return state, trace, m


def run_sweep_rows(stream, runs):
    """All (policy × seed × config) lanes in ONE vmapped device program
    (the repro.api.Sweep builder over repro.runtime.sweep) instead of a
    host loop re-scanning the stream per run. Returns
    [(state, trace, metrics), ...] in lane order; ``seconds`` is the
    amortised per-lane wall-clock."""
    from repro.api import Sweep
    results, dt = timed(lambda: Sweep(stream).lanes(runs).run())
    out = []
    for r in results:
        m = state_metrics(r.state)
        m["policy"] = r.policy
        m["seconds"] = dt / max(len(results), 1)
        m["sweep_seconds"] = dt
        m["sweep_lanes"] = len(results)
        m["events_per_s"] = (stream.num_events * len(results)
                             / max(dt, 1e-9))
        out.append((r.state, r.trace, m))
    return out
