"""Measure SDP boundary (halo) fractions on scaled proxy graphs.

The halo-mode dry-run (steps.build_gnn_halo) needs B_max — the published
boundary rows per shard. That is data-dependent, so we measure it: build a
power-law proxy with ogb-products' average degree, SDP-partition it into
P shards with the windowed engine, and record
boundary_vertices / shard_size per policy. Written to
artifacts/halo_frac.json; the dry-run sizes its ShapeDtypeStructs from it.

    PYTHONPATH=src python -m benchmarks.measure_halo [--nodes 40000]
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

from repro.core import EngineConfig, run_stream_windowed, state_metrics
from repro.graph.generators import make_graph
from repro.graph.halo import build_halo_spec
from repro.graph import stream as gstream
from repro.graph.csr import cap_degree


def measure(shape_name: str, n: int, avg_deg: float, p_shards: int,
            seed: int = 0) -> dict:
    g = make_graph("social", n, int(n * avg_deg / 2), seed=seed)
    g = cap_degree(g, 128)
    s = gstream.build_stream(g, seed=seed)
    out = {"n": g.n, "edges": g.num_edges, "p": p_shards}
    for policy in ("sdp", "hash"):
        st = run_stream_windowed(
            s, policy=policy, window=512,
            cfg=EngineConfig(k_max=p_shards, k_init=p_shards,
                             autoscale=False))
        a = np.array(st.assignment)
        a[a < 0] = 0
        spec = build_halo_spec(g, a, p_shards)
        per_shard = (spec.publish_idx >= 0).sum(axis=1)
        nb = spec.block_size
        out[policy] = float(per_shard.max() / max(nb, 1))
        out[f"{policy}_mean"] = float(per_shard.mean() / max(nb, 1))
        out[f"{policy}_cut"] = state_metrics(st)["edge_cut_ratio"]
    return out


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--nodes", type=int, default=40000)
    p.add_argument("--shards", type=int, default=256)
    p.add_argument("--out", type=str, default="artifacts/halo_frac.json")
    args = p.parse_args()
    res = {
        # ogb-products: avg degree 2E/N = 50.5 — power-law proxy
        "ogb_products": measure("ogb_products", args.nodes, 50.5,
                                args.shards),
        # cora-like: avg degree 7.8
        "full_graph_sm": measure("full_graph_sm", min(args.nodes, 2708),
                                 7.8, args.shards),
    }
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    # steps.build_gnn_halo reads {shape: {"sdp": frac}}
    payload = {k: {"sdp": v["sdp"], "hash": v["hash"], "detail": v}
               for k, v in res.items()}
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)
    print(json.dumps(payload, indent=1))


if __name__ == "__main__":
    main()
