"""Link-check the docs tree: dead file refs and anchors fail CI.

    python scripts/check_doc_links.py [files...]

Defaults to README.md, API.md, ROADMAP.md, and docs/*.md. Stdlib only —
no venv needed. Checks every markdown link ``[text](target)``:

* relative file targets must exist (resolved against the linking file);
* ``#anchor`` fragments — bare or on a relative ``.md`` target — must
  match a heading in the target file (GitHub slugification);
* absolute ``http(s)://`` / ``mailto:`` targets are skipped (no network
  in CI).
"""
from __future__ import annotations

import glob
import os
import re
import sys

LINK = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)\)")
HEADING = re.compile(r"^#{1,6}\s+(.*)$", re.M)
CODE_FENCE = re.compile(r"```.*?```", re.S)


def slugify(heading: str) -> str:
    """GitHub's anchor slug: drop markup, lowercase, strip punctuation,
    spaces to hyphens."""
    h = heading.strip().replace("`", "")
    h = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", h)     # linked headings
    h = re.sub(r"[^\w\- ]", "", h.lower(), flags=re.UNICODE)
    return h.strip().replace(" ", "-")


def anchors_of(path: str) -> set:
    with open(path, encoding="utf-8") as f:
        text = CODE_FENCE.sub("", f.read())
    return {slugify(m) for m in HEADING.findall(text)}


def check_file(path: str, repo: str) -> list[str]:
    errors = []
    with open(path, encoding="utf-8") as f:
        text = CODE_FENCE.sub("", f.read())
    rel = os.path.relpath(path, repo)
    for target in LINK.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        base, _, frag = target.partition("#")
        if base:
            dest = os.path.normpath(os.path.join(os.path.dirname(path),
                                                 base))
            if not os.path.exists(dest):
                errors.append(f"{rel}: dead link -> {target}")
                continue
        else:
            dest = path                                 # bare #anchor
        if frag:
            if not dest.endswith(".md"):
                continue                                # can't check
            if slugify(frag) not in anchors_of(dest):
                errors.append(f"{rel}: dead anchor -> {target}")
    return errors


def main(argv: list[str]) -> int:
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    files = argv or [p for p in
                     [os.path.join(repo, n)
                      for n in ("README.md", "API.md", "ROADMAP.md")]
                     if os.path.exists(p)] + sorted(
                         glob.glob(os.path.join(repo, "docs", "*.md")))
    errors = []
    for path in files:
        errors += check_file(path, repo)
    for e in errors:
        print(f"ERROR: {e}")
    print(f"checked {len(files)} files: "
          f"{'FAIL' if errors else 'ok'} ({len(errors)} dead refs)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
