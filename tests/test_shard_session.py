"""Vertex-sharded sessions: one session's vertex axis split across the
device mesh (repro.runtime.shard_session / repro.core.sharded_state).

The correctness gate is BIT-identity to the dense engines: every test
compares against ``run_stream`` (or a dense ``Partitioner``) on the same
stream. The sharded step runs the chooser oracle replicated over
psum-assembled window tables, so identity is structural, and these tests
must pass at ANY device count — CI runs this file both single-device and
under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

Also here: the adaptive rebalance cadence (``rebalance_drift=``) and the
chunked device→host checkpoint staging, both of which ride this PR.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import Partitioner, Sweep, SweepRun
from repro.checkpoint.manager import CheckpointManager, _stage_host
from repro.core import EngineConfig, run_stream
from repro.core.sharded_state import (
    gather_state, pad_rows, per_device_state_bytes, shard_state,
    unshard_state,
)
from repro.core.state import init_state
from repro.graph.generators import make_graph
from repro.graph import stream as gstream
from repro.launch.mesh import make_grid_mesh, make_vertices_mesh
from repro.runtime.shard_session import run_stream_sharded

multi_device = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs >1 device (XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)

W = 64  # small window: more windows (and psums) per stream


def _mixed_stream(n=80, m=240, seed=0):
    """ADD/DEL_EDGE/DEL_VERTEX mix — exercises every round-1 branch."""
    g = make_graph("social", n, m, seed=seed)
    return gstream.interleaved_churn(g, warmup_frac=0.25, del_every=3,
                                     seed=seed + 1)


def _assert_states_equal(dense, sharded, n):
    for f in dense._fields:
        a = np.asarray(getattr(dense, f))
        b = np.asarray(getattr(sharded, f))
        if f in ("assignment", "present", "adj"):
            a, b = a[:n], b[:n]
        if f == "adj":
            # sessions may sit at a wider max_deg tier than run_stream's
            # exact stream width — the extra columns must be -1 padding
            d = min(a.shape[1], b.shape[1])
            assert (a[:, d:] == -1).all() and (b[:, d:] == -1).all(), \
                "adj width padding leaked real neighbours"
            a, b = a[:, :d], b[:, :d]
        np.testing.assert_array_equal(a, b,
                                      err_msg=f"field {f!r} diverged")


# -- run_stream_sharded: the bit-identity gate ---------------------------

@pytest.mark.parametrize("policy,autoscale", [
    ("sdp", True), ("ldg", False), ("fennel", False)])
def test_run_stream_sharded_bit_identical(policy, autoscale):
    s = _mixed_stream()
    cfg = EngineConfig(k_max=8, k_init=2, autoscale=autoscale, max_cap=90)
    dense, _ = run_stream(s, policy=policy, cfg=cfg, seed=3)
    sharded = run_stream_sharded(s, policy=policy, cfg=cfg, seed=3,
                                 window=W)
    _assert_states_equal(dense, sharded, n=dense.assignment.shape[0])


@multi_device
def test_run_stream_sharded_every_mesh_width():
    """The same stream over every divisor-width mesh (1, 2, ..., all
    devices) — gathered results must all equal the dense run."""
    s = _mixed_stream(n=60, m=150, seed=7)
    cfg = EngineConfig(k_max=8, k_init=1, max_cap=60)
    dense, _ = run_stream(s, policy="sdp", cfg=cfg, seed=0)
    for width in range(1, jax.device_count() + 1):
        sharded = run_stream_sharded(
            s, policy="sdp", cfg=cfg, seed=0, window=W,
            mesh=make_vertices_mesh(width))
        _assert_states_equal(dense, sharded, n=dense.assignment.shape[0])


def test_heterogeneous_padding_no_phantom_vertices():
    """n=37 never divides a 2/4/8-device mesh: the padded rows must stay
    inert — absent, unassigned, and invisible to every counter."""
    g = make_graph("social", 37, 90, seed=2)
    s = gstream.build_stream(g, seed=2)
    cfg = EngineConfig(k_max=8, k_init=2, max_cap=40)
    dense, _ = run_stream(s, policy="sdp", cfg=cfg, seed=1)
    sharded = run_stream_sharded(s, policy="sdp", cfg=cfg, seed=1, window=W)
    _assert_states_equal(dense, sharded, n=37)
    # counters: phantom (padding) vertices would inflate these
    assert int(sharded.total_edges) == int(dense.total_edges)
    np.testing.assert_array_equal(np.asarray(sharded.vertex_count),
                                  np.asarray(dense.vertex_count))
    assert int(np.asarray(sharded.vertex_count).sum()) \
        == int(np.asarray(dense.present).sum())


def test_pad_rows_and_state_bytes():
    mesh = make_vertices_mesh()
    p = mesh.shape["vertices"]
    assert pad_rows(37, p) % p == 0 and pad_rows(37, p) >= 37
    state = shard_state(init_state(64, 4, 8, 2, 0), mesh)
    assert per_device_state_bytes(state) > 0
    # round-trip through the canonical dense layout is lossless
    back = unshard_state(state, n=64)
    ref = init_state(64, 4, 8, 2, 0)
    _assert_states_equal(ref, back, n=64)
    host = gather_state(state, n=64)
    assert isinstance(host.assignment, np.ndarray)
    assert host.assignment.shape == (64,)


# -- the session facade: Partitioner(sharded=True) -----------------------

def test_sharded_session_chop_and_grow():
    """Uneven chunk sizes + on-demand geometry growth: the sharded
    session must match a dense windowed session AND run_stream."""
    s = _mixed_stream(n=90, m=260, seed=5)
    cfg = EngineConfig(k_max=8, k_init=2, max_cap=90)
    et = np.asarray(s.etype)
    vx = np.asarray(s.vertex)
    nb = np.asarray(s.nbrs)
    shard = Partitioner(cfg, policy="sdp", sharded=True, window=W)
    dense = Partitioner(cfg, policy="sdp", engine="windowed", window=W)
    cuts = [0, 17, 130, 131, s.num_events]     # includes a 1-event chunk
    for a, b in zip(cuts[:-1], cuts[1:]):
        chunk = (et[a:b], vx[a:b], nb[a:b])
        shard.feed(chunk)
        dense.feed(chunk)
    shard.sync(), dense.sync()
    n_sem = shard._sem_geom.n
    assert n_sem == dense.n, "sharded session left the dense tier ladder"
    _assert_states_equal(dense.state,
                         unshard_state(shard.state, n=n_sem), n=n_sem)
    m = shard.metrics()
    assert m["shard_devices"] == jax.device_count()
    assert m["per_device_state_bytes"] > 0


@multi_device
def test_per_device_bytes_shrink_with_mesh_width():
    """The point of sharding: each device holds ~1/P of the O(n) state."""
    state = init_state(1024, 8, 8, 2, 0)
    b1 = per_device_state_bytes(shard_state(state, make_vertices_mesh(1)))
    bp = per_device_state_bytes(
        shard_state(init_state(1024, 8, 8, 2, 0), make_vertices_mesh()))
    assert bp < b1


def test_sharded_snapshot_restore_cross_layout(tmp_path):
    """Snapshot from a sharded session restores into BOTH a dense and a
    sharded session (any mesh width) and both resume bit-identically —
    the checkpoint is the canonical gathered layout."""
    s = _mixed_stream(n=70, m=200, seed=9)
    cfg = EngineConfig(k_max=8, k_init=2, max_cap=70)
    et, vx, nb = np.asarray(s.etype), np.asarray(s.vertex), np.asarray(s.nbrs)
    half = s.num_events // 2
    ref, _ = run_stream(s, policy="sdp", cfg=cfg, seed=0)

    live = Partitioner(cfg, policy="sdp", sharded=True, window=W, seed=0)
    live.feed((et[:half], vx[:half], nb[:half]))
    d = str(tmp_path / "ck")
    live.snapshot(d)
    live.feed((et[half:], vx[half:], nb[half:]))

    restored_dense = Partitioner.restore(d, cfg, policy="sdp",
                                         engine="windowed", window=W)
    restored_shard = Partitioner.restore(d, cfg, policy="sdp",
                                         sharded=True, window=W)
    for p in (restored_dense, restored_shard):
        assert p.cursor == half
        p.feed((et[half:], vx[half:], nb[half:]))

    n = ref.assignment.shape[0]
    _assert_states_equal(ref, unshard_state(live.state, n=n), n=n)
    _assert_states_equal(ref, restored_dense.state, n=n)
    _assert_states_equal(
        ref, unshard_state(restored_shard.state,
                           n=restored_shard._sem_geom.n), n=n)


def test_reshard_and_remesh_mid_session(tmp_path):
    """Mesh-width change mid-stream (gather → re-pad → re-place) is not
    semantics; RecoverableSession.remesh routes sharded sessions to it."""
    from repro.runtime.recovery import RecoverableSession
    s = _mixed_stream(n=50, m=140, seed=11)
    cfg = EngineConfig(k_max=8, k_init=2, max_cap=50)
    et, vx, nb = np.asarray(s.etype), np.asarray(s.vertex), np.asarray(s.nbrs)
    half = s.num_events // 2
    ref, _ = run_stream(s, policy="sdp", cfg=cfg, seed=0)

    part = Partitioner(cfg, policy="sdp", sharded=True, window=W, seed=0)
    sess = RecoverableSession(part, str(tmp_path / "rs"),
                              snapshot_every=10**9)
    sess.feed((et[:half], vx[:half], nb[:half]))
    sess.remesh(devices=1)           # "device loss": fall back to width 1
    assert part._mesh.shape["vertices"] == 1
    sess.feed((et[half:], vx[half:], nb[half:]))
    n = ref.assignment.shape[0]
    _assert_states_equal(ref, unshard_state(part.state, n=n), n=n)

    # dense sessions still need an explicit target device
    dense = Partitioner(cfg, policy="sdp", window=W)
    ds = RecoverableSession(dense, str(tmp_path / "rs2"),
                            snapshot_every=10**9)
    with pytest.raises(ValueError, match="needs the target device"):
        ds.remesh()


# -- sweep integration ---------------------------------------------------

def test_sweep_sharded_vertices_matches_run_stream():
    s = _mixed_stream(n=60, m=160, seed=13)
    runs = [SweepRun("sdp", EngineConfig(k_max=8, k_init=1, max_cap=60), 0),
            SweepRun("ldg", EngineConfig(k_max=8, k_init=3,
                                         autoscale=False), 1)]
    results = (Sweep(s).lanes(runs).windowed(W).sharded_vertices().run())
    for r in results:
        ref, _ = run_stream(s, policy=r.policy, cfg=r.cfg, seed=r.seed)
        assert r.trace is None
        _assert_states_equal(ref, r.state, n=ref.assignment.shape[0])


def test_sweep_sharded_vertices_validation():
    s = _mixed_stream(n=30, m=60, seed=1)
    with pytest.raises(ValueError, match="mutually exclusive"):
        Sweep(s).lane().windowed(W).sharded().sharded_vertices().run()
    with pytest.raises(ValueError, match="windowed engine"):
        Sweep(s).lane().scan().sharded_vertices().run()
    with pytest.raises(ValueError, match="Pallas"):
        Sweep(s).lane().windowed(W).kernel().sharded_vertices().run()
    with pytest.raises(ValueError, match="rebalance"):
        (Sweep(s).lane().windowed(W).rebalance(m=4, every=W)
         .sharded_vertices().run())


def test_sharded_session_validation():
    with pytest.raises(ValueError, match="Pallas"):
        Partitioner(sharded=True, use_kernel=True)
    with pytest.raises(ValueError, match="scan"):
        Partitioner(sharded=True, engine="scan")
    with pytest.raises(ValueError, match="scan"):
        Partitioner(sharded=True, collect_trace=True)
    p = Partitioner(sharded=True)
    with pytest.raises(ValueError, match="reshard"):
        p.place(jax.devices()[0])
    dense = Partitioner()
    with pytest.raises(ValueError, match="sharded=True sessions"):
        dense.reshard()


def test_mesh_builders_compose_or_raise():
    n_dev = jax.device_count()
    mesh = make_vertices_mesh()
    assert mesh.shape == {"vertices": n_dev}
    with pytest.raises(ValueError, match="local devices"):
        make_vertices_mesh(n_dev + 1)
    grid = make_grid_mesh(1, n_dev)
    assert grid.shape == {"lanes": 1, "vertices": n_dev}
    with pytest.raises(ValueError, match=r"lanes.*vertices|×|x"):
        make_grid_mesh(n_dev + 1, n_dev + 1)


# -- adaptive rebalance cadence (rebalance_drift=) -----------------------

def _feed_chunks(part, s, start, end, step):
    et, vx, nb = np.asarray(s.etype), np.asarray(s.vertex), np.asarray(s.nbrs)
    for t in range(start, end, step):
        part.feed((et[t:t + step], vx[t:t + step], nb[t:t + step]))


def test_drift_cadence_fires_on_hub_burst():
    """hub_arrivals drifts both signals up after the warmup baseline —
    the adaptive cadence must fire (the fixed cadence is off)."""
    g = make_graph("social", 200, 800, seed=0)
    s = gstream.hub_arrivals(g, hub_frac=0.05, warmup_frac=0.4, seed=0)
    warm = s.intervals[0]
    cfg = EngineConfig(k_max=8, k_init=4, autoscale=False)
    p = Partitioner(cfg, policy="sdp", rebalance_drift=0.05,
                    rebalance_m=16, rebalance_passes=1, window=W)
    _feed_chunks(p, s, 0, warm, warm)          # baseline = post-warmup
    assert p._drift_base is not None and p._drift_fires == 0
    _feed_chunks(p, s, warm, s.num_events, W)
    m = p.metrics()
    assert m["rebalance_drift_fires"] >= 1
    assert m["rebalances"] == m["rebalance_drift_fires"]
    # every fire re-bases: the recorded events carry the improvement
    assert len(p.rebalance_events) == m["rebalance_drift_fires"]


def test_drift_cadence_quiet_on_stable_stream():
    """A stable stream (signals near their baseline) must never fire."""
    g = make_graph("social", 200, 800, seed=0)
    s = gstream.build_stream(g, seed=1)
    cfg = EngineConfig(k_max=8, k_init=4, autoscale=False)
    p = Partitioner(cfg, policy="sdp", rebalance_drift=0.5,
                    rebalance_m=16, window=W)
    k = int(s.num_events * 0.8)
    _feed_chunks(p, s, 0, k, k)                # baseline after the bulk
    _feed_chunks(p, s, k, s.num_events, 32)
    assert p.metrics()["rebalance_drift_fires"] == 0
    assert p.metrics()["rebalances"] == 0


def test_drift_base_rides_checkpoints(tmp_path):
    g = make_graph("social", 120, 360, seed=3)
    s = gstream.build_stream(g, seed=3)
    cfg = EngineConfig(k_max=8, k_init=2)
    p = Partitioner(cfg, policy="sdp", rebalance_drift=0.05,
                    rebalance_m=8, window=W)
    _feed_chunks(p, s, 0, s.num_events // 2, W)
    assert p._drift_base is not None
    d = str(tmp_path / "ck")
    p.snapshot(d)
    q = Partitioner.restore(d, cfg, policy="sdp", rebalance_drift=0.05,
                            rebalance_m=8, window=W)
    assert q._drift_base == pytest.approx(p._drift_base)


# -- chunked device→host checkpoint staging ------------------------------

def test_stage_host_chunked_equals_direct():
    tree = {"big": jnp.arange(4096, dtype=jnp.int32).reshape(256, 16),
            "small": jnp.float32(3.5),
            "host": np.arange(7)}
    # chunk far smaller than a leaf → many row slices per leaf
    staged = _stage_host(tree, chunk_bytes=128)
    assert all(isinstance(v, np.ndarray) or np.isscalar(v)
               for v in jax.tree_util.tree_leaves(staged))
    np.testing.assert_array_equal(staged["big"], np.asarray(tree["big"]))
    np.testing.assert_array_equal(staged["small"], 3.5)
    np.testing.assert_array_equal(staged["host"], tree["host"])
    # chunk size that does not divide the row count exactly
    np.testing.assert_array_equal(
        _stage_host(tree, chunk_bytes=100)["big"], np.asarray(tree["big"]))


def test_checkpoint_manager_chunked_round_trip(tmp_path):
    """save_now under a tiny host_chunk_bytes stages in many chunks and
    the restored tree is bit-identical (no timing assertions)."""
    state = init_state(128, 6, 8, 2, 0)
    mgr = CheckpointManager(str(tmp_path), interval=1, host_chunk_bytes=64)
    mgr.save_now(5, state, blocking=True, geometry=None)
    like = init_state(128, 6, 8, 2, 0)
    restored, step = mgr.restore(like)
    assert step == 5
    for f in state._fields:
        np.testing.assert_array_equal(np.asarray(getattr(state, f)),
                                      np.asarray(getattr(restored, f)))
    with pytest.raises(ValueError, match="host_chunk_bytes"):
        CheckpointManager(str(tmp_path), host_chunk_bytes=0)


def test_sharded_snapshot_uses_canonical_rows(tmp_path):
    """A sharded session's checkpoint must record the SEMANTIC geometry
    (padding sliced off) so any layout can restore it."""
    if jax.device_count() == 1:
        pytest.skip("padding only exists on multi-device meshes")
    g = make_graph("social", 37, 90, seed=4)
    s = gstream.build_stream(g, seed=4)
    cfg = EngineConfig(k_max=8, k_init=2, max_cap=40)
    p = Partitioner(cfg, policy="sdp", sharded=True, window=W)
    p.feed(s)
    d = str(tmp_path / "ck")
    p.snapshot(d)
    mgr = CheckpointManager(d, interval=1)
    geom = mgr.geometry(mgr.latest())
    assert geom.n == p._sem_geom.n
    assert geom.n % jax.device_count() != 0 or geom.n == p.n
