"""Step-builder and HLO-stats coverage: bundle construction for every
cell family, collective wire-byte formulas, and a small-mesh recsys
compile."""
import os
import subprocess
import sys
import textwrap


from repro.launch.hlo_stats import (_collective_wire, _shape_elems_bytes,
                                    _split_type_op, Instr)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# hlo_stats unit coverage
# ---------------------------------------------------------------------------

def _instr(op, type_str, line):
    return Instr("x", op, type_str, "", line)


def test_collective_wire_formulas():
    line = "replica_groups=[2,4]<=[8]"       # 2 groups of 4
    by = 4 * 1024 * 1024                      # f32[1024,1024]
    t = "f32[1024,1024]{1,0}"
    op, nbytes, wire = _collective_wire(_instr("all-gather", t, line), 8)
    assert nbytes == by and abs(wire - by * 3 / 4) < 1
    _, _, wire = _collective_wire(_instr("all-reduce", t, line), 8)
    assert abs(wire - 2 * by * 3 / 4) < 1
    _, _, wire = _collective_wire(_instr("reduce-scatter", t, line), 8)
    assert abs(wire - by * 3) < 1
    _, _, wire = _collective_wire(_instr("collective-permute", t, line), 8)
    assert wire == by


def test_shape_parsing_tuple_types():
    elems, nbytes = _shape_elems_bytes(
        "(f32[8,4]{1,0}, bf16[16]{0}, s32[])")
    assert elems == 32 + 16 + 1
    assert nbytes == 128 + 32 + 4


def test_split_type_op_handles_index_comments():
    t, op = _split_type_op(
        "(s32[], f32[8,64]{1,0}, /*index=5*/f32[4]{0}) while(%tuple.54), "
        "condition=%c, body=%b")
    assert op == "while"
    assert t.endswith(")")


def test_split_type_op_plain():
    t, op = _split_type_op("f32[512,128]{1,0} dot(%a, %b), "
                           "lhs_contracting_dims={1}")
    assert (t, op) == ("f32[512,128]{1,0}", "dot")


# ---------------------------------------------------------------------------
# step builders: every family constructs a coherent bundle on the
# production mesh shape (no compile — specs/shardings only)
# ---------------------------------------------------------------------------

BUILDER_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import jax
from repro.configs import ARCHS
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step

mesh = make_production_mesh(multi_pod=False)
cells = [("gemma2-9b", "train_4k", "opt"),
         ("moonshot-v1-16b-a3b", "prefill_32k", "baseline"),
         ("deepseek-coder-33b", "decode_32k", "baseline"),
         ("nequip", "molecule", "baseline"),
         ("meshgraphnet", "ogb_products", "halo"),
         ("two-tower-retrieval", "retrieval_cand", "baseline")]
for arch, shape, scheme in cells:
    b = build_step(arch, shape, mesh, scheme)
    flat_specs = jax.tree.leaves(b.specs)
    flat_sh = jax.tree.leaves(b.in_shardings,
                              is_leaf=lambda x: hasattr(x, "spec"))
    assert len(flat_specs) > 0 and len(flat_sh) > 0
    assert b.meta.get("model_flops", 0) > 0, (arch, shape)
    # every sharding must be addressable on this mesh
    for sh in flat_sh:
        assert sh.mesh.devices.size == 256
print("BUNDLES_OK")
"""

RECSYS_SMALL_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, dataclasses
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_arch
from repro.models import recsys as RS

from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((4, 2), ("data", "model"))
cfg = dataclasses.replace(get_arch("two-tower-retrieval").smoke_config,
                          user_vocab=4096, item_vocab=4096)
params = RS.init_params(jax.random.PRNGKey(0), cfg)
psh = jax.tree.map(lambda _: NamedSharding(mesh, P()), params)
psh["user_table"] = NamedSharding(mesh, P("model", None))
psh["item_table"] = NamedSharding(mesh, P("model", None))
batch = {k: jnp.asarray(v) for k, v in RS.make_batch(cfg, 32).items()}
bsh = {"user_ids": NamedSharding(mesh, P("data", None, None)),
       "item_ids": NamedSharding(mesh, P("data", None, None)),
       "log_q": NamedSharding(mesh, P("data"))}
with mesh:
    loss, _ = jax.jit(lambda p, b: RS.loss_fn(p, b, cfg),
                      in_shardings=(psh, bsh))(params, batch)
import numpy as np
assert np.isfinite(float(loss))
print("RECSYS_SHARDED_OK", float(loss))
"""


def _run(code):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_bundles_construct_on_production_mesh():
    assert "BUNDLES_OK" in _run(BUILDER_CODE)


def test_recsys_sharded_loss_runs():
    """Row-sharded embedding tables produce a finite loss end-to-end on a
    real multi-device mesh (the production recsys layout, scaled down)."""
    assert "RECSYS_SHARDED_OK" in _run(RECSYS_SMALL_CODE)
