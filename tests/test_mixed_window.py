"""The mixed-event windowed engine must be bit-identical to the faithful
one-pass engine on delete-heavy *interleaved* streams — the paper's
real-time churn regime, where the legacy driver degenerated to
window-size-1 chunks."""
import numpy as np
import pytest

from repro.core import EngineConfig, run_stream, run_stream_windowed
from repro.graph.generators import make_graph
from repro.graph import stream as gstream


def _identical(a, b):
    np.testing.assert_array_equal(np.asarray(a.assignment),
                                  np.asarray(b.assignment))
    np.testing.assert_array_equal(np.asarray(a.present), np.asarray(b.present))
    np.testing.assert_array_equal(np.asarray(a.adj), np.asarray(b.adj))
    np.testing.assert_array_equal(np.asarray(a.edge_load),
                                  np.asarray(b.edge_load))
    np.testing.assert_array_equal(np.asarray(a.vertex_count),
                                  np.asarray(b.vertex_count))
    np.testing.assert_array_equal(np.asarray(a.active), np.asarray(b.active))
    assert int(a.cut_edges) == int(b.cut_edges)
    assert int(a.total_edges) == int(b.total_edges)
    assert int(a.num_partitions) == int(b.num_partitions)
    assert int(a.scale_events) == int(b.scale_events)
    assert int(a.denied_scaleout) == int(b.denied_scaleout)


def _del_fraction(s):
    dels = (s.etype == gstream.EVENT_DEL_VERTEX) | \
        (s.etype == gstream.EVENT_DEL_EDGE)
    return float(np.mean(dels))


def _churn_stream(seed=1):
    g = make_graph("social", 120, 360, seed=0)
    s = gstream.interleaved_churn(g, warmup_frac=0.15, del_every=2,
                                  edge_del_every=4, readd_every=6, seed=seed)
    assert _del_fraction(s) >= 0.30, "stream not delete-heavy enough"
    return s


@pytest.mark.parametrize("window", [8, 32, 256])
def test_mixed_window_equals_faithful_churn_autoscale(window):
    """≥30% deletion events interleaved with adds, autoscale on."""
    s = _churn_stream()
    cfg = EngineConfig(k_max=8, k_init=1, max_cap=100, autoscale=True)
    a, _ = run_stream(s, policy="sdp", cfg=cfg, seed=2)
    b = run_stream_windowed(s, policy="sdp", cfg=cfg, seed=2, window=window)
    _identical(a, b)


@pytest.mark.parametrize("policy", ["sdp", "greedy", "ldg", "fennel",
                                    "hash", "random"])
def test_mixed_window_all_policies(policy):
    s = _churn_stream(seed=7)
    cfg = EngineConfig(k_max=6, k_init=1 if policy == "sdp" else 4,
                       max_cap=110, autoscale=policy == "sdp")
    a, _ = run_stream(s, policy=policy, cfg=cfg, seed=3)
    b = run_stream_windowed(s, policy=policy, cfg=cfg, seed=3, window=32)
    _identical(a, b)


def test_mixed_window_alg1_guard():
    s = _churn_stream(seed=9)
    cfg = EngineConfig(k_max=6, k_init=1, max_cap=90, autoscale=True,
                       balance_guard="alg1")
    a, _ = run_stream(s, policy="sdp", cfg=cfg, seed=5)
    b = run_stream_windowed(s, policy="sdp", cfg=cfg, seed=5, window=64)
    _identical(a, b)


def test_mixed_window_with_pallas_kernel():
    """Kernel-scored mixed path == jnp-scored path == faithful engine."""
    s = _churn_stream(seed=11)
    cfg = EngineConfig(k_max=4, k_init=1, max_cap=130)
    a, _ = run_stream(s, policy="sdp", cfg=cfg, seed=6)
    b = run_stream_windowed(s, policy="sdp", cfg=cfg, seed=6, window=64,
                            use_kernel=True)
    _identical(a, b)


def test_mixed_window_readd_within_window():
    """add → delete → re-add of the same vertex inside ONE window must
    chain through the window-local label journal."""
    g = make_graph("mesh", 40, 100, seed=1)
    base = gstream.build_stream(g, seed=2)
    # craft: add everything, then [del v, add u(nbr v), re-add v] tight
    v = int(base.vertex[0])
    row_v = base.nbrs[0]
    extra_et = np.asarray(
        [gstream.EVENT_DEL_VERTEX, gstream.EVENT_ADD], np.int32)
    extra_vx = np.asarray([v, v], np.int32)
    extra_nb = np.stack([-np.ones_like(row_v), row_v])
    s = gstream.VertexStream(
        etype=np.concatenate([base.etype, extra_et]),
        vertex=np.concatenate([base.vertex, extra_vx]),
        nbrs=np.concatenate([base.nbrs, extra_nb]),
        n=base.n)
    cfg = EngineConfig(k_max=4, k_init=1, max_cap=60, autoscale=True)
    a, _ = run_stream(s, policy="sdp", cfg=cfg, seed=3)
    b = run_stream_windowed(s, policy="sdp", cfg=cfg, seed=3, window=256)
    _identical(a, b)
