"""Dedicated coverage for the runtime fault-tolerance building blocks
(repro.runtime.fault / repro.runtime.elastic): retry budgets, straggler
hooks, and the unconditional pre-rescale save — plus the forced-4-device
rescale round trip (bit-identity under scale-in -> scale-out)."""
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.runtime.fault import FaultTolerantLoop

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_subprocess(code: str) -> str:
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


# ---------------------------------------------------------------------------
# FaultTolerantLoop
# ---------------------------------------------------------------------------

def test_poisoned_batch_restores_and_skips(tmp_path):
    """One poisoned batch: restore the checkpointed state, skip it, and
    the surviving batches all land (the good-path sum is exact)."""
    m = CheckpointManager(str(tmp_path), interval=1)
    loop = FaultTolerantLoop(m, max_retries=2)
    batches = [1.0, 2.0, "poison", 4.0]

    def step_fn(state, batch):
        if batch == "poison":
            raise RuntimeError("node failure")
        return {"w": state["w"] + batch}, {}

    state = {"w": jnp.zeros(2)}
    final, steps = loop.run(state, iter(batches), step_fn, like=state)
    np.testing.assert_array_equal(np.asarray(final["w"]), np.full(2, 7.0))
    assert [e["event"] for e in loop.events] == ["failure"]
    assert loop.retries == 0              # reset after the recovery


def test_retry_budget_aborts_loudly(tmp_path):
    """A persistently failing step must abort after max_retries, not
    spin forever on restore-and-retry."""
    m = CheckpointManager(str(tmp_path), interval=1)
    loop = FaultTolerantLoop(m, max_retries=3)

    def step_fn(state, batch):
        raise RuntimeError("hard failure")

    state = {"w": jnp.zeros(2)}
    with pytest.raises(RuntimeError, match="hard failure"):
        loop.run(state, iter([1.0] * 10), step_fn, like=state)
    failures = [e for e in loop.events if e["event"] == "failure"]
    assert len(failures) == loop.max_retries + 1   # budget, then abort


def test_straggler_hook_fires_after_patience(tmp_path):
    """Consecutive slow steps past the patience fire on_straggler once
    and reset the streak (timing-free: the straggler oracle is driven
    directly)."""
    class Oracle(CheckpointManager):
        slow_steps: set = set()

        def is_straggler(self, seconds):
            return self._now_step in self.slow_steps

    m = Oracle(str(tmp_path), interval=10**9)
    m.slow_steps = {2, 3, 5}            # 2 consecutive, then an isolated one
    fired = []
    loop = FaultTolerantLoop(m, straggler_patience=2,
                             on_straggler=fired.append)

    def step_fn(state, batch):
        m._now_step = batch
        return state, {}

    loop.run({"w": jnp.zeros(1)}, iter(range(8)), step_fn)
    assert fired == [3]                 # streak of 2 at steps 2,3; 5 alone
    assert [e["step"] for e in loop.events
            if e["event"] == "straggler"] == [2, 3, 5]


# ---------------------------------------------------------------------------
# ElasticRunner
# ---------------------------------------------------------------------------

def test_rescale_saves_unconditionally(tmp_path):
    """The pre-rescale migration save must not be interval-gated: with
    interval far beyond the step, the checkpoint still lands before the
    mesh swap (a failed rescale can always fall back to disk)."""
    import jax
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.runtime.elastic import ElasticRunner

    def mesh_factory(devices):
        return Mesh(np.asarray(devices).reshape(len(devices)), ("data",))

    def shardings_fn(mesh, tree):
        return jax.tree.map(lambda x: NamedSharding(mesh, P()), tree,
                            is_leaf=lambda x: hasattr(x, "shape"))

    m = CheckpointManager(str(tmp_path), interval=10**9)
    runner = ElasticRunner(mesh_factory, shardings_fn, m)
    st = runner.place(jax.devices()[:1], {"w": jnp.arange(4.0)},
                      {"mu": jnp.zeros(4)}, step=7)
    assert m.latest() is None
    st2 = runner.rescale(st, jax.devices()[:1])
    assert m.latest() == 7              # save_now, not maybe_save
    np.testing.assert_array_equal(np.asarray(st2.params["w"]),
                                  np.arange(4.0))


RESCALE_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np, tempfile
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint.manager import CheckpointManager
from repro.runtime.elastic import ElasticRunner

def mesh_factory(devices):
    n = len(devices)
    return jax.sharding.Mesh(np.asarray(devices).reshape(n, 1),
                             ("data", "model"))

def shardings_fn(mesh, tree):
    return jax.tree.map(
        lambda x: NamedSharding(mesh, P("data") if np.ndim(x) >= 1
                                and np.shape(x)[0] % mesh.shape["data"] == 0
                                else P()), tree,
        is_leaf=lambda x: hasattr(x, "shape"))

# bit-patterns that expose any lossy migration (denormals, -0.0, big ints)
w = np.asarray([1e-39, -0.0, 3.14159, 2.0**31, -7.5, 1e38, 0.0, -1e-45] * 4,
               np.float32)
params = {"w": jnp.asarray(w)}
opt = {"mu": jnp.asarray(w[::-1].copy())}
with tempfile.TemporaryDirectory() as d:
    runner = ElasticRunner(mesh_factory, shardings_fn,
                           CheckpointManager(d, interval=1))
    st = runner.place(jax.devices()[:4], params, opt, step=1)
    st = runner.rescale(st, jax.devices()[:2])   # scale-in 4 -> 2
    st = runner.rescale(st, jax.devices()[:4])   # scale-out 2 -> 4
    assert np.asarray(st.params["w"]).tobytes() == w.tobytes()
    assert np.asarray(st.opt_state["mu"]).tobytes() == \\
        w[::-1].copy().tobytes()
    assert st.mesh.shape["data"] == 4
print("RESCALE_ROUNDTRIP_OK")
"""


def test_rescale_round_trip_bitwise_forced_4dev():
    out = _run_subprocess(RESCALE_CODE)
    assert "RESCALE_ROUNDTRIP_OK" in out
