"""Windowed engine (beyond-paper TPU optimisation) must be bit-identical
to the faithful one-pass engine, with and without the Pallas kernel."""
import numpy as np
import pytest

from repro.core import EngineConfig, run_stream, run_stream_windowed
from repro.graph.generators import make_graph
from repro.graph import stream as gstream


def _identical(a, b):
    np.testing.assert_array_equal(np.asarray(a.assignment),
                                  np.asarray(b.assignment))
    np.testing.assert_array_equal(np.asarray(a.edge_load),
                                  np.asarray(b.edge_load))
    np.testing.assert_array_equal(np.asarray(a.active), np.asarray(b.active))
    assert int(a.cut_edges) == int(b.cut_edges)
    assert int(a.total_edges) == int(b.total_edges)
    assert int(a.num_partitions) == int(b.num_partitions)
    assert int(a.scale_events) == int(b.scale_events)


@pytest.mark.parametrize("window", [1, 7, 64, 256])
def test_windowed_equals_faithful_static(window):
    g = make_graph("mesh", 130, 380, seed=0)
    s = gstream.build_stream(g, seed=1)
    cfg = EngineConfig(k_max=8, k_init=1, max_cap=140)
    a, _ = run_stream(s, policy="sdp", cfg=cfg, seed=2)
    b = run_stream_windowed(s, policy="sdp", cfg=cfg, seed=2, window=window)
    _identical(a, b)


@pytest.mark.parametrize("policy", ["sdp", "greedy", "ldg", "fennel"])
def test_windowed_equals_faithful_dynamic(policy):
    g = make_graph("social", 100, 300, seed=2)
    s = gstream.dynamic_schedule(g, n_intervals=3, seed=3,
                                 del_edges_per_interval=4)
    cfg = EngineConfig(k_max=6, k_init=1 if policy == "sdp" else 4,
                       max_cap=120, autoscale=policy == "sdp")
    a, _ = run_stream(s, policy=policy, cfg=cfg, seed=4)
    b = run_stream_windowed(s, policy=policy, cfg=cfg, seed=4, window=32)
    _identical(a, b)


def test_windowed_with_pallas_kernel():
    """Kernel-scored path == jnp-scored path == faithful engine."""
    g = make_graph("mesh", 90, 250, seed=5)
    s = gstream.build_stream(g, seed=6)
    cfg = EngineConfig(k_max=4, k_init=1, max_cap=150)
    a, _ = run_stream(s, policy="sdp", cfg=cfg, seed=7)
    b = run_stream_windowed(s, policy="sdp", cfg=cfg, seed=7, window=64,
                            use_kernel=True)
    _identical(a, b)
