"""``PartitionService`` must serve without changing the answer: after any
sequence of async submits the state is bit-identical to a synchronous
``feed`` of the same events in submission order — under coalescing,
backpressure (block and drop), mid-stream elastic auto-grow, and
queries racing ingest. Plus the host-side seams the service is built
from: ``prepare``/``feed_prepared``/``sync`` and ``poisson_arrivals``."""
import time

import numpy as np
import pytest

from repro.api import Partitioner, PartitionService, PreparedChunk
from repro.core import run_stream
from repro.graph import stream as gstream

from tests.test_api_partitioner import _churn_fixture, _identical


def _chunks(s, size):
    return [(s.etype[t:t + size], s.vertex[t:t + size], s.nbrs[t:t + size])
            for t in range(0, s.num_events, size)]


def _session(s, cfg, **kw):
    kw.setdefault("window", 32)
    return Partitioner.from_stream(s, cfg, seed=0, **kw)


# -- bit-identity under serving ---------------------------------------------

def test_service_state_bit_identical_to_sync_feed():
    """N async submits (coalesced however the ingest thread pleases)
    land exactly on the whole-stream run_stream state."""
    s, cfg = _churn_fixture()
    ref, _ = run_stream(s, policy="sdp", cfg=cfg, seed=0)
    with PartitionService(_session(s, cfg), max_pending_chunks=4) as svc:
        for chunk in _chunks(s, 17):
            svc.submit(chunk)
        svc.flush()
        _identical(ref, svc.partitioner.state)
        assert svc.partitioner.cursor == s.num_events


def test_where_consistency_after_async_feeds():
    """Mid-stream: flush() then where_many == a synchronous session fed
    the same prefix (read-your-submits after the barrier)."""
    s, cfg = _churn_fixture()
    chunks = _chunks(s, 13)
    k = len(chunks) // 2
    sync = _session(s, cfg)
    for c in chunks[:k]:
        sync.feed(c)
    labels_sync = np.asarray(sync.state.assignment)

    with PartitionService(_session(s, cfg)) as svc:
        for c in chunks[:k]:
            svc.submit(c)
        svc.flush()
        got = svc.where_many(np.arange(s.n))
        present = np.asarray(sync.state.present)
        np.testing.assert_array_equal(got, labels_sync)
        assert svc.where(int(np.flatnonzero(present)[0])) >= 0
        # out-of-range ids answer -1, not raise
        assert svc.where(-3) == -1 and svc.where(s.n + 99) == -1
        # the remainder still feeds afterwards — and lands on the ref
        for c in chunks[k:]:
            svc.submit(c)
        svc.flush()
        ref, _ = run_stream(s, policy="sdp", cfg=cfg, seed=0)
        _identical(ref, svc.partitioner.state)


def test_service_survives_midstream_auto_grow():
    """A session born tiny (n=10, max_deg=2) auto-grows under the
    service's coalesced feeds and still matches the same session grown
    synchronously — elastic geometry is chop- and serve-invariant."""
    s, cfg = _churn_fixture()
    sync = Partitioner(cfg, n=10, max_deg=2, seed=0, window=32)
    sync.feed(s)
    assert sync.regeometries >= 1

    part = Partitioner(cfg, n=10, max_deg=2, seed=0, window=32)
    with PartitionService(part, max_pending_chunks=4) as svc:
        for chunk in _chunks(s, 29):
            svc.submit(chunk)
        svc.flush()
        assert part.regeometries >= 1
        assert (part.n, part.max_deg) == (sync.n, sync.max_deg)
        _identical(sync.state, part.state)


# -- backpressure -----------------------------------------------------------

def test_drop_policy_sheds_and_counts():
    """queue-full + policy='drop': submit returns False, the chunk is
    counted dropped, and the final state is exactly the admitted
    prefix."""
    s, cfg = _churn_fixture()
    chunks = _chunks(s, 11)
    svc = PartitionService(_session(s, cfg), max_pending_chunks=2,
                          policy="drop", autostart=False)
    assert svc.submit(chunks[0]) and svc.submit(chunks[1])
    assert svc.submit(chunks[2]) is False        # queue full: shed
    m = svc.metrics()
    assert m["chunks_dropped"] == 1
    assert m["chunks_submitted"] == 3
    svc.start()
    svc.flush()
    svc.close()
    sync = _session(s, cfg).feed(chunks[0]).feed(chunks[1])
    _identical(sync.state, svc.partitioner.state)


def test_block_policy_times_out_then_drains():
    """queue-full + policy='block': submit waits; with a timeout it
    raises TimeoutError and the chunk is NOT admitted; once started the
    queue drains and further submits go through."""
    s, cfg = _churn_fixture()
    chunks = _chunks(s, 11)
    svc = PartitionService(_session(s, cfg), max_pending_chunks=2,
                          policy="block", autostart=False)
    svc.submit(chunks[0])
    svc.submit(chunks[1])
    t0 = time.perf_counter()
    with pytest.raises(TimeoutError, match="queue slot"):
        svc.submit(chunks[2], timeout=0.05)
    assert time.perf_counter() - t0 >= 0.05
    assert svc.metrics()["submit_blocked_s"] > 0
    svc.start()
    for c in chunks[2:]:
        svc.submit(c)                            # blocks at most briefly now
    svc.flush()
    svc.close()
    ref, _ = run_stream(s, policy="sdp", cfg=cfg, seed=0)
    _identical(ref, svc.partitioner.state)


def test_block_policy_unblocks_when_ingest_drains():
    """A submit blocked on a full queue completes (no timeout) as soon
    as the started ingest thread frees a slot."""
    s, cfg = _churn_fixture()
    chunks = _chunks(s, 11)
    svc = PartitionService(_session(s, cfg), max_pending_chunks=1,
                          policy="block", autostart=False)
    svc.submit(chunks[0])
    import threading
    done = threading.Event()

    def late_start():
        time.sleep(0.05)
        svc.start()

    threading.Thread(target=late_start, daemon=True).start()
    assert svc.submit(chunks[1]) is True         # blocks until start() drains
    done.set()
    svc.flush()
    svc.close()


# -- queries ----------------------------------------------------------------

def test_route_semantics_and_input_forms():
    s, cfg = _churn_fixture()
    with PartitionService(_session(s, cfg)) as svc:
        for c in _chunks(s, 40):
            svc.submit(c)
        svc.flush()
        ids = np.arange(s.n, dtype=np.int32)
        labels = svc.where_many(ids)
        rng = np.random.default_rng(0)
        edges = rng.integers(0, s.n, size=(32, 2)).astype(np.int32)
        r = svc.route(edges)
        np.testing.assert_array_equal(r.src_part, labels[edges[:, 0]])
        np.testing.assert_array_equal(r.dst_part, labels[edges[:, 1]])
        np.testing.assert_array_equal(
            r.cut, (r.src_part != r.dst_part) & (r.src_part >= 0)
            & (r.dst_part >= 0))
        # one (u, v) edge and a (src, dst) pair of arrays
        one = svc.route((int(edges[0, 0]), int(edges[0, 1])))
        assert one.src_part.shape == (1,)
        assert one.src_part[0] == r.src_part[0]
        pair = svc.route((edges[:, 0], edges[:, 1]))
        np.testing.assert_array_equal(pair.cut, r.cut)
        with pytest.raises(ValueError, match="route"):
            svc.route(np.zeros((3, 4), np.int32))


def test_metrics_counters_and_lifecycle():
    s, cfg = _churn_fixture()
    chunks = _chunks(s, 13)
    svc = PartitionService(_session(s, cfg), max_pending_chunks=8)
    for c in chunks:
        svc.submit(c)
    svc.flush()
    m = svc.metrics()
    assert m["chunks_ingested"] == len(chunks)
    assert m["events_ingested"] == s.num_events
    assert 1 <= m["batches_dispatched"] <= len(chunks)
    assert m["queue_depth"] == 0
    assert m["chunks_dropped"] == 0
    assert 0.0 <= m["device_busy_fraction"] <= 1.0
    assert m["feed_p50_ms"] is not None and m["feed_p99_ms"] is not None
    assert m["feed_p50_ms"] <= m["feed_p99_ms"] + 1e-9
    assert m["events_per_s"] > 0
    # the session's metrics ride along (cursor uniformity: satellite fix)
    assert m["cursor"] == s.num_events
    assert m["events_ingested"] == m["cursor"]
    assert "edge_cut" in m and "regeometries" in m
    assert svc.latencies().shape == (len(chunks),)
    svc.close()
    svc.close()                                  # idempotent
    with pytest.raises(RuntimeError, match="closed"):
        svc.submit(chunks[0])
    # queries outlive close()
    assert svc.where(0) in (-1, *range(cfg.k_max))
    assert "closed=True" in repr(svc)


def test_constructor_validation_and_flush_guard():
    s, cfg = _churn_fixture()
    part = _session(s, cfg)
    with pytest.raises(ValueError, match="policy"):
        PartitionService(part, policy="nope")
    with pytest.raises(ValueError, match="max_pending_chunks"):
        PartitionService(part, max_pending_chunks=0)
    with pytest.raises(ValueError, match="max_batch_events"):
        PartitionService(part, max_batch_events=0)
    svc = PartitionService(part, autostart=False)
    with pytest.raises(RuntimeError, match="never-started"):
        svc.flush()
    svc.start()
    svc.close()


def test_ingest_error_surfaces_not_hangs():
    """A poison chunk kills the ingest loop; flush() must raise the
    error (wrapped), not wait forever."""
    s, cfg = _churn_fixture()
    svc = PartitionService(_session(s, cfg), max_pending_chunks=4)
    svc.submit(42)                               # prepare() will TypeError
    with pytest.raises(RuntimeError, match="ingest loop died"):
        svc.flush(timeout=30)
    svc.close()


def test_max_batch_events_caps_coalescing():
    s, cfg = _churn_fixture()
    chunks = _chunks(s, 10)
    svc = PartitionService(_session(s, cfg),
                          max_pending_chunks=len(chunks) + 1,
                          max_batch_events=10, autostart=False)
    for c in chunks:
        svc.submit(c)
    svc.start()
    svc.flush()
    m = svc.metrics()
    svc.close()
    assert m["batches_dispatched"] == len(chunks)   # no merge allowed
    ref, _ = run_stream(s, policy="sdp", cfg=cfg, seed=0)
    _identical(ref, svc.partitioner.state)


# -- host-side seams the service is built from ------------------------------

def test_prepare_feed_prepared_equals_feed():
    s, cfg = _churn_fixture()
    ref, _ = run_stream(s, policy="sdp", cfg=cfg, seed=0)
    part = _session(s, cfg)
    for c in _chunks(s, 23):
        p = part.prepare(c)
        assert isinstance(p, PreparedChunk)
        assert p.etype.dtype == np.int32 and p.nbrs.ndim == 2
        assert p.num_events == len(c[0])
        part.feed_prepared(p)
    assert part.sync() is part
    _identical(ref, part.state)
    with pytest.raises(TypeError, match="VertexStream"):
        part.prepare(object())
    with pytest.raises(ValueError, match="shapes disagree"):
        part.prepare((s.etype[:4], s.vertex[:3], s.nbrs[:4]))


def test_poisson_arrivals_generator():
    s, _ = _churn_fixture()
    bounds, due = gstream.poisson_arrivals(s, rate=500.0, mean_batch=8.0,
                                           seed=3)
    sizes = np.diff(bounds)
    assert bounds[0] == 0 and bounds[-1] == s.num_events
    assert (sizes >= 1).all()
    assert due.shape == (len(sizes),)
    assert (np.diff(due) >= 0).all() and (due > 0).all()
    # long-run rate roughly lambda (loose: it's a Poisson process)
    assert s.num_events / due[-1] == pytest.approx(500.0, rel=0.5)
    # deterministic per seed; different seed, different schedule
    b2, d2 = gstream.poisson_arrivals(s, rate=500.0, mean_batch=8.0, seed=3)
    np.testing.assert_array_equal(bounds, b2)
    np.testing.assert_array_equal(due, d2)
    with pytest.raises(ValueError, match="rate"):
        gstream.poisson_arrivals(s, rate=0.0)
    with pytest.raises(ValueError, match="mean_batch"):
        gstream.poisson_arrivals(s, rate=1.0, mean_batch=-1.0)
