"""The sweep runtime must reproduce per-stream `run_stream` results
bit-for-bit on every lane (policies × seeds × configs × streams in one
program) — whole-stream or chunked, per-event scan or windowed lanes.
Entry point: the fluent ``repro.api.Sweep`` builder (the deprecated
``run_sweep`` shim is covered in tests/test_api_sweep.py)."""
import numpy as np
import pytest

from repro.api import Sweep, SweepRun
from repro.core import EngineConfig, run_stream
from repro.graph.generators import make_graph
from repro.graph import stream as gstream


def _lane_matches(result, stream):
    state, trace = run_stream(stream, policy=result.policy, cfg=result.cfg,
                              seed=result.seed)
    np.testing.assert_array_equal(np.asarray(state.assignment),
                                  np.asarray(result.state.assignment))
    np.testing.assert_array_equal(np.asarray(state.edge_load),
                                  np.asarray(result.state.edge_load))
    np.testing.assert_array_equal(np.asarray(state.active),
                                  np.asarray(result.state.active))
    assert int(state.cut_edges) == int(result.state.cut_edges)
    assert int(state.total_edges) == int(result.state.total_edges)
    assert int(state.num_partitions) == int(result.state.num_partitions)
    assert int(state.scale_events) == int(result.state.scale_events)
    if result.trace is None:
        return
    assert result.trace.cut_edges.shape[0] == stream.num_events
    for f in trace._fields:
        np.testing.assert_array_equal(np.asarray(getattr(trace, f)),
                                      np.asarray(getattr(result.trace, f)))


def test_sweep_policies_and_seeds_static_stream():
    g = make_graph("mesh", 110, 320, seed=0)
    s = gstream.build_stream(g, seed=1)
    runs = [
        SweepRun(policy, EngineConfig(
            k_max=8, k_init=1 if policy == "sdp" else 4,
            max_cap=130, autoscale=policy == "sdp"), seed)
        for policy in ("sdp", "ldg", "fennel", "hash", "random", "greedy")
        for seed in (0, 1)
    ]
    for r in Sweep(s).lanes(runs).run():
        _lane_matches(r, s)


def test_sweep_dynamic_stream_with_deletions():
    g = make_graph("social", 90, 260, seed=2)
    s = gstream.dynamic_schedule(g, n_intervals=3, seed=3,
                                 del_edges_per_interval=5)
    results = (
        Sweep(s)
        .lane("sdp", EngineConfig(k_max=8, k_init=1, max_cap=100), 0)
        .lane("sdp", EngineConfig(k_max=8, k_init=2, max_cap=10**9), 4)
        .lane("greedy", EngineConfig(k_max=8, k_init=4, autoscale=False), 0)
        .lane("ldg", EngineConfig(k_max=8, k_init=3, autoscale=False), 1)
        .run()
    )
    assert len(results) == 4
    for r in results:
        _lane_matches(r, s)


def test_sweep_config_lanes_vary_k():
    """fig8-style sweep: same policy, k_init varies per lane."""
    g = make_graph("mesh", 100, 300, seed=4)
    s = gstream.build_stream(g, seed=5)
    runs = [
        SweepRun("sdp",
                 EngineConfig(k_max=16, k_init=k, autoscale=False), 0)
        for k in (2, 4, 8, 16)
    ]
    for r in Sweep(s).lanes(runs).run():
        _lane_matches(r, s)


def _per_lane_fixture():
    """Lanes with their OWN streams: different orders, lengths, churn
    mixes — including an autoscale lane over a delete-heavy stream."""
    g = make_graph("social", 90, 260, seed=2)
    streams = [
        gstream.build_stream(g, seed=1),
        gstream.dynamic_schedule(g, n_intervals=3, seed=3,
                                 del_edges_per_interval=5),
        gstream.interleaved_churn(g, warmup_frac=0.2, del_every=3,
                                  edge_del_every=5, seed=4),
    ]
    runs = [
        SweepRun("sdp", EngineConfig(k_max=8, k_init=1, max_cap=100), 0),
        SweepRun("ldg", EngineConfig(k_max=8, k_init=3, autoscale=False), 1),
        SweepRun("sdp", EngineConfig(k_max=8, k_init=2, max_cap=120), 2),
    ]
    assert len({s.num_events for s in streams}) > 1, "want unequal lengths"
    return streams, runs


def test_sweep_per_lane_streams():
    """Each lane rides its own stream; every lane still bit-matches
    run_stream on that stream (traces sliced to the lane's true length)."""
    streams, runs = _per_lane_fixture()
    for r, s in zip(Sweep(streams).lanes(runs).run(), streams):
        _lane_matches(r, s)


def test_sweep_chunked_trace_matches_run_stream():
    """Chunked == unchunked == run_stream on every trace field, per lane,
    with a non-divisible chunk size and an autoscale lane (the chunked
    trace concatenation path)."""
    streams, runs = _per_lane_fixture()
    one = Sweep(streams).lanes(runs).run()
    chk = Sweep(streams).lanes(runs).chunked(37).run()
    for a, b, s in zip(one, chk, streams):
        _lane_matches(b, s)
        for f in a.trace._fields:
            np.testing.assert_array_equal(np.asarray(getattr(a.trace, f)),
                                          np.asarray(getattr(b.trace, f)))


def test_sweep_windowed_engine_matches_run_stream():
    """.windowed(): lanes ride the mixed-event window kernel and stay
    bit-identical to the faithful scan (states; traces are None)."""
    streams, runs = _per_lane_fixture()
    for r, s in zip(Sweep(streams).lanes(runs).windowed(64).run(), streams):
        assert r.trace is None
        _lane_matches(r, s)
        # windowed lanes also rebuild the full dense arrays — check them
        state, _ = run_stream(s, policy=r.policy, cfg=r.cfg, seed=r.seed)
        np.testing.assert_array_equal(np.asarray(state.present),
                                      np.asarray(r.state.present))
        np.testing.assert_array_equal(np.asarray(state.adj),
                                      np.asarray(r.state.adj))


def test_sweep_rejects_mismatched_static_shape():
    g = make_graph("mesh", 40, 100, seed=8)
    s = gstream.build_stream(g, seed=9)
    sw = (Sweep(s)
          .lane("sdp", EngineConfig(k_max=4), 0)
          .lane("sdp", EngineConfig(k_max=8), 0))
    with pytest.raises(ValueError, match="k_max"):
        sw.run()


def test_sweep_rejects_bad_inputs():
    g = make_graph("mesh", 40, 100, seed=8)
    s = gstream.build_stream(g, seed=9)
    with pytest.raises(ValueError, match="streams"):
        Sweep([s, s]).lane("sdp", EngineConfig(k_max=4)).run()
    with pytest.raises(ValueError, match="balance_guard"):
        (Sweep(s)
         .lane("sdp", EngineConfig(k_max=4))
         .lane("sdp", EngineConfig(k_max=4, balance_guard="alg1"))
         .run())
    with pytest.raises(ValueError, match="policy"):
        Sweep(s).lanes([("nope", EngineConfig(k_max=4), 0)]).run()
    assert Sweep(s).run() == []  # no lanes -> empty, like the old entry