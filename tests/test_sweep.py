"""The vmapped sweep runtime must reproduce per-stream `run_stream` results
bit-for-bit on every lane (policies × seeds × configs in one program)."""
import numpy as np
import pytest

from repro.core import EngineConfig, run_stream
from repro.graph.generators import make_graph
from repro.graph import stream as gstream
from repro.runtime.sweep import SweepRun, run_sweep


def _lane_matches(result, stream):
    state, trace = run_stream(stream, policy=result.policy, cfg=result.cfg,
                              seed=result.seed)
    np.testing.assert_array_equal(np.asarray(state.assignment),
                                  np.asarray(result.state.assignment))
    np.testing.assert_array_equal(np.asarray(state.edge_load),
                                  np.asarray(result.state.edge_load))
    np.testing.assert_array_equal(np.asarray(state.active),
                                  np.asarray(result.state.active))
    assert int(state.cut_edges) == int(result.state.cut_edges)
    assert int(state.total_edges) == int(result.state.total_edges)
    assert int(state.num_partitions) == int(result.state.num_partitions)
    assert int(state.scale_events) == int(result.state.scale_events)
    np.testing.assert_array_equal(np.asarray(trace.cut_edges),
                                  np.asarray(result.trace.cut_edges))
    np.testing.assert_array_equal(np.asarray(trace.load_std),
                                  np.asarray(result.trace.load_std))


def test_sweep_policies_and_seeds_static_stream():
    g = make_graph("mesh", 110, 320, seed=0)
    s = gstream.build_stream(g, seed=1)
    runs = [
        SweepRun(policy, EngineConfig(
            k_max=8, k_init=1 if policy == "sdp" else 4,
            max_cap=130, autoscale=policy == "sdp"), seed)
        for policy in ("sdp", "ldg", "fennel", "hash", "random", "greedy")
        for seed in (0, 1)
    ]
    for r in run_sweep(s, runs):
        _lane_matches(r, s)


def test_sweep_dynamic_stream_with_deletions():
    g = make_graph("social", 90, 260, seed=2)
    s = gstream.dynamic_schedule(g, n_intervals=3, seed=3,
                                 del_edges_per_interval=5)
    runs = [
        SweepRun("sdp", EngineConfig(k_max=8, k_init=1, max_cap=100), 0),
        SweepRun("sdp", EngineConfig(k_max=8, k_init=2, max_cap=10**9), 4),
        SweepRun("greedy",
                 EngineConfig(k_max=8, k_init=4, autoscale=False), 0),
        SweepRun("ldg", EngineConfig(k_max=8, k_init=3, autoscale=False), 1),
    ]
    for r in run_sweep(s, runs):
        _lane_matches(r, s)


def test_sweep_config_lanes_vary_k():
    """fig8-style sweep: same policy, k_init varies per lane."""
    g = make_graph("mesh", 100, 300, seed=4)
    s = gstream.build_stream(g, seed=5)
    runs = [
        SweepRun("sdp",
                 EngineConfig(k_max=16, k_init=k, autoscale=False), 0)
        for k in (2, 4, 8, 16)
    ]
    for r in run_sweep(s, runs):
        _lane_matches(r, s)


def test_sweep_chunked_equals_single_shot():
    g = make_graph("mesh", 80, 220, seed=6)
    s = gstream.build_stream(g, seed=7)
    runs = [SweepRun("sdp", EngineConfig(k_max=4, k_init=1, max_cap=90), 0),
            SweepRun("hash",
                     EngineConfig(k_max=4, k_init=3, autoscale=False), 0)]
    one = run_sweep(s, runs)
    chk = run_sweep(s, runs, chunk=23)
    for a, b in zip(one, chk):
        np.testing.assert_array_equal(np.asarray(a.state.assignment),
                                      np.asarray(b.state.assignment))
        assert int(a.state.cut_edges) == int(b.state.cut_edges)
        np.testing.assert_array_equal(np.asarray(a.trace.cut_edges),
                                      np.asarray(b.trace.cut_edges))


def test_sweep_rejects_mismatched_static_shape():
    g = make_graph("mesh", 40, 100, seed=8)
    s = gstream.build_stream(g, seed=9)
    runs = [SweepRun("sdp", EngineConfig(k_max=4), 0),
            SweepRun("sdp", EngineConfig(k_max=8), 0)]
    with pytest.raises(ValueError, match="k_max"):
        run_sweep(s, runs)
