"""Online rebalancing (repro.rebalance): recount-exact passes, the
no-op bit-identity contract, the whole-stack wiring (session cadence,
sweep lanes, service idle pass, crash recovery), and the adversarial
stream generators the fig16 quality benchmark runs on."""
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import Partitioner, Sweep
from repro.api.serve import PartitionService
from repro.core import EngineConfig, recompute_counters, run_stream
from repro.graph.generators import make_graph
from repro.graph import stream as gstream
from repro.rebalance import rebalance_state
from repro.runtime.recovery import RecoverableSession

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _counters_exact(state, k_max):
    rec = recompute_counters(np.asarray(state.assignment),
                             np.asarray(state.present),
                             np.asarray(state.adj), k_max)
    assert int(state.total_edges) == rec["total_edges"]
    assert int(state.cut_edges) == rec["cut_edges"]
    np.testing.assert_array_equal(np.asarray(state.edge_load),
                                  rec["edge_load"])
    np.testing.assert_array_equal(np.asarray(state.vertex_count),
                                  rec["vertex_count"])
    np.testing.assert_array_equal(np.asarray(state.cut_matrix),
                                  rec["cut_matrix"])


def _bit_identical(a, b):
    for f in a._fields:
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)),
                                      err_msg=f)


def _churn():
    g = make_graph("social", 90, 260, seed=2)
    s = gstream.interleaved_churn(g, warmup_frac=0.2, del_every=3,
                                  edge_del_every=5, seed=4)
    return s, EngineConfig(k_max=8, k_init=4, autoscale=False)


def _rebalance_within_guard(part, slack=0.25, **kw):
    """Run one rebalance and assert the Eq. 10 guard: any partition the
    pass loaded further ends at or below ``mean_active_load * (1+slack)``
    (migration checks it exactly per commit; LPA admission is capacity-
    probabilistic, so allow a couple of degrees of overshoot)."""
    pre = np.asarray(part.state.edge_load).astype(float)
    act = np.asarray(part.state.active)
    cap = max(pre[act].mean() * (1.0 + slack), 1.0)
    part.rebalance(slack=slack, **kw)
    post = np.asarray(part.state.edge_load).astype(float)
    gained = post > pre
    if gained.any():
        assert post[gained].max() <= cap + 2 * part.max_deg


# ---------------------------------------------------------------------------
# the passes: exact counters, monotone migration, no-op gates
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,passes", [(8, 0), (0, 3), (8, 3)])
def test_rebalance_counters_exact(m, passes):
    s, cfg = _churn()
    st, _ = run_stream(s, policy="sdp", cfg=cfg, seed=0)
    out, stats = rebalance_state(st, jnp.int32(s.num_events),
                                 jnp.float32(0.25),
                                 jnp.float32(cfg.max_cap), True,
                                 m=m, passes=passes)
    _counters_exact(out, cfg.k_max)
    if passes == 0:   # greedy commits only on strictly positive fresh gain
        assert int(stats.cut_after) <= int(stats.cut_before)
    assert int(stats.moved) >= 0


def test_rebalance_disabled_is_identity():
    s, cfg = _churn()
    st, _ = run_stream(s, policy="sdp", cfg=cfg, seed=0)
    out, stats = rebalance_state(st, jnp.int32(0), jnp.float32(0.25),
                                 jnp.float32(cfg.max_cap), False,
                                 m=8, passes=2)
    _bit_identical(st, out)
    assert int(stats.moved) == 0


def test_session_m0_bit_identical_and_events():
    s, cfg = _churn()
    a = Partitioner.from_stream(s, cfg, policy="sdp", seed=0)
    a.feed(s).sync()
    b = Partitioner.from_stream(s, cfg, policy="sdp", seed=0)
    b.feed(s).sync()
    ev = b.rebalance(m=0, passes=0)      # host-side no-op short-circuit
    assert ev["moved"] == 0 and b.metrics()["rebalances"] == 0
    _bit_identical(a.state, b.state)

    ev = b.rebalance(m=8, passes=1)
    assert ev["cursor"] == s.num_events
    assert b.rebalance_events[-1] is ev
    assert b.metrics()["rebalances"] == 1
    _counters_exact(b.state, cfg.k_max)


def test_auto_rebalance_cadence_and_guard():
    s, cfg = _churn()
    part = Partitioner.from_stream(s, cfg, policy="sdp", seed=0,
                                   auto_rebalance=True, rebalance_every=32,
                                   rebalance_m=8, rebalance_passes=1)
    t, T = 0, s.num_events
    while t < T:      # cadence is checked per feed (between windows)
        e = min(t + 20, T)
        part.feed((s.etype[t:e], s.vertex[t:e], s.nbrs[t:e]))
        t = e
    part.sync()
    assert part.metrics()["rebalances"] >= 2
    _counters_exact(part.state, cfg.k_max)
    _rebalance_within_guard(part, m=8, passes=1)
    with pytest.raises(ValueError):
        Partitioner.from_stream(s, cfg, auto_rebalance=True,
                                rebalance_m=0, rebalance_passes=0)


# ---------------------------------------------------------------------------
# property: rebalance anywhere between feed chunks keeps counters exact
# ---------------------------------------------------------------------------

def test_property_rebalance_between_chunks():
    hyp = pytest.importorskip("hypothesis")
    st_mod = pytest.importorskip("hypothesis.strategies")
    g = make_graph("social", 70, 180, seed=1)
    s = gstream.interleaved_churn(g, warmup_frac=0.3, del_every=4,
                                  edge_del_every=6, seed=1)
    cfg = EngineConfig(k_max=8, k_init=1, autoscale=True, max_cap=90)

    @hyp.settings(deadline=None, max_examples=12)
    @hyp.given(cut=st_mod.integers(1, s.num_events - 1),
               m=st_mod.integers(0, 12), passes=st_mod.integers(0, 2))
    def prop(cut, m, passes):
        part = Partitioner.from_stream(s, cfg, policy="sdp", seed=0)
        part.feed((s.etype[:cut], s.vertex[:cut], s.nbrs[:cut])).sync()
        part.rebalance(m=m, passes=passes)
        part.feed((s.etype[cut:], s.vertex[cut:], s.nbrs[cut:])).sync()
        _counters_exact(part.state, cfg.k_max)

    prop()


# ---------------------------------------------------------------------------
# sweep lanes: gated-off lanes are bit-identical, engines agree
# ---------------------------------------------------------------------------

def test_sweep_rebalance_lanes_gate_and_parity():
    s, cfg = _churn()
    plain = (Sweep(s).lane("sdp", cfg, 0).lane("greedy", cfg, 0)
             .windowed(16).run())
    mixed = (Sweep(s).lane("sdp", cfg, 0).lane("sdp", cfg, 0)
             .lane("greedy", cfg, 0).windowed(16)
             .rebalance(8, every=32, passes=1, lanes=[1]).run())
    _bit_identical(plain[0].state, mixed[0].state)   # gated-off lane
    _bit_identical(plain[1].state, mixed[2].state)
    _counters_exact(mixed[1].state, cfg.k_max)

    scan = (Sweep(s).lane("sdp", cfg, 0).lane("sdp", cfg, 0).scan()
            .rebalance(8, every=32, passes=1, lanes=[1]).run())
    _bit_identical(plain[0].state, scan[0].state)
    # same cadence + same pass: engines agree on the rebalanced lane
    _bit_identical(mixed[1].state, scan[1].state)
    assert scan[0].trace is not None


def test_sweep_rebalance_validation():
    s, cfg = _churn()
    with pytest.raises(ValueError, match="multiple of"):
        Sweep(s).lane("sdp", cfg).windowed(16).rebalance(8, every=24).run()
    with pytest.raises(ValueError, match="empty"):
        Sweep(s).lane("sdp", cfg).rebalance(0, passes=0).run()
    with pytest.raises(ValueError, match="out-of-range"):
        Sweep(s).lane("sdp", cfg).rebalance(8, lanes=[1]).run()


# ---------------------------------------------------------------------------
# adversarial generators: geometry, DEL discipline, engine recount
# ---------------------------------------------------------------------------

def _generator_cases():
    g = make_graph("social", 200, 800, seed=3)
    return [
        ("hub", gstream.hub_arrivals(g, del_frac=0.25, seed=5)),
        ("merge", gstream.community_merge(block=100, bridges=20, seed=5)),
        ("flash", gstream.flash_crowd(g, crowd=50, depart_frac=0.5,
                                      seed=5)),
    ]


@pytest.mark.parametrize("name,s", _generator_cases())
def test_generator_stream_discipline(name, s):
    geo = s.required_geometry()
    present = set()
    for t in range(s.num_events):
        et, v = int(s.etype[t]), int(s.vertex[t])
        assert 0 <= v < geo.n
        if et == gstream.EVENT_ADD:
            present.add(v)
        elif et == gstream.EVENT_DEL_VERTEX:
            assert v in present, f"{name}: DEL of absent vertex at {t}"
            present.discard(v)
    assert s.intervals[-1] == s.num_events
    assert all(a <= b for a, b in zip(s.intervals, s.intervals[1:]))


@pytest.mark.parametrize("name,s", _generator_cases())
def test_generator_engine_consistency(name, s):
    cfg = EngineConfig(k_max=8, k_init=4, autoscale=False)
    st, _ = run_stream(s, policy="sdp", cfg=cfg, seed=0)
    _counters_exact(st, cfg.k_max)
    gm = gstream.materialize_graph(s)
    assert gm.num_edges == int(st.total_edges)


def test_fig16_rebalance_improves_cut():
    """The acceptance gate: on at least two adversarial streams the
    rebalanced session ends with a better cut than plain SDP, and every
    pass keeps the destinations it loads within the Eq. 10 guard."""
    improved = 0
    for name, s in _generator_cases():
        cfg = EngineConfig(k_max=8, k_init=4, autoscale=False)
        plain = Partitioner.from_stream(s, cfg, policy="sdp", seed=0)
        plain.feed(s).sync()
        reb = Partitioner.from_stream(s, cfg, policy="sdp", seed=0)
        prev = 0
        for cur in sorted({int(c) for c in s.intervals}):
            if cur == prev:
                continue
            reb.feed((s.etype[prev:cur], s.vertex[prev:cur],
                      s.nbrs[prev:cur])).sync()
            prev = cur
            _rebalance_within_guard(reb, m=24, passes=2)
        _counters_exact(reb.state, cfg.k_max)
        if int(reb.state.cut_edges) < int(plain.state.cut_edges):
            improved += 1
    assert improved >= 2


# ---------------------------------------------------------------------------
# service: idle pass + drain
# ---------------------------------------------------------------------------

def test_service_drain_rebalance():
    s, cfg = _churn()
    part = Partitioner.from_stream(s, cfg, policy="sdp", seed=0)
    svc = PartitionService(part, idle_rebalance_s=0.05)
    svc.submit((s.etype, s.vertex, s.nbrs))
    ev = svc.drain_rebalance()
    assert ev["cursor"] == s.num_events
    m = svc.metrics()
    svc.close()
    assert m["rebalances"] >= 1
    assert "idle_rebalances" in m and m["idle_rebalance_s"] == 0.05
    _counters_exact(part.state, cfg.k_max)


# ---------------------------------------------------------------------------
# recovery: marker replay + a real SIGKILL between pass and next window
# ---------------------------------------------------------------------------

def test_recovery_replays_rebalance_marker(tmp_path):
    s, cfg = _churn()
    half = s.num_events // 2
    ref = Partitioner.from_stream(s, cfg, policy="sdp", seed=0)
    sess = RecoverableSession(ref, str(tmp_path), snapshot_every=10 ** 9)
    sess.checkpoint()     # genesis snapshot; everything after replays
    sess.feed((s.etype[:half], s.vertex[:half], s.nbrs[:half]))
    sess.rebalance(m=8, passes=1)
    sess.feed((s.etype[half:], s.vertex[half:], s.nbrs[half:]))
    sess.sync()
    got = RecoverableSession.recover(str(tmp_path), cfg, policy="sdp")
    got.sync()
    _bit_identical(sess.state, got.state)


def test_checkpoint_after_rebalance_not_double_applied(tmp_path):
    s, cfg = _churn()
    half = s.num_events // 2
    sess = RecoverableSession(
        Partitioner.from_stream(s, cfg, policy="sdp", seed=0),
        str(tmp_path), snapshot_every=10 ** 9)
    sess.feed((s.etype[:half], s.vertex[:half], s.nbrs[:half]))
    sess.rebalance(m=8, passes=1)
    sess.checkpoint()     # snapshot already contains the rebalanced state
    sess.feed((s.etype[half:], s.vertex[half:], s.nbrs[half:]))
    sess.sync()
    got = RecoverableSession.recover(str(tmp_path), cfg, policy="sdp")
    got.sync()
    _bit_identical(sess.state, got.state)


REBALANCE_CHILD = """
import os, signal
from repro.api import Partitioner
from repro.core import EngineConfig
from repro.graph.generators import make_graph
from repro.graph import stream as gstream
from repro.runtime.recovery import RecoverableSession

g = make_graph("social", 90, 260, seed=2)
s = gstream.interleaved_churn(g, warmup_frac=0.2, del_every=3,
                              edge_del_every=5, seed=4)
cfg = EngineConfig(k_max=8, k_init=4, autoscale=False)
part = Partitioner.from_stream(s, cfg, policy="sdp", seed=0)
sess = RecoverableSession(part, {d!r}, snapshot_every=10 ** 9)
sess.checkpoint()
half = s.num_events // 2
sess.feed((s.etype[:half], s.vertex[:half], s.nbrs[:half]))
sess.rebalance(m=8, passes=1)
sess.wait()               # journal + marker durable, next window never fed
print("CHILD_REBALANCED", sess.cursor, flush=True)
os.kill(os.getpid(), signal.SIGKILL)
"""


def test_sigkill_between_rebalance_and_next_window(tmp_path):
    s, cfg = _churn()
    half = s.num_events // 2
    ref = Partitioner.from_stream(s, cfg, policy="sdp", seed=0)
    ref.feed((s.etype[:half], s.vertex[:half], s.nbrs[:half])).sync()
    ref.rebalance(m=8, passes=1)
    ref.feed((s.etype[half:], s.vertex[half:], s.nbrs[half:])).sync()

    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-c",
         textwrap.dedent(REBALANCE_CHILD).format(d=str(tmp_path))],
        capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == -9, (out.returncode, out.stderr[-2000:])
    assert f"CHILD_REBALANCED {half}" in out.stdout

    sess = RecoverableSession.recover(str(tmp_path), cfg, policy="sdp")
    assert sess.cursor == half
    sess.feed((s.etype[half:], s.vertex[half:], s.nbrs[half:]))
    sess.sync()
    _bit_identical(ref.state, sess.state)
