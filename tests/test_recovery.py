"""Crash-safe long-lived sessions (repro.runtime.recovery): journal
ordering/pruning, injected mid-stream crashes, a genuinely SIGKILLed
process, and snapshot retention — recovery must rebuild the exact state
the uninterrupted run would have reached (bit-identical, modulo the
documented compaction relabel)."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.api import Partitioner
from repro.checkpoint.manager import CheckpointManager
from repro.core import EngineConfig, run_stream
from repro.graph.generators import make_graph
from repro.graph import stream as gstream
from repro.runtime.recovery import (
    CrashError, EventJournal, RecoverableSession,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _churn():
    g = make_graph("social", 90, 260, seed=2)
    s = gstream.interleaved_churn(g, warmup_frac=0.2, del_every=3,
                                  edge_del_every=5, seed=4)
    return s, EngineConfig(k_max=8, k_init=1, max_cap=100)


def _identical_modulo_relabel(ref, sess, n):
    ai = sess.to_internal(np.arange(n))
    got = np.full(n, -1, np.int64)
    got[ai >= 0] = np.asarray(sess.state.assignment)[ai[ai >= 0]]
    pres = np.asarray(ref.present)
    np.testing.assert_array_equal(np.asarray(ref.assignment)[pres],
                                  got[:len(pres)][pres])
    for f in ("num_partitions", "total_edges", "cut_edges",
              "denied_scaleout", "scale_events"):
        assert int(getattr(ref, f)) == int(getattr(sess.state, f)), f
    np.testing.assert_array_equal(np.asarray(ref.edge_load),
                                  np.asarray(sess.state.edge_load))


# ---------------------------------------------------------------------------
# the journal
# ---------------------------------------------------------------------------

def test_journal_order_reload_and_prune(tmp_path):
    j = EventJournal(str(tmp_path))
    j.append(0, [0, 0], [1, 2], [[2, -1], [1, -1]])
    j.append_marker(2, "compact")        # same cursor as the next chunk...
    j.append(2, [0], [3], [[1, 2]])      # ...but appended later
    es = j.entries()
    assert [(e.cursor, e.kind) for e in es] == \
        [(0, "events"), (2, "compact"), (2, "events")]
    et, vx, nb = j.load(es[2])
    np.testing.assert_array_equal(vx, [3])
    # a fresh handle (the recovering process) sees the same order and
    # continues the sequence numbers instead of colliding
    j2 = EventJournal(str(tmp_path))
    assert [(e.cursor, e.kind) for e in j2.entries()] == \
        [(0, "events"), (2, "compact"), (2, "events")]
    j2.append_marker(3, "shrink")
    assert j2.entries()[-1].kind == "shrink"
    # prune below cursor 2: the fully-consumed chunk goes, the rest stays
    assert j2.prune_below(2) == 1
    assert [(e.cursor, e.kind) for e in j2.entries()] == \
        [(2, "compact"), (2, "events"), (3, "shrink")]


def test_journal_ignores_torn_writes(tmp_path):
    j = EventJournal(str(tmp_path))
    j.append(0, [0], [1], [[2, -1]])
    # a crash mid-write leaves only a temp file — never a torn entry
    with open(os.path.join(str(tmp_path), "tmpabc123.tmp"), "wb") as f:
        f.write(b"half a npz")
    assert len(j.entries()) == 1


# ---------------------------------------------------------------------------
# injected crash -> recover -> bit-identical
# ---------------------------------------------------------------------------

def test_crash_recover_finish_bit_identical(tmp_path):
    """Crash at the worst-ordered point (chunk journaled, not fed), with
    a relabeling compaction earlier in the stream; recover + finish ==
    the run that never crashed."""
    s, cfg = _churn()
    ref, _ = run_stream(s, policy="sdp", cfg=cfg, seed=0)
    T = s.num_events

    part = Partitioner.from_stream(s, cfg, seed=0, window=32)
    sess = RecoverableSession(part, str(tmp_path), snapshot_every=40,
                              inject_crash_after=85)
    t, crashed = 0, False
    try:
        while t < T:
            e = min(t + 20, T)
            sess.feed((s.etype[t:e], s.vertex[t:e], s.nbrs[t:e]))
            if t == 40:
                sess.compact()
            t = e
    except CrashError:
        crashed = True
    assert crashed, "fixture must reach the injected crash point"
    sess.wait()

    sess2 = RecoverableSession.recover(str(tmp_path), cfg, window=32, seed=0)
    assert sess2.cursor > 85, "replay must cover the journaled-unfed chunk"
    t = sess2.cursor
    while t < T:
        e = min(t + 20, T)
        sess2.feed((s.etype[t:e], s.vertex[t:e], s.nbrs[t:e]))
        t = e
    sess2.sync()
    _identical_modulo_relabel(ref, sess2, s.n)
    assert sess2.metrics()["cursor"] == T


def test_recover_without_any_feed_tail(tmp_path):
    """Crash exactly on a snapshot boundary: the journal tail is empty
    and recovery is just the restore."""
    s, cfg = _churn()
    part = Partitioner.from_stream(s, cfg, seed=0, window=32)
    sess = RecoverableSession(part, str(tmp_path), snapshot_every=10**9)
    sess.feed(s)
    sess.checkpoint(blocking=True)
    sess.journal.prune_below(sess.cursor)
    sess2 = RecoverableSession.recover(str(tmp_path), cfg, window=32, seed=0)
    assert sess2.cursor == s.num_events
    _identical_modulo_relabel(sess.sync().state, sess2, s.n)


# ---------------------------------------------------------------------------
# a real dead process: SIGKILL mid-stream, recover in this one
# ---------------------------------------------------------------------------

CHILD_CODE = """
import os, signal
import numpy as np
from repro.api import Partitioner
from repro.core import EngineConfig
from repro.graph.generators import make_graph
from repro.graph import stream as gstream
from repro.runtime.recovery import RecoverableSession

g = make_graph("social", 90, 260, seed=2)
s = gstream.interleaved_churn(g, warmup_frac=0.2, del_every=3,
                              edge_del_every=5, seed=4)
cfg = EngineConfig(k_max=8, k_init=1, max_cap=100)
part = Partitioner.from_stream(s, cfg, seed=0, window=32)
sess = RecoverableSession(part, {d!r}, snapshot_every=30)
t = 0
while t < 80:
    e = min(t + 20, 80)
    sess.feed((s.etype[t:e], s.vertex[t:e], s.nbrs[t:e]))
    t = e
sess.wait()                       # snapshots on disk, journal written
print("CHILD_FED", sess.cursor, flush=True)
os.kill(os.getpid(), signal.SIGKILL)     # no atexit, no cleanup
"""


def test_sigkilled_process_recovers_bit_identical(tmp_path):
    s, cfg = _churn()
    ref, _ = run_stream(s, policy="sdp", cfg=cfg, seed=0)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-c",
         textwrap.dedent(CHILD_CODE).format(d=str(tmp_path))],
        capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == -9, (out.returncode, out.stderr[-2000:])
    assert "CHILD_FED 80" in out.stdout

    sess = RecoverableSession.recover(str(tmp_path), cfg, window=32, seed=0)
    assert sess.cursor == 80          # snapshot(60) + journal tail replayed
    T = s.num_events
    t = sess.cursor
    while t < T:
        e = min(t + 20, T)
        sess.feed((s.etype[t:e], s.vertex[t:e], s.nbrs[t:e]))
        t = e
    sess.sync()
    _identical_modulo_relabel(ref, sess, s.n)


# ---------------------------------------------------------------------------
# re-mesh on (simulated) device loss
# ---------------------------------------------------------------------------

def test_remesh_continues_bit_identical(tmp_path):
    import jax
    s, cfg = _churn()
    ref, _ = run_stream(s, policy="sdp", cfg=cfg, seed=0)
    mid = s.num_events // 2
    part = Partitioner.from_stream(s, cfg, seed=0, window=32)
    sess = RecoverableSession(part, str(tmp_path))
    sess.feed((s.etype[:mid], s.vertex[:mid], s.nbrs[:mid]))
    devices = jax.devices()
    sess.remesh(devices[-1])          # "device lost": move to a survivor
    sess.feed((s.etype[mid:], s.vertex[mid:], s.nbrs[mid:]))
    sess.sync()
    _identical_modulo_relabel(ref, sess, s.n)


# ---------------------------------------------------------------------------
# retention: keep_last prunes snapshots AND the journal follows
# ---------------------------------------------------------------------------

def test_keep_last_prunes_and_latest_restores(tmp_path):
    m = CheckpointManager(str(tmp_path), interval=1, keep_last=2)
    import jax.numpy as jnp
    for step in (3, 7, 11, 19):
        m.save_now(step, {"w": jnp.full(4, step)}, blocking=True)
    assert m._steps() == [11, 19]
    assert not os.path.exists(os.path.join(str(tmp_path),
                                           "ckpt_00000003.npz"))
    restored, step = m.restore({"w": jnp.zeros(4)})
    assert step == 19
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.full(4, 19))
    with pytest.raises(ValueError, match="keep_last"):
        CheckpointManager(str(tmp_path), keep_last=0)


def test_session_snapshots_bound_disk(tmp_path):
    """A long-lived session's periodic snapshots stay bounded: keep=2
    retains two checkpoints and the journal is pruned to what the oldest
    retained one needs."""
    s, cfg = _churn()
    part = Partitioner.from_stream(s, cfg, seed=0, window=32)
    sess = RecoverableSession(part, str(tmp_path), snapshot_every=20,
                              keep=2)
    sess.feed(s)
    sess.checkpoint(blocking=True)
    mgr = CheckpointManager(str(tmp_path), interval=1)
    steps = mgr._steps()
    assert len(steps) <= 2
    oldest = steps[0]
    for e in sess.journal.entries():
        if e.kind == "events":
            T = int(np.load(e.path)["etype"].shape[0])
            assert e.cursor + T > oldest      # nothing stale survived
    # and the retained tail still recovers
    sess2 = RecoverableSession.recover(str(tmp_path), cfg, window=32,
                                       seed=0)
    assert sess2.cursor == s.num_events
    _identical_modulo_relabel(sess.sync().state, sess2, s.n)


def test_validation():
    s, cfg = _churn()
    part = Partitioner.from_stream(s, cfg, seed=0)
    with pytest.raises(ValueError, match="snapshot_every"):
        RecoverableSession(part, "/tmp/x", snapshot_every=0)
