"""Sweep-builder validation (one place for every lane-compatibility rule)
and the deprecated ``run_sweep`` shim (warns but keeps working, and now
surfaces the windowed+chunk conflict instead of silently ignoring it)."""
import numpy as np
import pytest

from repro.api import Sweep, SweepRun
from repro.core import EngineConfig
from repro.graph.generators import make_graph
from repro.graph import stream as gstream
from repro.runtime.sweep import run_sweep


@pytest.fixture(scope="module")
def fixture():
    g = make_graph("mesh", 60, 150, seed=8)
    s = gstream.build_stream(g, seed=9)
    runs = [SweepRun("sdp", EngineConfig(k_max=4, k_init=1, max_cap=80), 0),
            SweepRun("ldg", EngineConfig(k_max=4, k_init=2,
                                         autoscale=False), 1)]
    return s, runs


def test_windowed_rejects_chunk(fixture):
    """`chunk` used to be silently ignored by the windowed engine — now
    it raises, from the builder and through the shim alike."""
    s, runs = fixture
    with pytest.raises(ValueError, match="chunk"):
        Sweep(s).lanes(runs).windowed(64).chunked(16).run()
    with pytest.raises(ValueError, match="chunk"):
        Sweep(s).lanes(runs).chunked(16).windowed(64).run()
    with pytest.raises(ValueError, match="chunk"), pytest.warns(
            DeprecationWarning):
        run_sweep(s, runs, engine="windowed", chunk=16)


def test_builder_knob_validation(fixture):
    s, runs = fixture
    with pytest.raises(ValueError, match="window"):
        Sweep(s).lanes(runs).windowed(0)
    with pytest.raises(ValueError, match="chunk"):
        Sweep(s).lanes(runs).chunked(0)


def test_run_sweep_shim_warns_and_matches_builder(fixture):
    s, runs = fixture
    want = Sweep(s).lanes(runs).run()
    with pytest.warns(DeprecationWarning, match="Sweep"):
        got = run_sweep(s, runs)
    for a, b in zip(want, got):
        np.testing.assert_array_equal(np.asarray(a.state.assignment),
                                      np.asarray(b.state.assignment))
        assert int(a.state.cut_edges) == int(b.state.cut_edges)

    want = Sweep(s).lanes(runs).windowed(32).run()
    with pytest.warns(DeprecationWarning):
        got = run_sweep(s, runs, engine="windowed", window=32)
    for a, b in zip(want, got):
        assert a.trace is None and b.trace is None
        np.testing.assert_array_equal(np.asarray(a.state.assignment),
                                      np.asarray(b.state.assignment))


def test_run_sweep_shim_heterogeneous_geometry_lanes():
    """After the sweep-runtime geometry changes the deprecated shim must
    still warn-and-work — including on per-lane streams of unequal
    (n, max_deg), which the runtime now pads to the union geometry."""
    streams = [gstream.build_stream(make_graph("mesh", 40, 100, seed=1),
                                    seed=1),
               gstream.build_stream(make_graph("mesh", 70, 180, seed=2),
                                    seed=2)]
    assert streams[0].n != streams[1].n
    runs = [SweepRun("sdp", EngineConfig(k_max=4, k_init=1, max_cap=60), 0),
            SweepRun("greedy", EngineConfig(k_max=4, k_init=2,
                                            autoscale=False), 1)]
    want = Sweep(streams).lanes(runs).run()
    with pytest.warns(DeprecationWarning, match="Sweep"):
        got = run_sweep(streams, runs)
    for a, b in zip(want, got):
        np.testing.assert_array_equal(np.asarray(a.state.assignment),
                                      np.asarray(b.state.assignment))
        assert int(a.state.cut_edges) == int(b.state.cut_edges)


def test_run_sweep_shim_rejects_unknown_engine(fixture):
    s, runs = fixture
    with pytest.raises(ValueError, match="engine"):
        run_sweep(s, runs, engine="nope")


def test_scan_resets_windowed(fixture):
    """.scan() after .windowed() re-arms the chunked path."""
    s, runs = fixture
    results = Sweep(s).lanes(runs).windowed(64).scan().chunked(16).run()
    assert all(r.trace is not None for r in results)
    ref = Sweep(s).lanes(runs).run()
    for a, b in zip(ref, results):
        for f in a.trace._fields:
            np.testing.assert_array_equal(np.asarray(getattr(a.trace, f)),
                                          np.asarray(getattr(b.trace, f)))
