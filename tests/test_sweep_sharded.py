"""Device-sharded sweep: shard_map over the "lanes" mesh must be
bit-identical per lane to run_stream, including when the lane count does
not divide the device count (padding must not leak into results).

The multi-device tests need >1 local device; CI runs this file under
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` in a second tier-1
job. On a single device only the forced-shard (1-device mesh) tests run.
"""
import jax
import numpy as np
import pytest

from repro.api import Sweep, SweepRun
from repro.core import EngineConfig, run_stream
from repro.graph.generators import make_graph
from repro.graph import stream as gstream

multi_device = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs >1 device (XLA_FLAGS=--xla_force_host_platform_device_count=4)",
)


def _assert_lane_matches(result, stream):
    state, trace = run_stream(stream, policy=result.policy, cfg=result.cfg,
                              seed=result.seed)
    np.testing.assert_array_equal(np.asarray(state.assignment),
                                  np.asarray(result.state.assignment))
    np.testing.assert_array_equal(np.asarray(state.edge_load),
                                  np.asarray(result.state.edge_load))
    np.testing.assert_array_equal(np.asarray(state.active),
                                  np.asarray(result.state.active))
    assert int(state.cut_edges) == int(result.state.cut_edges)
    assert int(state.total_edges) == int(result.state.total_edges)
    assert int(state.num_partitions) == int(result.state.num_partitions)
    assert int(state.scale_events) == int(result.state.scale_events)
    if result.trace is not None:
        assert result.trace.cut_edges.shape[0] == stream.num_events
        for f in trace._fields:
            np.testing.assert_array_equal(np.asarray(getattr(trace, f)),
                                          np.asarray(getattr(result.trace, f)))


def _fixture(n_lanes=5):
    """n_lanes lanes (default 5 — never a multiple of 2 or 4 devices),
    per-lane streams, autoscale + baseline mix."""
    g = make_graph("social", 80, 240, seed=0)
    streams = [
        gstream.build_stream(g, seed=1),
        gstream.dynamic_schedule(g, n_intervals=3, seed=2,
                                 del_edges_per_interval=4),
        gstream.interleaved_churn(g, warmup_frac=0.25, del_every=3, seed=3),
        gstream.build_stream(g, seed=4),
        gstream.build_stream(g, seed=5),
    ][:n_lanes]
    runs = [
        SweepRun("sdp", EngineConfig(k_max=8, k_init=1, max_cap=90), 0),
        SweepRun("ldg", EngineConfig(k_max=8, k_init=3, autoscale=False), 1),
        SweepRun("sdp", EngineConfig(k_max=8, k_init=2, max_cap=10**9), 2),
        SweepRun("fennel",
                 EngineConfig(k_max=8, k_init=4, autoscale=False), 0),
        SweepRun("greedy",
                 EngineConfig(k_max=8, k_init=4, autoscale=False), 3),
    ][:n_lanes]
    return streams, runs


def test_forced_shard_padding_no_leakage():
    """shard=True on whatever devices exist: lane axis is padded to a
    multiple of the device count and results are exactly the requested
    lanes — bit-identical to run_stream, no padded-lane leakage."""
    streams, runs = _fixture()
    results = Sweep(streams).lanes(runs).sharded().run()
    assert len(results) == len(runs)
    for r, s in zip(results, streams):
        _assert_lane_matches(r, s)


def test_forced_shard_matches_unsharded():
    """Sharded and vmapped-host paths agree bitwise on states AND traces."""
    streams, runs = _fixture(n_lanes=3)
    a = Sweep(streams).lanes(runs).sharded().run()
    b = Sweep(streams).lanes(runs).sharded(False).run()
    for ra, rb in zip(a, b):
        np.testing.assert_array_equal(np.asarray(ra.state.assignment),
                                      np.asarray(rb.state.assignment))
        for f in ra.trace._fields:
            np.testing.assert_array_equal(np.asarray(getattr(ra.trace, f)),
                                          np.asarray(getattr(rb.trace, f)))


@multi_device
def test_sharded_nondivisible_lanes_multi_device():
    """5 lanes on 2+ devices (auto-shard): exercises real cross-device
    placement with lane padding."""
    assert jax.device_count() >= 2
    streams, runs = _fixture()
    assert len(runs) % jax.device_count() != 0, "want a non-divisible count"
    for r, s in zip(Sweep(streams).lanes(runs).run(), streams):
        _assert_lane_matches(r, s)


@multi_device
def test_sharded_chunked_multi_device():
    streams, runs = _fixture(n_lanes=3)
    for r, s in zip(Sweep(streams).lanes(runs).chunked(29).run(), streams):
        _assert_lane_matches(r, s)


@multi_device
def test_sharded_windowed_multi_device():
    """Windowed-lane sweep under shard_map: states bit-match run_stream."""
    streams, runs = _fixture()
    for r, s in zip(Sweep(streams).lanes(runs).windowed(32).run(), streams):
        assert r.trace is None
        _assert_lane_matches(r, s)
