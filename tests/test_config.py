"""EngineConfig.__post_init__ must reject malformed knobs up front with
actionable messages (field name + offending value + what to change) —
not fail deep inside tracing."""
import pytest

from repro.core import EngineConfig


@pytest.mark.parametrize("kw,needle", [
    (dict(balance_guard="bogus"), "balance_guard='bogus'"),
    (dict(k_max=0), "k_max=0"),
    (dict(k_max=-3), "k_max=-3"),
    (dict(k_init=0), "k_init=0"),
    (dict(k_init=9, k_max=8), "k_init=9"),
    (dict(max_cap=0), "max_cap=0"),
    (dict(max_cap=-5), "max_cap=-5"),
    (dict(tolerance_param=-1.0), "tolerance_param=-1.0"),
    (dict(tolerance_param=101.0), "tolerance_param=101.0"),
    (dict(dest_param=-0.5), "dest_param=-0.5"),
    (dict(dest_param=150.0), "dest_param=150.0"),
    (dict(fennel_gamma=1.0), "fennel_gamma=1.0"),
    (dict(fennel_gamma=0.0), "fennel_gamma=0.0"),
    (dict(ldg_slack=0.5), "ldg_slack=0.5"),
])
def test_bad_config_raises_with_value_in_message(kw, needle):
    with pytest.raises(ValueError) as exc:
        EngineConfig(**kw)
    assert needle in str(exc.value)


def test_messages_are_actionable():
    with pytest.raises(ValueError, match="raise k_max or\\s+lower k_init"):
        EngineConfig(k_init=9, k_max=8)
    with pytest.raises(ValueError, match="'text'.*'alg1'"):
        EngineConfig(balance_guard="nope")


def test_boundary_values_accepted():
    EngineConfig(k_init=1, k_max=1)
    EngineConfig(tolerance_param=0.0, dest_param=100.0)
    EngineConfig(fennel_gamma=1.0001, ldg_slack=1.0)
