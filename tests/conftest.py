import warnings

import numpy as np
import pytest

warnings.filterwarnings("ignore", category=DeprecationWarning)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
