"""Optimizer, gradient compression, checkpointing, fault-tolerant loop."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import restore_pytree, save_pytree
from repro.checkpoint.manager import CheckpointManager
from repro.optim.compression import (compressed_allreduce_grads,
                                     init_error_feedback, int8_compress,
                                     int8_decompress)
from repro.optim.optimizers import (adamw, apply_updates,
                                    clip_by_global_norm, cosine_schedule,
                                    linear_warmup_cosine, sgd_momentum)
from repro.runtime.fault import FaultTolerantLoop


def test_adamw_converges_quadratic():
    w = {"a": jnp.asarray([5.0, -3.0]), "b": jnp.asarray(2.0)}
    opt = adamw(0.2, weight_decay=0.0)
    state = opt.init(w)

    def loss(w):
        return jnp.sum(w["a"] ** 2) + w["b"] ** 2

    for _ in range(120):
        g = jax.grad(loss)(w)
        upd, state = opt.update(g, state, w)
        w = apply_updates(w, upd)
    assert float(loss(w)) < 1e-3


def test_weight_decay_shrinks_params():
    w = {"a": jnp.ones(4) * 10.0}
    opt = adamw(0.1, weight_decay=0.5)
    state = opt.init(w)
    zero_g = {"a": jnp.zeros(4)}
    for _ in range(20):
        upd, state = opt.update(zero_g, state, w)
        w = apply_updates(w, upd)
    assert float(jnp.abs(w["a"]).max()) < 10.0


def test_clip_by_global_norm():
    g = {"x": jnp.ones(16) * 100.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 400.0) < 1e-3
    total = jnp.sqrt(jnp.sum(clipped["x"] ** 2))
    np.testing.assert_allclose(float(total), 1.0, rtol=1e-4)


def test_schedules():
    lr = cosine_schedule(1.0, 100)
    assert float(lr(0)) == pytest.approx(1.0)
    assert float(lr(100)) == pytest.approx(0.1, abs=1e-6)
    lrw = linear_warmup_cosine(1.0, 10, 100)
    assert float(lrw(0)) < float(lrw(9))
    assert float(lrw(10)) == pytest.approx(1.0, rel=1e-3)


def test_sgd_momentum_descends():
    w = jnp.asarray([4.0])
    opt = sgd_momentum(0.02)   # heavy-ball stable region for f=x²
    state = opt.init(w)
    for _ in range(150):
        g = 2 * w
        upd, state = opt.update(g, state, w)
        w = apply_updates(w, upd)
    assert abs(float(w[0])) < 0.1


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_int8_roundtrip_accuracy():
    x = jax.random.normal(jax.random.PRNGKey(0), (256,)) * 3.0
    q, s = int8_compress(x)
    err = np.abs(np.asarray(int8_decompress(q, s) - x))
    assert err.max() <= float(s) * 0.5 + 1e-6


def test_error_feedback_unbiased_over_time():
    """Mean compressed signal ≈ mean true signal once EF accumulates."""
    g = {"w": jnp.full((64,), 0.01)}   # tiny values → large relative quant
    err = init_error_feedback(g)
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_mesh_compat
    mesh = make_mesh_compat((1,), ("dp",))

    if hasattr(jax, "shard_map"):
        shard_map = jax.shard_map
    else:  # older jax keeps it in experimental
        from jax.experimental.shard_map import shard_map

    def run(err):
        f = shard_map(
            lambda gg, ee: compressed_allreduce_grads(gg, ee, "dp"),
            mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()))
        return f(g, err)

    total = jnp.zeros(64)
    for _ in range(16):
        out, err = run(err)
        total = total + out["w"]
    np.testing.assert_allclose(np.asarray(total / 16), 0.01, rtol=0.1)


# ---------------------------------------------------------------------------
# checkpointing + fault tolerance
# ---------------------------------------------------------------------------

def test_save_restore_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.asarray([1, 2], jnp.int32)}}
    path = os.path.join(tmp_path, "ckpt.npz")
    save_pytree(path, tree, step=7)
    like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    out = restore_pytree(path, like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_legacy_partition_state_fill_missing(tmp_path):
    """A pre-cut_matrix PartitionState checkpoint (12 leaves) restores into
    today's 13-leaf state: fill_missing aligns by key path, the trailing
    cut_matrix leaf keeps `like`'s value — which recount_cut_matrix rebuilds
    exactly from the restored (assignment, present, adj)."""
    import collections
    from repro.core import EngineConfig, run_stream
    from repro.core.state import PartitionState, recount_cut_matrix
    from repro.graph.generators import make_graph
    from repro.graph import stream as gstream

    g = make_graph("mesh", 40, 100, seed=0)
    s = gstream.build_stream(g, seed=0)
    state, _ = run_stream(
        s, policy="sdp", cfg=EngineConfig(k_max=4, k_init=2, autoscale=False))
    # a faithful stand-in for the pre-cut_matrix state type: same field
    # names (key paths align by attribute), no trailing cut_matrix leaf
    Legacy = collections.namedtuple("Legacy", PartitionState._fields[:-1])
    legacy = Legacy(*tuple(state)[:-1])
    path = os.path.join(tmp_path, "legacy.npz")
    save_pytree(path, legacy, step=1)

    like = jax.tree.map(jnp.zeros_like, state)
    with pytest.raises(ValueError, match="fill_missing"):
        restore_pytree(path, like)
    out = restore_pytree(path, like, fill_missing=True)
    restored = recount_cut_matrix(out)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_manager_retention_and_latest(tmp_path):
    m = CheckpointManager(str(tmp_path), interval=1, keep=2)
    tree = {"w": jnp.zeros(3)}
    for step in (1, 2, 3, 4):
        m.maybe_save(step, tree, blocking=True)
    assert m.latest() == 4
    assert m._steps() == [3, 4]          # retention gc
    restored, step = m.restore(tree)
    assert step == 4


def test_fault_loop_recovers_from_poison(tmp_path):
    """A step that raises → restore from checkpoint → continue."""
    m = CheckpointManager(str(tmp_path), interval=1)
    loop = FaultTolerantLoop(m, max_retries=2)
    state = {"w": jnp.zeros(2)}

    batches = [1.0, 2.0, "poison", 3.0]

    def step_fn(state, batch):
        if batch == "poison":
            raise RuntimeError("node failure")
        return {"w": state["w"] + batch}, {}

    final, steps = loop.run(state, iter(batches), step_fn, like=state)
    # poison batch skipped; recovery restored from the last checkpoint
    assert any(e["event"] == "failure" for e in loop.events)
    assert np.isfinite(np.asarray(final["w"])).all()


def test_straggler_detection(tmp_path):
    m = CheckpointManager(str(tmp_path), interval=10**9,
                          straggler_factor=2.0)
    for i in range(16):
        m.record_step(i, 0.1)
    assert not m.is_straggler(0.15)
    assert m.is_straggler(0.5)
