"""The fused Pallas window chooser (gather → score → argmax → commit in
one kernel, repro.kernels.fused_chooser) must be bit-identical to the
faithful per-event engine on delete-heavy interleaved streams — for every
policy, with autoscale on, through every surface it is wired to
(run_stream_windowed, the Partitioner session, the Sweep lanes), and for
both the Pallas kernel and its lax.scan oracle (``variant="ref"``).

CI runs these in interpret mode (repro.kernels.common.default_interpret
resolves ``jax.default_backend() != "tpu"``); on a real TPU the same
tests exercise the compiled kernel.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.api import Partitioner, Sweep
from repro.core import EngineConfig, run_stream, run_stream_windowed
from repro.core import transition as tx
from repro.core import windowed as wnd
from repro.graph.generators import make_graph
from repro.graph import stream as gstream
from repro.kernels import common as kcommon
from repro.kernels.fused_chooser.ops import run_window_mixed_fused

POLICIES6 = ["sdp", "greedy", "ldg", "fennel", "hash", "random"]


def _identical(a, b):
    for f in a._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f)), f)


def _churn_stream(seed=1, n=120, m=360):
    g = make_graph("social", n, m, seed=0)
    s = gstream.interleaved_churn(g, warmup_frac=0.15, del_every=2,
                                  edge_del_every=4, readd_every=6, seed=seed)
    dels = (s.etype == gstream.EVENT_DEL_VERTEX) | \
        (s.etype == gstream.EVENT_DEL_EDGE)
    assert float(np.mean(dels)) >= 0.30, "stream not delete-heavy enough"
    return s


def _cfg_for(policy, **kw):
    kw.setdefault("k_max", 6)
    kw.setdefault("max_cap", 110)
    kw.setdefault("k_init", 1 if policy == "sdp" else 4)
    kw.setdefault("autoscale", policy == "sdp")
    return EngineConfig(**kw)


# ---------------------------------------------------------------------------
# full-stream bit-identity: fused engine vs faithful per-event scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", POLICIES6)
def test_fused_full_stream_all_policies(policy):
    """Interleaved churn, every policy, fused kernel == faithful engine."""
    s = _churn_stream(seed=7)
    cfg = _cfg_for(policy)
    a, _ = run_stream(s, policy=policy, cfg=cfg, seed=3)
    b = run_stream_windowed(s, policy=policy, cfg=cfg, seed=3, window=32,
                            use_kernel=True)
    _identical(a, b)


@pytest.mark.parametrize("window", [8, 32, 256])
def test_fused_autoscale_windows(window):
    """Autoscale on (scale-out + scale-in inside windows), window sizes
    spanning smaller-than-tile to larger-than-stream."""
    s = _churn_stream()
    cfg = EngineConfig(k_max=8, k_init=1, max_cap=100, autoscale=True)
    a, _ = run_stream(s, policy="sdp", cfg=cfg, seed=2)
    b = run_stream_windowed(s, policy="sdp", cfg=cfg, seed=2, window=window,
                            use_kernel=True)
    _identical(a, b)


def test_fused_alg1_guard():
    s = _churn_stream(seed=9)
    cfg = EngineConfig(k_max=6, k_init=1, max_cap=90, autoscale=True,
                       balance_guard="alg1")
    a, _ = run_stream(s, policy="sdp", cfg=cfg, seed=5)
    b = run_stream_windowed(s, policy="sdp", cfg=cfg, seed=5, window=64,
                            use_kernel=True)
    _identical(a, b)


def test_ref_oracle_matches_kernel_and_faithful():
    """variant="ref" (the lax.scan oracle sharing make_slot_step) ==
    the Pallas kernel == the faithful engine, window by window."""
    s = _churn_stream(seed=11)
    cfg = _cfg_for("sdp", k_max=6)
    w = 32
    T = (s.num_events // w) * w
    state_x = state_k = state_r = None
    from repro.core.state import init_state
    state_x = init_state(s.n, s.max_deg, cfg.k_max, cfg.k_init, 4)
    state_k = init_state(s.n, s.max_deg, cfg.k_max, cfg.k_init, 4)
    state_r = init_state(s.n, s.max_deg, cfg.k_max, cfg.k_init, 4)
    et, vx = jnp.asarray(s.etype), jnp.asarray(s.vertex)
    nb = jnp.asarray(s.nbrs)
    for t in range(0, T, w):
        sl = slice(t, t + w)
        args = (et[sl], vx[sl], nb[sl], jnp.int32(t))
        state_x = wnd.run_window_mixed(state_x, *args, policy="sdp", cfg=cfg)
        state_k = run_window_mixed_fused(state_k, *args, policy="sdp",
                                         cfg=cfg)
        state_r = run_window_mixed_fused(state_r, *args, policy="sdp",
                                         cfg=cfg, variant="ref")
    _identical(state_x, state_k)
    _identical(state_x, state_r)


# ---------------------------------------------------------------------------
# geometry edges: off-tile shapes, k_max=1, deletion holes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window,k_max", [(13, 5), (8, 3), (48, 7)])
def test_fused_off_tile_geometry(window, k_max):
    """W, max_deg, k_max all coprime with the (8, 128) VPU tile — the
    fused kernel carries whole-window values, so no shape may assume
    tile-multiple padding."""
    s = _churn_stream(seed=5, n=90, m=250)
    assert s.max_deg % 128 != 0
    cfg = EngineConfig(k_max=k_max, k_init=1, max_cap=80, autoscale=True)
    a, _ = run_stream(s, policy="sdp", cfg=cfg, seed=1)
    b = run_stream_windowed(s, policy="sdp", cfg=cfg, seed=1, window=window,
                            use_kernel=True)
    _identical(a, b)


def test_fused_k_max_one():
    """k_max=1: every chooser must return partition 0; the scale hooks
    are structurally inert (no room to scale out)."""
    s = _churn_stream(seed=3, n=60, m=150)
    cfg = EngineConfig(k_max=1, k_init=1, max_cap=10**9, autoscale=False)
    for policy in ("sdp", "greedy", "hash"):
        a, _ = run_stream(s, policy=policy, cfg=cfg, seed=2)
        b = run_stream_windowed(s, policy=policy, cfg=cfg, seed=2, window=16,
                                use_kernel=True)
        _identical(a, b)
        assert np.asarray(b.assignment)[np.asarray(b.present)].max(
            initial=0) == 0


def test_fused_resumes_from_deletion_holes():
    """Start a window from a state with deletion holes (present=False
    vertices whose adjacency rows still name them as neighbours): the
    touch-table apply must keep the holes at label -1 while the remap
    composes committed labels."""
    s = _churn_stream(seed=13)
    cfg = _cfg_for("sdp")
    half = (s.num_events // 2 // 32) * 32
    first = gstream.VertexStream(etype=s.etype[:half], vertex=s.vertex[:half],
                                 nbrs=s.nbrs[:half], n=s.n)
    mid, _ = run_stream(first, policy="sdp", cfg=cfg, seed=6)
    assert not bool(np.asarray(mid.present).all()), "no holes to test"
    w = 64
    sl = slice(half, half + w)
    args = (jnp.asarray(s.etype[sl]), jnp.asarray(s.vertex[sl]),
            jnp.asarray(s.nbrs[sl]), jnp.int32(half))
    a = wnd.run_window_mixed(mid, *args, policy="sdp", cfg=cfg)
    b = run_window_mixed_fused(mid, *args, policy="sdp", cfg=cfg)
    _identical(a, b)
    holes = ~np.asarray(a.present)
    assert (np.asarray(a.assignment)[holes] == -1).all()


# ---------------------------------------------------------------------------
# session + sweep surfaces
# ---------------------------------------------------------------------------

def test_partitioner_use_kernel_parity_and_coverage():
    """The session with use_kernel=True is bit-identical to run_stream,
    and metrics() reports the kernel/fallback window split (full windows
    ride the kernel, the auto engine's small tails stay XLA scan)."""
    s = _churn_stream(seed=3)
    cfg = _cfg_for("sdp")
    ref, _ = run_stream(s, policy="sdp", cfg=cfg, seed=0)
    p = Partitioner(cfg, n=s.n, max_deg=s.max_deg, policy="sdp", seed=0,
                    window=32, use_kernel=True)
    t = 0
    while t < s.num_events:
        sl = slice(t, min(t + 100, s.num_events))
        p.feed((s.etype[sl], s.vertex[sl], s.nbrs[sl]))
        t = sl.stop
    _identical(ref, p.state)
    m = p.metrics()
    assert m["kernel_windows"] > 0
    assert m["fallback_windows"] > 0          # the 100-event calls leave tails
    q = Partitioner(cfg, n=s.n, max_deg=s.max_deg, policy="sdp", seed=0,
                    window=32)
    q.feed(s)
    assert q.metrics()["kernel_windows"] == 0  # default surface: all XLA
    _identical(ref, q.state)


def test_sweep_kernel_lanes_parity():
    """Sweep(...).windowed().kernel() == the XLA windowed lanes, per-lane
    streams, mixed policies/autoscale."""
    cfgs = [_cfg_for("sdp"), _cfg_for("greedy"), _cfg_for("ldg")]
    runs = [("sdp", cfgs[0], 0), ("greedy", cfgs[1], 1), ("ldg", cfgs[2], 2)]
    streams = [_churn_stream(seed=i) for i in range(3)]
    rx = Sweep(streams).lanes(runs).windowed(32).run()
    rk = Sweep(streams).lanes(runs).windowed(32).kernel().run()
    for a, b in zip(rx, rk):
        _identical(a.state, b.state)


def test_sweep_kernel_shared_stream_sharded():
    """Shared-stream broadcast + shard_map path (check_rep off for the
    pallas_call) stays bit-identical, even forced onto one device."""
    s = _churn_stream(seed=2)
    cfg = _cfg_for("sdp", autoscale=False, k_init=3)
    runs = [("sdp", cfg, i) for i in range(3)]
    rx = Sweep(s).lanes(runs).windowed(32).run()
    rk = Sweep(s).lanes(runs).windowed(32).kernel().sharded().run()
    for a, b in zip(rx, rk):
        _identical(a.state, b.state)


def test_sweep_kernel_requires_windowed_engine():
    s = _churn_stream(seed=2)
    with pytest.raises(ValueError, match="windowed engine"):
        Sweep(s).lane("sdp", _cfg_for("sdp")).kernel().run()


# ---------------------------------------------------------------------------
# seams: RNG table, interpret resolution
# ---------------------------------------------------------------------------

def test_rand_index_table_matches_per_event_randint():
    """tab[i, m-1] must equal the faithful engine's tie-break draw
    randint(fold_in(key, t0+i), 0, m) for every live partition count m —
    the whole reason the kernel can avoid tracing threefry per slot."""
    key = jax.random.PRNGKey(42)
    t0, w, k_max = 37, 19, 6
    tab = np.asarray(tx.rand_index_table(key, jnp.int32(t0), w, k_max))
    assert tab.shape == (w, k_max)
    for i in range(w):
        ek = jax.random.fold_in(key, t0 + i)
        for m in range(1, k_max + 1):
            assert tab[i, m - 1] == int(jax.random.randint(ek, (), 0, m))


def test_interpret_resolution():
    """One definition site: default follows the backend, the env var
    overrides, and an explicit argument beats both."""
    backend_default = jax.default_backend() != "tpu"
    assert kcommon.default_interpret() is backend_default
    assert kcommon.resolve_interpret(None) is backend_default
    assert kcommon.resolve_interpret(True) is True
    assert kcommon.resolve_interpret(False) is False


def test_interpret_env_override(monkeypatch):
    monkeypatch.setenv(kcommon._ENV, "0")
    assert kcommon.default_interpret() is False
    monkeypatch.setenv(kcommon._ENV, "1")
    assert kcommon.default_interpret() is True
    monkeypatch.setenv(kcommon._ENV, "false")
    assert kcommon.default_interpret() is False
    monkeypatch.delenv(kcommon._ENV)
    assert kcommon.default_interpret() is (jax.default_backend() != "tpu")
