"""Distribution layer: halo exchange vs naive aggregation, sharding rules,
hlo_stats loop-aware analysis, small-mesh step compilation, elastic
re-shard. Uses a subprocess with forced host devices where a multi-device
mesh is required (the main test process keeps the default 1 device)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np


from repro.core import EngineConfig, run_stream
from repro.graph.generators import make_graph
from repro.graph.halo import build_halo_spec, gather_nodes, scatter_nodes
from repro.graph import stream as gstream
from repro.launch.hlo_stats import analyze
from repro.runtime import sharding as SHR

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_subprocess(code: str) -> str:
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


# ---------------------------------------------------------------------------
# hlo_stats (single-device, no mesh needed)
# ---------------------------------------------------------------------------

def test_hlo_stats_loop_free_matches_cost_analysis():
    def f(x, w1, w2):
        return ((x @ w1) @ w2).sum()
    specs = [jax.ShapeDtypeStruct(s, jnp.float32)
             for s in ((64, 128), (128, 256), (256, 64))]
    co = jax.jit(f).lower(*specs).compile()
    st = analyze(co.as_text(), 1)
    expect = 2 * 64 * 128 * 256 + 2 * 64 * 256 * 64
    assert abs(st["flops_per_device"] - expect) / expect < 1e-6


def test_hlo_stats_scan_multiplies_trip_count():
    def g(x, ws):
        def body(x, w):
            return jnp.tanh(x @ w), ()
        x, _ = jax.lax.scan(body, x, ws)
        return x.sum()
    specs = [jax.ShapeDtypeStruct((32, 64), jnp.float32),
             jax.ShapeDtypeStruct((6, 64, 64), jnp.float32)]
    co = jax.jit(g).lower(*specs).compile()
    st = analyze(co.as_text(), 1)
    expect = 2 * 32 * 64 * 64 * 6
    assert abs(st["flops_per_device"] - expect) / expect < 1e-6
    # XLA's own analysis undercounts by the trip count — that's the bug
    # this module exists to fix
    ca = co.cost_analysis()
    # jax 0.4.x returns a one-element list of dicts, newer jax a dict
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    assert ca["flops"] < st["flops_per_device"]


# ---------------------------------------------------------------------------
# halo exchange (multi-device via subprocess)
# ---------------------------------------------------------------------------

HALO_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.core import EngineConfig, run_stream
from repro.graph.generators import make_graph
from repro.graph import stream as gstream
from repro.graph.halo import build_halo_spec, scatter_nodes, gather_nodes
from repro.runtime.gnn_sharded import make_sharded_aggregate, naive_aggregate

g = make_graph("mesh", 96, 260, seed=0)
s = gstream.build_stream(g, seed=0)
st, _ = run_stream(s, policy="sdp",
                   cfg=EngineConfig(k_max=4, k_init=4, autoscale=False))
assign = np.array(st.assignment); assign[assign < 0] = 0
spec = build_halo_spec(g, assign, 4)
from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((4,), ("data",))
x = np.random.default_rng(0).standard_normal((g.n, 8)).astype(np.float32)
blocks = scatter_nodes(spec, x)
agg = make_sharded_aggregate(mesh, spec)
out = agg(jnp.asarray(blocks), jnp.asarray(spec.publish_idx),
          jnp.asarray(spec.halo_map), jnp.asarray(spec.senders),
          jnp.asarray(spec.receivers))
e = g.edge_array()
snd = np.concatenate([e[:, 0], e[:, 1]])
rcv = np.concatenate([e[:, 1], e[:, 0]])
ref = naive_aggregate(jnp.asarray(x), jnp.asarray(snd), jnp.asarray(rcv))
np.testing.assert_allclose(gather_nodes(spec, np.asarray(out)),
                           np.asarray(ref), rtol=1e-5, atol=1e-5)
print("HALO_OK", spec.publish_size, spec.halo_size)
"""


def test_halo_aggregation_matches_naive():
    out = _run_subprocess(HALO_CODE)
    assert "HALO_OK" in out


def test_halo_collective_volume_tracks_edge_cut():
    """SDP partitioning must shrink the halo (collective bytes) vs hash."""
    g = make_graph("mesh", 400, 1100, seed=1)
    s = gstream.build_stream(g, seed=1)
    pub = {}
    for pol in ("sdp", "hash"):
        st, _ = run_stream(s, policy=pol,
                           cfg=EngineConfig(k_max=4, k_init=4,
                                            autoscale=False))
        a = np.array(st.assignment)
        a[a < 0] = 0
        spec = build_halo_spec(g, a, 4)
        # true (unpadded) boundary volume = rows actually published
        pub[pol] = int((spec.publish_idx >= 0).sum())
    # distinct-boundary-vertex volume saturates at small k, so the factor
    # is milder than the 2× edge-cut gap — but must track direction
    assert pub["sdp"] < 0.8 * pub["hash"], pub


def test_scatter_gather_roundtrip():
    g = make_graph("mesh", 50, 140, seed=2)
    assign = np.random.default_rng(0).integers(0, 3, g.n)
    spec = build_halo_spec(g, assign, 3)
    x = np.random.default_rng(1).standard_normal((g.n, 5)).astype(np.float32)
    blocks = scatter_nodes(spec, x)
    back = gather_nodes(spec, blocks)
    np.testing.assert_array_equal(back, x)


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

def test_lm_param_rules_cover_all_paths():
    from repro.configs import ARCHS
    from repro.models import transformer as T
    import functools
    for arch_id in ("gemma2-9b", "moonshot-v1-16b-a3b"):
        cfg = ARCHS[arch_id].config
        like = jax.eval_shape(functools.partial(T.init_params, cfg=cfg),
                              jax.random.PRNGKey(0))
        paths, vals, _ = SHR.tree_paths(like)
        rules = SHR.lm_param_rules_probe() if hasattr(
            SHR, "lm_param_rules_probe") else None
        # every 2D+ tensor must match a non-replicated rule
        import re
        rule_list = [
            (r"embed$", 1), (r"lm_head$", 1), (r"attn/w[qkvo]$", 1),
            (r"mlp/w[igo]$", 1), (r"moe/router$", 1), (r"moe/w[igo]$", 1),
            (r"ln", 0),
        ]
        for p, v in zip(paths, vals):
            matched = any(re.search(pat, p) for pat, _ in rule_list)
            assert matched, f"param path {p} matches no sharding rule"


def test_shape_divisibility_for_production_mesh():
    """Every LM arch's TP/FSDP dims divide the 16×16 and 2×16×16 meshes."""
    from repro.configs import ARCHS
    for arch_id, arch in ARCHS.items():
        if arch.family != "lm":
            continue
        cfg = arch.config
        for tp in (16,):
            assert (cfg.n_heads * cfg.head_dim) % tp == 0, arch_id
            assert (cfg.n_kv_heads * cfg.head_dim) % tp == 0, arch_id
            assert cfg.d_ff % tp == 0 or cfg.moe is not None, arch_id
            assert cfg.vocab % tp == 0, arch_id
        for fsdp in (16, 32):
            assert cfg.d_model % fsdp == 0, arch_id
        if cfg.moe is not None:
            assert cfg.moe.n_experts % 16 == 0 or cfg.moe.n_experts <= 16, \
                arch_id


# ---------------------------------------------------------------------------
# end-to-end small-mesh compile (the dry-run path on 8 devices)
# ---------------------------------------------------------------------------

SMALL_DRYRUN_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.launch.steps import build_step
from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((4, 2), ("data", "model"))
b = build_step("pna", "molecule", mesh)
with mesh:
    co = jax.jit(b.fn, in_shardings=b.in_shardings,
                 out_shardings=b.out_shardings,
                 donate_argnums=b.donate).lower(*b.specs).compile()
print("COMPILED", co.memory_analysis().temp_size_in_bytes > 0)
"""


def test_small_mesh_step_compiles():
    out = _run_subprocess(SMALL_DRYRUN_CODE)
    assert "COMPILED" in out


# ---------------------------------------------------------------------------
# elastic re-shard
# ---------------------------------------------------------------------------

ELASTIC_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint.manager import CheckpointManager
from repro.runtime.elastic import ElasticRunner
import tempfile

def mesh_factory(devices):
    n = len(devices)
    return jax.sharding.Mesh(np.asarray(devices).reshape(n, 1),
                             ("data", "model"))

def shardings_fn(mesh, tree):
    return jax.tree.map(
        lambda x: NamedSharding(mesh, P("data") if np.ndim(x) >= 1
                                and np.shape(x)[0] % mesh.shape["data"] == 0
                                else P()), tree,
        is_leaf=lambda x: hasattr(x, "shape"))

params = {"w": jnp.arange(32, dtype=jnp.float32)}
opt = {"mu": jnp.zeros(32)}
with tempfile.TemporaryDirectory() as d:
    runner = ElasticRunner(mesh_factory, shardings_fn,
                           CheckpointManager(d, interval=1))
    st = runner.place(jax.devices()[:8], params, opt, step=3)
    st2 = runner.rescale(st, jax.devices()[:4])   # scale-in: 8 -> 4
    np.testing.assert_array_equal(np.asarray(st2.params["w"]),
                                  np.arange(32, dtype=np.float32))
    assert st2.mesh.shape["data"] == 4
    st3 = runner.rescale(st2, jax.devices()[:8])  # scale-out: 4 -> 8
    np.testing.assert_array_equal(np.asarray(st3.params["w"]),
                                  np.arange(32, dtype=np.float32))
print("ELASTIC_OK")
"""


def test_elastic_rescale_preserves_state():
    out = _run_subprocess(ELASTIC_CODE)
    assert "ELASTIC_OK" in out
