"""Faithful engine vs the dict/set oracle (paper Algorithm 1 semantics)."""
import numpy as np
import pytest

from repro.core import (EngineConfig, run_reference, run_stream,
                        recompute_counters, state_metrics)
from repro.graph.datasets import load_dataset
from repro.graph.generators import make_graph
from repro.graph import stream as gstream


def _assert_match(state, ref):
    """JAX engine state must match the oracle exactly."""
    n = state.assignment.shape[0]
    a = np.asarray(state.assignment)
    for v in range(n):
        if v in ref.assignment:
            assert a[v] == ref.assignment[v], f"vertex {v}"
        else:
            assert a[v] == -1, f"vertex {v} should be absent"
    np.testing.assert_array_equal(np.asarray(state.edge_load), ref.edge_load)
    np.testing.assert_array_equal(np.asarray(state.vertex_count),
                                  ref.vertex_count)
    np.testing.assert_array_equal(np.asarray(state.active), ref.active)
    assert int(state.total_edges) == ref.total_edges
    assert int(state.cut_edges) == ref.cut_edges
    assert int(state.num_partitions) == ref.num_partitions
    assert int(state.denied_scaleout) == ref.denied
    assert int(state.scale_events) == ref.scale_events
    # pairwise cut matrix: engine's incremental O(K²) maintenance must
    # match the oracle's, and its invariants must hold (the oracle's
    # cut_edges is recomputed from scratch at scale-in, so the half-sum
    # check pits the engine's incremental merge against an independent
    # from-scratch count)
    cm = np.asarray(state.cut_matrix)
    np.testing.assert_array_equal(cm, ref.cut_matrix)
    np.testing.assert_array_equal(cm, cm.T)
    np.testing.assert_array_equal(cm.sum(axis=1), np.asarray(state.edge_load))
    assert (cm.sum() - np.trace(cm)) // 2 == int(state.cut_edges)


CASES = [
    ("sdp", EngineConfig(k_max=8, k_init=1, max_cap=150)),
    ("sdp", EngineConfig(k_max=4, k_init=2, max_cap=80,
                         balance_guard="alg1")),
    ("sdp", EngineConfig(k_max=8, k_init=1, max_cap=10**9)),  # no scaling
    ("greedy", EngineConfig(k_max=6, k_init=4, autoscale=False)),
    ("ldg", EngineConfig(k_max=6, k_init=4, autoscale=False)),
    ("fennel", EngineConfig(k_max=6, k_init=4, autoscale=False)),
    ("hash", EngineConfig(k_max=6, k_init=3, autoscale=False)),
    ("random", EngineConfig(k_max=6, k_init=3, autoscale=False)),
]


@pytest.mark.parametrize("policy,cfg", CASES)
def test_engine_matches_oracle_static(policy, cfg):
    g = make_graph("mesh", 120, 350, seed=1)
    s = gstream.build_stream(g, seed=2)
    state, _ = run_stream(s, policy=policy, cfg=cfg, seed=3)
    ref = run_reference(s, policy=policy, cfg=cfg, seed=3)
    _assert_match(state, ref)


@pytest.mark.parametrize("policy,cfg", CASES[:3])
def test_engine_matches_oracle_dynamic(policy, cfg):
    """Add/delete protocol (§5.3.1) including vertex+edge deletions."""
    g = make_graph("social", 90, 260, seed=4)
    s = gstream.dynamic_schedule(g, n_intervals=4, seed=5,
                                 del_edges_per_interval=5)
    state, _ = run_stream(s, policy=policy, cfg=cfg, seed=6)
    ref = run_reference(s, policy=policy, cfg=cfg, seed=6)
    _assert_match(state, ref)


def test_counters_match_recompute():
    """Incremental counters == from-scratch recomputation (Eq. 9/10)."""
    g = load_dataset("grqc", scale=0.05)
    s = gstream.dynamic_schedule(g, n_intervals=3, seed=0)
    cfg = EngineConfig(k_max=8, k_init=1, max_cap=120)
    state, _ = run_stream(s, policy="sdp", cfg=cfg)
    rec = recompute_counters(np.asarray(state.assignment),
                             np.asarray(state.present),
                             np.asarray(state.adj), cfg.k_max)
    assert int(state.total_edges) == rec["total_edges"]
    assert int(state.cut_edges) == rec["cut_edges"]
    np.testing.assert_array_equal(np.asarray(state.edge_load),
                                  rec["edge_load"])
    np.testing.assert_array_equal(np.asarray(state.vertex_count),
                                  rec["vertex_count"])


def test_scale_out_triggers():
    """Eq. 5: small MAXCAP forces extra partitions."""
    g = make_graph("mesh", 150, 400, seed=0)
    s = gstream.build_stream(g, seed=0)
    small, _ = run_stream(s, policy="sdp",
                          cfg=EngineConfig(k_max=8, k_init=1, max_cap=60))
    big, _ = run_stream(s, policy="sdp",
                        cfg=EngineConfig(k_max=8, k_init=1, max_cap=10**9))
    assert int(small.num_partitions) > int(big.num_partitions) == 1
    assert int(small.scale_events) > 0


def test_scale_in_merges_partitions():
    """Deleting most vertices should trigger §4.2.3 scale-in migration."""
    g = make_graph("mesh", 100, 300, seed=1)
    add = gstream.build_stream(g, seed=1)
    rng = np.random.default_rng(2)
    present = np.asarray(add.vertex)
    dels = rng.choice(present, size=int(0.9 * present.size), replace=False)
    del_stream = gstream.VertexStream(
        etype=np.full(dels.size, gstream.EVENT_DEL_VERTEX, np.int32),
        vertex=dels.astype(np.int32),
        nbrs=-np.ones((dels.size, add.max_deg), np.int32),
        n=add.n)
    s = gstream.concat_streams([add, del_stream])
    cfg = EngineConfig(k_max=8, k_init=1, max_cap=60,
                       tolerance_param=60.0, dest_param=5.0)
    state, trace = run_stream(s, policy="sdp", cfg=cfg)
    peak = int(np.asarray(trace.num_partitions).max())
    assert int(state.num_partitions) < peak, "scale-in never fired"


def test_nth_active_clamps_out_of_range():
    """Regression: i >= popcount(active) used to argmax an all-False mask
    and silently return slot 0 — possibly an *inactive* partition. Now i
    wraps modulo the active count."""
    import jax.numpy as jnp
    from repro.core.transition import nth_active
    active = jnp.asarray([False, True, False, True, False])
    assert int(nth_active(active, jnp.int32(0))) == 1
    assert int(nth_active(active, jnp.int32(1))) == 3
    assert int(nth_active(active, jnp.int32(2))) == 1   # wraps, stays active
    assert int(nth_active(active, jnp.int32(5))) == 3
    assert bool(active[int(nth_active(active, jnp.int32(17)))])


def test_host_and_traced_imbalance_agree_after_scaling():
    """Eq. 10 is defined once (metrics.load_imbalance, active-partition
    count as denominator): the host-side state_metrics and the traced
    load_stats in the event trace must agree after scale-out AND scale-in
    events (they used to divide by popcount(active) vs num_partitions
    respectively, which drift apart the moment the two invariants do)."""
    from repro.core.metrics import load_imbalance
    g = make_graph("mesh", 100, 300, seed=1)
    add = gstream.build_stream(g, seed=1)
    rng = np.random.default_rng(2)
    present = np.asarray(add.vertex)
    dels = rng.choice(present, size=int(0.9 * present.size), replace=False)
    del_stream = gstream.VertexStream(
        etype=np.full(dels.size, gstream.EVENT_DEL_VERTEX, np.int32),
        vertex=dels.astype(np.int32),
        nbrs=-np.ones((dels.size, add.max_deg), np.int32),
        n=add.n)
    s = gstream.concat_streams([add, del_stream])
    cfg = EngineConfig(k_max=8, k_init=1, max_cap=60,
                       tolerance_param=60.0, dest_param=5.0)
    state, trace = run_stream(s, policy="sdp", cfg=cfg)
    assert int(state.scale_events) > 0
    m = state_metrics(state)
    ref = load_imbalance(np.asarray(state.edge_load), np.asarray(state.active))
    assert m["load_imbalance"] == ref
    np.testing.assert_allclose(float(np.asarray(trace.load_std)[-1]), ref,
                               rtol=1e-5, atol=1e-6)


def test_sdp_beats_hash_on_edge_cut():
    """Directional claim from the paper: SDP ≪ hash/random edge-cut."""
    g = load_dataset("3elt", scale=0.2)
    s = gstream.build_stream(g, seed=0)
    cfg = EngineConfig(k_max=4, k_init=4, autoscale=False)
    cuts = {}
    for pol in ("sdp", "hash"):
        st, _ = run_stream(s, policy=pol, cfg=cfg)
        cuts[pol] = state_metrics(st)["edge_cut_ratio"]
    assert cuts["sdp"] < 0.5 * cuts["hash"]


def test_duplicate_add_ignored():
    g = make_graph("mesh", 30, 80, seed=0)
    s1 = gstream.build_stream(g, seed=0)
    dup = gstream.concat_streams([s1, s1])  # every vertex added twice
    cfg = EngineConfig(k_max=4, k_init=2, autoscale=False)
    st1, _ = run_stream(s1, policy="greedy", cfg=cfg)
    st2, _ = run_stream(dup, policy="greedy", cfg=cfg)
    np.testing.assert_array_equal(np.asarray(st1.assignment),
                                  np.asarray(st2.assignment))
    assert int(st1.total_edges) == int(st2.total_edges)


def test_chunked_run_equals_single_shot():
    """run_stream(chunk=...) must be resumable without drift."""
    g = make_graph("mesh", 80, 220, seed=3)
    s = gstream.build_stream(g, seed=3)
    cfg = EngineConfig(k_max=4, k_init=1, max_cap=100)
    a, _ = run_stream(s, policy="sdp", cfg=cfg)
    b, _ = run_stream(s, policy="sdp", cfg=cfg, chunk=17)
    np.testing.assert_array_equal(np.asarray(a.assignment),
                                  np.asarray(b.assignment))
    assert int(a.cut_edges) == int(b.cut_edges)
