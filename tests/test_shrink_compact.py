"""Bidirectional elastic geometry (PR 8): ``shrink_tier`` hysteresis
bands, ``compact_state``/``shrink_state`` semantics, and the session-level
``compact()``/``shrink_to()``/``maybe_shrink()``/auto-shrink seams — every
shrink path must be a semantics no-op modulo the documented relabeling,
proven bit-identical against an uninterrupted ``run_stream``."""
import numpy as np
import pytest

from repro.api import Partitioner
from repro.core import (
    EngineConfig, Geometry, compact_state, grow_tier, live_extent, next_pow2,
    run_stream, shrink_state, shrink_tier, state_bytes,
)
from repro.core.geometry import geometry_of
from repro.graph.generators import make_graph
from repro.graph import stream as gstream
from repro.graph.stream import EVENT_ADD, EVENT_DEL_VERTEX


def _churn():
    g = make_graph("social", 90, 260, seed=2)
    s = gstream.interleaved_churn(g, warmup_frac=0.2, del_every=3,
                                  edge_del_every=5, seed=4)
    return s, EngineConfig(k_max=8, k_init=1, max_cap=100)


def _ring(lo, hi):
    """ADD events forming a cycle over ids [lo, hi) — max_deg 2."""
    ids = np.arange(lo, hi, dtype=np.int32)
    et = np.full(len(ids), EVENT_ADD, np.int32)
    nb = np.stack([ids - 1, ids + 1], 1).astype(np.int32)
    nb[0, 0], nb[-1, 1] = hi - 1, lo
    return et, ids, nb


def _dels(ids):
    ids = np.asarray(ids, np.int32)
    return (np.full(len(ids), EVENT_DEL_VERTEX, np.int32), ids,
            np.full((len(ids), 2), -1, np.int32))


def _cat(*chunks):
    return tuple(np.concatenate(parts) for parts in zip(*chunks))


# ---------------------------------------------------------------------------
# shrink_tier: the hysteresis bands
# ---------------------------------------------------------------------------

def test_shrink_tier_bands():
    cur = Geometry(1024, 64, 8)
    # above 1/(2*hysteresis) occupancy: hold the tier
    assert shrink_tier(cur, Geometry(129, 64)) == cur
    assert shrink_tier(cur, Geometry(200, 40)) == cur
    # at/below the band: land at next_pow2(2*req) — at most half-full
    t = shrink_tier(cur, Geometry(100, 4))
    assert t == Geometry(256, 8, 8)
    assert t.n >= 2 * 100 and t.max_deg >= 2 * 4
    # dimensions shrink independently
    assert shrink_tier(cur, Geometry(500, 4)) == Geometry(1024, 8, 8)
    # k_max is config-pinned: never auto-shrinks
    assert shrink_tier(cur, Geometry(1, 1)).k_max == 8
    with pytest.raises(ValueError, match="hysteresis"):
        shrink_tier(cur, Geometry(1, 1), hysteresis=1)


def test_shrink_grow_bands_never_overlap():
    """No thrash: content that just triggered a shrink sits at <= half the
    new tier, and content that just forced a growth sits above the shrink
    band of the grown tier — one update can never bounce back."""
    for n in (100, 129, 255, 500, 1000):
        req = Geometry(n, 4)
        shrunk = shrink_tier(Geometry(4096, 64, 8), req)
        assert shrink_tier(shrunk, req) == shrunk          # stable point
        grown = grow_tier(Geometry(1, 1, 8), req)
        assert shrink_tier(grown, req) == grown


# ---------------------------------------------------------------------------
# compact_state / shrink_state
# ---------------------------------------------------------------------------

def test_compact_state_counters_bitwise_and_relabel():
    s, cfg = _churn()
    ref, _ = run_stream(s, policy="sdp", cfg=cfg, seed=0)
    # counters survive any repack bitwise; the donated input is consumed,
    # so pull the reference values first
    want = {f: np.asarray(getattr(ref, f)).copy()
            for f in ("edge_load", "vertex_count", "active", "cut_matrix")}
    want_sc = {f: int(getattr(ref, f)) for f in
               ("num_partitions", "total_edges", "cut_edges",
                "denied_scaleout", "scale_events")}
    asg = np.asarray(ref.assignment).copy()
    pres = np.asarray(ref.present).copy()
    before = state_bytes(ref)
    packed, _ = live_extent(ref)
    st, perm = compact_state(ref)
    # default target: smallest pow2 tier holding the packed content,
    # capped at the current dims (a non-pow2 state never grows to "shrink")
    assert geometry_of(st) == Geometry(min(next_pow2(packed.n), 90),
                                       min(next_pow2(packed.max_deg), 64),
                                       cfg.k_max)
    assert geometry_of(st).covers(Geometry(packed.n, packed.max_deg))
    assert state_bytes(st) <= before
    for f, w in want.items():
        np.testing.assert_array_equal(w, np.asarray(getattr(st, f)), f)
    for f, w in want_sc.items():
        assert w == int(getattr(st, f)), f
    # the permutation carries every present vertex's label across
    keep = perm >= 0
    assert keep[pres].all()
    np.testing.assert_array_equal(
        asg[pres], np.asarray(st.assignment)[perm[pres]])
    assert np.asarray(st.present)[perm[pres]].all()


def test_shrink_state_truncates_or_points_at_compact():
    et, vx, nb = _ring(0, 40)
    cfg = EngineConfig(k_max=4, k_init=2, max_cap=10**6)
    part = Partitioner(cfg, n=512, max_deg=8, seed=0).feed((et, vx, nb))
    small = shrink_state(part.state, Geometry(64, 2, 4))
    assert geometry_of(small) == Geometry(64, 2, 4)
    assert int(np.asarray(small.present).sum()) == 40
    # content beyond the target: truncation refuses and names the fix
    part2 = Partitioner(cfg, n=512, max_deg=2, seed=0) \
        .feed(_ring(100, 140))
    with pytest.raises(ValueError, match="compact_state"):
        shrink_state(part2.state, Geometry(64, 2, 4))


# ---------------------------------------------------------------------------
# session seams: compact / shrink_to / maybe_shrink / auto_shrink
# ---------------------------------------------------------------------------

def test_session_relabel_compact_bit_identical_modulo_relabel():
    """Grow to a 1024 tier, churn most of it away, compact (relabels),
    keep feeding ORIGINAL ids (re-adds of deleted ids, brand-new ids,
    survivor edges): final state == uninterrupted run_stream of the
    concatenated stream, modulo the id map."""
    cfg = EngineConfig(k_max=4, k_init=2, max_cap=10**6)
    head = _cat(_ring(0, 600), _dels(np.arange(0, 550)))
    tail = _ring(540, 560)          # re-adds + survivors, original ids
    et, vx, nb = _cat(head, tail)
    width = nb.shape[1]
    ref, _ = run_stream(gstream.VertexStream(
        et, vx, nb, n=1024, intervals=(len(et),)), policy="sdp",
        cfg=cfg, seed=0)

    part = Partitioner(cfg, seed=0).feed(head)
    assert part.n == 1024
    part.compact()
    assert part.n < 1024 and part.metrics()["compactions"] == 1
    assert part.to_internal([599])[0] != 599        # genuinely relabeled
    part.feed(tail)

    ai = part.to_internal(np.arange(1024))
    got = np.full(1024, -1, np.int64)
    got[ai >= 0] = np.asarray(part.state.assignment)[ai[ai >= 0]]
    pres = np.asarray(ref.present)
    np.testing.assert_array_equal(np.asarray(ref.assignment)[pres],
                                  got[:len(pres)][pres])
    for f in ("num_partitions", "total_edges", "cut_edges",
              "denied_scaleout", "scale_events"):
        assert int(getattr(ref, f)) == int(getattr(part.state, f)), f
    np.testing.assert_array_equal(np.asarray(ref.edge_load),
                                  np.asarray(part.state.edge_load))
    kinds = [e["kind"] for e in part.geometry_events]
    assert "grow" in kinds and "shrink" in kinds
    # round-trip: external -> internal -> external is the identity on
    # live ids
    live = np.flatnonzero(got >= 0)
    np.testing.assert_array_equal(part.to_external(part.to_internal(live)),
                                  live)
    _ = width


def test_maybe_shrink_gate_and_auto_shrink():
    cfg = EngineConfig(k_max=4, k_init=2, max_cap=10**6)
    part = Partitioner(cfg, seed=0).feed(_ring(0, 600))
    assert not part.maybe_shrink()          # dense: gate says no
    assert part.n == 1024
    auto = Partitioner(cfg, seed=0, auto_shrink=True, shrink_every=64)
    auto.feed(_ring(0, 600))
    auto.feed(_dels(np.arange(0, 590)))     # churn empties the tier
    assert auto.n < 1024                    # auto-shrink fired in feed
    assert auto.metrics()["shrinks"] >= 1
    # equal content, smaller bytes
    part.feed(_dels(np.arange(0, 590)))
    dense = {v: int(l) for v, l in enumerate(
        np.asarray(part.state.assignment)) if l >= 0
        and np.asarray(part.state.present)[v]}
    for v, want in dense.items():
        ai = int(auto.to_internal([v])[0])
        assert ai >= 0 and int(np.asarray(auto.state.assignment)[ai]) == want
    assert auto.metrics()["state_bytes"] < part.metrics()["state_bytes"]


def test_shrink_to_validation():
    cfg = EngineConfig(k_max=4, k_init=2, max_cap=10**6)
    part = Partitioner(cfg, seed=0).feed(_ring(0, 100))
    with pytest.raises(ValueError, match="grow_to"):
        part.shrink_to(n=4 * part.n)
    with pytest.raises(ValueError, match="cannot hold"):
        part.shrink_to(n=32)                # 100 live vertices never fit
    part.shrink_to(n=128)                   # exact-target shrink works
    assert part.n == 128


def test_hash_policy_refuses_relabel_compaction():
    """``hash`` assigns by vertex id — relabeling would silently change
    its semantics, so the relabel path refuses with the reason."""
    cfg = EngineConfig(k_max=4, k_init=2, max_cap=10**6)
    part = Partitioner(cfg, policy="hash", seed=0).feed(_ring(100, 140))
    with pytest.raises(ValueError, match="hash"):
        part.shrink_to(n=64)
    # the id-preserving truncation stays available to hash sessions
    tr = Partitioner(cfg, policy="hash", seed=0).feed(_ring(0, 40))
    tr.shrink_to(n=64)
    assert tr.n == 64


def test_restore_into_smaller_tier_round_trip(tmp_path):
    """Snapshot at the peak tier, restore right-sized, continue feeding:
    equal to the uninterrupted session (the raise-on-shrink restore rule
    is gone)."""
    cfg = EngineConfig(k_max=4, k_init=2, max_cap=10**6)
    head = _cat(_ring(0, 600), _dels(np.arange(0, 560)))
    tail = _ring(560, 580)
    part = Partitioner(cfg, seed=0).feed(head)
    assert part.n == 1024
    part.snapshot(str(tmp_path))
    part.feed(tail)

    sess = Partitioner.restore(str(tmp_path), cfg, n=128, max_deg=2, seed=0)
    assert sess.n == 128
    assert [e["kind"] for e in sess.geometry_events][:1] == ["restore"]
    sess.feed(tail)
    ids = np.arange(540, 600)
    ref_l = np.asarray(part.state.assignment)[part.to_internal(ids)]
    got_l = np.asarray(sess.state.assignment)[sess.to_internal(ids)]
    np.testing.assert_array_equal(ref_l, got_l)
    for f in ("cut_edges", "total_edges", "num_partitions"):
        assert int(getattr(part.state, f)) == int(getattr(sess.state, f)), f


def test_id_map_survives_snapshot_restore(tmp_path):
    """A relabeled session's external-id map rides the checkpoint extras:
    restore answers queries in original ids."""
    cfg = EngineConfig(k_max=4, k_init=2, max_cap=10**6)
    part = Partitioner(cfg, seed=0).feed(
        _cat(_ring(0, 600), _dels(np.arange(0, 550))))
    part.compact()
    assert part._ext2int is not None
    want = {int(v): int(np.asarray(part.state.assignment)[
        part.to_internal([v])[0]]) for v in range(550, 600)}
    part.snapshot(str(tmp_path))
    sess = Partitioner.restore(str(tmp_path), cfg, seed=0)
    for v, lab in want.items():
        ai = int(sess.to_internal([v])[0])
        assert ai >= 0 and int(np.asarray(sess.state.assignment)[ai]) == lab
    # a deleted id referenced by no survivor's row was dropped: unmapped
    # (id 0 would NOT do — survivor 599's ring row still references it,
    # and referenced slots are kept so a re-add cannot dangle)
    assert int(sess.to_internal([100])[0]) == -1
