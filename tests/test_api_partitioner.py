"""The stateful ``Partitioner`` session must be bit-identical to one
whole-stream ``run_stream`` no matter how the stream is chopped across
``feed()`` calls (chunks of 1, 7, window-straddling sizes; autoscale
events landing exactly on a boundary) and across ``snapshot()`` →
``restore()`` → ``feed(rest)``."""
import os

import numpy as np
import pytest

from repro.api import Partitioner
from repro.checkpoint.manager import CheckpointManager
from repro.core import EngineConfig, run_stream
from repro.core.state import PartitionState
from repro.graph.generators import make_graph
from repro.graph import stream as gstream


def _churn_fixture():
    """Delete-heavy interleaved churn with autoscale on — the regime where
    every transition type (add / del vertex / del edge / scale-out /
    scale-in) crosses chunk boundaries."""
    g = make_graph("social", 90, 260, seed=2)
    s = gstream.interleaved_churn(g, warmup_frac=0.2, del_every=3,
                                  edge_del_every=5, seed=4)
    cfg = EngineConfig(k_max=8, k_init=1, max_cap=100)
    return s, cfg


def _identical(ref: PartitionState, got: PartitionState):
    for f in ("assignment", "present", "adj", "edge_load", "vertex_count",
              "active", "cut_matrix"):
        np.testing.assert_array_equal(np.asarray(getattr(ref, f)),
                                      np.asarray(getattr(got, f)), f)
    for f in ("num_partitions", "total_edges", "cut_edges",
              "denied_scaleout", "scale_events"):
        assert int(getattr(ref, f)) == int(getattr(got, f)), f


def _feed_chunked(part: Partitioner, s, chunk: int):
    t = 0
    while t < s.num_events:
        e = min(t + chunk, s.num_events)
        part.feed((s.etype[t:e], s.vertex[t:e], s.nbrs[t:e]))
        t = e
    return part


@pytest.mark.parametrize("engine", ["auto", "scan", "windowed"])
@pytest.mark.parametrize("chunk", [1, 7, 50])
def test_feed_chunked_bit_identical_to_run_stream(engine, chunk):
    """Chunks of 1, 7, and window-straddling 50 (window=32) through every
    backend == one whole-stream run_stream, bitwise."""
    s, cfg = _churn_fixture()
    ref, _ = run_stream(s, policy="sdp", cfg=cfg, seed=0)
    part = Partitioner.from_stream(s, cfg, seed=0, engine=engine, window=32)
    _feed_chunked(part, s, chunk)
    assert part.cursor == s.num_events
    _identical(ref, part.state)


def test_feed_whole_stream_and_vertexstream_input():
    s, cfg = _churn_fixture()
    ref, _ = run_stream(s, policy="sdp", cfg=cfg, seed=0)
    part = Partitioner.from_stream(s, cfg, seed=0, window=32).feed(s)
    _identical(ref, part.state)
    m = part.metrics()
    assert m["events_ingested"] == s.num_events
    assert m["edge_cut"] == int(ref.cut_edges)


def test_feed_split_exactly_at_autoscale_event():
    """Chop the stream exactly where a scale event fires: the first event
    of the second chunk sees the post-scale state, RNG still aligned."""
    g = make_graph("social", 90, 260, seed=2)
    s = gstream.dynamic_schedule(g, add_pct=25.0, del_pct=10.0,
                                 n_intervals=4, seed=3,
                                 del_edges_per_interval=5)
    cfg = EngineConfig(k_max=8, k_init=1, max_cap=40, tolerance_param=35.0)
    ref, trace = run_stream(s, policy="sdp", cfg=cfg, seed=0)
    parts = np.asarray(trace.num_partitions)
    bounds = np.flatnonzero(np.diff(parts)) + 1     # event AFTER each scale
    assert bounds.size >= 2, "fixture must actually autoscale"
    for cut in (int(bounds[0]), int(bounds[-1])):
        part = Partitioner.from_stream(s, cfg, seed=0, window=32)
        part.feed((s.etype[:cut], s.vertex[:cut], s.nbrs[:cut]))
        part.feed((s.etype[cut:], s.vertex[cut:], s.nbrs[cut:]))
        _identical(ref, part.state)


def test_trace_chunked_matches_run_stream():
    s, cfg = _churn_fixture()
    _, ref_trace = run_stream(s, policy="sdp", cfg=cfg, seed=0)
    part = Partitioner.from_stream(s, cfg, seed=0, collect_trace=True)
    _feed_chunked(part, s, 23)
    tr = part.trace()
    for f in tr._fields:
        np.testing.assert_array_equal(np.asarray(getattr(tr, f)),
                                      np.asarray(getattr(ref_trace, f)), f)


def test_snapshot_restore_feed_rest(tmp_path):
    """snapshot() -> restore() -> feed(rest) == one uninterrupted run."""
    s, cfg = _churn_fixture()
    ref, _ = run_stream(s, policy="sdp", cfg=cfg, seed=0)
    mid = s.num_events // 2
    part = Partitioner.from_stream(s, cfg, seed=0, window=32)
    part.feed((s.etype[:mid], s.vertex[:mid], s.nbrs[:mid]))
    step = part.snapshot(str(tmp_path))
    assert step == mid

    sess = Partitioner.restore(str(tmp_path), cfg, n=s.n, max_deg=s.max_deg,
                               window=32)
    assert sess.cursor == mid
    sess.feed((s.etype[mid:], s.vertex[mid:], s.nbrs[mid:]))
    _identical(ref, sess.state)


def test_snapshot_nonblocking_wait(tmp_path):
    """snapshot(blocking=False) + wait() persists; the session reuses one
    manager per directory so pending writers are joined, not leaked."""
    s, cfg = _churn_fixture()
    part = Partitioner.from_stream(s, cfg, seed=0, window=32).feed(s)
    part.snapshot(str(tmp_path), blocking=False)
    assert part._managers[str(tmp_path)] is not None
    part.wait()
    sess = Partitioner.restore(str(tmp_path), cfg, n=s.n, max_deg=s.max_deg)
    assert sess.cursor == s.num_events
    _identical(part.state, sess.state)


def test_restore_pre_cut_matrix_checkpoint(tmp_path):
    """A bare PartitionState checkpoint WITHOUT the trailing cut_matrix
    leaf (the pre-PR-3 layout) restores via fill_missing, is healed with
    recount_cut_matrix, and the resumed session stays bit-identical."""
    import collections
    s, cfg = _churn_fixture()
    ref, _ = run_stream(s, policy="sdp", cfg=cfg, seed=0)
    mid = s.num_events // 2
    part = Partitioner.from_stream(s, cfg, seed=0, window=32)
    part.feed((s.etype[:mid], s.vertex[:mid], s.nbrs[:mid]))
    # same field names so key paths align by attribute, no cut_matrix leaf
    Legacy = collections.namedtuple("Legacy", PartitionState._fields[:-1])
    legacy = Legacy(*tuple(part.state)[:-1])
    CheckpointManager(str(tmp_path), interval=1).maybe_save(
        mid, legacy, blocking=True)

    sess = Partitioner.restore(str(tmp_path), cfg, n=s.n, max_deg=s.max_deg,
                               window=32)
    assert sess.cursor == mid
    sess.feed((s.etype[mid:], s.vertex[mid:], s.nbrs[mid:]))
    _identical(ref, sess.state)


def test_restore_grows_larger_rejects_impossible(tmp_path):
    """Restore takes its shapes from the checkpoint's recorded geometry:
    a larger requested geometry grows the restored state (semantics
    no-op); a smaller one shrinks into it (PR 8) unless the live content
    cannot fit even densely packed, which raises."""
    s, cfg = _churn_fixture()
    part = Partitioner.from_stream(s, cfg, seed=0)
    part.feed(s)
    part.snapshot(str(tmp_path))
    big = Partitioner.restore(str(tmp_path), cfg, n=s.n + 5,
                              max_deg=s.max_deg + 2)
    assert (big.n, big.max_deg) == (s.n + 5, s.max_deg + 2)
    assert big.cursor == s.num_events
    np.testing.assert_array_equal(np.asarray(part.state.assignment),
                                  np.asarray(big.state.assignment)[:s.n])
    assert not np.asarray(big.state.present)[s.n:].any()
    with pytest.raises(ValueError, match="packed"):
        Partitioner.restore(str(tmp_path), cfg, n=5, max_deg=s.max_deg)
    with pytest.raises(ValueError, match="k_max"):
        Partitioner.restore(
            str(tmp_path),
            EngineConfig(k_max=cfg.k_max - 2, k_init=1, max_cap=100))
    with pytest.raises(FileNotFoundError):
        Partitioner.restore(os.path.join(str(tmp_path), "empty"), cfg,
                            n=s.n, max_deg=s.max_deg)


def test_constructor_and_feed_validation():
    s, cfg = _churn_fixture()
    with pytest.raises(ValueError, match="policy"):
        Partitioner.from_stream(s, cfg, policy="nope")
    with pytest.raises(ValueError, match="engine"):
        Partitioner.from_stream(s, cfg, engine="nope")
    with pytest.raises(ValueError, match="window"):
        Partitioner.from_stream(s, cfg, window=0)
    with pytest.raises(ValueError, match="collect_trace"):
        Partitioner.from_stream(s, cfg, engine="windowed",
                                collect_trace=True)
    with pytest.raises(ValueError, match="> 0"):
        Partitioner(cfg, n=0, max_deg=3)
    part = Partitioner(cfg, n=s.n, max_deg=s.max_deg)
    with pytest.raises(RuntimeError, match="collect_trace"):
        part.trace()
    with pytest.raises(TypeError, match="VertexStream"):
        part.feed(42)
    with pytest.raises(ValueError, match="shapes disagree"):
        part.feed((s.etype[:4], s.vertex[:3], s.nbrs[:4]))


def test_feed_grows_instead_of_raising():
    """The old fixed-shape feed errors (vertex id beyond the universe,
    wider neighbour rows, mismatched stream n) are gone: feed auto-grows
    the session geometry and keeps going (tests/test_geometry.py holds
    the bit-identity coverage)."""
    s, cfg = _churn_fixture()
    part = Partitioner(cfg, n=10, max_deg=2, seed=0)
    part.feed(s)                      # ids up to s.n-1, rows s.max_deg wide
    assert part.n >= s.n and part.max_deg >= s.max_deg
    assert part.regeometries >= 1
    assert part.metrics()["regeometries"] == part.regeometries
    other = gstream.VertexStream(etype=s.etype[:1], vertex=s.vertex[:1],
                                 nbrs=s.nbrs[:1], n=4 * part.n)
    part.feed(other)                  # larger declared universe grows too
    assert part.n >= 4 * s.n


def test_feed_narrow_and_padded_wide_rows():
    """Neighbour rows narrower than the session pad with -1; wider rows
    whose extra columns are all -1 trim losslessly."""
    s, cfg = _churn_fixture()
    ref, _ = run_stream(s, policy="sdp", cfg=cfg, seed=0)
    wide = np.concatenate(
        [s.nbrs, np.full((s.num_events, 3), -1, np.int32)], axis=1)
    part = Partitioner.from_stream(s, cfg, seed=0, window=32)
    part.feed((s.etype, s.vertex, wide))
    _identical(ref, part.state)

    sess = Partitioner(cfg, n=s.n, max_deg=s.max_deg + 2, seed=0)
    sess.feed(s)   # narrower stream rows pad up to the session width
    assert int(sess.state.cut_edges) == int(ref.cut_edges)
    np.testing.assert_array_equal(np.asarray(ref.assignment),
                                  np.asarray(sess.state.assignment))


def test_empty_feed_is_noop():
    s, cfg = _churn_fixture()
    part = Partitioner.from_stream(s, cfg, collect_trace=True)
    part.feed((s.etype[:0], s.vertex[:0], s.nbrs[:0]))
    assert part.cursor == 0
    assert part.trace().cut_edges.shape == (0,)
