"""Hypothesis property tests on the partitioner's invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dep: pip install -e .[test] (CI runs it)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (EngineConfig, Geometry, PartitionState, grow_state,
                        init_state, recompute_counters, run_stream,
                        state_metrics)
from repro.core.engine import run_events
from repro.core.offline import cut_of, offline_partition
from repro.graph.csr import from_edge_list
from repro.graph.generators import make_graph
from repro.graph import stream as gstream
from repro.graph.stream import normalize_rows


@st.composite
def random_graph(draw, max_n=40):
    n = draw(st.integers(5, max_n))
    m = draw(st.integers(0, 3 * n))
    edges = draw(st.lists(
        st.tuples(st.integers(0, n - 1), st.integers(0, n - 1)),
        min_size=m, max_size=m))
    return from_edge_list(np.asarray(edges, np.int64).reshape(-1, 2), n=n) \
        if edges else from_edge_list(np.zeros((0, 2), np.int64), n=n)


@st.composite
def engine_case(draw):
    g = draw(random_graph())
    k_max = draw(st.integers(2, 6))
    k_init = draw(st.integers(1, k_max))
    max_cap = draw(st.sampled_from([20, 60, 10**9]))
    policy = draw(st.sampled_from(["sdp", "greedy", "ldg", "hash"]))
    seed = draw(st.integers(0, 5))
    dynamic = draw(st.booleans())
    return g, policy, EngineConfig(
        k_max=k_max, k_init=k_init, max_cap=max_cap,
        autoscale=policy == "sdp"), seed, dynamic


@given(engine_case())
@settings(max_examples=25, deadline=None)
def test_invariants(case):
    g, policy, cfg, seed, dynamic = case
    s = (gstream.dynamic_schedule(g, n_intervals=2, seed=seed)
         if dynamic else gstream.build_stream(g, seed=seed))
    state, trace = run_stream(s, policy=policy, cfg=cfg, seed=seed)

    # 1. incremental counters == from-scratch recomputation
    rec = recompute_counters(np.asarray(state.assignment),
                             np.asarray(state.present),
                             np.asarray(state.adj), cfg.k_max)
    assert int(state.total_edges) == rec["total_edges"]
    assert int(state.cut_edges) == rec["cut_edges"]
    np.testing.assert_array_equal(np.asarray(state.edge_load),
                                  rec["edge_load"])
    np.testing.assert_array_equal(np.asarray(state.cut_matrix),
                                  rec["cut_matrix"])

    # 2. structural invariants
    m = state_metrics(state)
    assert 0.0 <= m["edge_cut_ratio"] <= 1.0
    assert 1 <= m["num_partitions"] <= cfg.k_max
    a = np.asarray(state.assignment)
    act = np.asarray(state.active)
    present = np.asarray(state.present)
    assert (a[present] >= 0).all()
    assert act[a[present]].all(), "vertex assigned to inactive partition"
    assert (a[~present] == -1).all()
    # vertex counts add up
    assert int(np.asarray(state.vertex_count).sum()) == int(present.sum())

    # 3. trace is consistent with the final state
    assert int(np.asarray(trace.cut_edges)[-1]) == int(state.cut_edges)


@st.composite
def churn_case(draw):
    g = draw(random_graph(max_n=40))
    kwargs = dict(
        warmup_frac=draw(st.floats(0.1, 0.5)),
        del_every=draw(st.integers(2, 4)),
        edge_del_every=draw(st.integers(0, 5)),
        readd_every=draw(st.integers(0, 6)),
        seed=draw(st.integers(0, 5)),
    )
    cfg = EngineConfig(
        k_max=draw(st.integers(2, 6)), k_init=1,
        max_cap=draw(st.sampled_from([20, 60, 10**9])),
        tolerance_param=draw(st.sampled_from([25.0, 60.0])),
        autoscale=True)
    return g, kwargs, cfg, draw(st.integers(0, 5))


@given(churn_case())
@settings(max_examples=15, deadline=None)
def test_cut_matrix_matches_recount_after_churn(case):
    """After random interleaved churn (vertex+edge deletions, re-adds)
    with autoscale on, the incrementally maintained pairwise cut matrix —
    including every O(K²) scale-in row/col fold — must be symmetric, have
    row sums equal to edge_load, half-sum to cut_edges, and match
    metrics.recompute_counters' from-scratch pairwise recount exactly."""
    g, kwargs, cfg, seed = case
    s = gstream.interleaved_churn(g, **kwargs)
    if s.num_events == 0:
        return
    state, _ = run_stream(s, policy="sdp", cfg=cfg, seed=seed)
    rec = recompute_counters(np.asarray(state.assignment),
                             np.asarray(state.present),
                             np.asarray(state.adj), cfg.k_max)
    cm = np.asarray(state.cut_matrix)
    np.testing.assert_array_equal(cm, cm.T)
    np.testing.assert_array_equal(cm.sum(axis=1),
                                  np.asarray(state.edge_load))
    assert (cm.sum() - np.trace(cm)) // 2 == int(state.cut_edges)
    np.testing.assert_array_equal(cm, rec["cut_matrix"])
    assert int(state.cut_edges) == rec["cut_edges"]
    assert int(state.total_edges) == rec["total_edges"]


@given(churn_case(),
       st.sampled_from([(8, 1), (32, 2), (64, 5)]),
       st.sampled_from(["sdp", "greedy", "hash"]))
@settings(max_examples=8, deadline=None)
def test_grow_state_commutes_with_events(case, extra, policy):
    """grow_state -> k events == k events -> grow_state, bit-for-bit on
    every leaf: growth is a semantics no-op, so it can land anywhere in
    the stream (which is what lets the elastic session auto-grow
    mid-feed). LDG is excluded — its capacity knob reads the live ``n``
    (repro.core.geometry documents the caveat)."""
    g, kwargs, cfg, seed = case
    s = gstream.interleaved_churn(g, **kwargs)
    if s.num_events == 0:
        return
    if policy != "sdp":
        cfg = EngineConfig(k_max=cfg.k_max, k_init=cfg.k_max,
                           max_cap=cfg.max_cap, autoscale=False)
    extra_n, extra_d = extra
    geom = Geometry(s.n + extra_n, s.max_deg + extra_d, cfg.k_max)
    small = init_state(s.n, s.max_deg, cfg.k_max, cfg.k_init, seed)
    et, vx = jnp.asarray(s.etype), jnp.asarray(s.vertex)
    a, _ = run_events(
        grow_state(small, geom), et, vx,
        jnp.asarray(normalize_rows(s.nbrs, geom.max_deg)), jnp.int32(0),
        policy=policy, cfg=cfg)
    b, _ = run_events(small, et, vx, jnp.asarray(s.nbrs), jnp.int32(0),
                      policy=policy, cfg=cfg)
    b = grow_state(b, geom)
    for fa, fb, name in zip(a, b, PartitionState._fields):
        np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb), name)


@given(churn_case(), st.sampled_from(
    ["sdp", "greedy", "ldg", "fennel", "hash", "random"]),
    st.sampled_from([8, 32, 64]))
@settings(max_examples=10, deadline=None)
def test_fused_chooser_equals_make_chooser(case, policy, window):
    """The fused Pallas window chooser must agree bit-for-bit with the
    faithful engine (whose decisions come from transition.make_chooser)
    over random interleaved churn, for every policy — gather, scoring,
    argmax tie-breaks, RNG table, touch-table apply, and the in-window
    scale hooks all at once."""
    from repro.core import run_stream_windowed
    g, kwargs, cfg, seed = case
    if policy != "sdp":
        cfg = EngineConfig(k_max=cfg.k_max, k_init=cfg.k_max,
                           max_cap=cfg.max_cap, autoscale=False)
    s = gstream.interleaved_churn(g, **kwargs)
    if s.num_events == 0:
        return
    a, _ = run_stream(s, policy=policy, cfg=cfg, seed=seed)
    b = run_stream_windowed(s, policy=policy, cfg=cfg, seed=seed,
                            window=window, use_kernel=True)
    for fa, fb, name in zip(a, b, PartitionState._fields):
        np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb), name)


@given(random_graph(max_n=30), st.integers(2, 4), st.integers(0, 3))
@settings(max_examples=15, deadline=None)
def test_offline_partitioner_invariants(g, k, seed):
    if g.n < k:
        return
    a = offline_partition(g, k, seed=seed)
    assert a.shape == (g.n,)
    assert a.min() >= 0 and a.max() < k
    sizes = np.bincount(a, minlength=k)
    # BFS-grow + FM keeps blocks within a generous 2× balance envelope
    assert sizes.max() <= max(2 * g.n / k + 1, sizes.min() + g.n // 2)
    assert 0 <= cut_of(g, a) <= g.num_edges


@given(st.integers(0, 4), st.floats(10.0, 40.0), st.floats(1.0, 10.0))
@settings(max_examples=10, deadline=None)
def test_dynamic_schedule_protocol(seed, add_pct, del_pct):
    """§5.3.1: every interval adds ~add% and deletes ~del% of |V|."""
    g = make_graph("mesh", 60, 160, seed=seed)
    s = gstream.dynamic_schedule(g, add_pct=add_pct, del_pct=del_pct,
                                 n_intervals=3, seed=seed)
    n_add = int(round(g.n * add_pct / 100))
    n_del = int(round(g.n * del_pct / 100))
    adds = int((s.etype == gstream.EVENT_ADD).sum())
    dels = int((s.etype == gstream.EVENT_DEL_VERTEX).sum())
    assert adds <= 3 * n_add
    assert dels <= 3 * n_del
    if n_add:
        assert adds >= min(3 * n_add, g.n) - 2 * n_add  # cursor exhaustion ok
    # no vertex deleted while absent
    present: set = set()
    for i in range(s.num_events):
        if s.etype[i] == gstream.EVENT_ADD:
            present.add(int(s.vertex[i]))
        elif s.etype[i] == gstream.EVENT_DEL_VERTEX:
            assert int(s.vertex[i]) in present
            present.discard(int(s.vertex[i]))
