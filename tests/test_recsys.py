"""Two-tower retrieval: loss/scoring shapes, embedding-bag path, training
signal sanity."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.models import recsys as RS


def _setup(batch=16, seed=0):
    cfg = ARCHS["two-tower-retrieval"].smoke_config
    params = RS.init_params(jax.random.PRNGKey(seed), cfg)
    b = {k: jnp.asarray(v) for k, v in RS.make_batch(cfg, batch,
                                                     seed=seed).items()}
    return cfg, params, b


def test_loss_and_metrics():
    cfg, params, batch = _setup()
    (loss, metrics), grads = jax.value_and_grad(
        RS.loss_fn, has_aux=True)(params, batch, cfg)
    assert np.isfinite(float(loss))
    assert 0.0 <= float(metrics["in_batch_acc"]) <= 1.0
    # embedding tables receive gradient
    assert float(jnp.sum(jnp.abs(grads["user_table"]))) > 0
    assert float(jnp.sum(jnp.abs(grads["item_table"]))) > 0


def test_tower_outputs_normalised():
    cfg, params, batch = _setup()
    u = RS.user_embed(params, batch, cfg)
    v = RS.item_embed(params, batch, cfg)
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(u, axis=-1)),
                               1.0, rtol=1e-4)
    assert u.shape == (16, cfg.tower_mlp[-1])
    assert v.shape == (16, cfg.tower_mlp[-1])


def test_serve_and_retrieval_shapes():
    cfg, params, batch = _setup(batch=4)
    s = RS.serve_score(params, batch, cfg)
    assert s.shape == (4,)
    cand = jax.random.normal(jax.random.PRNGKey(3),
                             (64, cfg.tower_mlp[-1]))
    scores = RS.score_candidates(params, dict(batch, cand_item_emb=cand),
                                 cfg)
    assert scores.shape == (4, 64)


def test_kernel_tower_path_matches_ref():
    """use_kernel=True (Pallas embedding_bag) == jnp path."""
    cfg, params, batch = _setup(batch=4)
    u_ref = RS.user_embed(params, batch, cfg, use_kernel=False)
    u_ker = RS.user_embed(params, batch, cfg, use_kernel=True)
    np.testing.assert_allclose(np.asarray(u_ref), np.asarray(u_ker),
                               rtol=1e-4, atol=1e-5)


def test_training_reduces_loss():
    """A few SGD steps on a fixed batch must reduce the sampled-softmax
    loss (the towers can overfit 8 pairs easily)."""
    cfg, params, batch = _setup(batch=8)

    @jax.jit
    def step(params):
        (loss, _), g = jax.value_and_grad(RS.loss_fn, has_aux=True)(
            params, batch, cfg)
        params = jax.tree.map(lambda p, gg: p - 0.5 * gg, params, g)
        return params, loss

    losses = []
    for _ in range(12):
        params, loss = step(params)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.8, losses
