"""GNN model tests: 4 assigned archs (reduced configs), equivariance,
molecule readout, minibatch sampler integration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.graph.generators import make_graph
from repro.graph.sampler import make_minibatch, subgraph_sizes
from repro.models.gnn import common as C
from repro.models.gnn import so3

GNN_ARCHS = ["meshgraphnet", "schnet", "nequip", "pna"]


def _model(arch_id):
    from repro.launch.steps import _GNN_MODELS
    return _GNN_MODELS[arch_id]


def _batch_for(arch_id, seed=0):
    g = make_graph("mesh", 80, 220, seed=seed)
    return C.graph_to_batch(g, 12, with_positions=True, seed=seed)


@pytest.mark.parametrize("arch_id", GNN_ARCHS)
def test_smoke_full_graph(arch_id):
    """Per-arch smoke: reduced config, one forward+backward, no NaNs."""
    cfg = ARCHS[arch_id].smoke_config
    mod = _model(arch_id)
    batch = _batch_for(arch_id)
    if arch_id in ("meshgraphnet", "pna"):
        params = mod.init_params(jax.random.PRNGKey(0), cfg, d_node=12)
    else:
        params = mod.init_params(jax.random.PRNGKey(0), cfg)
    (loss, _), grads = jax.value_and_grad(
        mod.loss_fn, has_aux=True)(params, batch, cfg)
    assert np.isfinite(float(loss))
    for g_ in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(g_)).all()


@pytest.mark.parametrize("arch_id", GNN_ARCHS)
def test_smoke_molecule_batch(arch_id):
    """Batched-small-graphs shape: per-graph readout loss."""
    cfg = ARCHS[arch_id].smoke_config
    mod = _model(arch_id)
    batch = C.batch_molecules(6, 10, 18, seed=1, d_feat=12)
    if arch_id in ("meshgraphnet", "pna"):
        params = mod.init_params(jax.random.PRNGKey(0), cfg, d_node=12)
    else:
        params = mod.init_params(jax.random.PRNGKey(0), cfg)
    loss, _ = mod.loss_fn(params, batch, cfg)
    assert np.isfinite(float(loss))


def test_minibatch_sampler_shapes():
    g = make_graph("social", 200, 800, seed=0)
    fanouts = (5, 3)
    batch = make_minibatch(g, 8, 16, fanouts, seed=0)
    n, e = subgraph_sizes(16, fanouts)
    assert batch["node_feat"].shape == (n, 8)
    assert batch["senders"].shape == (e,)
    assert batch["positions"].shape == (n, 3)
    valid = batch["senders"] >= 0
    assert valid.any()
    # edges point into the subgraph
    assert batch["receivers"][valid].max() < n
    assert batch["node_mask"][:16].all() and not batch["node_mask"][16:].any()


def test_padded_edges_are_noops():
    """-1-padded edges must not change any model's output."""
    cfg = ARCHS["pna"].smoke_config
    mod = _model("pna")
    batch = _batch_for("pna")
    params = mod.init_params(jax.random.PRNGKey(0), cfg, d_node=12)
    out1 = mod.apply(params, batch, cfg)
    batch2 = dict(batch)
    pad = 37
    batch2["senders"] = np.concatenate(
        [batch["senders"], -np.ones(pad, np.int32)])
    batch2["receivers"] = np.concatenate(
        [batch["receivers"], -np.ones(pad, np.int32)])
    out2 = mod.apply(params, batch2, cfg)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# SO(3) machinery + NequIP equivariance
# ---------------------------------------------------------------------------

def test_wigner_d_is_representation():
    rng = np.random.default_rng(0)
    rots = so3._rand_rotations(2, seed=1)
    for l in (1, 2):
        d1 = so3.wigner_d(l, rots[0])
        d2 = so3.wigner_d(l, rots[1])
        d12 = so3.wigner_d(l, rots[0] @ rots[1])
        np.testing.assert_allclose(d1 @ d2, d12, atol=1e-8)
        # orthogonality
        np.testing.assert_allclose(d1 @ d1.T, np.eye(d1.shape[0]),
                                   atol=1e-8)


def test_clebsch_gordan_equivariance_identity():
    """C must intertwine: D3[n,m] C[i,j,m] == D1[i,k] D2[j,l] C[k,l,n]
    (the so3.clebsch_gordan docstring identity) for random rotations."""
    for (l1, l2, l3) in so3.paths(2):
        c = so3.clebsch_gordan(l1, l2, l3)
        if np.allclose(c, 0):
            continue
        r = so3._rand_rotations(1, seed=3)[0]
        d1, d2, d3 = (so3.wigner_d(l, r) for l in (l1, l2, l3))
        lhs = np.einsum("mn,ijm->ijn", d3, c)
        rhs = np.einsum("ik,jl,kln->ijn", d1, d2, c)
        np.testing.assert_allclose(lhs, rhs, atol=1e-7)


def test_nequip_rotation_invariance():
    """Rotating all positions must leave NequIP's scalar output unchanged."""
    cfg = ARCHS["nequip"].smoke_config
    mod = _model("nequip")
    params = mod.init_params(jax.random.PRNGKey(0), cfg)
    batch = C.batch_molecules(3, 8, 14, seed=2)
    out1 = mod.apply(params, batch, cfg)
    r = so3._rand_rotations(1, seed=4)[0]
    batch2 = dict(batch)
    batch2["positions"] = batch["positions"] @ r.T
    out2 = mod.apply(params, batch2, cfg)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-4, atol=1e-4)


def test_nequip_translation_invariance():
    cfg = ARCHS["nequip"].smoke_config
    mod = _model("nequip")
    params = mod.init_params(jax.random.PRNGKey(0), cfg)
    batch = C.batch_molecules(2, 8, 14, seed=5)
    out1 = mod.apply(params, batch, cfg)
    batch2 = dict(batch)
    batch2["positions"] = batch["positions"] + np.array([1.7, -0.3, 2.2],
                                                        np.float32)
    out2 = mod.apply(params, batch2, cfg)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-4, atol=1e-4)


def test_schnet_rotation_invariance():
    cfg = ARCHS["schnet"].smoke_config
    mod = _model("schnet")
    params = mod.init_params(jax.random.PRNGKey(0), cfg)
    batch = C.batch_molecules(2, 8, 14, seed=6)
    out1 = mod.apply(params, batch, cfg)
    r = so3._rand_rotations(1, seed=7)[0]
    batch2 = dict(batch)
    batch2["positions"] = batch["positions"] @ r.T
    out2 = mod.apply(params, batch2, cfg)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-4, atol=1e-4)


def test_segment_ops_padding():
    x = jnp.ones((5, 3))
    seg = jnp.asarray([0, 0, 1, -1, -1], jnp.int32)
    out = C.segment_sum_pad(x, seg, 2)
    np.testing.assert_allclose(np.asarray(out),
                               [[2, 2, 2], [1, 1, 1]])
    mean = C.segment_mean_pad(x * 2, seg, 2)
    np.testing.assert_allclose(np.asarray(mean), [[2, 2, 2], [2, 2, 2]])
