"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.embedding_bag.embedding_bag import embedding_bag
from repro.kernels.embedding_bag.ops import bag_lookup
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ops import attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.partition_affinity.partition_affinity import (
    partition_affinity)
from repro.kernels.partition_affinity.ref import partition_affinity_ref
from repro.kernels.segment_spmm.ops import ell_aggregate
from repro.kernels.segment_spmm.ref import segment_spmm_ref
from repro.kernels.segment_spmm.segment_spmm import segment_spmm


# ---------------------------------------------------------------------------
# partition_affinity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("w,d,k", [(1, 1, 2), (7, 13, 3), (64, 32, 8),
                                   (130, 257, 16), (256, 64, 64)])
def test_partition_affinity_shapes(w, d, k):
    key = jax.random.PRNGKey(w * 1000 + d)
    labels = jax.random.randint(key, (w, d), -1, k).astype(jnp.int32)
    s1, d1 = partition_affinity(labels, k_max=k, block_w=64, block_d=64)
    s2, d2 = partition_affinity_ref(labels, k_max=k)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))


def test_partition_affinity_all_padding():
    labels = jnp.full((16, 8), -1, jnp.int32)
    s, d = partition_affinity(labels, k_max=4)
    assert int(jnp.sum(s)) == 0 and int(jnp.sum(d)) == 0


# ---------------------------------------------------------------------------
# segment_spmm (ELL aggregation)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,dmax,f,mode", [
    (8, 1, 8, "sum"), (20, 6, 40, "sum"), (20, 6, 40, "mean"),
    (33, 9, 130, "sum"), (5, 3, 256, "mean")])
def test_segment_spmm_shapes(n, dmax, f, mode):
    kx, ka = jax.random.split(jax.random.PRNGKey(n + dmax))
    x = jax.random.normal(kx, (n, f), jnp.float32)
    adj = jax.random.randint(ka, (n, dmax), -1, n).astype(jnp.int32)
    out = segment_spmm(x, adj, mode=mode, block_f=64)
    ref = segment_spmm_ref(x, adj, mode=mode)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_segment_spmm_dtypes(dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 32)).astype(dtype)
    adj = jax.random.randint(jax.random.PRNGKey(1), (16, 4), -1, 16)
    out = segment_spmm(x, adj.astype(jnp.int32))
    ref = segment_spmm_ref(x, adj.astype(jnp.int32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-2, atol=2e-2)


def test_ell_aggregate_grad():
    """custom-vjp backward == autodiff through the reference."""
    x = jax.random.normal(jax.random.PRNGKey(2), (12, 16))
    adj = jax.random.randint(jax.random.PRNGKey(3), (12, 5), -1, 12)
    adj = adj.astype(jnp.int32)

    def f_kernel(x):
        return jnp.sum(ell_aggregate(x, adj, "sum", False) ** 2)

    def f_ref(x):
        return jnp.sum(segment_spmm_ref(x, adj, mode="sum") ** 2)

    g1 = jax.grad(f_kernel)(x)
    g2 = jax.grad(f_ref)(x)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-5)


# ---------------------------------------------------------------------------
# embedding_bag
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("v,d,b,l,mode", [
    (10, 8, 4, 1, "sum"), (50, 24, 12, 5, "sum"), (50, 24, 12, 5, "mean"),
    (100, 130, 7, 9, "sum"), (30, 256, 3, 4, "mean")])
def test_embedding_bag_shapes(v, d, b, l, mode):
    kt, ki = jax.random.split(jax.random.PRNGKey(v + b))
    table = jax.random.normal(kt, (v, d), jnp.float32)
    idx = jax.random.randint(ki, (b, l), -1, v).astype(jnp.int32)
    out = embedding_bag(table, idx, mode=mode, block_d=64)
    ref = embedding_bag_ref(table, idx, mode=mode)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)


def test_bag_lookup_grad_matches_ref():
    table = jax.random.normal(jax.random.PRNGKey(4), (20, 8))
    idx = jax.random.randint(jax.random.PRNGKey(5), (6, 3), -1, 20)
    idx = idx.astype(jnp.int32)

    def f(t):
        return jnp.sum(bag_lookup(t, idx, "mean", False) ** 2)

    def f_ref(t):
        return jnp.sum(embedding_bag_ref(t, idx, mode="mean") ** 2)

    np.testing.assert_allclose(np.asarray(jax.grad(f)(table)),
                               np.asarray(jax.grad(f_ref)(table)), rtol=1e-5)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

ATTN_CASES = [
    dict(b=1, h=1, hkv=1, sq=16, sk=16, d=8),
    dict(b=2, h=4, hkv=2, sq=64, sk=64, d=16),
    dict(b=2, h=4, hkv=1, sq=33, sk=65, d=32),   # ragged → padding paths
    dict(b=1, h=8, hkv=8, sq=128, sk=128, d=64),
]


@pytest.mark.parametrize("case", ATTN_CASES)
@pytest.mark.parametrize("kw", [
    dict(causal=True),
    dict(causal=True, window=16),
    dict(causal=True, softcap=30.0),
    dict(causal=False),
])
def test_flash_attention_sweep(case, kw):
    keys = jax.random.split(jax.random.PRNGKey(case["sq"]), 3)
    q = jax.random.normal(keys[0], (case["b"], case["h"], case["sq"],
                                    case["d"]), jnp.float32)
    k = jax.random.normal(keys[1], (case["b"], case["hkv"], case["sk"],
                                    case["d"]), jnp.float32)
    v = jax.random.normal(keys[2], (case["b"], case["hkv"], case["sk"],
                                    case["d"]), jnp.float32)
    if not kw.get("causal", True) and case["sq"] != case["sk"]:
        pytest.skip("bidirectional ragged handled by mask in ref only")
    o1 = flash_attention(q, k, v, block_q=32, block_k=32, **kw)
    o2 = attention_ref(q, k, v, **kw)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_decode_offset():
    """q_offset decode semantics: 1 query attending to a long cache."""
    keys = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(keys[0], (2, 4, 1, 16))
    k = jax.random.normal(keys[1], (2, 2, 96, 16))
    v = jax.random.normal(keys[2], (2, 2, 96, 16))
    o1 = flash_attention(q, k, v, q_offset=95, block_q=1, block_k=32)
    o2 = attention_ref(q, k, v, q_offset=95)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-5, atol=2e-5)


def test_attention_wrapper_grad():
    keys = jax.random.split(jax.random.PRNGKey(10), 3)
    q = jax.random.normal(keys[0], (1, 2, 16, 8))
    k = jax.random.normal(keys[1], (1, 1, 16, 8))
    v = jax.random.normal(keys[2], (1, 1, 16, 8))

    def f(q, k, v):
        return jnp.sum(attention(q, k, v, True, 0, 0.0, 0, True))

    def f_ref(q, k, v):
        return jnp.sum(attention_ref(q, k, v))

    g1 = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)
