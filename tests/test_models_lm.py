"""LM model tests: all 5 assigned archs (reduced configs), decode
consistency, chunked attention/xent equivalence, MoE dispatch."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.moe import MoEConfig, moe_apply, moe_init

LM_ARCHS = [a for a, d in ARCHS.items() if d.family == "lm"]


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_smoke_forward_backward(arch_id):
    """Per-arch smoke: one train step on CPU, shapes + finiteness."""
    cfg = ARCHS[arch_id].smoke_config
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    logits, _ = T.forward(params, toks, cfg)
    assert logits.shape == (2, 16, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    (loss, metrics), grads = jax.value_and_grad(
        T.loss_fn, has_aux=True)(params, batch, cfg)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_prefill_decode_consistency(arch_id):
    """prefill + in-place decode == full forward at the next position."""
    cfg = ARCHS[arch_id].smoke_config
    if cfg.moe is not None:
        pytest.skip("MoE capacity depends on token count; dense-only check")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 9), 0, cfg.vocab)
    s_max = 12
    _, ck, cv = T.prefill_step(params, toks[:, :-1], cfg)
    # pad prefill cache (B, 8) into the preallocated (B, s_max) slots
    pad = s_max - ck.shape[2]
    ck = jnp.pad(ck, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    cv = jnp.pad(cv, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    logits_dec, _, _ = T.decode_step_inplace(
        params, toks[:, -1:], ck, cv, jnp.int32(toks.shape[1] - 1), cfg)
    logits_full, _ = T.forward(params, toks, cfg)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_full[:, -1]),
                               rtol=2e-4, atol=2e-4)


def test_chunked_attention_equals_direct():
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    b, s, h, d = 2, 64, 4, 16
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, 2, d))
    v = jax.random.normal(ks[2], (b, s, 2, d))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    for window, softcap in ((0, 0.0), (16, 0.0), (0, 25.0)):
        a = L.attention_traced(q, k, v, q_positions=pos, k_positions=pos,
                               window=window, softcap=softcap)
        c = L.attention_chunked(q, k, v, q_positions=pos, k_positions=pos,
                                window=window, softcap=softcap, chunk=16)
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   rtol=2e-5, atol=2e-5)


def test_chunked_xent_equals_direct():
    key = jax.random.PRNGKey(1)
    t, d, v = 64, 16, 97
    x = jax.random.normal(key, (t, d))
    head = jax.random.normal(jax.random.PRNGKey(2), (d, v))
    labels = jax.random.randint(jax.random.PRNGKey(3), (t,), 0, v)
    mask = (jnp.arange(t) % 3 != 0).astype(jnp.float32)
    direct = L.softmax_xent((x @ head)[None], labels[None],
                            label_mask=mask[None])
    chunked = L.chunked_softmax_xent(x, head, labels, label_mask=mask,
                                     chunk=16)
    np.testing.assert_allclose(float(chunked), float(direct), rtol=1e-5)
    # gradient parity
    g1 = jax.grad(lambda h: L.chunked_softmax_xent(
        x, h, labels, label_mask=mask, chunk=16))(head)
    g2 = jax.grad(lambda h: L.softmax_xent(
        (x @ h)[None], labels[None], label_mask=mask[None]))(head)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               rtol=1e-4, atol=1e-6)


def test_gemma2_window_pattern():
    cfg = ARCHS["gemma2-9b"].config
    w = cfg.windows
    assert w[0] == 4096 and w[1] == 0 and len(w) == 42
    assert (w[::2] == 4096).all() and (w[1::2] == 0).all()


def test_param_counts_match_public_sizes():
    """Total params must be in the ballpark of the public model sizes."""
    expect = {"gemma2-9b": (8.5e9, 10.5e9),
              "deepseek-coder-33b": (31e9, 35e9),
              "phi3-mini-3.8b": (3.5e9, 4.2e9),
              "llama4-scout-17b-a16e": (95e9, 112e9)}  # 109B total public
    for arch_id, (lo, hi) in expect.items():
        n = ARCHS[arch_id].config.param_count()
        assert lo <= n <= hi, f"{arch_id}: {n:.3e}"
    # active params: scout publishes ~17B active INCLUDING a shared expert
    # the assigned config omits (16e top-1 only) — so expect ~11B here
    a = ARCHS["llama4-scout-17b-a16e"].config.active_param_count()
    assert 9e9 <= a <= 20e9


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def _moe_setup(g=1, e=8, k=2, t=32, d=16):
    cfg = MoEConfig(n_experts=e, top_k=k, d_ff_expert=32,
                    dispatch_groups=g, capacity_factor=8.0)
    p = moe_init(jax.random.PRNGKey(0), d, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, t // 2, d))
    return cfg, p, x


def test_moe_group_dispatch_matches_global_at_high_capacity():
    """With capacity ≫ tokens nothing is dropped, so G=1 and G=4 agree."""
    cfg1, p, x = _moe_setup(g=1)
    cfg4 = dataclasses.replace(cfg1, dispatch_groups=4)
    y1, _, l1 = moe_apply(p, x, cfg1)
    y4, _, l4 = moe_apply(p, x, cfg4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y4),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l4))


def test_moe_capacity_drops_tokens():
    cfg, p, x = _moe_setup()
    tight = dataclasses.replace(cfg, capacity_factor=0.25)
    _, _, load_full = moe_apply(p, x, cfg)
    _, _, load_tight = moe_apply(p, x, tight)
    assert float(load_tight.sum()) < float(load_full.sum())
    t = x.shape[0] * x.shape[1]
    assert float(load_full.sum()) == t * cfg.top_k  # nothing dropped


def test_moe_balance_bias_shifts_load():
    """SDP-style balance guard: biasing against a hot expert moves load."""
    cfg, p, x = _moe_setup()
    biased = dataclasses.replace(cfg, balance_bias=50.0)
    _, _, load0 = moe_apply(p, x, cfg)
    hot = jnp.zeros(cfg.n_experts).at[int(jnp.argmax(load0))].set(1e3)
    _, _, load1 = moe_apply(p, x, biased, expert_load=hot)
    assert float(load1[int(jnp.argmax(load0))]) <= float(load0.max())


def test_moe_grad_flows():
    cfg, p, x = _moe_setup()

    def f(p):
        y, aux, _ = moe_apply(p, x, cfg)
        return jnp.sum(y ** 2) + aux

    g = jax.grad(f)(p)
    for leaf in jax.tree.leaves(g):
        assert np.isfinite(np.asarray(leaf)).all()
    assert float(jnp.sum(jnp.abs(g["router"]))) > 0
