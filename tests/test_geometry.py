"""Elastic state geometry (repro.core.geometry): tier math, grow_state
semantics-neutrality, the session auto-grow bit-identity contract (a
session started at tier-minimal geometry and grown >=2 times must end
bit-identical to one whole-stream run at the final geometry, for the
scan and windowed backends), heterogeneous-geometry sweep lanes, and
geometry-aware checkpoints (record / restore-grow / pre-geometry
inference + heal)."""
import collections

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import Partitioner, Sweep, SweepRun
from repro.checkpoint.manager import CheckpointManager
from repro.core import (
    EngineConfig, Geometry, geometry_of, grow_state, grow_tier, next_pow2,
    run_stream,
)
from repro.core.engine import run_events
from repro.core.state import PartitionState, init_state
from repro.graph.generators import make_graph
from repro.graph import stream as gstream
from repro.graph.stream import normalize_rows


def _identical(ref: PartitionState, got: PartitionState):
    for f in ("assignment", "present", "adj", "edge_load", "vertex_count",
              "active", "cut_matrix"):
        np.testing.assert_array_equal(np.asarray(getattr(ref, f)),
                                      np.asarray(getattr(got, f)), f)
    for f in ("num_partitions", "total_edges", "cut_edges",
              "denied_scaleout", "scale_events"):
        assert int(getattr(ref, f)) == int(getattr(got, f)), f


def _feed_chunked(part: Partitioner, s, chunk: int):
    t = 0
    while t < s.num_events:
        e = min(t + chunk, s.num_events)
        part.feed((s.etype[t:e], s.vertex[t:e], s.nbrs[t:e]))
        t = e
    return part


def _relabel_by_first_sight(s: gstream.VertexStream) -> gstream.VertexStream:
    """Isomorphic stream whose vertex ids are assigned in order of first
    appearance — the id universe then GROWS with the cursor (a serving
    stream whose size nobody knows), driving repeated tier growth when
    fed chunked into a tier-minimal session."""
    ids: dict[int, int] = {}

    def m(x: int) -> int:
        return ids.setdefault(int(x), len(ids))

    vx = np.empty_like(s.vertex)
    nb = np.empty_like(s.nbrs)
    for i in range(s.num_events):
        vx[i] = m(s.vertex[i]) if s.vertex[i] >= 0 else -1
        for j in range(s.nbrs.shape[1]):
            u = s.nbrs[i, j]
            nb[i, j] = m(u) if u >= 0 else -1
    return gstream.VertexStream(etype=s.etype.copy(), vertex=vx, nbrs=nb,
                                n=max(len(ids), 1), intervals=s.intervals)


def _growing_churn_fixture():
    """Delete-heavy interleaved churn (every transition type + autoscale)
    relabelled so the id universe grows with the cursor."""
    g = make_graph("social", 300, 900, seed=7)
    s = _relabel_by_first_sight(
        gstream.interleaved_churn(g, warmup_frac=0.2, del_every=3,
                                  edge_del_every=5, seed=4))
    cfg = EngineConfig(k_max=8, k_init=1, max_cap=300)
    return s, cfg


# -- tier math ---------------------------------------------------------------

def test_tier_math():
    assert next_pow2(0) == 1 and next_pow2(1) == 1
    assert next_pow2(2) == 2 and next_pow2(3) == 4
    assert next_pow2(1024) == 1024 and next_pow2(1025) == 2048
    cur = Geometry(90, 7, 8)
    # exceeded dims double at minimum: next_pow2(max(91, 2*90)) = 256
    assert grow_tier(cur, Geometry(91, 7)) == Geometry(256, 7, 8)
    assert grow_tier(cur, Geometry(64, 3)) == cur          # covered: no-op
    assert grow_tier(cur, Geometry(1000, 20)) == Geometry(1024, 32, 8)
    assert grow_tier(cur, Geometry(90, 7, 12)).k_max == 12  # k grows exactly
    assert Geometry(8, 4, 2).union(Geometry(6, 9)) == Geometry(8, 9, 2)
    assert Geometry(8, 4, 2).covers(Geometry(8, 4))
    assert not Geometry(8, 4, 2).covers(Geometry(9, 4))
    assert not Geometry(8, 4, 2).covers(Geometry(8, 4, 3))
    assert Geometry(90, 7).tiered() == Geometry(128, 8)


def test_normalize_rows_and_required_geometry():
    nb = np.array([[3, -1, -1], [5, 7, -1]], np.int32)
    widened = normalize_rows(nb, 4)
    assert widened.shape == (2, 4) and np.all(widened[:, 3] == -1)
    np.testing.assert_array_equal(normalize_rows(nb, 2), nb[:, :2])
    with pytest.raises(ValueError, match="max_deg"):
        normalize_rows(nb, 1)   # column 1 holds a real id
    s = gstream.VertexStream(
        etype=np.zeros(2, np.int32), vertex=np.array([0, 9], np.int32),
        nbrs=np.pad(nb, ((0, 0), (0, 2)), constant_values=-1), n=4)
    # n covers declared universe AND referenced ids; width is the real
    # content width (all-pad trailing columns don't count)
    assert s.required_geometry() == Geometry(10, 2)


# -- grow_state --------------------------------------------------------------

def test_grow_state_pads_inert_and_never_shrinks():
    g = make_graph("mesh", 60, 150, seed=1)
    s = gstream.build_stream(g, seed=1)
    cfg = EngineConfig(k_max=4, k_init=1, max_cap=60)
    state, _ = run_stream(s, cfg=cfg, seed=0)
    geom = Geometry(s.n + 40, s.max_deg + 3, cfg.k_max + 4)
    big = grow_state(state, geom)
    assert geometry_of(big) == geom
    np.testing.assert_array_equal(np.asarray(big.assignment)[:s.n],
                                  np.asarray(state.assignment))
    np.testing.assert_array_equal(np.asarray(big.adj)[:s.n, :s.max_deg],
                                  np.asarray(state.adj))
    np.testing.assert_array_equal(
        np.asarray(big.cut_matrix)[:cfg.k_max, :cfg.k_max],
        np.asarray(state.cut_matrix))
    assert np.all(np.asarray(big.assignment)[s.n:] == -1)
    assert not np.asarray(big.present)[s.n:].any()
    assert np.all(np.asarray(big.adj)[:, s.max_deg:] == -1)
    assert np.asarray(big.edge_load)[cfg.k_max:].sum() == 0
    assert not np.asarray(big.active)[cfg.k_max:].any()
    assert int(big.cut_edges) == int(state.cut_edges)
    assert int(big.num_partitions) == int(state.num_partitions)
    # covered geometry is the identity, shrinking is refused
    assert grow_state(state, geometry_of(state)) is state
    assert grow_state(state, Geometry(s.n, s.max_deg)) is state  # k None
    with pytest.raises(ValueError, match="shrink"):
        grow_state(state, Geometry(s.n - 1, s.max_deg, cfg.k_max))


def test_grow_then_events_commutes_with_events_then_grow():
    """grow_state -> events == events -> grow_state, bit-for-bit on every
    leaf (the deterministic twin of the hypothesis property in
    tests/test_property.py)."""
    g = make_graph("social", 90, 260, seed=2)
    s = gstream.interleaved_churn(g, warmup_frac=0.2, del_every=3,
                                  edge_del_every=5, seed=4)
    cfg = EngineConfig(k_max=8, k_init=1, max_cap=100)
    small = init_state(s.n, s.max_deg, cfg.k_max, cfg.k_init, 0)
    geom = Geometry(s.n + 37, s.max_deg + 2, cfg.k_max)
    et, vx = jnp.asarray(s.etype), jnp.asarray(s.vertex)
    a, _ = run_events(
        grow_state(small, geom), et, vx,
        jnp.asarray(normalize_rows(s.nbrs, geom.max_deg)), jnp.int32(0),
        policy="sdp", cfg=cfg)
    b, _ = run_events(small, et, vx, jnp.asarray(s.nbrs), jnp.int32(0),
                      policy="sdp", cfg=cfg)
    b = grow_state(b, geom)
    for fa, fb, name in zip(a, b, PartitionState._fields):
        np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb), name)


# -- session auto-grow (the acceptance contract) -----------------------------

@pytest.mark.parametrize("engine", ["scan", "windowed", "auto"])
def test_autogrow_bit_identical_to_presized(engine):
    """A session started at tier-minimal (1, 1) geometry and fed a churn
    stream forcing >=2 auto-grows ends bit-identical — assignment,
    every counter, cut_matrix — to one whole-stream run_stream at the
    final geometry, on every backend."""
    s, cfg = _growing_churn_fixture()
    part = Partitioner(cfg, seed=0, engine=engine, window=32)
    _feed_chunked(part, s, 41)
    assert part.cursor == s.num_events
    assert part.regeometries >= 2, "fixture must force repeated tier growth"
    assert part.geometry.covers(s.required_geometry())
    ref, _ = run_stream(s, policy="sdp", cfg=cfg, seed=0,
                        geometry=part.geometry)
    _identical(ref, part.state)


def test_unsized_session_grows_from_nothing():
    """Partitioner() with no n/max_deg at all — the serving shape for a
    stream whose size nobody knows in advance."""
    s, cfg = _growing_churn_fixture()
    part = Partitioner(cfg, seed=0, window=64).feed(s)
    ref, _ = run_stream(s, policy="sdp", cfg=cfg, seed=0,
                        geometry=part.geometry)
    _identical(ref, part.state)


def test_grow_to_presizes_exactly():
    cfg = EngineConfig(k_max=4, k_init=1)
    part = Partitioner(cfg, n=16, max_deg=2)
    part.grow_to(n=500, max_deg=11)
    assert (part.n, part.max_deg) == (500, 11)    # exact, no tier rounding
    assert part.regeometries == 1
    part.grow_to(n=100)                           # never shrinks; no-op
    assert (part.n, part.max_deg) == (500, 11)
    assert part.regeometries == 1


def test_engine_guards_row_width():
    """The engine boundary rejects rows that disagree with the state's
    allocated width, with an actionable message (instead of an opaque
    XLA scatter error deep inside the scan)."""
    cfg = EngineConfig(k_max=4, k_init=1)
    state = init_state(8, 3, cfg.k_max, cfg.k_init, 0)
    with pytest.raises(ValueError, match="max_deg"):
        run_events(state, jnp.zeros(2, jnp.int32), jnp.zeros(2, jnp.int32),
                   jnp.full((2, 5), -1, jnp.int32), jnp.int32(0),
                   policy="sdp", cfg=cfg)
    g = make_graph("mesh", 40, 100, seed=8)
    s = gstream.build_stream(g, seed=9)
    with pytest.raises(ValueError, match="requires at least"):
        run_stream(s, cfg=cfg, geometry=Geometry(10, 1))


# -- heterogeneous-geometry sweep lanes --------------------------------------

def _heterogeneous_fixture():
    gs = [make_graph("mesh", 60, 150, seed=1),
          make_graph("social", 90, 260, seed=2),
          make_graph("mesh", 140, 380, seed=3)]
    streams = [
        gstream.build_stream(gs[0], seed=1),
        gstream.dynamic_schedule(gs[1], n_intervals=3, seed=3,
                                 del_edges_per_interval=5),
        gstream.interleaved_churn(gs[2], warmup_frac=0.3, del_every=4,
                                  seed=5),
    ]
    assert len({s.n for s in streams}) == 3, "want three distinct universes"
    assert len({s.max_deg for s in streams}) > 1, "want unequal row widths"
    runs = [
        SweepRun("sdp", EngineConfig(k_max=8, k_init=1, max_cap=100), 0),
        SweepRun("greedy", EngineConfig(k_max=8, k_init=3,
                                        autoscale=False), 1),
        SweepRun("sdp", EngineConfig(k_max=8, k_init=2, max_cap=140), 2),
    ]
    union = Geometry(max(s.n for s in streams),
                     max(s.max_deg for s in streams))
    return streams, runs, union


def test_heterogeneous_sweep_lanes_scan():
    """ACCEPTANCE: three lanes of pairwise-different (n, max_deg) stack
    into ONE program; each lane — state AND trace — bit-matches
    run_stream on its own stream at the union geometry (which equals its
    own-geometry run for these policies, repro.core.geometry)."""
    streams, runs, union = _heterogeneous_fixture()
    for r, s in zip(Sweep(streams).lanes(runs).run(), streams):
        ref, trace = run_stream(s, policy=r.policy, cfg=r.cfg, seed=r.seed,
                                geometry=union)
        _identical(ref, r.state)
        assert r.trace.cut_edges.shape[0] == s.num_events
        for f in trace._fields:
            np.testing.assert_array_equal(np.asarray(getattr(trace, f)),
                                          np.asarray(getattr(r.trace, f)), f)


def test_heterogeneous_sweep_lanes_windowed():
    streams, runs, union = _heterogeneous_fixture()
    for r, s in zip(Sweep(streams).lanes(runs).windowed(64).run(), streams):
        assert r.trace is None
        ref, _ = run_stream(s, policy=r.policy, cfg=r.cfg, seed=r.seed,
                            geometry=union)
        _identical(ref, r.state)


def test_heterogeneous_sweep_lanes_sharded_forced():
    """Heterogeneous lanes THROUGH the shard_map path: .sharded(True)
    forces it even on one device, and under CI's forced-4-device matrix
    job this also exercises lane padding with unequal-geometry lanes."""
    streams, runs, union = _heterogeneous_fixture()
    for r, s in zip(Sweep(streams).lanes(runs).sharded().run(), streams):
        ref, _ = run_stream(s, policy=r.policy, cfg=r.cfg, seed=r.seed,
                            geometry=union)
        _identical(ref, r.state)


# -- geometry-aware checkpoints ----------------------------------------------

def test_snapshot_records_geometry_restore_needs_no_shapes(tmp_path):
    s, cfg = _growing_churn_fixture()
    ref, _ = run_stream(s, policy="sdp", cfg=cfg, seed=0)
    mid = s.num_events // 2
    part = Partitioner.from_stream(s, cfg, seed=0, window=32)
    part.feed((s.etype[:mid], s.vertex[:mid], s.nbrs[:mid]))
    part.snapshot(str(tmp_path))
    assert CheckpointManager(str(tmp_path), interval=1).geometry() \
        == Geometry(s.n, s.max_deg, cfg.k_max)
    sess = Partitioner.restore(str(tmp_path), cfg, window=32)  # no shapes
    assert (sess.n, sess.max_deg) == (s.n, s.max_deg)
    assert sess.cursor == mid
    sess.feed((s.etype[mid:], s.vertex[mid:], s.nbrs[mid:]))
    _identical(ref, sess.state)


def test_restore_into_larger_session_continues_bit_identically(tmp_path):
    """Snapshot at the stream geometry, restore pre-grown, finish the
    stream: identical to run_stream at the large geometry from t=0."""
    s, cfg = _growing_churn_fixture()
    big = Geometry(s.n + 64, s.max_deg + 4, cfg.k_max)
    ref, _ = run_stream(s, policy="sdp", cfg=cfg, seed=0, geometry=big)
    mid = s.num_events // 2
    part = Partitioner.from_stream(s, cfg, seed=0, window=32)
    part.feed((s.etype[:mid], s.vertex[:mid], s.nbrs[:mid]))
    part.snapshot(str(tmp_path))
    sess = Partitioner.restore(str(tmp_path), cfg, n=big.n,
                               max_deg=big.max_deg, window=32)
    assert (sess.n, sess.max_deg) == (big.n, big.max_deg)
    sess.feed((s.etype[mid:], s.vertex[mid:], s.nbrs[mid:]))
    _identical(ref, sess.state)


def test_checkpoint_geometry_without_k_max_roundtrips(tmp_path):
    """A save recording a k_max-less Geometry (k_max is Optional by
    design) must not make the checkpoint unrestorable through the
    geometry path, and shape inference must survive junk payloads."""
    from repro.checkpoint.ckpt import checkpoint_geometry, save_pytree
    state = init_state(12, 3, 4, 1, 0)
    p = str(tmp_path / "ckpt_00000000.npz")
    save_pytree(p, state, step=0, geometry=Geometry(12, 3))
    assert checkpoint_geometry(p) == Geometry(12, 3, None)
    # ... and the k_max-less metadata cannot dodge the restore-time
    # shrink guard: the payload's real k is validated after restore
    with pytest.raises(ValueError, match="cfg.k_max"):
        Partitioner.restore(str(tmp_path), EngineConfig(k_max=2, k_init=1))
    # no geometry recorded: inferred from the saved npy headers
    save_pytree(p, state, step=0)
    assert checkpoint_geometry(p) == Geometry(12, 3, 4)
    # not a partition state at all -> None, not an exception
    save_pytree(p, {"weights": np.zeros(3)}, step=0)
    assert checkpoint_geometry(p) is None


def test_pre_geometry_checkpoint_restores_into_larger_session(tmp_path):
    """SATELLITE: a checkpoint with NO geometry metadata — and no
    cut_matrix leaf either (the oldest layout) — restores via leaf-shape
    inference, heals through the fill_missing + recount path, and grows
    into a larger session that finishes the stream bit-identically."""
    s, cfg = _growing_churn_fixture()
    big = Geometry(s.n + 32, s.max_deg + 3, cfg.k_max)
    ref, _ = run_stream(s, policy="sdp", cfg=cfg, seed=0, geometry=big)
    mid = s.num_events // 2
    part = Partitioner.from_stream(s, cfg, seed=0, window=32)
    part.feed((s.etype[:mid], s.vertex[:mid], s.nbrs[:mid]))
    # same field names so key paths align by attribute; no cut_matrix
    # leaf, no geometry= passed to the manager
    Legacy = collections.namedtuple("Legacy", PartitionState._fields[:-1])
    legacy = Legacy(*tuple(part.state)[:-1])
    CheckpointManager(str(tmp_path), interval=1).maybe_save(
        mid, legacy, blocking=True)

    sess = Partitioner.restore(str(tmp_path), cfg, n=big.n,
                               max_deg=big.max_deg, window=32)
    assert sess.cursor == mid
    assert (sess.n, sess.max_deg) == (big.n, big.max_deg)
    sess.feed((s.etype[mid:], s.vertex[mid:], s.nbrs[mid:]))
    _identical(ref, sess.state)
