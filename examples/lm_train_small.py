"""End-to-end LM training: a ~100M-parameter dense transformer for a few
hundred steps through the production train driver (fault-tolerant loop,
async checkpoints, resumable data pipeline).

    PYTHONPATH=src python examples/lm_train_small.py            # quick
    PYTHONPATH=src python examples/lm_train_small.py --hundred-m --steps 200

The quick mode trains a ~15M model so the example finishes in minutes on
this 1-core CPU container; --hundred-m builds the ~100M config (same code
path, longer wall time).
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.data import pipeline as dp
from repro.models import transformer as T
from repro.optim.optimizers import (adamw, apply_updates,
                                    linear_warmup_cosine)
from repro.runtime.fault import FaultTolerantLoop


def config(hundred_m: bool) -> T.LMConfig:
    if hundred_m:   # ~103M params
        return T.LMConfig(name="lm-100m", n_layers=12, d_model=768,
                          n_heads=12, n_kv_heads=4, head_dim=64,
                          d_ff=2048, vocab=8192, dtype="float32",
                          tie_embeddings=True)
    return T.LMConfig(name="lm-15m", n_layers=6, d_model=384, n_heads=6,
                      n_kv_heads=2, head_dim=64, d_ff=1024, vocab=4096,
                      dtype="float32", tie_embeddings=True)


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=40)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--hundred-m", action="store_true")
    p.add_argument("--ckpt-dir", type=str, default="/tmp/lm_small_ckpt")
    args = p.parse_args()

    cfg = config(args.hundred_m)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    print(f"model {cfg.name}: {n/1e6:.1f}M params")

    opt = adamw(linear_warmup_cosine(3e-4, 10, args.steps))
    opt_state = opt.init(params)
    ckpt = CheckpointManager(args.ckpt_dir, interval=max(args.steps // 3, 1))
    loop = FaultTolerantLoop(ckpt)

    @jax.jit
    def step_fn(state, batch):
        params, opt_state = state
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        (loss, m), grads = jax.value_and_grad(T.loss_fn, has_aux=True)(
            params, batch, cfg)
        upd, opt_state = opt.update(grads, opt_state, params)
        return (apply_updates(params, upd), opt_state), dict(m, loss=loss)

    losses = []
    t0 = time.time()

    def stepper(state, batch):
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        if len(losses) % 10 == 1:
            print(f"step {len(losses):4d} loss {losses[-1]:.4f} "
                  f"({time.time()-t0:.0f}s)", flush=True)
        return state, metrics

    batches = dp.token_batches(cfg.vocab, args.batch, args.seq, seed=0)
    data = (next(batches) for _ in range(args.steps))
    state, final = loop.run((params, opt_state), data, stepper)
    ckpt.wait()
    print(f"done: loss {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"in {time.time()-t0:.0f}s; checkpoints in {args.ckpt_dir}")
    assert losses[-1] < losses[0], "loss must decrease on random data "\
        "(memorising the seeded stream)"


if __name__ == "__main__":
    main()
