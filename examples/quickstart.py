"""Quickstart: stream a dynamic graph through SDP and the baselines.

    PYTHONPATH=src python examples/quickstart.py

Builds the GrQc-like collaboration graph (paper Table 2), streams it
through a stateful ``Partitioner`` *session* — events are fed interval by
interval, exactly as they would arrive in serving, with metrics readable
mid-stream — and prints the paper's three metrics (edge-cut ratio Eq. 9,
load imbalance Eq. 10, execution time) for SDP vs the streaming
baselines. Feeding in chunks is bit-identical to one whole-stream run.
"""
import time

from repro.api import Partitioner
from repro.core import EngineConfig
from repro.graph.datasets import load_dataset
from repro.graph import stream as gstream


def main():
    g = load_dataset("grqc", scale=0.3)
    print(f"graph: |V|={g.n} |E|={g.num_edges} (grqc-like, Table 2)")
    s = gstream.dynamic_schedule(g, add_pct=25.0, del_pct=5.0,
                                 n_intervals=4, seed=0)
    print(f"stream: {s.num_events} events "
          f"(adds+deletes, {len(s.intervals)} intervals)\n")

    print(f"{'policy':10s} {'edge-cut':>9s} {'imbalance':>10s} "
          f"{'partitions':>10s} {'seconds':>8s}")
    for policy in ("sdp", "ldg", "fennel", "greedy", "hash", "random"):
        cfg = EngineConfig(k_max=8, k_init=1 if policy == "sdp" else 4,
                           max_cap=g.num_edges // 3,
                           autoscale=policy == "sdp")
        part = Partitioner.from_stream(s, cfg, policy=policy)
        t0 = time.perf_counter()
        prev = 0
        for mark in (*s.intervals, s.num_events):
            # events arrive interval by interval; the session keeps its
            # device-resident state and stays observable between calls
            part.feed((s.etype[prev:mark], s.vertex[prev:mark],
                       s.nbrs[prev:mark]))
            prev = mark
        dt = time.perf_counter() - t0
        m = part.metrics()
        print(f"{policy:10s} {m['edge_cut_ratio']:9.4f} "
              f"{m['load_imbalance']:10.1f} {m['num_partitions']:10d} "
              f"{dt:8.2f}")
    print("\nSDP assigns each arriving vertex to the partition holding most"
          "\nof its neighbours (Eq. 1), guarded by the communication-aware"
          "\nbalance test (Eqs. 2-4), and auto-scales partitions (Eq. 5-8).")

    # -- elastic geometry: nobody declared the stream's size -------------
    # a session built with NO n/max_deg grows its state along power-of-two
    # tiers as events reference new ids — bit-identical to a presized run
    cfg = EngineConfig(k_max=8, k_init=1, max_cap=g.num_edges // 3)
    part = Partitioner(cfg, policy="sdp")
    prev = 0
    for mark in (*s.intervals, s.num_events):
        part.feed((s.etype[prev:mark], s.vertex[prev:mark],
                   s.nbrs[prev:mark]))
        prev = mark
    print(f"\nelastic session: started at (n=1, max_deg=1), grew to "
          f"(n={part.n}, max_deg={part.max_deg}) in "
          f"{part.regeometries} regeometries; edge-cut "
          f"{part.metrics()['edge_cut_ratio']:.4f}")


if __name__ == "__main__":
    main()
