"""Long-lived session example: churn, auto-shrink, crash, recovery.

    PYTHONPATH=src python examples/long_lived_session.py

The lifecycle a months-running partition session actually lives:

1. a ring graph grows the session to its peak power-of-two tier;
2. bulk deletes empty most of it — ``auto_shrink`` hands the peak
   buffers back mid-feed (hysteresis-gated, so live traffic never
   thrashes between tiers);
3. an explicit ``compact()`` densely re-packs the survivors (relabeling
   absorbed by the session id map — callers keep speaking original ids);
4. a crash is injected mid-feed AFTER the chunk hit the event journal
   and BEFORE it executed (the worst-ordered single point a real crash
   can hit);
5. ``RecoverableSession.recover`` restores the latest snapshot, replays
   the journaled tail, and the session continues — bit-identical to a
   run that never crashed (checked at the end).

Covers docs/API.md "Shrink & compaction" + "Fault tolerance" and the
lifecycle diagram in docs/ARCHITECTURE.md.
"""
import tempfile

import numpy as np

from repro.api import Partitioner
from repro.core import EngineConfig
from repro.graph.stream import EVENT_ADD, EVENT_DEL_VERTEX
from repro.runtime.recovery import CrashError, RecoverableSession

PEAK = 1500          # vertices at the session's high-water mark
SURVIVORS = 80       # vertices left after the bulk deletes


def ring(lo, hi):
    ids = np.arange(lo, hi, dtype=np.int32)
    et = np.full(len(ids), EVENT_ADD, np.int32)
    nb = np.stack([ids - 1, ids + 1], 1).astype(np.int32)
    nb[0, 0], nb[-1, 1] = hi - 1, lo
    return et, ids, nb


def dels(lo, hi):
    ids = np.arange(lo, hi, dtype=np.int32)
    return (np.full(len(ids), EVENT_DEL_VERTEX, np.int32), ids,
            np.full((len(ids), 2), -1, np.int32))


def main():
    cfg = EngineConfig(k_max=8, k_init=4, max_cap=500)
    log = []             # every chunk fed, for the bit-identity check

    with tempfile.TemporaryDirectory() as ckpt_dir:
        part = Partitioner(cfg, seed=0, auto_shrink=True, shrink_every=256)
        sess = RecoverableSession(part, ckpt_dir, snapshot_every=512)

        def feed(chunk):
            log.append(chunk)
            sess.feed(chunk)

        # 1. grow: the session tier-doubles up to the peak
        feed(ring(0, PEAK))
        print(f"peak: n={sess.geometry.n} max_deg={sess.geometry.max_deg} "
              f"state_bytes={sess.metrics()['state_bytes']}")

        # 2. churn: bulk deletes leave SURVIVORS vertices; auto_shrink
        # notices within shrink_every events and drops the tier mid-feed
        lo = PEAK - SURVIVORS
        for start in range(0, lo, 256):
            feed(dels(start, min(start + 256, lo)))
        assert sess.geometry.n < PEAK, "auto-shrink should have fired"
        print(f"after churn + auto-shrink: n={sess.geometry.n} "
              f"state_bytes={sess.metrics()['state_bytes']} "
              f"shrinks={sess.metrics()['shrinks']}")

        # 3. explicit compact: densely re-pack what's left (relabels;
        # queries keep speaking original ids through the id map)
        sess.compact()
        log.append("compact")
        label_before = int(np.asarray(sess.state.assignment)[
            sess.to_internal([PEAK - 1])[0]])
        print(f"after compact: n={sess.geometry.n} "
              f"vertex {PEAK - 1} -> slot "
              f"{int(sess.to_internal([PEAK - 1])[0])}, "
              f"partition {label_before}")

        # 4. crash mid-feed: the chunk is journaled but never executes
        sess.inject_crash_after = sess.cursor
        try:
            feed(ring(lo, PEAK))
        except CrashError as err:
            print(f"crash: {err}")
        sess.wait()

        # 5. recover in a "fresh process": snapshot + journal replay
        sess2 = RecoverableSession.recover(
            ckpt_dir, cfg, seed=0, auto_shrink=True, shrink_every=256)
        print(f"recovered: cursor={sess2.cursor} n={sess2.geometry.n}")
        feed2 = ring(0, SURVIVORS // 2)       # life goes on after recovery
        log.append(feed2)
        sess2.feed(feed2).sync()

        # the whole lifecycle must equal one uninterrupted session
        ref = Partitioner(cfg, seed=0, auto_shrink=True, shrink_every=256)
        for item in log:
            ref.compact() if item == "compact" else ref.feed(item)
        ref.sync()
        assert int(np.asarray(ref.state.cut_edges)) == \
            int(np.asarray(sess2.state.cut_edges))
        ids = np.arange(lo, PEAK)
        np.testing.assert_array_equal(
            np.asarray(ref.state.assignment)[ref.to_internal(ids)],
            np.asarray(sess2.state.assignment)[sess2.to_internal(ids)])
        print(f"bit-identical to the uninterrupted run "
              f"(cut={int(np.asarray(sess2.state.cut_edges))}, "
              f"final n={sess2.geometry.n}); "
              f"metrics={{shrinks: {sess2.metrics()['shrinks']}, "
              f"compactions: {sess2.metrics()['compactions']}, "
              f"snapshots: {sess2.metrics()['snapshots']}}}")


if __name__ == "__main__":
    main()
