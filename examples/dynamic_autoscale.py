"""Auto-scaling demo (paper §4.2.3 / Fig. 9): machines are provisioned as
the stream grows and released after bulk deletions.

    PYTHONPATH=src python examples/dynamic_autoscale.py
"""
import numpy as np

from repro.core import EngineConfig, run_stream, trace_at
from repro.graph.datasets import load_dataset
from repro.graph import stream as gstream


def main():
    g = load_dataset("3elt", scale=1.0)
    # add 25% per interval, then delete 10% — forces scale-out then -in
    s = gstream.dynamic_schedule(g, add_pct=25.0, del_pct=10.0,
                                 n_intervals=4, seed=0)
    cap = int(1.5 * g.num_edges / 5)      # capacity ⇒ ~5 machines at peak
    cfg = EngineConfig(k_max=16, k_init=1, max_cap=cap,
                       tolerance_param=35.0, dest_param=5.0)
    state, trace = run_stream(s, policy="sdp", cfg=cfg)

    parts = np.asarray(trace.num_partitions)
    cut = np.asarray(trace.cut_edges)
    tot = np.maximum(np.asarray(trace.total_edges), 1)
    print("event     machines  edge-cut-ratio")
    marks = np.linspace(1, s.num_events - 1, 16).astype(int)
    for t in marks:
        bar = "#" * int(parts[t])
        print(f"{t:8d}  {parts[t]:2d} {bar:16s} {cut[t]/tot[t]:.4f}")
    print(f"\nscale events: {int(state.scale_events)}, "
          f"final machines: {int(state.num_partitions)}, "
          f"peak: {int(parts.max())}")
    at = trace_at(trace, s.intervals)
    print("interval edge-cut:",
          " -> ".join(f"{r:.3f}" for r in at["edge_cut_ratio"]))


if __name__ == "__main__":
    main()
