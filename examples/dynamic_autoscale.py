"""Auto-scaling demo (paper §4.2.3 / Fig. 9): machines are provisioned as
the stream grows and released after bulk deletions — observed live
through a stateful ``Partitioner`` session, then checkpointed and
resumed mid-stream without changing a single decision.

    PYTHONPATH=src python examples/dynamic_autoscale.py
"""
import os
import tempfile

import numpy as np

from repro.api import Partitioner
from repro.core import EngineConfig, trace_at


def main():
    from repro.graph.datasets import load_dataset
    from repro.graph import stream as gstream

    g = load_dataset("3elt", scale=1.0)
    # add 25% per interval, then delete 10% — forces scale-out then -in
    s = gstream.dynamic_schedule(g, add_pct=25.0, del_pct=10.0,
                                 n_intervals=4, seed=0)
    cap = int(1.5 * g.num_edges / 5)      # capacity ⇒ ~5 machines at peak
    cfg = EngineConfig(k_max=16, k_init=1, max_cap=cap,
                       tolerance_param=35.0, dest_param=5.0)

    # feed the first half, snapshot, resume in a NEW session, feed the
    # rest — bit-identical to an uninterrupted run (tested in CI)
    part = Partitioner.from_stream(s, cfg, policy="sdp", collect_trace=True)
    mid = s.num_events // 2
    part.feed((s.etype[:mid], s.vertex[:mid], s.nbrs[:mid]))
    ckpt_dir = os.path.join(tempfile.mkdtemp(), "session")
    part.snapshot(ckpt_dir)
    print(f"mid-stream:  {part.metrics()['num_partitions']} machines after "
          f"{part.cursor} events (snapshot -> {ckpt_dir})")
    first_half = part.trace()

    # no shapes needed: the checkpoint records its geometry in metadata
    part = Partitioner.restore(ckpt_dir, cfg, policy="sdp",
                               collect_trace=True)
    part.feed((s.etype[mid:], s.vertex[mid:], s.nbrs[mid:]))
    tr = part.trace()   # post-restore events (traces are not checkpointed)
    state = part.state

    parts = np.concatenate([np.asarray(first_half.num_partitions),
                            np.asarray(tr.num_partitions)])
    cut = np.concatenate([np.asarray(first_half.cut_edges),
                          np.asarray(tr.cut_edges)])
    tot = np.maximum(np.concatenate([np.asarray(first_half.total_edges),
                                     np.asarray(tr.total_edges)]), 1)
    print("event     machines  edge-cut-ratio")
    marks = np.linspace(1, s.num_events - 1, 16).astype(int)
    for t in marks:
        bar = "#" * int(parts[t])
        print(f"{t:8d}  {parts[t]:2d} {bar:16s} {cut[t]/tot[t]:.4f}")
    print(f"\nscale events: {int(state.scale_events)}, "
          f"final machines: {int(state.num_partitions)}, "
          f"peak: {int(parts.max())}")
    from repro.core import EventTrace
    full = EventTrace(
        total_edges=tot, cut_edges=cut, num_partitions=parts,
        load_std=np.concatenate([np.asarray(first_half.load_std),
                                 np.asarray(tr.load_std)]))
    at = trace_at(full, s.intervals)
    print("interval edge-cut:",
          " -> ".join(f"{r:.3f}" for r in at["edge_cut_ratio"]))


if __name__ == "__main__":
    main()
