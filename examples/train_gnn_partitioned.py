"""End-to-end driver: SDP-partitioned distributed GNN training.

    PYTHONPATH=src python examples/train_gnn_partitioned.py --steps 30

This is the paper's technique working as a first-class framework feature:
  1. a graph arrives as a stream → SDP partitions it online (Alg. 1);
  2. the partition becomes the device layout: nodes are blocked per shard
     (repro.graph.halo), and every message-passing layer exchanges ONLY the
     published boundary rows (halo exchange under shard_map) — the
     collective volume is the edge-cut SDP minimised;
  3. a PNA-style GNN trains data-distributed over N host devices, with the
     hash-partition layout run side-by-side to show the communication win.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import argparse          # noqa: E402
import time              # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np       # noqa: E402

from repro.api import Partitioner                               # noqa: E402
from repro.core import EngineConfig                             # noqa: E402
from repro.graph.generators import make_graph                   # noqa: E402
from repro.graph.halo import build_halo_spec, scatter_nodes     # noqa: E402
from repro.graph import stream as gstream                       # noqa: E402
from repro.models import layers as L                            # noqa: E402
from repro.optim.optimizers import adamw, apply_updates         # noqa: E402
from repro.runtime.gnn_sharded import make_sharded_aggregate    # noqa: E402


def build_layout(g, policy, n_shards):
    s = gstream.build_stream(g, seed=0)
    cfg = EngineConfig(k_max=n_shards, k_init=n_shards, autoscale=False)
    part = Partitioner.from_stream(s, cfg, policy=policy).feed(s)
    m = part.metrics()
    assign = np.array(part.state.assignment)
    assign[assign < 0] = 0
    spec = build_halo_spec(g, assign, n_shards)
    return spec, m


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=30)
    p.add_argument("--nodes", type=int, default=600)
    p.add_argument("--hidden", type=int, default=32)
    args = p.parse_args()

    from repro.launch.mesh import make_mesh_compat
    n_dev = len(jax.devices())
    mesh = make_mesh_compat((n_dev,), ("data",))
    g = make_graph("mesh", args.nodes, 3 * args.nodes, seed=0)
    rng = np.random.default_rng(0)
    feats = rng.standard_normal((g.n, args.hidden)).astype(np.float32)
    targets = rng.standard_normal((g.n, 1)).astype(np.float32)

    for policy in ("sdp", "hash"):
        spec, m = build_layout(g, policy, n_dev)
        agg = make_sharded_aggregate(mesh, spec)
        xb = jnp.asarray(scatter_nodes(spec, feats))      # (P, Nb, F)
        yb = jnp.asarray(scatter_nodes(spec, targets))
        maskb = jnp.asarray(scatter_nodes(
            spec, np.ones((g.n, 1), np.float32)))
        halo_args = tuple(jnp.asarray(a) for a in
                          (spec.publish_idx, spec.halo_map, spec.senders,
                           spec.receivers))

        key = jax.random.PRNGKey(0)
        params = {
            "w1": L.dense_init(key, args.hidden, args.hidden)["w"],
            "w2": L.dense_init(jax.random.fold_in(key, 1),
                               args.hidden, 1)["w"],
        }
        opt = adamw(3e-3, weight_decay=0.0)
        opt_state = opt.init(params)

        def loss_fn(params, xb):
            h = jnp.tanh(xb @ params["w1"])
            aggd = agg(h, *halo_args)                     # halo exchange
            pred = aggd @ params["w2"]
            return jnp.sum(((pred - yb) ** 2) * maskb) / jnp.sum(maskb)

        @jax.jit
        def step(params, opt_state):
            loss, grads = jax.value_and_grad(loss_fn)(params, xb)
            upd, opt_state = opt.update(grads, opt_state, params)
            return apply_updates(params, upd), opt_state, loss

        losses = []
        t0 = time.perf_counter()
        for _ in range(args.steps):
            params, opt_state, loss = step(params, opt_state)
            losses.append(float(loss))
        dt = time.perf_counter() - t0

        vol = spec.collective_bytes_per_layer(args.hidden)
        print(f"[{policy:4s}] edge-cut={m['edge_cut_ratio']:.4f} "
              f"halo-bytes/layer={vol/1e3:.1f}KB "
              f"loss {losses[0]:.4f} -> {losses[-1]:.4f} "
              f"({args.steps} steps, {dt:.1f}s, {n_dev} devices)")
    print("\nSDP's lower edge-cut translates 1:1 into lower halo-exchange"
          "\nvolume — the distributed-training win the paper's partitioner"
          "\nbuys (see EXPERIMENTS.md §Perf for the ogb_products version).")


if __name__ == "__main__":
    main()
