"""Serving example: a live partition service over a churning graph.

    PYTHONPATH=src python examples/serve_partition.py

A ``Partitioner`` session wrapped in ``PartitionService``: event chunks
arrive on a Poisson process and are submitted (cheap enqueues) while the
double-buffered ingest thread coalesces and dispatches them; mid-stream
the example answers routing queries (``where`` / ``route``) without
stalling ingest, then flushes and checks the final state is bit-identical
to a synchronous whole-stream feed of the same events.

Covers the serving lifecycle documented in docs/SERVING.md: start →
submit under backpressure → query → flush → metrics → close.
"""
import time

import numpy as np

from repro.api import Partitioner, PartitionService
from repro.core import EngineConfig
from repro.graph.datasets import load_dataset
from repro.graph.stream import interleaved_churn, poisson_arrivals


def main():
    g = load_dataset("3elt", scale=0.25)
    s = interleaved_churn(g, warmup_frac=0.25, del_every=3,
                          edge_del_every=7, seed=0)
    cfg = EngineConfig(k_max=16, k_init=1, autoscale=True,
                       max_cap=max(s.num_events // 6, 30))

    # reference: the same events fed synchronously in one call
    ref = Partitioner.from_stream(s, cfg, seed=0, engine="windowed",
                                  window=128).feed(s).sync().state

    part = Partitioner.from_stream(s, cfg, seed=0, engine="windowed",
                                   window=128)
    bounds, due = poisson_arrivals(s, rate=4000.0, mean_batch=24.0, seed=1)
    chunks = [(s.etype[a:b], s.vertex[a:b], s.nbrs[a:b])
              for a, b in zip(bounds[:-1], bounds[1:])]

    with PartitionService(part, max_pending_chunks=32,
                          policy="block") as svc:
        t0 = time.perf_counter()
        mid = len(chunks) // 2
        for i, chunk in enumerate(chunks):
            ahead = due[i] - (time.perf_counter() - t0)
            if ahead > 0:
                time.sleep(ahead)
            svc.submit(chunk, arrival=t0 + due[i])
            if i == mid:
                # mid-stream queries: consistent snapshot, ingest keeps
                # running — no flush needed unless you require
                # read-your-submits
                labels = svc.where_many([0, 1, 2, 3])
                r = svc.route(np.array([[0, 1], [2, 3]]))
                print(f"mid-stream:   where_many([0..3]) = {labels}, "
                      f"cut edges = {int(r.cut.sum())}/2")
        svc.flush()
        m = svc.metrics()
        print(f"served:       {m['chunks_ingested']} chunks "
              f"({m['events_ingested']} events) in "
              f"{m['batches_dispatched']} coalesced batches")
        print(f"latency:      p50 {m['feed_p50_ms']:.1f} ms, "
              f"p99 {m['feed_p99_ms']:.1f} ms at "
              f"{m['events_per_s']:.0f} events/s")
        print(f"backpressure: policy={m['backpressure_policy']}, "
              f"max queue depth {m['max_queue_depth']}/"
              f"{m['max_pending_chunks']}, "
              f"submit blocked {m['submit_blocked_s']*1e3:.1f} ms")

        final = svc.partitioner.state
        same = all(np.array_equal(np.asarray(a), np.asarray(b))
                   for a, b in zip(ref, final))
        print(f"bit-identity: service state == synchronous feed: {same}")
        assert same, "service must reproduce the synchronous feed exactly"


if __name__ == "__main__":
    main()
