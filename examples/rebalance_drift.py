"""Online rebalancing example: repair a drifting partition mid-stream.

    PYTHONPATH=src python examples/rebalance_drift.py

SDP assigns each vertex once, so an adversarial arrival order rots the
cut: this script streams a hub-arrival schedule (low-degree warmup, then
every hub at once) into two sessions — one plain, one with
``auto_rebalance`` firing a greedy-migration + LPA pass between feed
windows — and prints the Eq. 9 cut ratio and Eq. 10 imbalance of both,
plus the ``rebalance_events`` lifecycle trace. Ends with the recount
check the subsystem is gated on: the incrementally maintained counters
equal a from-scratch recount after every pass.

Covers docs/API.md "Rebalancing" and the fig16 quality benchmark
(benchmarks/fig16_quality.py) in miniature.
"""
import numpy as np

from repro.api import Partitioner
from repro.core import EngineConfig, recompute_counters
from repro.core.metrics import normalized_load_imbalance
from repro.graph.generators import make_graph
from repro.graph.stream import hub_arrivals


def run(auto: bool):
    g = make_graph("social", 600, 2400, seed=7)
    s = hub_arrivals(g, hub_frac=0.03, del_frac=0.1, seed=7)
    cfg = EngineConfig(k_max=8, k_init=4, autoscale=False)
    kw = dict(auto_rebalance=True, rebalance_every=128,
              rebalance_m=48, rebalance_passes=2) if auto else {}
    part = Partitioner.from_stream(s, cfg, policy="sdp", seed=0, **kw)
    t, T = 0, s.num_events
    while t < T:                       # feed in windows; the cadence
        e = min(t + 64, T)             # check runs between them
        part.feed((s.etype[t:e], s.vertex[t:e], s.nbrs[t:e]))
        t = e
    part.sync()
    if auto:
        part.rebalance()               # one final repair pass
    return part


def main():
    plain = run(auto=False)
    reb = run(auto=True)

    for name, part in (("plain sdp", plain), ("sdp+rebalance", reb)):
        m = part.metrics()
        imb = normalized_load_imbalance(np.asarray(part.state.edge_load),
                                        np.asarray(part.state.active))
        print(f"{name:14s} cut_ratio={m['edge_cut_ratio']:.4f} "
              f"imbalance={imb:.3f} rebalances={m['rebalances']} "
              f"moves={m['rebalance_moves']}")

    print("rebalance_events:")
    for ev in reb.rebalance_events:
        print(f"  cursor={ev['cursor']:4d} cut {ev['cut_before']:4d} -> "
              f"{ev['cut_after']:4d}  moved={ev['moved']}")

    # the gate the whole subsystem rides on: incremental counters ==
    # from-scratch recount after every rebalance
    st = reb.state
    rec = recompute_counters(np.asarray(st.assignment),
                             np.asarray(st.present),
                             np.asarray(st.adj), reb.cfg.k_max)
    assert int(st.cut_edges) == rec["cut_edges"]
    np.testing.assert_array_equal(np.asarray(st.cut_matrix),
                                  rec["cut_matrix"])
    assert int(reb.state.cut_edges) <= int(plain.state.cut_edges), \
        "rebalance should not end worse on this schedule"
    print("recount exact; rebalanced cut <= plain cut")


if __name__ == "__main__":
    main()
