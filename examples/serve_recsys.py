"""Serving example: two-tower retrieval scoring with batched requests.

    PYTHONPATH=src python examples/serve_recsys.py

Covers the three serving shapes of the assignment: online p99 batches,
bulk offline scoring, and 1-query-vs-many-candidates retrieval.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import recsys as RS


def main():
    arch = get_arch("two-tower-retrieval")
    cfg = arch.smoke_config
    params = RS.init_params(jax.random.PRNGKey(0), cfg)

    serve = jax.jit(lambda p, b: RS.serve_score(p, b, cfg))
    retrieve = jax.jit(lambda p, b: RS.score_candidates(p, b, cfg))

    # online scoring (serve_p99 shape, reduced)
    b1 = {k: jnp.asarray(v) for k, v in RS.make_batch(cfg, 64).items()
          if k != "log_q"}
    serve(params, b1).block_until_ready()      # warm
    t0 = time.perf_counter()
    for i in range(20):
        serve(params, b1).block_until_ready()
    dt = (time.perf_counter() - t0) / 20
    print(f"online scoring: batch=64, {dt*1e3:.2f} ms/batch "
          f"({64/dt:.0f} pairs/s)")

    # bulk offline scoring
    b2 = {k: jnp.asarray(v) for k, v in RS.make_batch(cfg, 4096).items()
          if k != "log_q"}
    t0 = time.perf_counter()
    serve(params, b2).block_until_ready()
    print(f"bulk scoring:   batch=4096, {time.perf_counter()-t0:.2f} s")

    # retrieval: 1 query × candidate corpus
    corpus = jax.random.normal(jax.random.PRNGKey(1),
                               (16384, cfg.tower_mlp[-1]))
    corpus = corpus / jnp.linalg.norm(corpus, axis=-1, keepdims=True)
    q = {k: jnp.asarray(v[:1]) for k, v in RS.make_batch(cfg, 1).items()
         if k != "log_q"}
    q["cand_item_emb"] = corpus
    t0 = time.perf_counter()
    scores = retrieve(params, q).block_until_ready()
    top = jnp.argsort(scores[0])[-5:][::-1]
    print(f"retrieval:      1 query x {corpus.shape[0]} candidates, "
          f"{(time.perf_counter()-t0)*1e3:.1f} ms; top-5 ids {np.asarray(top)}")


if __name__ == "__main__":
    main()
