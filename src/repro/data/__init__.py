from repro.data.pipeline import (
    token_batches, lm_batch, graph_full_batch, graph_minibatches,
    recsys_batches,
)

__all__ = ["token_batches", "lm_batch", "graph_full_batch",
           "graph_minibatches", "recsys_batches"]
