"""Deterministic synthetic data pipelines (offline container: no corpora).

Every generator is seeded and stateless-resumable: batch t is a pure
function of (seed, t), so a restart from checkpoint step t replays the
exact stream — a requirement for the fault-tolerance tests.
"""
from __future__ import annotations

from typing import Iterator

import numpy as np

from repro.graph.csr import Graph
from repro.graph.sampler import make_minibatch
from repro.models.gnn.common import graph_to_batch
from repro.models.recsys import TwoTowerConfig, make_batch


def lm_batch(vocab: int, batch: int, seq: int, *, seed: int, step: int) -> dict:
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    toks = rng.integers(0, vocab, (batch, seq + 1), dtype=np.int64)
    return {"tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32)}


def token_batches(vocab: int, batch: int, seq: int, *, seed: int = 0,
                  start_step: int = 0) -> Iterator[dict]:
    step = start_step
    while True:
        yield lm_batch(vocab, batch, seq, seed=seed, step=step)
        step += 1


def graph_full_batch(g: Graph, d_feat: int, *, with_positions=False,
                     out_dim=1, seed: int = 0) -> dict:
    return graph_to_batch(g, d_feat, seed=seed,
                          with_positions=with_positions, out_dim=out_dim)


def graph_minibatches(g: Graph, d_feat: int, batch_nodes: int,
                      fanouts: tuple[int, ...], *, seed: int = 0,
                      start_step: int = 0) -> Iterator[dict]:
    step = start_step
    while True:
        yield make_minibatch(g, d_feat, batch_nodes, fanouts,
                             seed=seed + step)
        step += 1


def recsys_batches(cfg: TwoTowerConfig, batch: int, *, seed: int = 0,
                   start_step: int = 0) -> Iterator[dict]:
    step = start_step
    while True:
        yield make_batch(cfg, batch, seed=seed + step)
        step += 1
