"""Pure-Python/numpy oracle for the streaming engine.

An independent, dict/set-based reimplementation of Algorithm 1 — the same
shape as the paper's Java artifact — used by the property tests to verify
the JAX engines bit-for-bit. Random draws use the identical
``fold_in(base_key, event_index)`` scheme so ties and random placements
match exactly.
"""
from __future__ import annotations

import numpy as np
import jax

from repro.core.config import EngineConfig
from repro.graph.stream import (
    EVENT_ADD, EVENT_DEL_EDGE, EVENT_DEL_VERTEX, VertexStream,
)


class RefState:
    def __init__(self, n: int, k_max: int, k_init: int, seed: int):
        self.n = n
        self.k_max = k_max
        self.assignment = {}          # vertex -> partition
        self.adj: dict[int, set] = {}  # vertex -> neighbour set (given at add)
        self.active = [i < k_init for i in range(k_max)]
        self.edge_load = [0] * k_max
        self.vertex_count = [0] * k_max
        self.total_edges = 0
        self.cut_edges = 0
        self.denied = 0
        self.scale_events = 0
        # pairwise cut counts, same convention as PartitionState.cut_matrix:
        # [p][q] (p != q) = present edges between p and q, diagonal = 2×
        # internal edges. Maintained incrementally; _scale_in still derives
        # cut_edges from a from-scratch recount, so the engines' matrix-based
        # merged cut is verified against an independent computation.
        self.cut_matrix = np.zeros((k_max, k_max), np.int64)
        self.base_key = jax.random.PRNGKey(seed)

    @property
    def num_partitions(self):
        return sum(self.active)

    def loads_active(self):
        return [(l, k) for k, (l, a) in enumerate(zip(self.edge_load, self.active)) if a]


def _load_stats(s: RefState):
    loads = [l for l, _ in s.loads_active()]
    p = max(len(loads), 1)
    avg_d = (max(loads) - min(loads)) / p if loads else 0.0
    mean = sum(loads) / p
    var = sum((l - mean) ** 2 for l in loads) / p
    return avg_d, float(np.sqrt(var))


def _scores(s: RefState, nbrs) -> tuple[list, int]:
    sc = [0] * s.k_max
    deg = 0
    for u in nbrs:
        if u in s.assignment:
            sc[s.assignment[u]] += 1
            deg += 1
    return sc, deg


def _argmin_load(s: RefState, mask=None):
    best, bk = None, None
    for k in range(s.k_max):
        ok = s.active[k] if mask is None else mask[k]
        if ok and (best is None or s.edge_load[k] < best):
            best, bk = s.edge_load[k], k
    return bk


def _nth_active(s: RefState, i: int) -> int:
    c = -1
    for k in range(s.k_max):
        if s.active[k]:
            c += 1
            if c == i:
                return k
    raise AssertionError("no active partition")


def _affinity(s: RefState, sc, key) -> int:
    best = max(sc[k] if s.active[k] else -1 for k in range(s.k_max))
    if best > 0:
        tied = [s.active[k] and sc[k] == best for k in range(s.k_max)]
        return _argmin_load(s, tied)
    i = int(jax.random.randint(key, (), 0, max(s.num_partitions, 1)))
    return _nth_active(s, i)


def _choose(s: RefState, policy: str, cfg: EngineConfig, sc, deg, v, key) -> int:
    if policy in ("greedy",):
        return _affinity(s, sc, key)
    if policy == "sdp":
        avg_d, load_dev = _load_stats(s)
        w_dev = (s.total_edges / max(s.cut_edges, 1)) * load_dev
        th = w_dev - load_dev
        if cfg.balance_guard == "text":
            guard = s.num_partitions > 1 and avg_d > th
            return _argmin_load(s) if guard else _affinity(s, sc, key)
        guard = s.num_partitions > 1 and load_dev > th
        return _affinity(s, sc, key) if guard else _argmin_load(s)
    if policy == "ldg":
        k_act = max(s.num_partitions, 1)
        cap = cfg.ldg_slack * s.n / k_act
        h = [sc[k] * max(1.0 - s.vertex_count[k] / cap, 0.0) if s.active[k] else -np.inf
             for k in range(s.k_max)]
        best = max(h)
        tied = [s.active[k] and h[k] >= best - 1e-6 for k in range(s.k_max)]
        cand = [(s.vertex_count[k], k) for k in range(s.k_max) if tied[k]]
        return min(cand)[1]
    if policy == "fennel":
        g = cfg.fennel_gamma
        m = s.total_edges + deg
        nt = max(sum(s.vertex_count), 1)
        k_act = max(s.num_partitions, 1)
        alpha = cfg.fennel_alpha_scale * np.sqrt(k_act) * m / nt**1.5
        h = [sc[k] - alpha * g * s.vertex_count[k] ** (g - 1.0) if s.active[k] else -np.inf
             for k in range(s.k_max)]
        best = max(h)
        tied = [s.active[k] and h[k] >= best - 1e-6 for k in range(s.k_max)]
        cand = [(s.vertex_count[k], k) for k in range(s.k_max) if tied[k]]
        return min(cand)[1]
    if policy == "hash":
        return _nth_active(s, int(v) % max(s.num_partitions, 1))
    if policy == "random":
        i = int(jax.random.randint(key, (), 0, max(s.num_partitions, 1)))
        return _nth_active(s, i)
    raise ValueError(policy)


def _scale_out(s: RefState, cfg: EngineConfig):
    p = max(s.num_partitions, 1)
    if cfg.max_cap <= s.total_edges / p:
        if all(s.active):
            s.denied += 1
        else:
            s.active[s.active.index(False)] = True
            s.scale_events += 1


def _recompute_cut(s: RefState) -> int:
    cut = 0
    for v, nbrs in s.adj.items():
        if v not in s.assignment:
            continue
        for u in nbrs:
            if u in s.assignment and s.assignment[u] != s.assignment[v]:
                cut += 1
    return cut // 2


def _scale_in(s: RefState, cfg: EngineConfig):
    l = cfg.tolerance_param * cfg.max_cap / 100.0
    dest_threshold = cfg.max_cap - cfg.dest_param * cfg.max_cap / 100.0
    under = sum(1 for load, _ in s.loads_active() if load < l)
    if s.num_partitions <= 1 or under < 2:
        return
    src = _argmin_load(s)
    mask = list(s.active)
    mask[src] = False
    dst = _argmin_load(s, mask)
    if s.edge_load[src] + s.edge_load[dst] > dest_threshold:
        return
    for v, p in list(s.assignment.items()):
        if p == src:
            s.assignment[v] = dst
    s.edge_load[dst] += s.edge_load[src]
    s.edge_load[src] = 0
    s.vertex_count[dst] += s.vertex_count[src]
    s.vertex_count[src] = 0
    s.active[src] = False
    s.scale_events += 1
    s.cut_edges = _recompute_cut(s)  # independent of the pairwise matrix
    cm = s.cut_matrix
    row = cm[src, :].copy()
    cm[dst, :] += row
    cm[:, dst] += row
    cm[dst, dst] += cm[src, src]
    cm[src, :] = 0
    cm[:, src] = 0


def run_reference(
    stream: VertexStream, *, policy: str = "sdp",
    cfg: EngineConfig | None = None, seed: int = 0,
) -> RefState:
    cfg = cfg or EngineConfig()
    s = RefState(stream.n, cfg.k_max, cfg.k_init, seed)
    for i in range(stream.num_events):
        et = int(stream.etype[i])
        v = int(stream.vertex[i])
        key = jax.random.fold_in(s.base_key, i)
        if et == EVENT_ADD:
            if policy == "sdp" and cfg.autoscale:
                _scale_out(s, cfg)
            nbrs = [int(u) for u in stream.nbrs[i] if u >= 0]
            sc, deg = _scores(s, nbrs)
            p = _choose(s, policy, cfg, sc, deg, v, key)
            if v not in s.assignment:
                s.assignment[v] = p
                s.adj[v] = set(nbrs)
                s.vertex_count[p] += 1
                for k in range(s.k_max):
                    s.edge_load[k] += sc[k]
                s.edge_load[p] += deg
                s.total_edges += deg
                s.cut_edges += deg - sc[p]
                s.cut_matrix[p, :] += np.asarray(sc)
                s.cut_matrix[:, p] += np.asarray(sc)
        elif et == EVENT_DEL_VERTEX:
            if v in s.assignment:
                nbrs = s.adj.get(v, set())
                sc, deg = _scores(s, nbrs)
                p = s.assignment[v]
                for k in range(s.k_max):
                    s.edge_load[k] -= sc[k]
                s.edge_load[p] -= deg
                s.vertex_count[p] -= 1
                s.total_edges -= deg
                s.cut_edges -= deg - sc[p]
                s.cut_matrix[p, :] -= np.asarray(sc)
                s.cut_matrix[:, p] -= np.asarray(sc)
                del s.assignment[v]
            if policy == "sdp" and cfg.autoscale:
                _scale_in(s, cfg)
        elif et == EVENT_DEL_EDGE:
            u = int(stream.nbrs[i][0])
            exists = (v in s.assignment and u in s.assignment
                      and u in s.adj.get(v, set()))
            if exists:
                pv, pu = s.assignment[v], s.assignment[u]
                s.edge_load[pv] -= 1
                s.edge_load[pu] -= 1
                s.total_edges -= 1
                s.cut_edges -= int(pv != pu)
                s.cut_matrix[pv, pu] -= 1
                s.cut_matrix[pu, pv] -= 1
            if u >= 0:
                s.adj.get(v, set()).discard(u)
                s.adj.get(u, set()).discard(v)
    return s
