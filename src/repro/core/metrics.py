"""Paper §5.2 performance metrics (Eqs. 9–10) computed from first principles.

These recompute from (assignment, present, adjacency) rather than trusting
the engine's incremental counters — the property tests assert both agree.
"""
from __future__ import annotations

import numpy as np


def recompute_counters(
    assignment: np.ndarray, present: np.ndarray, adj: np.ndarray, k_max: int
) -> dict[str, np.ndarray]:
    """Exact (edge_load, vertex_count, total_edges, cut_edges, cut_matrix)
    from scratch.

    ``cut_matrix`` is the (k_max, k_max) pairwise count the engines maintain
    incrementally (PartitionState.cut_matrix): entry [p, q] (p != q) counts
    present edges between partitions p and q once per direction, and the
    diagonal [p, p] counts each internal edge of p twice — so rows sum to
    ``edge_load`` and the off-diagonal half-sum is ``cut_edges``.
    """
    assignment = np.asarray(assignment)
    present = np.asarray(present)
    adj = np.asarray(adj)
    n, _ = adj.shape
    valid = adj >= 0
    safe = np.where(valid, adj, 0)
    nb_present = valid & present[safe] & present[:, None]
    deg = nb_present.sum(axis=1)
    vertex_count = np.bincount(
        assignment[present & (assignment >= 0)], minlength=k_max
    )[:k_max]
    edge_load = np.zeros(k_max, dtype=np.int64)
    own = np.broadcast_to(assignment[:, None], adj.shape)
    np.add.at(edge_load, own[nb_present], 1)
    cut_matrix = np.zeros((k_max, k_max), dtype=np.int64)
    np.add.at(cut_matrix, (own[nb_present], assignment[safe][nb_present]), 1)
    total = int(deg.sum()) // 2
    diff = nb_present & (assignment[:, None] != assignment[safe])
    cut = int(diff.sum()) // 2
    return {
        "edge_load": edge_load,
        "vertex_count": vertex_count.astype(np.int64),
        "total_edges": total,
        "cut_edges": cut,
        "cut_matrix": cut_matrix,
    }


def edge_cut_ratio(cut_edges: int, total_edges: int) -> float:
    """Eq. 9."""
    return cut_edges / max(total_edges, 1)


def load_imbalance(edge_load: np.ndarray, active: np.ndarray) -> float:
    """Eq. 10: population std of per-partition load over active partitions."""
    load = np.asarray(edge_load, np.float64)[np.asarray(active, bool)]
    if load.size == 0:
        return 0.0
    return float(np.sqrt(np.mean((load - load.mean()) ** 2)))


def normalized_load_imbalance(edge_load: np.ndarray, active: np.ndarray) -> float:
    """Eq. 10 normalised by mean load (scale-free; used for cross-dataset plots)."""
    load = np.asarray(edge_load, np.float64)[np.asarray(active, bool)]
    if load.size == 0 or load.mean() == 0:
        return 0.0
    return load_imbalance(edge_load, active) / load.mean()


def replication_factor(n_replicas: int, n_vertices: int) -> float:
    """Vertex-cut metric (HDRF-family baselines)."""
    return n_replicas / max(n_vertices, 1)
