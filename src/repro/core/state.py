"""Partition state: the paper's meta-data maps as dense JAX arrays.

partitionInfoMap<p, List<v>>  -> assignment (n,) inverted index
edgeInfoMap<v, List<edges>>   -> adj (n, max_deg) + present (n,)
graph summary (Alg. 2)        -> edge_load / vertex_count / totals
"""
from __future__ import annotations

import contextlib
import functools
import warnings
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.geometry import Geometry, geometry_of


class PartitionState(NamedTuple):
    assignment: jax.Array    # (n,) int32, -1 = absent
    present: jax.Array       # (n,) bool
    adj: jax.Array           # (n, max_deg) int32, -1 padded (symmetric cap)
    edge_load: jax.Array     # (k_max,) int32 — paper "load": Σ incident edges
    vertex_count: jax.Array  # (k_max,) int32
    active: jax.Array        # (k_max,) bool
    num_partitions: jax.Array  # () int32
    total_edges: jax.Array   # () int32 — present edges
    cut_edges: jax.Array     # () int32 — present cut edges
    denied_scaleout: jax.Array  # () int32 — scale-outs blocked by k_max
    scale_events: jax.Array  # () int32 — scale-out + scale-in events executed
    key: jax.Array           # PRNG key
    # (k_max, k_max) int32 symmetric pairwise cut counts: [p, q] (p != q) is
    # the number of present edges between partitions p and q; [p, p] counts
    # each internal edge of p twice (once per endpoint). Row sums therefore
    # equal edge_load, and the off-diagonal half-sum equals cut_edges —
    # which is what lets scale-in merge src→dst in O(K²) instead of a full
    # adjacency recompute (see repro.core.transition). Kept LAST so
    # pre-cut_matrix checkpoints restore by positional key with only the
    # trailing leaf missing (repro.checkpoint.ckpt fill_missing).
    cut_matrix: jax.Array


def init_state(n: int, max_deg: int, k_max: int, k_init: int, seed: int = 0) -> PartitionState:
    active = jnp.arange(k_max) < k_init
    return PartitionState(
        assignment=jnp.full((n,), -1, jnp.int32),
        present=jnp.zeros((n,), bool),
        adj=jnp.full((n, max_deg), -1, jnp.int32),
        edge_load=jnp.zeros((k_max,), jnp.int32),
        vertex_count=jnp.zeros((k_max,), jnp.int32),
        active=active,
        num_partitions=jnp.asarray(k_init, jnp.int32),
        total_edges=jnp.asarray(0, jnp.int32),
        cut_edges=jnp.asarray(0, jnp.int32),
        denied_scaleout=jnp.asarray(0, jnp.int32),
        scale_events=jnp.asarray(0, jnp.int32),
        key=jax.random.PRNGKey(seed),
        cut_matrix=jnp.zeros((k_max, k_max), jnp.int32),
    )


def grow_state(state: PartitionState, geom: Geometry) -> PartitionState:
    """Host-side regeometry: pad ``state`` to the larger ``geom``.

    New vertex rows are absent (``assignment=-1``, ``present=False``,
    ``adj=-1``), wider neighbour rows are -1-padded, and new partition
    slots are inactive with zero counters — all of which are inert in
    every transition core, so growing ``n``/``max_deg`` is a semantics
    no-op: the grown state is bit-identical (original slots plus all
    counters, including ``cut_matrix``) to one allocated at ``geom``
    from the start (see repro.core.geometry for the neutrality argument
    and the one LDG-knob caveat). Growing ``k_max`` adds scale-out
    headroom going forward. Never shrinks. ``geom.k_max=None`` keeps the
    current partition-slot count."""
    n0, d0 = state.adj.shape
    k0 = state.edge_load.shape[0]
    n1, d1 = int(geom.n), int(geom.max_deg)
    k1 = int(geom.k_max) if geom.k_max else int(k0)
    if n1 < n0 or d1 < d0 or k1 < k0:
        raise ValueError(
            f"grow_state cannot shrink: state is (n={n0}, max_deg={d0}, "
            f"k_max={k0}), requested (n={n1}, max_deg={d1}, k_max={k1}) — "
            "build a fresh session for a smaller universe")
    if (n1, d1, k1) == (n0, d0, k0):
        return state
    dn, dd, dk = n1 - n0, d1 - d0, k1 - k0
    return state._replace(
        assignment=jnp.pad(state.assignment, (0, dn), constant_values=-1),
        present=jnp.pad(state.present, (0, dn)),
        adj=jnp.pad(state.adj, ((0, dn), (0, dd)), constant_values=-1),
        edge_load=jnp.pad(state.edge_load, (0, dk)),
        vertex_count=jnp.pad(state.vertex_count, (0, dk)),
        active=jnp.pad(state.active, (0, dk)),
        cut_matrix=jnp.pad(state.cut_matrix, ((0, dk), (0, dk))),
    )


@functools.partial(jax.jit, static_argnames=("geom",), donate_argnums=(0,))
def _apply_repack(state: PartitionState, keep_idx, entry_map,
                  geom: Geometry) -> PartitionState:
    """Device half of compact/shrink: gather the kept vertex slots into a
    dense ``(geom.n, geom.max_deg)`` layout and relabel every adjacency
    entry through ``entry_map`` (old slot id → new slot id, -1 dropped).
    ``keep_idx[new] = old`` (-1 = fresh padding slot). Donated: the old
    tier's buffers are released to XLA the moment the repack dispatches,
    so a tier transition never holds peak+target+scratch states live —
    the capacity-aware half of the shrink story."""
    n_old, d_old = state.adj.shape
    valid = keep_idx >= 0
    src = jnp.where(valid, keep_idx, 0)
    present = valid & state.present[src]
    rows = state.adj[src]
    rows = (rows[:, :geom.max_deg] if geom.max_deg <= d_old else jnp.pad(
        rows, ((0, 0), (0, geom.max_deg - d_old)), constant_values=-1))
    ent = entry_map[jnp.clip(rows, 0, n_old - 1)]
    # scrub: absent slots' rows are stale history (the deletion cores
    # never clear them — they are masked by `present`), and relabeling
    # would dangle them, so they leave the repack empty
    rows = jnp.where(present[:, None] & (rows >= 0), ent, -1)
    assignment = jnp.where(present, state.assignment[src], -1)
    return state._replace(assignment=assignment, present=present, adj=rows)


@contextlib.contextmanager
def _quiet_donation():
    """A repack donates the old state so XLA frees the peak-tier buffers
    immediately, but the n-sized leaves change shape, so they cannot be
    aliased into the output — jax warns about exactly that. The early
    free is the point; the warning is expected, not a bug."""
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        yield


def _present_extent(present: np.ndarray, adj: np.ndarray):
    """Host scan of the semantically live content: the keep mask (present
    slots plus every slot a present row references — deleted neighbours
    keep their slot because the deletion cores leave them in survivors'
    rows, and a re-add must find the same slot for the counters to keep
    matching an uninterrupted run), and the tight (n, width) extent of
    the present rows."""
    keep = present.copy()
    live_rows = adj[present] if present.any() else adj[:0]
    refs = live_rows[live_rows >= 0]
    if refs.size:
        keep[refs] = True
    cols = np.flatnonzero((live_rows >= 0).any(axis=0))
    width = int(cols[-1]) + 1 if cols.size else 1
    return keep, width


def compact_state(state: PartitionState,
                  geom: Geometry | None = None
                  ) -> tuple[PartitionState, np.ndarray]:
    """Dense re-pack of the live vertex slots — the relabel-aware shrink.

    Keeps every present slot and every slot referenced by a present
    adjacency row (see ``_present_extent``), packs them in ascending-id
    order at the front of a ``geom``-sized state (default: the tight
    extent), and relabels all adjacency entries accordingly. Returns
    ``(new_state, perm)`` with ``perm[old_id] = new_id`` (-1 = dropped).

    Semantics: every transition core is invariant under this relabeling —
    scores, counters, scale decisions and the cursor-keyed RNG depend on
    presence, adjacency *structure* and the event index, never on the
    slot numbers themselves — so a compacted session remains bit-identical
    to the uninterrupted run modulo ``perm``. Two documented exceptions
    (repro.core.geometry): the ``hash`` policy assigns by raw vertex id,
    and LDG's capacity knob reads the allocated ``n``. Callers keep the
    inverse of ``perm`` to answer queries in original ids
    (``repro.api.Partitioner.compact``).

    Counters (edge_load, cut_matrix, totals, key, …) pass through
    untouched; absent slots' stale rows are scrubbed. The device gather
    donates the old state, so the transition releases the peak-tier
    buffers immediately."""
    present = np.asarray(state.present)
    adj = np.asarray(state.adj)
    cur = geometry_of(state)
    keep, width = _present_extent(present, adj)
    keep_idx = np.flatnonzero(keep).astype(np.int32)
    tight = Geometry(max(len(keep_idx), 1), width)
    if geom is None:
        geom = tight
    if geom.k_max is not None and int(geom.k_max) != cur.k_max:
        raise ValueError(
            f"compact_state cannot change k_max (state has {cur.k_max}, "
            f"requested {geom.k_max}): partition-slot geometry is "
            "config-pinned — grow it via restore with a larger cfg.k_max")
    if not Geometry(geom.n, geom.max_deg).covers(tight):
        raise ValueError(
            f"live content needs (n={tight.n}, max_deg={tight.max_deg}) "
            f"— {len(keep_idx)} slots are present or referenced by a "
            f"present row — but the requested geometry is (n={geom.n}, "
            f"max_deg={geom.max_deg})")
    perm = np.full(cur.n, -1, np.int32)
    perm[keep_idx] = np.arange(len(keep_idx), dtype=np.int32)
    pad = np.full(int(geom.n) - len(keep_idx), -1, np.int32)
    with _quiet_donation():
        new = _apply_repack(
            state, jnp.asarray(np.concatenate([keep_idx, pad])),
            jnp.asarray(perm),
            Geometry(int(geom.n), int(geom.max_deg), cur.k_max))
    return new, perm


def shrink_state(state: PartitionState, geom: Geometry) -> PartitionState:
    """Truncate ``state`` to the smaller ``geom`` without relabeling —
    the exact inverse of ``grow_state``, legal only when the live content
    already fits: no present slot, and no entry of a present row, at or
    beyond ``geom.n``, and no present-row entry in columns >=
    ``geom.max_deg``. Raises (pointing at ``compact_state``) otherwise.
    Slot ids are preserved, so no permutation is involved; absent slots'
    stale rows are scrubbed (they are semantics-free, see
    ``compact_state``). ``geom.k_max`` must be None or unchanged."""
    present = np.asarray(state.present)
    adj = np.asarray(state.adj)
    cur = geometry_of(state)
    n1, d1 = int(geom.n), int(geom.max_deg)
    if geom.k_max is not None and int(geom.k_max) != cur.k_max:
        raise ValueError(
            f"shrink_state cannot change k_max (state has {cur.k_max}, "
            f"requested {geom.k_max}): partition-slot geometry is "
            "config-pinned")
    keep, width = _present_extent(present, adj)
    hi = np.flatnonzero(present)
    top = int(hi[-1]) + 1 if hi.size else 1
    refs_top = int(np.flatnonzero(keep)[-1]) + 1 if keep.any() else 1
    if max(top, refs_top) > n1 or width > d1:
        raise ValueError(
            f"live content reaches (n={max(top, refs_top)}, "
            f"max_deg={width}) — beyond the requested (n={n1}, "
            f"max_deg={d1}); slot ids are preserved by shrink_state, so "
            "re-pack with compact_state to move high slots down first")
    entry_map = np.concatenate([
        np.arange(n1, dtype=np.int32),
        np.full(max(cur.n - n1, 0), -1, np.int32)])
    with _quiet_donation():
        return _apply_repack(state, jnp.arange(n1, dtype=jnp.int32),
                             jnp.asarray(entry_map),
                             Geometry(n1, d1, cur.k_max))


def live_extent(state: PartitionState) -> tuple[Geometry, Geometry]:
    """``(packed, prefix)`` — the two tight geometries of the live
    content. ``packed`` is what a dense re-pack (``compact_state``)
    needs: kept-slot count × used row width. ``prefix`` preserves slot
    ids (``shrink_state`` truncation): highest kept slot + 1 × the same
    width. ``prefix.n >= packed.n`` always; equality means truncation
    already achieves the dense packing and no relabel is needed."""
    present = np.asarray(state.present)
    adj = np.asarray(state.adj)
    k = geometry_of(state).k_max
    keep, width = _present_extent(present, adj)
    idx = np.flatnonzero(keep)
    packed = Geometry(max(len(idx), 1), width, k)
    prefix = Geometry(int(idx[-1]) + 1 if idx.size else 1, width, k)
    return packed, prefix


def state_bytes(state: PartitionState) -> int:
    """Total bytes of the state's device arrays — the memory the session
    actually pays at its current geometry (what shrinking reclaims)."""
    return int(sum(np.dtype(leaf.dtype).itemsize * int(np.prod(leaf.shape))
                   for leaf in jax.tree_util.tree_leaves(state)))


def recount_cut_matrix(state: PartitionState) -> PartitionState:
    """Rebuild ``cut_matrix`` from (assignment, present, adj) — for states
    restored from pre-cut_matrix checkpoints (the counters are exact, so a
    recounted state is indistinguishable from an incrementally maintained
    one)."""
    from repro.core.metrics import recompute_counters
    rec = recompute_counters(
        np.asarray(state.assignment), np.asarray(state.present),
        np.asarray(state.adj), state.edge_load.shape[0])
    return state._replace(
        cut_matrix=jnp.asarray(rec["cut_matrix"], jnp.int32))


def state_metrics(s: PartitionState) -> dict[str, np.ndarray]:
    """Host-side summary (edge-cut ratio Eq. 9, load imbalance Eq. 10).

    Imbalance comes from ``metrics.load_imbalance`` — the one Eq. 10
    definition shared with the traced ``transition.load_stats`` (both
    normalise by the active-partition count)."""
    from repro.core.metrics import load_imbalance
    imb = load_imbalance(np.asarray(s.edge_load), np.asarray(s.active))
    tot = int(s.total_edges)
    return {
        "edge_cut": int(s.cut_edges),
        "total_edges": tot,
        "edge_cut_ratio": float(int(s.cut_edges) / max(tot, 1)),
        "load_imbalance": imb,
        "num_partitions": int(s.num_partitions),
        "denied_scaleout": int(s.denied_scaleout),
        "scale_events": int(s.scale_events),
    }
