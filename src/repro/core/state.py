"""Partition state: the paper's meta-data maps as dense JAX arrays.

partitionInfoMap<p, List<v>>  -> assignment (n,) inverted index
edgeInfoMap<v, List<edges>>   -> adj (n, max_deg) + present (n,)
graph summary (Alg. 2)        -> edge_load / vertex_count / totals
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class PartitionState(NamedTuple):
    assignment: jax.Array    # (n,) int32, -1 = absent
    present: jax.Array       # (n,) bool
    adj: jax.Array           # (n, max_deg) int32, -1 padded (symmetric cap)
    edge_load: jax.Array     # (k_max,) int32 — paper "load": Σ incident edges
    vertex_count: jax.Array  # (k_max,) int32
    active: jax.Array        # (k_max,) bool
    num_partitions: jax.Array  # () int32
    total_edges: jax.Array   # () int32 — present edges
    cut_edges: jax.Array     # () int32 — present cut edges
    denied_scaleout: jax.Array  # () int32 — scale-outs blocked by k_max
    scale_events: jax.Array  # () int32 — scale-out + scale-in events executed
    key: jax.Array           # PRNG key


def init_state(n: int, max_deg: int, k_max: int, k_init: int, seed: int = 0) -> PartitionState:
    active = jnp.arange(k_max) < k_init
    return PartitionState(
        assignment=jnp.full((n,), -1, jnp.int32),
        present=jnp.zeros((n,), bool),
        adj=jnp.full((n, max_deg), -1, jnp.int32),
        edge_load=jnp.zeros((k_max,), jnp.int32),
        vertex_count=jnp.zeros((k_max,), jnp.int32),
        active=active,
        num_partitions=jnp.asarray(k_init, jnp.int32),
        total_edges=jnp.asarray(0, jnp.int32),
        cut_edges=jnp.asarray(0, jnp.int32),
        denied_scaleout=jnp.asarray(0, jnp.int32),
        scale_events=jnp.asarray(0, jnp.int32),
        key=jax.random.PRNGKey(seed),
    )


def state_metrics(s: PartitionState) -> dict[str, np.ndarray]:
    """Host-side summary (edge-cut ratio Eq. 9, load imbalance Eq. 10)."""
    load = np.asarray(s.edge_load, np.float64)
    act = np.asarray(s.active)
    k = max(int(act.sum()), 1)
    mean = load[act].sum() / k if act.any() else 0.0
    imb = float(np.sqrt(np.sum((load[act] - mean) ** 2) / k)) if act.any() else 0.0
    tot = int(s.total_edges)
    return {
        "edge_cut": int(s.cut_edges),
        "total_edges": tot,
        "edge_cut_ratio": float(int(s.cut_edges) / max(tot, 1)),
        "load_imbalance": imb,
        "num_partitions": int(s.num_partitions),
        "denied_scaleout": int(s.denied_scaleout),
        "scale_events": int(s.scale_events),
    }
