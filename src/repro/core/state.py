"""Partition state: the paper's meta-data maps as dense JAX arrays.

partitionInfoMap<p, List<v>>  -> assignment (n,) inverted index
edgeInfoMap<v, List<edges>>   -> adj (n, max_deg) + present (n,)
graph summary (Alg. 2)        -> edge_load / vertex_count / totals
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.geometry import Geometry


class PartitionState(NamedTuple):
    assignment: jax.Array    # (n,) int32, -1 = absent
    present: jax.Array       # (n,) bool
    adj: jax.Array           # (n, max_deg) int32, -1 padded (symmetric cap)
    edge_load: jax.Array     # (k_max,) int32 — paper "load": Σ incident edges
    vertex_count: jax.Array  # (k_max,) int32
    active: jax.Array        # (k_max,) bool
    num_partitions: jax.Array  # () int32
    total_edges: jax.Array   # () int32 — present edges
    cut_edges: jax.Array     # () int32 — present cut edges
    denied_scaleout: jax.Array  # () int32 — scale-outs blocked by k_max
    scale_events: jax.Array  # () int32 — scale-out + scale-in events executed
    key: jax.Array           # PRNG key
    # (k_max, k_max) int32 symmetric pairwise cut counts: [p, q] (p != q) is
    # the number of present edges between partitions p and q; [p, p] counts
    # each internal edge of p twice (once per endpoint). Row sums therefore
    # equal edge_load, and the off-diagonal half-sum equals cut_edges —
    # which is what lets scale-in merge src→dst in O(K²) instead of a full
    # adjacency recompute (see repro.core.transition). Kept LAST so
    # pre-cut_matrix checkpoints restore by positional key with only the
    # trailing leaf missing (repro.checkpoint.ckpt fill_missing).
    cut_matrix: jax.Array


def init_state(n: int, max_deg: int, k_max: int, k_init: int, seed: int = 0) -> PartitionState:
    active = jnp.arange(k_max) < k_init
    return PartitionState(
        assignment=jnp.full((n,), -1, jnp.int32),
        present=jnp.zeros((n,), bool),
        adj=jnp.full((n, max_deg), -1, jnp.int32),
        edge_load=jnp.zeros((k_max,), jnp.int32),
        vertex_count=jnp.zeros((k_max,), jnp.int32),
        active=active,
        num_partitions=jnp.asarray(k_init, jnp.int32),
        total_edges=jnp.asarray(0, jnp.int32),
        cut_edges=jnp.asarray(0, jnp.int32),
        denied_scaleout=jnp.asarray(0, jnp.int32),
        scale_events=jnp.asarray(0, jnp.int32),
        key=jax.random.PRNGKey(seed),
        cut_matrix=jnp.zeros((k_max, k_max), jnp.int32),
    )


def grow_state(state: PartitionState, geom: Geometry) -> PartitionState:
    """Host-side regeometry: pad ``state`` to the larger ``geom``.

    New vertex rows are absent (``assignment=-1``, ``present=False``,
    ``adj=-1``), wider neighbour rows are -1-padded, and new partition
    slots are inactive with zero counters — all of which are inert in
    every transition core, so growing ``n``/``max_deg`` is a semantics
    no-op: the grown state is bit-identical (original slots plus all
    counters, including ``cut_matrix``) to one allocated at ``geom``
    from the start (see repro.core.geometry for the neutrality argument
    and the one LDG-knob caveat). Growing ``k_max`` adds scale-out
    headroom going forward. Never shrinks. ``geom.k_max=None`` keeps the
    current partition-slot count."""
    n0, d0 = state.adj.shape
    k0 = state.edge_load.shape[0]
    n1, d1 = int(geom.n), int(geom.max_deg)
    k1 = int(geom.k_max) if geom.k_max else int(k0)
    if n1 < n0 or d1 < d0 or k1 < k0:
        raise ValueError(
            f"grow_state cannot shrink: state is (n={n0}, max_deg={d0}, "
            f"k_max={k0}), requested (n={n1}, max_deg={d1}, k_max={k1}) — "
            "build a fresh session for a smaller universe")
    if (n1, d1, k1) == (n0, d0, k0):
        return state
    dn, dd, dk = n1 - n0, d1 - d0, k1 - k0
    return state._replace(
        assignment=jnp.pad(state.assignment, (0, dn), constant_values=-1),
        present=jnp.pad(state.present, (0, dn)),
        adj=jnp.pad(state.adj, ((0, dn), (0, dd)), constant_values=-1),
        edge_load=jnp.pad(state.edge_load, (0, dk)),
        vertex_count=jnp.pad(state.vertex_count, (0, dk)),
        active=jnp.pad(state.active, (0, dk)),
        cut_matrix=jnp.pad(state.cut_matrix, ((0, dk), (0, dk))),
    )


def recount_cut_matrix(state: PartitionState) -> PartitionState:
    """Rebuild ``cut_matrix`` from (assignment, present, adj) — for states
    restored from pre-cut_matrix checkpoints (the counters are exact, so a
    recounted state is indistinguishable from an incrementally maintained
    one)."""
    from repro.core.metrics import recompute_counters
    rec = recompute_counters(
        np.asarray(state.assignment), np.asarray(state.present),
        np.asarray(state.adj), state.edge_load.shape[0])
    return state._replace(
        cut_matrix=jnp.asarray(rec["cut_matrix"], jnp.int32))


def state_metrics(s: PartitionState) -> dict[str, np.ndarray]:
    """Host-side summary (edge-cut ratio Eq. 9, load imbalance Eq. 10).

    Imbalance comes from ``metrics.load_imbalance`` — the one Eq. 10
    definition shared with the traced ``transition.load_stats`` (both
    normalise by the active-partition count)."""
    from repro.core.metrics import load_imbalance
    imb = load_imbalance(np.asarray(s.edge_load), np.asarray(s.active))
    tot = int(s.total_edges)
    return {
        "edge_cut": int(s.cut_edges),
        "total_edges": tot,
        "edge_cut_ratio": float(int(s.cut_edges) / max(tot, 1)),
        "load_imbalance": imb,
        "num_partitions": int(s.num_partitions),
        "denied_scaleout": int(s.denied_scaleout),
        "scale_events": int(s.scale_events),
    }
