"""Engine configuration (paper §4.2 knobs). Frozen+hashable for jit statics."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Knobs of Algorithm 1 / §4.2.2–4.2.3.

    Attributes:
      k_max: static upper bound on partitions (XLA shapes); the paper's cloud
        can grow unboundedly, we grow logically up to k_max and count denials.
      k_init: partitions active at t=0 (paper starts with one worker).
      max_cap: MAXCAP — maximum edge-load capacity of one partition.
      tolerance_param: Eq. 6 `toleranceParameter` (%); scale-in trigger
        l = tolerance_param*MAXCAP/100.
      dest_param: Eq. 7 `param` (%); destinationThreshold = MAXCAP −
        param*MAXCAP/100.
      balance_guard: 'text' → §4.2.2 semantics (AVG_d > TH ⇒ least-loaded);
        'alg1' → Algorithm 1 listing semantics (σ > TH ⇒ affinity path,
        else least-loaded). The two disagree in the paper; 'text' is default
        and the discrepancy is documented in DESIGN.md.
      autoscale: enable §4.2.3 scale-out/in (SDP=True; baselines=False).
      fennel_gamma / fennel_alpha_scale: Fennel policy constants.
      ldg_slack: LDG capacity slack factor (C = slack * n / k).
    """

    k_max: int = 16
    k_init: int = 1
    max_cap: int = 1 << 30
    tolerance_param: float = 25.0
    dest_param: float = 5.0
    balance_guard: str = "text"
    autoscale: bool = True
    fennel_gamma: float = 1.5
    fennel_alpha_scale: float = 1.0
    ldg_slack: float = 1.1

    def __post_init__(self):
        if self.balance_guard not in ("text", "alg1"):
            raise ValueError("balance_guard must be 'text' or 'alg1'")
        if not (1 <= self.k_init <= self.k_max):
            raise ValueError("need 1 <= k_init <= k_max")


POLICIES = ("sdp", "ldg", "fennel", "hash", "random", "greedy")
