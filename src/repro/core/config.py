"""Engine configuration (paper §4.2 knobs). Frozen+hashable for jit statics."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Knobs of Algorithm 1 / §4.2.2–4.2.3.

    Attributes:
      k_max: static upper bound on partitions (XLA shapes); the paper's cloud
        can grow unboundedly, we grow logically up to k_max and count denials.
      k_init: partitions active at t=0 (paper starts with one worker).
      max_cap: MAXCAP — maximum edge-load capacity of one partition.
      tolerance_param: Eq. 6 `toleranceParameter` (%); scale-in trigger
        l = tolerance_param*MAXCAP/100.
      dest_param: Eq. 7 `param` (%); destinationThreshold = MAXCAP −
        param*MAXCAP/100.
      balance_guard: 'text' → §4.2.2 semantics (AVG_d > TH ⇒ least-loaded);
        'alg1' → Algorithm 1 listing semantics (σ > TH ⇒ affinity path,
        else least-loaded). The two disagree in the paper; 'text' is default
        and the discrepancy is documented in DESIGN.md.
      autoscale: enable §4.2.3 scale-out/in (SDP=True; baselines=False).
      fennel_gamma / fennel_alpha_scale: Fennel policy constants.
      ldg_slack: LDG capacity slack factor (C = slack * n / k).
    """

    k_max: int = 16
    k_init: int = 1
    max_cap: int = 1 << 30
    tolerance_param: float = 25.0
    dest_param: float = 5.0
    balance_guard: str = "text"
    autoscale: bool = True
    fennel_gamma: float = 1.5
    fennel_alpha_scale: float = 1.0
    ldg_slack: float = 1.1

    def __post_init__(self):
        """Reject malformed configs here, with actionable messages, instead
        of letting them fail deep inside tracing (shape errors from a bad
        k_max, silent no-op scaling from a bad percentage, ...)."""
        if self.balance_guard not in ("text", "alg1"):
            raise ValueError(
                f"balance_guard={self.balance_guard!r} is unknown: expected "
                "'text' (§4.2.2 prose semantics, default) or 'alg1' "
                "(Algorithm 1 listing semantics) — the two disagree in the "
                "paper, see DESIGN.md")
        if self.k_max < 1:
            raise ValueError(
                f"k_max={self.k_max} must be >= 1: it is the static upper "
                "bound on partitions and sizes every (k_max,)-shaped array")
        if not (1 <= self.k_init <= self.k_max):
            raise ValueError(
                f"k_init={self.k_init} must satisfy 1 <= k_init <= k_max="
                f"{self.k_max}: k_init partitions are active at t=0 and the "
                "engine can only grow logically up to k_max — raise k_max or "
                "lower k_init")
        if self.max_cap <= 0:
            raise ValueError(
                f"max_cap={self.max_cap} must be > 0: it is MAXCAP, the "
                "per-partition edge-load capacity (Eqs. 5-7); a non-positive "
                "capacity makes every partition permanently overloaded")
        if not 0.0 <= self.tolerance_param <= 100.0:
            raise ValueError(
                f"tolerance_param={self.tolerance_param} must be a "
                "percentage in [0, 100]: Eq. 6 sets the scale-in trigger to "
                "l = tolerance_param*MAXCAP/100")
        if not 0.0 <= self.dest_param <= 100.0:
            raise ValueError(
                f"dest_param={self.dest_param} must be a percentage in "
                "[0, 100]: Eq. 7 sets destinationThreshold = MAXCAP - "
                "dest_param*MAXCAP/100")
        if self.fennel_gamma <= 1.0:
            raise ValueError(
                f"fennel_gamma={self.fennel_gamma} must be > 1: Fennel's "
                "cost term alpha*|S|^gamma needs a superlinear exponent "
                "(the paper uses 1.5) or the balance pressure vanishes")
        if self.ldg_slack < 1.0:
            raise ValueError(
                f"ldg_slack={self.ldg_slack} must be >= 1: LDG capacity is "
                "C = slack*n/k, and slack < 1 under-provisions every "
                "partition below an even split")


POLICIES = ("sdp", "ldg", "fennel", "hash", "random", "greedy")
