"""Offline (static) partitioner — METIS stand-in for the paper's Fig. 5.

The paper compares SDP against METIS as the offline upper bound. METIS
itself is not available offline; we implement a classical two-stage
equivalent: BFS region growing to balanced seeds + boundary
Fiduccia–Mattheyses-style refinement sweeps. It sees the whole graph
(not streaming), so — like METIS in Fig. 5 — it should beat every
streaming method on edge-cut.
"""
from __future__ import annotations

import numpy as np

from repro.graph.csr import Graph


def bfs_grow(g: Graph, k: int, seed: int = 0) -> np.ndarray:
    """Grow k balanced regions by multi-source BFS."""
    rng = np.random.default_rng(seed)
    assignment = -np.ones(g.n, dtype=np.int32)
    target = (g.n + k - 1) // k
    sizes = np.zeros(k, dtype=np.int64)
    order = rng.permutation(g.n)
    frontiers: list[list[int]] = [[] for _ in range(k)]
    seeds = order[:k]
    for p, s in enumerate(seeds):
        assignment[s] = p
        sizes[p] = 1
        frontiers[p] = [int(s)]
    # round-robin BFS expansion
    progress = True
    while progress:
        progress = False
        for p in np.argsort(sizes):
            if sizes[p] >= target or not frontiers[p]:
                continue
            nxt = []
            for v in frontiers[p]:
                for u in g.neighbors(v):
                    if assignment[u] < 0 and sizes[p] < target:
                        assignment[u] = p
                        sizes[p] += 1
                        nxt.append(int(u))
                        progress = True
            frontiers[p] = nxt
    # orphans (disconnected) → least loaded
    for v in order:
        if assignment[v] < 0:
            p = int(np.argmin(sizes))
            assignment[v] = p
            sizes[p] += 1
    return assignment


def fm_refine(g: Graph, assignment: np.ndarray, k: int, passes: int = 4,
              balance_slack: float = 0.05) -> np.ndarray:
    """Boundary FM sweeps: move a vertex to the neighbouring partition with
    max gain if balance stays within slack."""
    assignment = assignment.copy()
    sizes = np.bincount(assignment, minlength=k).astype(np.int64)
    cap = int(np.ceil(g.n / k * (1 + balance_slack)))
    floor = int(np.floor(g.n / k * (1 - balance_slack)))
    for _ in range(passes):
        moved = 0
        for v in range(g.n):
            nb = g.neighbors(v)
            if nb.size == 0:
                continue
            p = assignment[v]
            counts = np.bincount(assignment[nb], minlength=k)
            q = int(np.argmax(counts))
            gain = counts[q] - counts[p]
            if q != p and gain > 0 and sizes[q] < cap and sizes[p] > floor:
                assignment[v] = q
                sizes[p] -= 1
                sizes[q] += 1
                moved += 1
        if moved == 0:
            break
    return assignment


def offline_partition(g: Graph, k: int, seed: int = 0, passes: int = 4) -> np.ndarray:
    return fm_refine(g, bfs_grow(g, k, seed), k, passes=passes)


def cut_of(g: Graph, assignment: np.ndarray) -> int:
    e = g.edge_array()
    return int((assignment[e[:, 0]] != assignment[e[:, 1]]).sum())
