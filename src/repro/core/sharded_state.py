"""Vertex-sharded placement of `PartitionState`.

One session's O(n) leaves (the dense label journal ``assignment``, the
presence mask, the adjacency rows) are laid out as per-device row blocks
along a 1-D "vertices" mesh axis; every O(K)/O(K²) leaf (loads, active
mask, cut matrix) and the scalar counters stay fully replicated — the
transformer-shard idiom of sharding the one big axis and replicating the
small state that every step needs whole.

The persistent representation is plain GSPMD global arrays carrying
`NamedSharding`s: the same `PartitionState` NamedTuple as the dense
engines, so geometry helpers (`geometry_of`, `grow_state`), checkpoint
serialization (which gathers via ``np.asarray``), and metrics all work
unchanged. Only the window step itself (repro.runtime.shard_session)
drops into `shard_map` over these shardings.

Row padding: the row count must divide the mesh; `shard_state` pads rows
up to the next multiple with the same inert (-1/0) fill `grow_state`
uses. Padded rows are semantically absent vertices — no event ever
references an id ≥ the semantic n, so they never enter counters (the
heterogeneous-padding test in tests/test_shard_session.py is the gate).
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.geometry import Geometry, geometry_of
from repro.core.state import PartitionState, grow_state


def n_shards(mesh: jax.sharding.Mesh) -> int:
    return mesh.shape["vertices"]


def pad_rows(n: int, shards: int) -> int:
    """Smallest multiple of ``shards`` that is >= n (and >= shards)."""
    return max(-(-n // shards), 1) * shards


def state_specs() -> PartitionState:
    """PartitionSpec per leaf: row leaves split on "vertices", the rest
    replicated. Ranks written out in full (scalar leaves get ``P()``)."""
    return PartitionState(
        assignment=P("vertices"),
        present=P("vertices"),
        adj=P("vertices", None),
        edge_load=P(None),
        vertex_count=P(None),
        active=P(None),
        num_partitions=P(),
        total_edges=P(),
        cut_edges=P(),
        denied_scaleout=P(),
        scale_events=P(),
        key=P(None),
        cut_matrix=P(None, None),
    )


def state_shardings(mesh: jax.sharding.Mesh) -> PartitionState:
    """`state_specs` bound to a mesh as a NamedSharding pytree (the
    leaves are shardings, so this is safe to pass to `jax.device_put`)."""
    return PartitionState(*(NamedSharding(mesh, s) for s in state_specs()))


def shard_state(state: PartitionState,
                mesh: jax.sharding.Mesh) -> PartitionState:
    """Place a (dense or differently-sharded) state on the vertices mesh,
    padding rows up to a multiple of the shard count first."""
    shards = n_shards(mesh)
    g = geometry_of(state)
    target = pad_rows(g.n, shards)
    if target != g.n:
        state = grow_state(state, Geometry(target, g.max_deg, g.k_max))
    return jax.device_put(state, state_shardings(mesh))


def gather_state(state: PartitionState,
                 n: int | None = None) -> PartitionState:
    """Gather to host numpy in the canonical dense layout, optionally
    slicing the row padding back off (``n`` = semantic row count). This
    is what checkpoints persist, so sharded and dense sessions round-trip
    interchangeably."""
    host = jax.tree.map(np.asarray, state)
    if n is not None and n < host.assignment.shape[0]:
        host = host._replace(assignment=host.assignment[:n],
                             present=host.present[:n],
                             adj=host.adj[:n])
    return host


def unshard_state(state: PartitionState,
                  n: int | None = None) -> PartitionState:
    """Gather back to ordinary single-device arrays (row padding sliced
    off when ``n`` is given) — the exact shapes a dense run produces."""
    import jax.numpy as jnp
    return jax.tree.map(jnp.asarray, gather_state(state, n))


def per_device_state_bytes(state: PartitionState) -> int:
    """Peak resident state bytes on any one device: each device pays for
    its own row blocks plus a full copy of every replicated leaf. On a
    dense (unsharded) state this degenerates to `state_bytes`."""
    per: dict = {}
    for leaf in jax.tree.leaves(state):
        if isinstance(leaf, jax.Array) and hasattr(leaf, "addressable_shards"):
            for sh in leaf.addressable_shards:
                per[sh.device] = per.get(sh.device, 0) + sh.data.nbytes
        else:
            arr = np.asarray(leaf)
            per[None] = per.get(None, 0) + arr.nbytes
    return max(per.values()) if per else 0
