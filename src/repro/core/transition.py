"""Unified event-transition kernel — the ONE definition site for SDP's
add/delete transitions, policy dispatch, and autoscale hooks.

Three engine paths consume these functions:

  (a) the faithful per-event scan (``repro.core.engine.run_events``),
  (b) the mixed-window journal kernel (``repro.core.windowed``), and
  (c) the vmapped/sharded sweep lanes (``repro.runtime.sweep``).

They differ only in *how the knobs enter the graph*, which is the
static-vs-traced parameterization this module provides:

* **static knob** (``make_transition`` → ``EventTransition.step``) —
  ``policy`` is a Python string and ``autoscale`` a Python bool. The
  chooser is picked at trace time, the scale hooks are traced
  unconditionally (``scale_out``/``scale_in`` are internally
  data-dependent no-ops when their trigger is false), and the event
  branches dispatch through ``lax.switch`` — right for a *scalar* event
  type, which executes exactly one branch. This is the single-run
  engine path: one compiled program per (policy, cfg).

* **traced knob** (``make_masked_step``) — ``policy_idx`` is a traced
  int32 dispatched with ``lax.switch`` over the full policy table
  (``make_chooser``), and ``autoscale`` a traced bool gating the scale
  effects per lane. The event branches are fused into one branch-free
  masked step, because under ``vmap`` a *batched* switch/cond computes
  every branch and selects. This is the sweep path: one compiled
  program for ALL (policy × seed × config) lanes.

The bit-identity contract: because ``make_knobs`` performs every
host-side arithmetic step (products, percentages) before values enter
the graph, a traced f32 knob executes exactly the same f32 ops as the
trace-time-constant knob, and ``lax.cond(pred, f, identity)`` evaluates
``f`` with the same operands as an unconditional ``f`` when ``pred`` is
true. Every lane of every path is therefore bit-identical to the
faithful engine — enforced by tests/test_sdp_engine.py,
tests/test_mixed_window.py, tests/test_sweep.py and
tests/test_sweep_sharded.py.

The pairwise cut-matrix invariant
---------------------------------
``PartitionState.cut_matrix`` is a (k_max, k_max) int32 symmetric matrix of
pairwise cut counts, maintained incrementally by every transition core:

* ``cut_matrix[p, q]`` (p != q) = number of *present* edges between
  partitions p and q; ``cut_matrix[p, p]`` counts each internal edge of p
  twice (once per endpoint);
* row sums equal ``edge_load`` and the off-diagonal half-sum equals
  ``cut_edges`` (``metrics.recompute_counters`` recounts all of it from
  scratch; the property tests assert agreement).

``commit_add`` scatter-adds the chooser's already-computed ``scores``
vector into row/col p, ``del_vertex_core`` subtracts it, ``del_edge_core``
touches one (pv, pu) pair, and ``make_masked_step`` merges the three
effects with masks exactly like the other counters. ``scale_in``'s merged
cut is then just ``cut_edges - cut_matrix[src, dst]`` and the migrate
folds row/col src into dst in O(K²) — the per-event O(n·max_deg)
``recompute_cut`` adjacency pass is gone from every engine path (it
survives only as the from-scratch reference for tests and benchmarks).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.config import EngineConfig, POLICIES
from repro.core.state import PartitionState
from repro.graph.stream import EVENT_ADD, EVENT_DEL_EDGE, EVENT_DEL_VERTEX

# a Python int, not a jnp constant: masked_argmin runs inside the fused
# Pallas kernel body, where captured device constants are not allowed —
# a weak-typed literal traces to the same int32 ops either way
_BIG = 2**30


class EventTrace(NamedTuple):
    """Per-event metric trace (paper captures these at interval boundaries)."""
    total_edges: jax.Array
    cut_edges: jax.Array
    num_partitions: jax.Array
    load_std: jax.Array


# ---------------------------------------------------------------------------
# engine knobs
# ---------------------------------------------------------------------------

class Knobs(NamedTuple):
    """Numeric policy/scaling knobs derived from EngineConfig on the host.

    All Python-level arithmetic (products, percentages) happens in
    ``make_knobs`` so that the static path (fields are weak Python scalars,
    embedded as f32 constants at trace time) and the dynamic sweep path
    (fields are traced f32 scalars, see repro.runtime.sweep) perform
    bit-identical f32 operations.
    """
    max_cap: jax.Array | float            # Eq. 5 MAXCAP
    scale_in_l: jax.Array | float         # Eq. 6 l = tolerance*MAXCAP/100
    scale_in_dest: jax.Array | float      # Eq. 7 destinationThreshold
    ldg_cap_num: jax.Array | float        # ldg_slack * n (cap = this / k)
    fennel_gamma: jax.Array | float
    fennel_gm1: jax.Array | float         # gamma - 1
    fennel_alpha_scale: jax.Array | float


def make_knobs(cfg: EngineConfig, n: int) -> Knobs:
    """Host-side knob derivation shared by every engine path."""
    return Knobs(
        max_cap=cfg.max_cap,
        scale_in_l=cfg.tolerance_param * cfg.max_cap / 100.0,
        scale_in_dest=cfg.max_cap - cfg.dest_param * cfg.max_cap / 100.0,
        ldg_cap_num=cfg.ldg_slack * n,
        fennel_gamma=cfg.fennel_gamma,
        fennel_gm1=cfg.fennel_gamma - 1.0,
        fennel_alpha_scale=cfg.fennel_alpha_scale,
    )


def knobs_arrays(cfg: EngineConfig, n: int) -> Knobs:
    """Knobs as f32 scalars — the traced/vmapped form for the sweep runtime."""
    kn = make_knobs(cfg, n)
    return Knobs(*(jnp.float32(x) for x in kn))


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------

def neighbor_stats(state: PartitionState, row: jax.Array):
    """(scores[k], deg, nb_present, safe_row): affinity of one vertex row.

    scores[k] = |E(v) ∩ P_k| over *present* neighbours (paper Eq. 1).
    """
    valid = row >= 0
    safe_row = jnp.where(valid, row, 0)
    nb_present = valid & state.present[safe_row]
    nb_assign = jnp.where(nb_present, state.assignment[safe_row], -1)
    k_max = state.edge_load.shape[0]
    onehot = (nb_assign[:, None] == jnp.arange(k_max, dtype=jnp.int32)[None, :])
    scores = jnp.sum(onehot, axis=0, dtype=jnp.int32)
    deg = jnp.sum(nb_present, dtype=jnp.int32)
    return scores, deg, nb_present, safe_row


def nth_active(active: jax.Array, i: jax.Array) -> jax.Array:
    """Index of the i-th active partition, with i taken modulo the active
    count. Callers draw i in [0, num_partitions); clamping keeps the result
    an *active* index even if num_partitions ever drifts from
    popcount(active) (an unclamped argmax over an all-False mask would
    silently return slot 0, possibly inactive). All-inactive still yields 0
    — there is no valid answer in that state."""
    cnt = jnp.sum(active, dtype=jnp.int32)
    i = jnp.mod(i, jnp.maximum(cnt, 1))
    cum = jnp.cumsum(active.astype(jnp.int32)) - 1
    return jnp.argmax((cum == i) & active).astype(jnp.int32)


def masked_argmin(x: jax.Array, mask: jax.Array) -> jax.Array:
    return jnp.argmin(jnp.where(mask, x, _BIG)).astype(jnp.int32)


def load_stats(state):
    """(avg_d, load_dev) over active partitions — Eqs. 2 & 10.

    ``state`` is any carrier of active/edge_load/num_partitions
    (PartitionState or the windowed engine's SmallState).
    """
    act = state.active
    load = state.edge_load.astype(jnp.float32)
    # normalise by popcount(active), the same denominator as the host-side
    # metrics.load_imbalance / state_metrics (num_partitions is kept equal
    # to it by the scale hooks, but the two definitions must not drift)
    p = jnp.maximum(jnp.sum(act, dtype=jnp.int32).astype(jnp.float32), 1.0)
    maxl = jnp.max(jnp.where(act, load, -jnp.inf))
    minl = jnp.min(jnp.where(act, load, jnp.inf))
    avg_d = (maxl - minl) / p
    mean = jnp.sum(jnp.where(act, load, 0.0)) / p
    var = jnp.sum(jnp.where(act, (load - mean) ** 2, 0.0)) / p
    return avg_d, jnp.sqrt(var)


# ---------------------------------------------------------------------------
# policies: choose a partition for an arriving vertex
# ---------------------------------------------------------------------------

def _affinity_choice_at(state, scores: jax.Array, ridx: jax.Array):
    """Paper Alg. 3 with the random draw precomputed: argmax affinity; tie →
    min load; no overlap → the ``ridx``-th active partition. The key-driven
    ``_affinity_choice`` below and the fused Pallas chooser (which consumes
    a per-slot ``rand_index_table``) share this body, so the two cannot
    drift."""
    act = state.active
    s = jnp.where(act, scores, -1)
    best = jnp.max(s)
    tied = act & (s == best)
    p_tie = masked_argmin(state.edge_load, tied)          # tie → min load
    p_rand = nth_active(act, ridx)                        # no overlap → random
    return jnp.where(best > 0, p_tie, p_rand)


def _rand_index(state, key: jax.Array) -> jax.Array:
    """The ONE random draw any policy makes: an index in
    [0, num_partitions). ``rand_index_table`` precomputes it per possible
    ``num_partitions`` so the fused kernel can look it up instead."""
    return jax.random.randint(key, (), 0, jnp.maximum(state.num_partitions, 1))


def _affinity_choice(state, scores: jax.Array, key: jax.Array):
    """Paper Alg. 3: argmax affinity; tie → min load; no overlap → random."""
    return _affinity_choice_at(state, scores, _rand_index(state, key))


def _sdp_guard_inputs(state):
    avg_d, load_dev = load_stats(state)
    cut = jnp.maximum(state.cut_edges.astype(jnp.float32), 1.0)
    w_dev = (state.total_edges.astype(jnp.float32) / cut) * load_dev  # Eq. 4
    th = w_dev - load_dev                                             # Eq. 3
    return avg_d, load_dev, th


def _sdp_text_pick(state, p_aff):
    """§4.2.2 guard around an already-made affinity choice."""
    avg_d, _, th = _sdp_guard_inputs(state)
    p_min = masked_argmin(state.edge_load, state.active)
    guard = (state.num_partitions > 1) & (avg_d > th)
    return jnp.where(guard, p_min, p_aff)


def _sdp_alg1_pick(state, p_aff):
    """Alg. 1 listing guard around an already-made affinity choice."""
    _, load_dev, th = _sdp_guard_inputs(state)
    p_min = masked_argmin(state.edge_load, state.active)
    guard = (state.num_partitions > 1) & (load_dev > th)
    return jnp.where(guard, p_aff, p_min)


def _choose_sdp_text(state, scores, deg, v, key, kn: Knobs, n: int):
    """§4.2.2 text semantics: imbalance (AVG_d > TH) ⇒ least-loaded."""
    return _sdp_text_pick(state, _affinity_choice(state, scores, key))


def _choose_sdp_alg1(state, scores, deg, v, key, kn: Knobs, n: int):
    """Alg. 1 listing semantics: σ > TH ⇒ affinity path, else least-loaded."""
    return _sdp_alg1_pick(state, _affinity_choice(state, scores, key))


def _choose_ldg(state, scores, deg, v, key, kn: Knobs, n: int):
    k = jnp.maximum(state.num_partitions.astype(jnp.float32), 1.0)
    cap = kn.ldg_cap_num / k
    w = 1.0 - state.vertex_count.astype(jnp.float32) / cap
    h = scores.astype(jnp.float32) * jnp.maximum(w, 0.0)
    h = jnp.where(state.active, h, -jnp.inf)
    best = jnp.max(h)
    tied = state.active & (h >= best - 1e-6)
    return masked_argmin(state.vertex_count, tied)


def _choose_fennel(state, scores, deg, v, key, kn: Knobs, n: int):
    m = state.total_edges.astype(jnp.float32) + deg.astype(jnp.float32)
    nt = jnp.maximum(jnp.sum(state.vertex_count).astype(jnp.float32), 1.0)
    k = jnp.maximum(state.num_partitions.astype(jnp.float32), 1.0)
    alpha = kn.fennel_alpha_scale * jnp.sqrt(k) * m / (nt**1.5)
    cost = alpha * kn.fennel_gamma * \
        state.vertex_count.astype(jnp.float32) ** kn.fennel_gm1
    h = jnp.where(state.active, scores.astype(jnp.float32) - cost, -jnp.inf)
    best = jnp.max(h)
    tied = state.active & (h >= best - 1e-6)
    return masked_argmin(state.vertex_count, tied)


def _choose_hash(state, scores, deg, v, key, kn: Knobs, n: int):
    idx = jnp.mod(v, jnp.maximum(state.num_partitions, 1))
    return nth_active(state.active, idx)


def _choose_random(state, scores, deg, v, key, kn: Knobs, n: int):
    idx = jax.random.randint(key, (), 0, jnp.maximum(state.num_partitions, 1))
    return nth_active(state.active, idx)


def _choose_greedy(state, scores, deg, v, key, kn: Knobs, n: int):
    return _affinity_choice(state, scores, key)


POLICY_INDEX = {p: i for i, p in enumerate(POLICIES)}


def policy_fns(balance_guard: str):
    """Policy table in POLICIES order — indexable by POLICY_INDEX for the
    static engines or by a traced lax.switch index in the sweep runtime."""
    sdp = _choose_sdp_text if balance_guard == "text" else _choose_sdp_alg1
    return (sdp, _choose_ldg, _choose_fennel, _choose_hash, _choose_random,
            _choose_greedy)


def make_chooser(balance_guard: str, policy: str | None = None,
                 policy_idx: jax.Array | None = None) -> Callable:
    """``choose(state, scores, deg, v, key, kn, n) -> p`` under either knob:
    static-string (trace-time table pick) or traced-index (lax.switch)."""
    table = policy_fns(balance_guard)
    if (policy is None) == (policy_idx is None):
        raise ValueError("pass exactly one of policy / policy_idx")
    if policy is not None:
        return table[POLICY_INDEX[policy]]

    def choose(state, scores, deg, v, key, kn, n):
        return jax.lax.switch(
            policy_idx, list(table), state, scores, deg, v, key, kn, n)
    return choose


# ---------------------------------------------------------------------------
# table-driven choosers (the fused Pallas kernel's policy seam)
# ---------------------------------------------------------------------------
#
# Identical policy bodies with the single random draw hoisted out: every
# key-consuming policy draws exactly ``_rand_index`` (randint in
# [0, num_partitions)), so a chooser parameterized on that *index* instead
# of the key needs no RNG inside the kernel. ``rand_index_table``
# precomputes the draw for every possible num_partitions per window slot —
# the kernel looks up ``rand_tab[slot, num_partitions - 1]`` and feeds it
# to ``make_table_chooser``'s table, which reuses the exact ``_choose_*``
# bodies above. Bit-identity with ``make_chooser`` is a theorem of
# ``randint(key, (), 0, m)`` being reproducible per (key, m), asserted by
# tests/test_fused_chooser.py property tests.

def _choose_sdp_text_at(state, scores, deg, v, ridx, kn: Knobs, n: int):
    return _sdp_text_pick(state, _affinity_choice_at(state, scores, ridx))


def _choose_sdp_alg1_at(state, scores, deg, v, ridx, kn: Knobs, n: int):
    return _sdp_alg1_pick(state, _affinity_choice_at(state, scores, ridx))


def _choose_random_at(state, scores, deg, v, ridx, kn: Knobs, n: int):
    return nth_active(state.active, ridx)


def _choose_greedy_at(state, scores, deg, v, ridx, kn: Knobs, n: int):
    return _affinity_choice_at(state, scores, ridx)


def policy_fns_at(balance_guard: str):
    """Table-driven policy table in POLICIES order: each entry takes the
    precomputed random index where ``policy_fns`` takes a PRNG key. The
    ldg/fennel/hash entries never consume randomness, so the key-position
    argument is simply ignored and the functions are shared verbatim."""
    sdp = _choose_sdp_text_at if balance_guard == "text" else _choose_sdp_alg1_at
    return (sdp, _choose_ldg, _choose_fennel, _choose_hash, _choose_random_at,
            _choose_greedy_at)


def make_table_chooser(balance_guard: str, policy: str | None = None,
                       policy_idx: jax.Array | None = None) -> Callable:
    """``choose(state, scores, deg, v, ridx, kn, n) -> p`` — the
    ``make_chooser`` contract with the PRNG key replaced by the precomputed
    random index ``ridx`` (see ``rand_index_table``). Same static-string /
    traced-index parameterization; the traced form is built *inside* the
    fused kernel body so the lax.switch runs on the kernel's scalars."""
    table = policy_fns_at(balance_guard)
    if (policy is None) == (policy_idx is None):
        raise ValueError("pass exactly one of policy / policy_idx")
    if policy is not None:
        return table[POLICY_INDEX[policy]]

    def choose(state, scores, deg, v, ridx, kn, n):
        return jax.lax.switch(
            policy_idx, list(table), state, scores, deg, v, ridx, kn, n)
    return choose


def rand_index_table(base_key: jax.Array, t0, w: int, k_max: int) -> jax.Array:
    """(w, k_max) int32 table of the per-slot random draw for every possible
    partition count: ``tab[i, m-1] = randint(fold_in(base_key, t0+i), (),
    0, m)``. ``fold_in(base_key, t0+i)`` is exactly the per-event key of
    ``scan_events``, and ``randint`` with a static maxval m draws the same
    bits as the traced-maxval draw inside ``_rand_index`` — so a chooser
    reading ``tab[i, num_partitions-1]`` reproduces the key-driven engines
    bit-for-bit without tracing threefry inside the Pallas kernel."""
    idx = t0 + jnp.arange(w, dtype=jnp.int32)
    keys = jax.vmap(lambda i: jax.random.fold_in(base_key, i))(idx)

    def per_key(k):
        return jnp.stack([jax.random.randint(k, (), 0, m)
                          for m in range(1, k_max + 1)])

    return jax.vmap(per_key)(keys).astype(jnp.int32)


# ---------------------------------------------------------------------------
# scaling (§4.2.3)
# ---------------------------------------------------------------------------

def scale_out(state, kn: Knobs):
    """Eq. 5: if MAXCAP ≤ |E|/|P|, activate one more partition.

    ``state`` is any carrier of active/num_partitions/total_edges/
    scale_events/denied_scaleout (PartitionState or SmallState)."""
    p = jnp.maximum(state.num_partitions.astype(jnp.float32), 1.0)
    adding_threshold = state.total_edges.astype(jnp.float32) / p
    want = kn.max_cap <= adding_threshold
    slot_free = ~jnp.all(state.active)
    do = want & slot_free
    slot = jnp.argmax(~state.active).astype(jnp.int32)  # first inactive slot
    return state._replace(
        active=state.active.at[slot].set(jnp.where(do, True, state.active[slot])),
        num_partitions=state.num_partitions + do.astype(jnp.int32),
        scale_events=state.scale_events + do.astype(jnp.int32),
        denied_scaleout=state.denied_scaleout + (want & ~slot_free).astype(jnp.int32),
    )


def recompute_cut(assignment, present, adj) -> jax.Array:
    """Exact cut count (each undirected edge stored twice in adj).

    A full O(n·max_deg) adjacency pass — NOT used on any engine path
    anymore (scale-in reads the incremental ``cut_matrix`` instead); kept
    as the from-scratch reference for tests. The fig12 recompute baseline
    deliberately carries its own copy (benchmarks stay grep-clean of
    engine-layer recompute call sites); keep the two in sync."""
    valid = adj >= 0
    safe = jnp.where(valid, adj, 0)
    nb_present = valid & present[safe]
    both = nb_present & present[:, None]
    diff = assignment[:, None] != assignment[safe]
    return (jnp.sum(both & diff, dtype=jnp.int32) // 2).astype(jnp.int32)


def merge_cut_matrix(cut_matrix: jax.Array, src, dst) -> jax.Array:
    """Fold row/col ``src`` into ``dst`` in O(K²): relabelling every
    src-vertex as dst sends M'[a, b] = Σ M[p, q] over p→a, q→b under the
    map src→dst. Preserves symmetry, row sums (= merged edge_load), and
    the off-diagonal half-sum dropping by exactly M[src, dst] (= the
    merged cut delta)."""
    row = cut_matrix[src, :]
    ss = cut_matrix[src, src]
    cm = (cut_matrix.at[dst, :].add(row).at[:, dst].add(row)
          .at[dst, dst].add(ss))
    return cm.at[src, :].set(0).at[:, src].set(0)


def scale_in_trigger(small, kn: Knobs):
    """Eqs. 6–8 trigger: (src, dst, do). `small` is any state carrying
    active/edge_load/num_partitions — shared with the windowed journal."""
    under = small.active & (small.edge_load.astype(jnp.float32) < kn.scale_in_l)
    n_under = jnp.sum(under, dtype=jnp.int32)
    src = masked_argmin(small.edge_load, small.active)
    mask2 = small.active.at[src].set(False)
    dst = masked_argmin(small.edge_load, mask2)
    fits = (small.edge_load[src] + small.edge_load[dst]).astype(
        jnp.float32) <= kn.scale_in_dest
    do = (small.num_partitions > 1) & (n_under >= 2) & fits
    return src, dst, do


def scale_in(state: PartitionState, kn: Knobs,
             gate=True, *, cut_fn=None) -> PartitionState:
    """Eqs. 6–8: if ≥2 machines under l, migrate min-load machine into the
    next-least-loaded one (if it fits under destinationThreshold).
    ``gate`` AND-composes an outer condition (e.g. "this event was a
    DEL_VERTEX" in the fused masked step) into the migrate trigger.

    The merged cut comes from the incremental pairwise matrix:
    ``cut_edges - cut_matrix[src, dst]`` plus an O(K²) row/col fold — no
    adjacency pass. ``cut_fn`` (assignment, present, adj) -> cut swaps in a
    from-scratch recompute instead; only the fig12 benchmark baseline uses
    it (the counters are exact, so both produce identical states)."""
    src, dst, do = scale_in_trigger(state, kn)
    do = do & gate

    def migrate(s: PartitionState) -> PartitionState:
        assignment = jnp.where(s.assignment == src, dst, s.assignment)
        edge_load = s.edge_load.at[dst].add(s.edge_load[src]).at[src].set(0)
        vertex_count = s.vertex_count.at[dst].add(s.vertex_count[src]).at[src].set(0)
        if cut_fn is None:
            cut = s.cut_edges - s.cut_matrix[src, dst]
        else:
            cut = cut_fn(assignment, s.present, s.adj)
        return s._replace(
            assignment=assignment, edge_load=edge_load, vertex_count=vertex_count,
            active=s.active.at[src].set(False),
            num_partitions=s.num_partitions - 1,
            cut_edges=cut,
            cut_matrix=merge_cut_matrix(s.cut_matrix, src, dst),
            scale_events=s.scale_events + 1,
        )

    return jax.lax.cond(do, migrate, lambda s: s, state)


# ---------------------------------------------------------------------------
# event transition cores (shared by every engine path)
# ---------------------------------------------------------------------------

def commit_add(state: PartitionState, v, row, p, scores, deg):
    """Apply an ADD decision (partition p, scores vs current presence).

    Non-fresh (duplicate) adds scatter to the out-of-bounds row n, which
    drop-mode scatters skip — cheaper inside a scan than a full-array
    select, and identical values."""
    n = state.assignment.shape[0]
    fresh = ~state.present[v]  # ignore duplicate adds
    tgt = jnp.where(fresh, v, n)
    d = jnp.where(fresh, deg, 0)
    sc = jnp.where(fresh, scores, 0)
    return state._replace(
        assignment=state.assignment.at[tgt].set(p, mode="drop"),
        present=state.present.at[v].set(True),
        adj=state.adj.at[tgt].set(row, mode="drop"),
        vertex_count=state.vertex_count.at[p].add(fresh.astype(jnp.int32)),
        edge_load=(state.edge_load + sc).at[p].add(d),
        total_edges=state.total_edges + d,
        cut_edges=state.cut_edges + d - sc[p],
        cut_matrix=state.cut_matrix.at[p, :].add(sc).at[:, p].add(sc),
    )


def del_vertex_core(state: PartitionState, v):
    """Remove vertex v and its incident edges (no scale-in)."""
    n = state.assignment.shape[0]
    was = state.present[v]
    own_row = state.adj[v]
    scores, deg, _, _ = neighbor_stats(state, own_row)
    p = jnp.maximum(state.assignment[v], 0)
    d = jnp.where(was, deg, 0)
    sc = jnp.where(was, scores, 0)
    return state._replace(
        assignment=state.assignment.at[jnp.where(was, v, n)].set(
            -1, mode="drop"),
        present=state.present.at[v].set(False),
        vertex_count=state.vertex_count.at[p].add(-was.astype(jnp.int32)),
        edge_load=(state.edge_load - sc).at[p].add(-d),
        total_edges=state.total_edges - d,
        cut_edges=state.cut_edges - (d - sc[p]),
        cut_matrix=state.cut_matrix.at[p, :].add(-sc).at[:, p].add(-sc),
    )


def del_edge_core(state: PartitionState, v, row):
    """Remove edge (v, row[0]) if it exists (no config dependence)."""
    u = row[0]
    safe_u = jnp.maximum(u, 0)
    in_adj = jnp.any(state.adj[v] == u) & (u >= 0)
    exists = state.present[v] & state.present[safe_u] & in_adj
    pv = jnp.maximum(state.assignment[v], 0)
    pu = jnp.maximum(state.assignment[safe_u], 0)
    e = exists.astype(jnp.int32)
    cutdec = (exists & (pv != pu)).astype(jnp.int32)
    # row-wise edits only (u < 0 rewrites the rows with themselves) — a
    # full-array select here is a per-event O(n·max_deg) copy in the scan
    row_v = jnp.where((state.adj[v] == u) & (u >= 0), -1, state.adj[v])
    adj = state.adj.at[v].set(row_v)
    row_u = jnp.where((adj[safe_u] == v) & (u >= 0), -1, adj[safe_u])
    adj = adj.at[safe_u].set(row_u)
    return state._replace(
        adj=adj,
        edge_load=state.edge_load.at[pv].add(-e).at[pu].add(-e),
        total_edges=state.total_edges - e,
        cut_edges=state.cut_edges - cutdec,
        cut_matrix=state.cut_matrix.at[pv, pu].add(-e).at[pu, pv].add(-e),
    )


def migrate_core(state: PartitionState, v, dst, gate=True):
    """Move present vertex v to partition ``dst`` (the rebalance
    transition, see ``repro.rebalance``). Returns ``(state, did)``.

    Algebraically ``del_vertex_core(v)`` followed by ``commit_add(v)``
    at ``dst`` with the same neighbour scores — legal because a move
    never changes the adjacency, so every neighbour's label histogram
    is the same before and after. The deltas net out: neighbours'
    edge_load terms cancel, ``total_edges`` is untouched, and only the
    src/dst rows+columns of ``cut_matrix`` move. Gated-off calls (or
    moves to the current / an inactive partition) return the state
    bit-identically via the same drop-mode scatter trick as
    ``commit_add``."""
    n = state.assignment.shape[0]
    scores, deg, _, _ = neighbor_stats(state, state.adj[v])
    src = jnp.maximum(state.assignment[v], 0)
    dst = jnp.clip(dst, 0, state.edge_load.shape[0] - 1)
    do = (gate & state.present[v] & (state.assignment[v] >= 0)
          & state.active[dst] & (dst != src))
    e = do.astype(jnp.int32)
    d = jnp.where(do, deg, 0)
    sc = jnp.where(do, scores, 0)
    moved = state._replace(
        assignment=state.assignment.at[jnp.where(do, v, n)].set(
            dst, mode="drop"),
        vertex_count=state.vertex_count.at[src].add(-e).at[dst].add(e),
        edge_load=state.edge_load.at[src].add(-d).at[dst].add(d),
        cut_edges=state.cut_edges + sc[src] - sc[dst],
        cut_matrix=(state.cut_matrix
                    .at[src, :].add(-sc).at[:, src].add(-sc)
                    .at[dst, :].add(sc).at[:, dst].add(sc)),
    )
    return moved, do


# ---------------------------------------------------------------------------
# the parameterized transition kernel
# ---------------------------------------------------------------------------

class EventTransition(NamedTuple):
    """Event branches in EVENT_* code order — ``list(trn)`` is directly the
    branch table for ``lax.switch`` over the event type."""
    apply_add: Callable       # (state, v, row, key) -> state
    apply_del_vertex: Callable
    apply_del_edge: Callable
    apply_pad: Callable

    def step(self, state, et, v, row, key):
        """One event through the branch switch (scalar ``et`` executes
        exactly one branch — right for the single-lane reference engine;
        batched lanes use ``make_masked_step`` instead, see its docstring)."""
        return jax.lax.switch(jnp.clip(et, 0, 3), list(self),
                              state, v, row, key)


def make_scale_hooks(kn: Knobs, autoscale: bool):
    """(scale_out_hook, scale_in_hook) under the static knob: False hooks
    are identity and trace nothing; True hooks trace the — internally
    data-dependent — scale ops unconditionally. Traced per-lane autoscale
    belongs to ``make_masked_step`` (its gates mask the scale effects)."""
    if not autoscale:
        return (lambda s: s), (lambda s: s)
    return (lambda s: scale_out(s, kn)), (lambda s: scale_in(s, kn))


def make_transition(
    kn: Knobs,
    n: int,
    *,
    balance_guard: str,
    policy: str,
    autoscale: bool = False,
) -> EventTransition:
    """Build the four event branches for one engine lane — the
    *static-knob* binding: ``policy`` is a Python string and ``autoscale``
    a Python bool (the caller resolves ``cfg.autoscale and policy ==
    "sdp"``). The branch switch is right when the event type is a scalar;
    batched lanes (the sweep's traced knob) use ``make_masked_step``.
    """
    choose = make_chooser(balance_guard, policy)
    so_hook, si_hook = make_scale_hooks(kn, autoscale)

    def apply_add(state, v, row, key):
        state = so_hook(state)
        scores, deg, _, _ = neighbor_stats(state, row)
        p = choose(state, scores, deg, v, key, kn, n)
        return commit_add(state, v, row, p, scores, deg)

    def apply_del_vertex(state, v, row, key):
        state = del_vertex_core(state, v)
        return si_hook(state)

    def apply_del_edge(state, v, row, key):
        return del_edge_core(state, v, row)

    def apply_pad(state, v, row, key):
        return state

    return EventTransition(apply_add, apply_del_vertex, apply_del_edge,
                           apply_pad)


def make_masked_step(
    kn: Knobs,
    n: int,
    *,
    balance_guard: str,
    policy: str | None = None,
    policy_idx: jax.Array | None = None,
    autoscale=False,
    cut_fn=None,
) -> Callable:
    """Fused, branch-free event step: ``step(state, et, v, row, key)``.

    Bit-identical to ``EventTransition.step`` (same cores, same op order)
    but merges the three event effects with masks and row-level drop-mode
    scatters instead of a ``lax.switch``. Under ``vmap`` — the sweep's
    traced path — a switch/cond with a *batched* predicate computes every
    branch and selects, so the reference step pays all four branches plus
    a full-state (incl. (n, max_deg) adjacency) select per event per
    lane; here only one masked neighbour-gather per effect remains and
    every large-array write is an unconditional drop-mode scatter (the
    same design that makes the mixed-window kernel fast). Knob
    parameterization matches ``make_transition``. ``cut_fn`` is forwarded
    to ``scale_in`` (fig12 recompute baseline only).
    """
    choose = make_chooser(balance_guard, policy, policy_idx)
    static_auto = isinstance(autoscale, bool)
    scaling = autoscale is not False   # trace-level: any scaling code?

    def step(state: PartitionState, et, v, row, key) -> PartitionState:
        is_add = et == EVENT_ADD
        is_dv = et == EVENT_DEL_VERTEX
        is_de = et == EVENT_DEL_EDGE

        # --- scale-out before the ADD decision (§4.2.3, add events only);
        # touches only the O(K) fields, so the masked merge is cheap ---
        if scaling:
            gate = is_add if static_auto else is_add & autoscale
            so = scale_out(state, kn)
            state = state._replace(
                active=jnp.where(gate, so.active, state.active),
                num_partitions=jnp.where(gate, so.num_partitions,
                                         state.num_partitions),
                scale_events=jnp.where(gate, so.scale_events,
                                       state.scale_events),
                denied_scaleout=jnp.where(gate, so.denied_scaleout,
                                          state.denied_scaleout),
            )

        # --- ADD effect (commit_add quantities; faithful apply_add) ---
        row_add = jnp.where(is_add, row, -1)
        sc_add, deg_add, _, _ = neighbor_stats(state, row_add)
        p_add = choose(state, sc_add, deg_add, v, key, kn, n)
        fresh = is_add & ~state.present[v]
        d_add = jnp.where(fresh, deg_add, 0)
        sc_a = jnp.where(fresh, sc_add, 0)

        # --- DEL_VERTEX effect (del_vertex_core quantities) ---
        own_row = state.adj[v]
        row_dv = jnp.where(is_dv, own_row, -1)
        sc_dvs, deg_dv, _, _ = neighbor_stats(state, row_dv)
        was = is_dv & state.present[v]
        p_dv = jnp.maximum(state.assignment[v], 0)
        d_dv = jnp.where(was, deg_dv, 0)
        sc_d = jnp.where(was, sc_dvs, 0)

        # --- DEL_EDGE effect (del_edge_core quantities) ---
        u = row[0]
        safe_u = jnp.maximum(u, 0)
        in_adj = jnp.any(own_row == u) & (u >= 0)
        exists = is_de & state.present[v] & state.present[safe_u] & in_adj
        pu = jnp.maximum(state.assignment[safe_u], 0)
        e = exists.astype(jnp.int32)
        cutdec = (exists & (p_dv != pu)).astype(jnp.int32)

        # --- masked counter merge (one event type per step ⇒ exact) ---
        vertex_count = (state.vertex_count
                        .at[p_add].add(fresh.astype(jnp.int32))
                        .at[p_dv].add(-was.astype(jnp.int32)))
        edge_load = ((state.edge_load + sc_a - sc_d)
                     .at[p_add].add(d_add).at[p_dv].add(-d_dv)
                     .at[p_dv].add(-e).at[pu].add(-e))
        total_edges = state.total_edges + d_add - d_dv - e
        cut_edges = (state.cut_edges + (d_add - sc_a[p_add])
                     - (d_dv - sc_d[p_dv]) - cutdec)
        cut_matrix = (state.cut_matrix
                      .at[p_add, :].add(sc_a).at[:, p_add].add(sc_a)
                      .at[p_dv, :].add(-sc_d).at[:, p_dv].add(-sc_d)
                      .at[p_dv, pu].add(-e).at[pu, p_dv].add(-e))

        # --- row-level array updates (never a full-array select) ---
        assignment = (state.assignment
                      .at[jnp.where(fresh, v, n)].set(p_add, mode="drop")
                      .at[jnp.where(was, v, n)].set(-1, mode="drop"))
        present = (state.present
                   .at[jnp.where(is_add, v, n)].set(True, mode="drop")
                   .at[jnp.where(is_dv, v, n)].set(False, mode="drop"))
        row_v_de = jnp.where((own_row == u) & (u >= 0), -1, own_row)
        w1_val = jnp.where(is_add, row, jnp.where(is_de, row_v_de, own_row))
        w1_tgt = jnp.where(fresh | is_de, v, n)
        adj = state.adj.at[w1_tgt].set(w1_val, mode="drop")
        row_u = adj[safe_u]                   # after write 1 (self-loops)
        row_u_de = jnp.where((row_u == v) & (u >= 0), -1, row_u)
        adj = adj.at[jnp.where(is_de, safe_u, n)].set(row_u_de, mode="drop")

        state = state._replace(
            assignment=assignment, present=present, adj=adj,
            vertex_count=vertex_count, edge_load=edge_load,
            total_edges=total_edges, cut_edges=cut_edges,
            cut_matrix=cut_matrix,
        )

        # --- scale-in after DEL_VERTEX (faithful apply_del_vertex) ---
        if scaling:
            gate_dv = is_dv if static_auto else is_dv & autoscale
            state = scale_in(state, kn, gate=gate_dv, cut_fn=cut_fn)
        return state

    return step


def scan_events(
    step_fn: Callable,    # (state, et, v, row, key) -> state
    state: PartitionState,
    etype: jax.Array,     # (T,)
    vertex: jax.Array,    # (T,)
    nbrs: jax.Array,      # (T, max_deg)
    t0: jax.Array,        # () global index of first event (RNG alignment)
) -> tuple[PartitionState, EventTrace]:
    """Per-event lax.scan over one lane — the faithful event loop shared by
    the reference engine (``EventTransition.step``) and every sweep lane
    (``make_masked_step``)."""
    base_key = state.key

    def step(s: PartitionState, ev):
        et, v, row, i = ev
        key = jax.random.fold_in(base_key, i)
        sv = jnp.maximum(v, 0)
        s = step_fn(s, et, sv, row, key)
        _, load_dev = load_stats(s)
        tr = EventTrace(s.total_edges, s.cut_edges, s.num_partitions, load_dev)
        return s, tr

    idx = t0 + jnp.arange(etype.shape[0], dtype=jnp.int32)
    return jax.lax.scan(step, state, (etype, vertex, nbrs, idx))
