"""SDP core: the paper's contribution as a composable JAX module."""
from repro.core.config import EngineConfig, POLICIES
from repro.core.geometry import (
    Geometry, geometry_of, grow_tier, next_pow2, shrink_tier,
)
from repro.core.state import (
    PartitionState, compact_state, grow_state, init_state, live_extent,
    recount_cut_matrix, shrink_state, state_bytes, state_metrics,
)
from repro.core.engine import run_events, run_stream, trace_at, EventTrace
from repro.core.windowed import (
    run_stream_windowed, run_window_adds, run_window_mixed,
)
from repro.core.metrics import (
    recompute_counters, edge_cut_ratio, load_imbalance,
    normalized_load_imbalance,
)
from repro.core.offline import offline_partition, cut_of
from repro.core.ref import run_reference

__all__ = [
    "EngineConfig", "POLICIES", "PartitionState", "init_state",
    "Geometry", "geometry_of", "grow_tier", "next_pow2", "shrink_tier",
    "grow_state", "shrink_state", "compact_state", "state_bytes",
    "live_extent", "recount_cut_matrix", "state_metrics",
    "run_events", "run_stream", "trace_at", "EventTrace",
    "run_stream_windowed", "run_window_adds", "run_window_mixed",
    "recompute_counters", "edge_cut_ratio", "load_imbalance",
    "normalized_load_imbalance", "offline_partition", "cut_of", "run_reference",
]
