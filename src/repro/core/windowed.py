"""Windowed streaming engine — the beyond-paper TPU optimisation.

The paper assigns strictly one vertex at a time; that serialises the hot
affinity gather and starves the VPU/MXU. This engine processes a *window*
of W arriving events per device step:

  1. committed scores (W, K) — one batched gather+one-hot-histogram against
     the state as of window start (the `partition_affinity` Pallas kernel);
  2. a tiny sequential fixup scan over the W decisions that adds the
     intra-window neighbour contributions and maintains the load /
     cut / scaling counters.

The decomposition is exact: for window vertex i, the faithful engine's
score is (committed neighbours) + (window neighbours whose presence or
label changed before i), which is precisely scores_committed[i] plus the
fixup increment. RNG uses the same fold_in(base_key, global_event_index)
scheme, so the windowed engine is **bit-identical** to repro.core.engine —
verified by tests — while the O(W·max_deg·K) work is batched.

Two window kernels exist:

* ``run_window_adds`` — ADD-only windows, carries just the O(K) counter
  slice through the fixup scan (the fast path for insert-only streams);
* ``run_window_mixed`` — arbitrary interleavings of ADD / DEL_VERTEX /
  DEL_EDGE processed entirely on device, scoring every slot from a dense
  per-vertex label journal; the transition semantics come verbatim from
  ``repro.core.transition`` (the single definition site shared with the
  faithful engine and the sweep runtime). ``sweep_window_mixed`` is the
  same kernel under the *traced* knob (lax.switch policy, per-lane
  autoscale gate), vmapped across sweep lanes — how the ``Sweep``
  builder's ``.windowed()`` mode (repro.api.sweep; ``run_sweep`` is its
  deprecation shim) inherits the window speedup. Under ``use_kernel``
  both kinds swap in their Pallas form: ``partition_affinity`` for the
  batched committed scores here, and ``repro.kernels.fused_chooser`` for
  the entire mixed-window slot loop (plus its lane-batched
  ``sweep_window_mixed_fused`` twin) — same bit-identity contract.

The host driver slices the stream into *fixed* windows — deletion events
no longer split windows, so delete-heavy churn streams (the paper's
real-time regime) keep the batched fast path instead of degenerating into
window-size-1 chunks.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import transition as tx
from repro.core.config import EngineConfig
from repro.core.geometry import Geometry, check_row_width, resolve_geometry
from repro.core.state import PartitionState, init_state
from repro.graph.stream import (
    EVENT_ADD, EVENT_DEL_EDGE, EVENT_DEL_VERTEX, EVENT_PAD, VertexStream,
    normalize_rows,
)


class SmallState(NamedTuple):
    """The O(K)/O(K²) slice of PartitionState carried through the fixup scan."""
    active: jax.Array
    edge_load: jax.Array
    vertex_count: jax.Array
    num_partitions: jax.Array
    total_edges: jax.Array
    cut_edges: jax.Array
    denied_scaleout: jax.Array
    scale_events: jax.Array
    cut_matrix: jax.Array    # (k_max, k_max) pairwise cuts (see transition)


def _small(state: PartitionState) -> SmallState:
    return SmallState(
        state.active, state.edge_load, state.vertex_count, state.num_partitions,
        state.total_edges, state.cut_edges, state.denied_scaleout,
        state.scale_events, state.cut_matrix,
    )


def committed_scores(state: PartitionState, rows: jax.Array):
    """Batched paper-Eq.-1 affinity of W vertices vs the committed state.

    This is the reference (jnp) path; `repro.kernels.partition_affinity`
    provides the Pallas TPU kernel with identical semantics (swap via
    ``use_kernel=True`` in run_stream_windowed). Tolerates committed
    states with deletion holes: absent neighbours (present=False) score
    as empty regardless of their stale assignment entries.
    """
    valid = rows >= 0
    safe = jnp.where(valid, rows, 0)
    nb_present = valid & state.present[safe]
    nb_assign = jnp.where(nb_present, state.assignment[safe], -1)
    k_max = state.edge_load.shape[0]
    onehot = nb_assign[..., None] == jnp.arange(k_max, dtype=jnp.int32)
    scores = jnp.sum(onehot, axis=1, dtype=jnp.int32)   # (W, K)
    deg = jnp.sum(nb_present, axis=1, dtype=jnp.int32)  # (W,)
    return scores, deg


def _run_window_adds(
    state: PartitionState,
    vs: jax.Array,       # (W,) vertex ids (-1 pad allowed)
    rows: jax.Array,     # (W, max_deg)
    t0: jax.Array,       # () global event index of window start
    *,
    policy: str,
    cfg: EngineConfig,
    score_fn=None,
) -> PartitionState:
    """Process one ADD-only window. Bit-identical to the faithful engine.

    Unjitted body — ``run_window_adds`` is the plain jitted binding; the
    session facade (repro.api.partitioner) re-jits it with the carried
    state donated."""
    check_row_width(state, rows)
    n = state.assignment.shape[0]
    w = vs.shape[0]
    k_max = state.edge_load.shape[0]
    base_key = state.key
    kn = tx.make_knobs(cfg, n)
    choose = tx.make_chooser(cfg.balance_guard, policy)
    is_add = vs >= 0
    safe_vs = jnp.where(is_add, vs, 0)

    sfn = score_fn or committed_scores
    scores_c, deg_c = sfn(state, rows)                       # (W,K), (W,)
    # window-position lookup for intra-window neighbour fixup
    # (pad slots scatter to sentinel row n so they never clobber a vertex)
    pos_of = jnp.full((n + 1,), -1, jnp.int32).at[
        jnp.where(is_add, vs, n)
    ].set(jnp.arange(w, dtype=jnp.int32))
    valid = rows >= 0
    win_pos = jnp.where(valid, pos_of[jnp.where(valid, rows, 0)], -1)  # (W,D)

    def fix_step(carry, i):
        small, w_assign = carry
        key = jax.random.fold_in(base_key, t0 + i)
        if policy == "sdp" and cfg.autoscale:
            # faithful engine scales out per ADD event only (pads skip it)
            small = jax.lax.cond(
                is_add[i], lambda s: tx.scale_out(s, kn), lambda s: s, small
            )
        intra = (win_pos[i] >= 0) & (win_pos[i] < i)
        nb_wa = jnp.where(intra, w_assign[jnp.where(intra, win_pos[i], 0)], -1)
        onehot = nb_wa[:, None] == jnp.arange(k_max, dtype=jnp.int32)
        sc = scores_c[i] + jnp.sum(onehot, axis=0, dtype=jnp.int32)
        deg = deg_c[i] + jnp.sum(intra, dtype=jnp.int32)
        p = choose(small, sc, deg, safe_vs[i], key, kn, n)
        do = is_add[i] & ~state.present[safe_vs[i]]
        d = jnp.where(do, deg, 0)
        scm = jnp.where(do, sc, 0)
        small = small._replace(
            vertex_count=small.vertex_count.at[p].add(do.astype(jnp.int32)),
            edge_load=(small.edge_load + scm).at[p].add(d),
            total_edges=small.total_edges + d,
            cut_edges=small.cut_edges + d - scm[p],
            cut_matrix=small.cut_matrix.at[p, :].add(scm).at[:, p].add(scm),
        )
        w_assign = w_assign.at[i].set(jnp.where(do, p, w_assign[i]))
        return (small, w_assign), None

    small0 = _small(state)
    w_assign0 = jnp.full((w,), -1, jnp.int32)
    (small, w_assign), _ = jax.lax.scan(
        fix_step, (small0, w_assign0), jnp.arange(w, dtype=jnp.int32)
    )

    fresh = is_add & (w_assign >= 0)
    # scatter target: non-fresh slots (pads, duplicate adds) go to the
    # out-of-bounds row n, which jax scatters DROP — they must not write,
    # or a pad could clobber a real vertex's slot (duplicate .set indices
    # have undefined winners).
    tgt = jnp.where(fresh, safe_vs, n)
    assignment = state.assignment.at[tgt].set(
        jnp.where(fresh, w_assign, -1), mode="drop")
    present = state.present.at[tgt].set(True, mode="drop")
    adj = state.adj.at[tgt].set(
        jnp.where(fresh[:, None], rows, -1), mode="drop")
    return state._replace(
        assignment=assignment, present=present, adj=adj,
        active=small.active, edge_load=small.edge_load,
        vertex_count=small.vertex_count, num_partitions=small.num_partitions,
        total_edges=small.total_edges, cut_edges=small.cut_edges,
        denied_scaleout=small.denied_scaleout, scale_events=small.scale_events,
        cut_matrix=small.cut_matrix,
    )


run_window_adds = functools.partial(
    jax.jit, static_argnames=("policy", "cfg", "score_fn"))(_run_window_adds)


def _scale_in_journal(small: SmallState, label_now, kn):
    """transition.scale_in (§4.2.3, Eqs. 6–8) on the window-local journal
    representation (label_now ≡ assignment, label_now >= 0 ≡ present).
    The trigger is shared with the faithful engine so the two cannot
    drift; only the migrate body differs (journal instead of state). The
    merged cut comes from the incremental pairwise matrix — the journal's
    slot step maintains it with the same row scatters as the faithful
    cores, so no adjacency pass (the old per-window recompute_cut) is
    needed here either."""
    src, dst, do = tx.scale_in_trigger(small, kn)

    def migrate(args):
        sm, ln = args
        ln2 = jnp.where(ln == src, dst, ln)
        sm2 = sm._replace(
            edge_load=sm.edge_load.at[dst].add(
                sm.edge_load[src]).at[src].set(0),
            vertex_count=sm.vertex_count.at[dst].add(
                sm.vertex_count[src]).at[src].set(0),
            active=sm.active.at[src].set(False),
            num_partitions=sm.num_partitions - 1,
            cut_edges=sm.cut_edges - sm.cut_matrix[src, dst],
            cut_matrix=tx.merge_cut_matrix(sm.cut_matrix, src, dst),
            scale_events=sm.scale_events + 1,
        )
        return sm2, ln2

    return jax.lax.cond(do, migrate, lambda a: a, (small, label_now))


def _window_mixed_lane(
    state: PartitionState,
    ets: jax.Array,      # (W,) event types (EVENT_* codes)
    vs: jax.Array,       # (W,) subject vertex ids (-1 pad allowed)
    rows: jax.Array,     # (W, max_deg) neighbour rows / deletion operands
    t0: jax.Array,       # () global event index of window start
    kn: tx.Knobs,        # static (python floats) or traced (f32 scalars)
    *,
    choose,              # transition.make_chooser under either knob
    autoscaling: bool,   # trace-level gate: is any scaling code traced?
    do_scale=None,       # traced bool (sweep lanes) or None (static engine)
) -> PartitionState:
    """One mixed window for one lane — the shared body under either knob.

    Because deletions (and earlier adds) inside the window change
    neighbour presence mid-window, scores are read from a dense
    per-vertex label journal ``label_now`` (≡ present ? assignment : -1,
    maintained with one O(1) scatter per slot) rather than from the
    window-start snapshot: the snapshot's batched committed scores would
    cancel exactly against the per-slot correction term, so hoisting them
    here would be pure redundant work (the ADD-only kernel above keeps
    the hoist — there the intra-window fixup is genuinely sparse). Any
    add → delete → re-add chain inside the window is tracked exactly.

    The fixup scan carries only (counters, label_now, adj), and no
    conditional touches the O(n·max_deg) adjacency as a *written*
    operand: one slot holds exactly one event type, so each branch's
    effect (transition.commit_add / del_vertex_core / del_edge_core
    semantics) is computed as a masked O(max_deg·K) contribution to the
    counters plus at most two row-level drop-mode scatters into adj.
    XLA conditionals copy every large operand a branch writes — which is
    what made per-event processing of this state memory-bound in the
    first place. The scale-in cond below no longer touches adj at all:
    the merged cut is read off the incremental O(K²) cut_matrix (no
    per-event recompute pass), and the cond writes only the small
    counters plus the O(n) label journal — same per-delete cost as the
    faithful engine's assignment rewrite, negligible next to adj.

    ``do_scale`` extends the trace-time ``autoscaling`` gate to a
    per-lane runtime gate for the sweep: a runtime-False lane masks the
    scale-out select and scale-in cond to no-ops, bit-identical to a
    statically non-autoscaling trace.
    """
    n = state.assignment.shape[0]
    w = vs.shape[0]
    k_max = state.edge_load.shape[0]
    base_key = state.key

    ets = jnp.where(vs >= 0, ets, EVENT_PAD)
    is_add = ets == EVENT_ADD
    is_dv = ets == EVENT_DEL_VERTEX
    is_de = ets == EVENT_DEL_EDGE
    safe_vs = jnp.where(vs >= 0, vs, 0)

    rows_add = jnp.where(is_add[:, None], rows, -1)

    arange_k = jnp.arange(k_max, dtype=jnp.int32)

    def onehot_sum(labels):
        return jnp.sum(labels[:, None] == arange_k, axis=0, dtype=jnp.int32)

    def step(carry, i):
        small, label_now, adj = carry
        key = jax.random.fold_in(base_key, t0 + i)
        v = safe_vs[i]
        row = rows[i]
        add_i, dv_i, de_i = is_add[i], is_dv[i], is_de[i]
        own_row = adj[v]                          # (D,) pre-event adjacency
        u = row[0]
        safe_u = jnp.maximum(u, 0)

        # --- ADD: corrected scores + policy choice (faithful apply_add) ---
        if autoscaling:
            gate = add_i if do_scale is None else add_i & do_scale
            scaled = tx.scale_out(small, kn)
            small = jax.tree_util.tree_map(
                lambda a, b: jnp.where(gate, a, b), scaled, small)
        # one journal gather + histogram serves the whole slot: an ADD
        # scores its event row, a DEL_VERTEX its own adjacency row, and a
        # slot holds exactly one event type, so the sources never overlap.
        # (p is still computed for non-ADD slots but only reaches zero-
        # masked scatters — the values written are exact either way.)
        src_row = jnp.where(add_i, rows_add[i], jnp.where(dv_i, own_row, -1))
        eff = jnp.where(src_row >= 0, label_now[jnp.maximum(src_row, 0)], -1)
        sc_eff = onehot_sum(eff)
        deg_eff = jnp.sum(eff >= 0, dtype=jnp.int32)
        p = choose(small, sc_eff, deg_eff, v, key, kn, n)
        fresh = add_i & (label_now[v] < 0)
        d_add = jnp.where(fresh, deg_eff, 0)
        sc_a = jnp.where(fresh, sc_eff, 0)

        # --- DEL_VERTEX (faithful del_vertex_core over the journal) ---
        was = dv_i & (label_now[v] >= 0)
        p_dv = jnp.maximum(label_now[v], 0)
        d_dv = jnp.where(was, deg_eff, 0)
        sc_d = jnp.where(was, sc_eff, 0)

        # --- DEL_EDGE (faithful _del_edge_core over the journal) ---
        in_adj = jnp.any(own_row == u) & (u >= 0)
        exists = de_i & (label_now[v] >= 0) & (label_now[safe_u] >= 0) & in_adj
        pv = jnp.maximum(label_now[v], 0)
        pu = jnp.maximum(label_now[safe_u], 0)
        e = exists.astype(jnp.int32)
        cutdec = (exists & (pv != pu)).astype(jnp.int32)

        # --- masked counter merge (one event type per slot ⇒ exact) ---
        small = small._replace(
            vertex_count=(small.vertex_count
                          .at[p].add(fresh.astype(jnp.int32))
                          .at[p_dv].add(-was.astype(jnp.int32))),
            edge_load=((small.edge_load + sc_a - sc_d)
                       .at[p].add(d_add).at[p_dv].add(-d_dv)
                       .at[pv].add(-e).at[pu].add(-e)),
            total_edges=small.total_edges + d_add - d_dv - e,
            cut_edges=(small.cut_edges + (d_add - sc_a[p])
                       - (d_dv - sc_d[p_dv]) - cutdec),
            cut_matrix=(small.cut_matrix
                        .at[p, :].add(sc_a).at[:, p].add(sc_a)
                        .at[p_dv, :].add(-sc_d).at[:, p_dv].add(-sc_d)
                        .at[pv, pu].add(-e).at[pu, pv].add(-e)),
        )

        # --- row-level array updates (never a full-array select) ---
        new_lbl = jnp.where(add_i, jnp.where(fresh, p, label_now[v]),
                            jnp.where(dv_i, -1, label_now[v]))
        label_now = label_now.at[jnp.where(vs[i] >= 0, v, n)].set(
            new_lbl, mode="drop")
        row_v_de = jnp.where((own_row == u) & (u >= 0), -1, own_row)
        w1_val = jnp.where(add_i, row, jnp.where(de_i, row_v_de, own_row))
        w1_tgt = jnp.where(fresh | de_i, v, n)
        adj = adj.at[w1_tgt].set(w1_val, mode="drop")
        row_u = adj[safe_u]                       # after write 1 (self-loops)
        row_u_de = jnp.where((row_u == v) & (u >= 0), -1, row_u)
        adj = adj.at[jnp.where(de_i, safe_u, n)].set(row_u_de, mode="drop")

        # --- scale-in after DEL_VERTEX (faithful apply_del_vertex) ---
        if autoscaling:
            gate_dv = dv_i if do_scale is None else dv_i & do_scale
            small, label_now = jax.lax.cond(
                gate_dv,
                lambda sm, ln: _scale_in_journal(sm, ln, kn),
                lambda sm, ln: (sm, ln),
                small, label_now,
            )
        return (small, label_now, adj), None

    small0 = _small(state)
    label_now0 = jnp.where(state.present, state.assignment, -1)
    (small, label_now, adj), _ = jax.lax.scan(
        step, (small0, label_now0, state.adj),
        jnp.arange(w, dtype=jnp.int32),
    )
    return state._replace(
        assignment=label_now, present=label_now >= 0, adj=adj,
        active=small.active, edge_load=small.edge_load,
        vertex_count=small.vertex_count, num_partitions=small.num_partitions,
        total_edges=small.total_edges, cut_edges=small.cut_edges,
        denied_scaleout=small.denied_scaleout, scale_events=small.scale_events,
        cut_matrix=small.cut_matrix,
    )


def _run_window_mixed(
    state: PartitionState,
    ets: jax.Array,      # (W,) event types (EVENT_* codes)
    vs: jax.Array,       # (W,) subject vertex ids (-1 pad allowed)
    rows: jax.Array,     # (W, max_deg) neighbour rows / deletion operands
    t0: jax.Array,       # () global event index of window start
    *,
    policy: str,
    cfg: EngineConfig,
) -> PartitionState:
    """Process one window of interleaved ADD / DEL_VERTEX / DEL_EDGE events
    entirely on device, bit-identical to the faithful engine — the
    static-knob entry over ``_window_mixed_lane`` (see its docstring for
    the journal decomposition). Unjitted body — ``run_window_mixed`` is
    the plain jitted binding; repro.api.partitioner re-jits it with the
    carried state donated."""
    check_row_width(state, rows)
    n = state.assignment.shape[0]
    return _window_mixed_lane(
        state, ets, vs, rows, t0, tx.make_knobs(cfg, n),
        choose=tx.make_chooser(cfg.balance_guard, policy),
        autoscaling=policy == "sdp" and cfg.autoscale,
    )


run_window_mixed = functools.partial(
    jax.jit, static_argnames=("policy", "cfg"))(_run_window_mixed)


def sweep_window_mixed(
    states: PartitionState,   # stacked (L, ...) lanes
    kns: tx.Knobs,            # stacked (L,) f32 knobs
    policy_idx: jax.Array,    # (L,) int32 into POLICIES order
    autoscale: jax.Array,     # (L,) bool (cfg.autoscale per lane)
    ets: jax.Array,           # (L, T) per-lane — or (T,) shared — events
    vs: jax.Array,            # (L, T) / (T,)
    rows: jax.Array,          # (L, T, max_deg) / (T, max_deg)
    t0: jax.Array,            # () global event index of the first event
    *,
    balance_guard: str,
    autoscale_mode: str,      # "off" | "dynamic"
    window: int = 256,
    shared_stream: bool = False,
) -> PartitionState:
    """A whole stream of mixed windows across all sweep lanes, in ONE
    device program: per lane, a lax.scan over windows whose body
    dynamic-slices the next ``window`` events and runs
    ``_window_mixed_lane`` under the *traced* knob (policy via
    lax.switch, autoscale via a per-lane runtime gate) — no host loop,
    no per-window re-dispatch. T must be a multiple of ``window``
    (right-pad with EVENT_PAD). Sweeps thereby ride the same window
    kernel as single runs, bit-identical per lane. ``shared_stream``
    takes one (T,)-shaped stream for every lane: the O(T·max_deg)
    neighbour tensor rides vmap in_axes=None unbatched while the O(T)
    etype/vertex columns are broadcast lane-wise on device (see
    repro.runtime.sweep._scan_lanes for why the vertex index must be
    lane-batched). Not jitted here — the sweep runtime wraps it in jit
    or shard_map+jit (repro.runtime.sweep)."""
    check_row_width(states, rows)
    dynamic = autoscale_mode == "dynamic"
    sdp_idx = tx.POLICY_INDEX["sdp"]

    def one_lane(state, kn, pidx, auto, ets_l, vs_l, rows_l):
        do = auto & (pidx == sdp_idx)
        choose = tx.make_chooser(balance_guard, policy_idx=pidx)
        n_windows = ets_l.shape[0] // window

        def body(s, w):
            i0 = w * window
            s = _window_mixed_lane(
                s,
                jax.lax.dynamic_slice_in_dim(ets_l, i0, window),
                jax.lax.dynamic_slice_in_dim(vs_l, i0, window),
                jax.lax.dynamic_slice_in_dim(rows_l, i0, window),
                t0 + i0, kn,
                choose=choose, autoscaling=dynamic,
                do_scale=do if dynamic else None,
            )
            return s, None

        s, _ = jax.lax.scan(body, state,
                            jnp.arange(n_windows, dtype=jnp.int32))
        return s

    ax = None if shared_stream else 0
    if shared_stream:
        lanes = states.assignment.shape[0]
        ets = jnp.broadcast_to(ets, (lanes,) + ets.shape)
        vs = jnp.broadcast_to(vs, (lanes,) + vs.shape)
    return jax.vmap(one_lane, in_axes=(0, 0, 0, 0, 0, 0, ax))(
        states, kns, policy_idx, autoscale, ets, vs, rows)


def _pad_to(arr, length, fill):
    pad = length - arr.shape[0]
    if pad <= 0:
        return jnp.asarray(arr)
    shape = (pad,) + arr.shape[1:]
    return jnp.concatenate([jnp.asarray(arr), jnp.full(shape, fill, arr.dtype)])


def run_stream_windowed(
    stream: VertexStream,
    *,
    policy: str = "sdp",
    cfg: EngineConfig | None = None,
    seed: int = 0,
    window: int = 256,
    use_kernel: bool = False,
    geometry: Geometry | None = None,
) -> PartitionState:
    """Host driver: fixed windows of ``window`` events per device step.

    Pure-ADD windows take the small-carry ``run_window_adds`` kernel;
    windows containing deletions take ``run_window_mixed``, which scores
    from its label journal instead. Both are bit-identical to
    ``run_stream``. (The pre-mixed legacy driver that split windows at
    deletion boundaries lives on only as the fig10 benchmark baseline,
    benchmarks/fig10_time.py.) ``geometry`` overrides the state
    allocation exactly as in ``run_stream`` — growth is a semantics
    no-op (repro.core.geometry).

    ``use_kernel=True`` routes BOTH window kinds through Pallas: pure-ADD
    windows score their batched committed affinities with the
    ``partition_affinity`` kernel, and mixed windows run the whole
    slot loop — gather, score, policy argmax, commit — inside the fused
    chooser kernel (``repro.kernels.fused_chooser``), still bit-identical.
    Interpret mode resolves per backend at ONE site
    (``repro.kernels.common.default_interpret``). The per-event scan
    engine (``repro.core.engine.run_stream``) remains pure XLA — it is
    the faithful reference the kernels are verified against; session
    callers see the split in ``Partitioner.metrics()``
    (``kernel_windows`` vs ``fallback_windows``).
    """
    cfg = cfg or EngineConfig()
    geom = resolve_geometry(stream, cfg, geometry)
    state = init_state(geom.n, geom.max_deg, geom.k_max, cfg.k_init, seed)
    if use_kernel:
        from repro.kernels.fused_chooser.ops import run_window_mixed_fused
        from repro.kernels.partition_affinity.ops import scores_for_state
        score_fn = scores_for_state
        mixed_fn = run_window_mixed_fused
    else:
        score_fn = None
        mixed_fn = run_window_mixed

    et = np.asarray(stream.etype)
    vx = jnp.asarray(stream.vertex)
    nb = jnp.asarray(normalize_rows(stream.nbrs, geom.max_deg))

    T = stream.num_events
    for t in range(0, T, window):
        end = min(t + window, T)
        ets_w = _pad_to(et[t:end], window, EVENT_PAD)
        vs_w = _pad_to(vx[t:end], window, -1)
        rows_w = _pad_to(nb[t:end], window, -1)
        if np.all(et[t:end] == EVENT_ADD):
            state = run_window_adds(
                state, vs_w, rows_w, jnp.int32(t),
                policy=policy, cfg=cfg, score_fn=score_fn,
            )
        else:
            state = mixed_fn(
                state, ets_w, vs_w, rows_w, jnp.int32(t),
                policy=policy, cfg=cfg,
            )
    return state
