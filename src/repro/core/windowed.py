"""Windowed streaming engine — the beyond-paper TPU optimisation.

The paper assigns strictly one vertex at a time; that serialises the hot
affinity gather and starves the VPU/MXU. This engine processes a *window*
of W arriving vertices per device step:

  1. committed scores (W, K) — one batched gather+one-hot-histogram against
     the state as of window start (the `partition_affinity` Pallas kernel);
  2. a tiny sequential fixup scan over the W decisions that adds the
     intra-window neighbour contributions and maintains the load /
     cut / scaling counters.

The decomposition is exact: for window vertex i, the faithful engine's
score is (committed neighbours) + (window neighbours assigned before i),
which is precisely scores_committed[i] + the fixup increment. RNG uses the
same fold_in(base_key, global_event_index) scheme, so the windowed engine
is **bit-identical** to repro.core.engine — verified by tests — while the
O(W·max_deg·K) work is batched.

Deletion events are processed through the faithful branch (they are rare
and O(max_deg)); windows are split at deletion boundaries.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine as eng
from repro.core.config import EngineConfig
from repro.core.state import PartitionState, init_state
from repro.graph.stream import EVENT_ADD, VertexStream


class SmallState(NamedTuple):
    """The O(K) slice of PartitionState carried through the fixup scan."""
    active: jax.Array
    edge_load: jax.Array
    vertex_count: jax.Array
    num_partitions: jax.Array
    total_edges: jax.Array
    cut_edges: jax.Array
    denied_scaleout: jax.Array
    scale_events: jax.Array


def _small(state: PartitionState) -> SmallState:
    return SmallState(
        state.active, state.edge_load, state.vertex_count, state.num_partitions,
        state.total_edges, state.cut_edges, state.denied_scaleout,
        state.scale_events,
    )


def committed_scores(state: PartitionState, rows: jax.Array):
    """Batched paper-Eq.-1 affinity of W vertices vs the committed state.

    This is the reference (jnp) path; `repro.kernels.partition_affinity`
    provides the Pallas TPU kernel with identical semantics (swap via
    ``use_kernel=True`` in run_stream_windowed).
    """
    valid = rows >= 0
    safe = jnp.where(valid, rows, 0)
    nb_present = valid & state.present[safe]
    nb_assign = jnp.where(nb_present, state.assignment[safe], -1)
    k_max = state.edge_load.shape[0]
    onehot = nb_assign[..., None] == jnp.arange(k_max, dtype=jnp.int32)
    scores = jnp.sum(onehot, axis=1, dtype=jnp.int32)   # (W, K)
    deg = jnp.sum(nb_present, axis=1, dtype=jnp.int32)  # (W,)
    return scores, deg


@functools.partial(jax.jit, static_argnames=("policy", "cfg", "score_fn"))
def run_window_adds(
    state: PartitionState,
    vs: jax.Array,       # (W,) vertex ids (-1 pad allowed)
    rows: jax.Array,     # (W, max_deg)
    t0: jax.Array,       # () global event index of window start
    *,
    policy: str,
    cfg: EngineConfig,
    score_fn=None,
) -> PartitionState:
    """Process one ADD-only window. Bit-identical to the faithful engine."""
    n = state.assignment.shape[0]
    w = vs.shape[0]
    k_max = state.edge_load.shape[0]
    base_key = state.key
    is_add = vs >= 0
    safe_vs = jnp.where(is_add, vs, 0)

    sfn = score_fn or committed_scores
    scores_c, deg_c = sfn(state, rows)                       # (W,K), (W,)
    # window-position lookup for intra-window neighbour fixup
    # (pad slots scatter to sentinel row n so they never clobber a vertex)
    pos_of = jnp.full((n + 1,), -1, jnp.int32).at[
        jnp.where(is_add, vs, n)
    ].set(jnp.arange(w, dtype=jnp.int32))
    valid = rows >= 0
    win_pos = jnp.where(valid, pos_of[jnp.where(valid, rows, 0)], -1)  # (W,D)

    def fix_step(carry, i):
        small, w_assign = carry
        key = jax.random.fold_in(base_key, t0 + i)
        if policy == "sdp" and cfg.autoscale:
            # faithful engine scales out per ADD event only (pads skip it)
            small = jax.lax.cond(
                is_add[i], lambda s: eng.scale_out(s, cfg), lambda s: s, small
            )
        intra = (win_pos[i] >= 0) & (win_pos[i] < i)
        nb_wa = jnp.where(intra, w_assign[jnp.where(intra, win_pos[i], 0)], -1)
        onehot = nb_wa[:, None] == jnp.arange(k_max, dtype=jnp.int32)
        sc = scores_c[i] + jnp.sum(onehot, axis=0, dtype=jnp.int32)
        deg = deg_c[i] + jnp.sum(intra, dtype=jnp.int32)
        p = eng._POLICY_FNS[policy](small, sc, deg, safe_vs[i], key, cfg, n)
        do = is_add[i] & ~state.present[safe_vs[i]]
        d = jnp.where(do, deg, 0)
        scm = jnp.where(do, sc, 0)
        small = small._replace(
            vertex_count=small.vertex_count.at[p].add(do.astype(jnp.int32)),
            edge_load=(small.edge_load + scm).at[p].add(d),
            total_edges=small.total_edges + d,
            cut_edges=small.cut_edges + d - scm[p],
        )
        w_assign = w_assign.at[i].set(jnp.where(do, p, w_assign[i]))
        return (small, w_assign), None

    small0 = _small(state)
    w_assign0 = jnp.full((w,), -1, jnp.int32)
    (small, w_assign), _ = jax.lax.scan(
        fix_step, (small0, w_assign0), jnp.arange(w, dtype=jnp.int32)
    )

    fresh = is_add & (w_assign >= 0)
    # scatter target: non-fresh slots (pads, duplicate adds) go to the
    # out-of-bounds row n, which jax scatters DROP — they must not write,
    # or a pad could clobber a real vertex's slot (duplicate .set indices
    # have undefined winners).
    tgt = jnp.where(fresh, safe_vs, n)
    assignment = state.assignment.at[tgt].set(
        jnp.where(fresh, w_assign, -1), mode="drop")
    present = state.present.at[tgt].set(True, mode="drop")
    adj = state.adj.at[tgt].set(
        jnp.where(fresh[:, None], rows, -1), mode="drop")
    return state._replace(
        assignment=assignment, present=present, adj=adj,
        active=small.active, edge_load=small.edge_load,
        vertex_count=small.vertex_count, num_partitions=small.num_partitions,
        total_edges=small.total_edges, cut_edges=small.cut_edges,
        denied_scaleout=small.denied_scaleout, scale_events=small.scale_events,
    )


def run_stream_windowed(
    stream: VertexStream,
    *,
    policy: str = "sdp",
    cfg: EngineConfig | None = None,
    seed: int = 0,
    window: int = 256,
    use_kernel: bool = False,
) -> PartitionState:
    """Host driver: windows of ADDs through run_window_adds, other events
    through the faithful engine. Deterministically equal to run_stream."""
    cfg = cfg or EngineConfig()
    state = init_state(stream.n, stream.max_deg, cfg.k_max, cfg.k_init, seed)
    if use_kernel:
        from repro.kernels.partition_affinity.ops import scores_for_state
        score_fn = scores_for_state
    else:
        score_fn = None

    et = np.asarray(stream.etype)
    vx = jnp.asarray(stream.vertex)
    nb = jnp.asarray(stream.nbrs)
    t = 0
    T = stream.num_events
    while t < T:
        if et[t] == EVENT_ADD:
            end = t
            while end < T and et[end] == EVENT_ADD and end - t < window:
                end += 1
            w = end - t
            vs_w = vx[t:end]
            rows_w = nb[t:end]
            if w < window:  # pad to fixed window for compile-cache hits
                vs_w = jnp.concatenate([vs_w, jnp.full(window - w, -1, jnp.int32)])
                rows_w = jnp.concatenate(
                    [rows_w, jnp.full((window - w, stream.max_deg), -1, jnp.int32)]
                )
            state = run_window_adds(
                state, vs_w, rows_w, jnp.int32(t),
                policy=policy, cfg=cfg, score_fn=score_fn,
            )
            t = end
        else:
            end = t
            while end < T and et[end] != EVENT_ADD:
                end += 1
            state, _ = eng.run_events(
                state, jnp.asarray(et[t:end]), vx[t:end], nb[t:end],
                jnp.int32(t), policy=policy, cfg=cfg,
            )
            t = end
    return state
