"""Faithful one-pass streaming engine (paper Algorithm 1) as a lax.scan.

Every event (add vertex / delete vertex / delete edge) is processed in
arrival order, exactly one pass, with the partition decision taken from the
state as of that event — the TPU-native equivalent of the paper's Java
event loop. Policies: SDP (Alg. 1 + §4.2.2 balance guard + §4.2.3 scaling)
and the streaming baselines (LDG, Fennel, hash, random, pure greedy).

The transition bodies (policy dispatch, apply_add / apply_del_* branches,
scale_out / scale_in) live in ``repro.core.transition`` — the single
definition site shared with the windowed kernels and the sweep runtime.
This module is the *static-knob* driver: policy and config are Python
values, so XLA sees one specialized program per (policy, cfg).

The driver is split in two: ``_run_events`` is the unjitted body and
``run_events`` its plain jitted binding; the session facade
(repro.api.partitioner) re-jits the body with the carried state donated,
so streaming ``feed()`` calls reuse buffers instead of copying the state
per call. ``run_stream`` stays the whole-stream reference entry.

The windowed engine (repro.core.windowed) is bit-identical to this one but
restructures the hot affinity scoring into a batched kernel; this module is
the semantic reference. For the same reason it is deliberately OUTSIDE the
``use_kernel`` surface: the Pallas kernels (partition_affinity scoring,
the fused_chooser window loop) attach to the windowed paths only, and
their bit-identity gates all compare against this scan — a session on
``engine="scan"`` (or its small-tail fallback) therefore always scores
with XLA gathers, counted as ``fallback_windows`` in
``Partitioner.metrics()``. The carried ``PartitionState`` includes the
incremental pairwise ``cut_matrix`` (see the transition-module docstring
for its invariant), so autoscale scale-ins here — like everywhere — merge
cuts in O(K²) with no adjacency recompute.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import EngineConfig
from repro.core.geometry import Geometry, check_row_width, resolve_geometry
from repro.core.state import PartitionState, init_state
from repro.core.transition import (
    EventTrace, Knobs, make_knobs, knobs_arrays, neighbor_stats, nth_active,
    masked_argmin, load_stats, policy_fns, POLICY_INDEX, scale_out, scale_in,
    scale_in_trigger, make_transition, scan_events,
)
from repro.graph.stream import VertexStream, normalize_rows

__all__ = [
    "EventTrace", "Knobs", "make_knobs", "knobs_arrays", "neighbor_stats",
    "nth_active", "masked_argmin", "load_stats", "policy_fns", "POLICY_INDEX",
    "scale_out", "scale_in", "scale_in_trigger", "run_events", "run_stream",
    "trace_at",
]


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def _run_events(
    state: PartitionState,
    etype: jax.Array,     # (T,)
    vertex: jax.Array,    # (T,)
    nbrs: jax.Array,      # (T, max_deg)
    t0: jax.Array,        # () global index of first event (RNG alignment)
    *,
    policy: str,
    cfg: EngineConfig,
) -> tuple[PartitionState, EventTrace]:
    """Process a chunk of events; resumable (checkpoint state between chunks).

    Unjitted body — ``run_events`` is the plain jitted binding; the session
    facade (repro.api.partitioner) jits it again with the carried state
    donated, so back-to-back ``feed()`` calls reuse the (n, max_deg)
    adjacency buffers instead of copying them per call.
    """
    check_row_width(state, nbrs)
    n = state.assignment.shape[0]
    trn = make_transition(
        make_knobs(cfg, n), n,
        balance_guard=cfg.balance_guard, policy=policy,
        autoscale=cfg.autoscale and policy == "sdp",
    )
    return scan_events(trn.step, state, etype, vertex, nbrs, t0)


run_events = functools.partial(
    jax.jit, static_argnames=("policy", "cfg"))(_run_events)


def run_stream(
    stream: VertexStream,
    *,
    policy: str = "sdp",
    cfg: EngineConfig | None = None,
    seed: int = 0,
    chunk: int | None = None,
    geometry: Geometry | None = None,
) -> tuple[PartitionState, EventTrace]:
    """Host entry: run a full stream through the faithful engine.

    ``geometry`` overrides the state allocation (default: the stream's
    declared ``(n, max_deg)`` with the config's ``k_max``) — how an
    elastic session's auto-grown run is replayed whole-stream at its
    final geometry, and how heterogeneous sweep lanes are checked
    against their padded shape. Must cover the stream's
    ``required_geometry()``; growing is a semantics no-op for every
    policy except LDG (see repro.core.geometry)."""
    cfg = cfg or EngineConfig()
    geom = resolve_geometry(stream, cfg, geometry)
    state = init_state(geom.n, geom.max_deg, geom.k_max, cfg.k_init, seed)
    et = jnp.asarray(stream.etype)
    vx = jnp.asarray(stream.vertex)
    nb = jnp.asarray(normalize_rows(stream.nbrs, geom.max_deg))
    if chunk is None:
        return run_events(state, et, vx, nb, jnp.int32(0), policy=policy, cfg=cfg)
    traces = []
    t = 0
    while t < stream.num_events:
        sl = slice(t, min(t + chunk, stream.num_events))
        state, tr = run_events(
            state, et[sl], vx[sl], nb[sl], jnp.int32(t), policy=policy, cfg=cfg
        )
        traces.append(tr)
        t = sl.stop
    trace = EventTrace(*(jnp.concatenate([getattr(tr, f) for tr in traces])
                         for f in EventTrace._fields))
    return state, trace


def trace_at(trace: EventTrace, indices) -> dict[str, np.ndarray]:
    """Sample the trace at interval boundaries (paper's capture points)."""
    idx = np.asarray(indices, dtype=np.int64) - 1
    idx = np.clip(idx, 0, np.asarray(trace.total_edges).shape[0] - 1)
    tot = np.asarray(trace.total_edges)[idx]
    cut = np.asarray(trace.cut_edges)[idx]
    return {
        "total_edges": tot,
        "cut_edges": cut,
        "edge_cut_ratio": cut / np.maximum(tot, 1),
        "num_partitions": np.asarray(trace.num_partitions)[idx],
        "load_std": np.asarray(trace.load_std)[idx],
    }
