"""Faithful one-pass streaming engine (paper Algorithm 1) as a lax.scan.

Every event (add vertex / delete vertex / delete edge) is processed in
arrival order, exactly one pass, with the partition decision taken from the
state as of that event — the TPU-native equivalent of the paper's Java
event loop. Policies: SDP (Alg. 1 + §4.2.2 balance guard + §4.2.3 scaling)
and the streaming baselines (LDG, Fennel, hash, random, pure greedy).

The windowed engine (repro.core.windowed) is bit-identical to this one but
restructures the hot affinity scoring into a batched kernel; this module is
the semantic reference.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import EngineConfig
from repro.core.state import PartitionState, init_state
from repro.graph.stream import (
    EVENT_ADD, EVENT_DEL_EDGE, EVENT_DEL_VERTEX, VertexStream,
)

_BIG = jnp.int32(2**30)


class EventTrace(NamedTuple):
    """Per-event metric trace (paper captures these at interval boundaries)."""
    total_edges: jax.Array
    cut_edges: jax.Array
    num_partitions: jax.Array
    load_std: jax.Array


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------

def neighbor_stats(state: PartitionState, row: jax.Array):
    """(scores[k], deg, nb_present, safe_row): affinity of one vertex row.

    scores[k] = |E(v) ∩ P_k| over *present* neighbours (paper Eq. 1).
    """
    valid = row >= 0
    safe_row = jnp.where(valid, row, 0)
    nb_present = valid & state.present[safe_row]
    nb_assign = jnp.where(nb_present, state.assignment[safe_row], -1)
    k_max = state.edge_load.shape[0]
    onehot = (nb_assign[:, None] == jnp.arange(k_max, dtype=jnp.int32)[None, :])
    scores = jnp.sum(onehot, axis=0, dtype=jnp.int32)
    deg = jnp.sum(nb_present, dtype=jnp.int32)
    return scores, deg, nb_present, safe_row


def nth_active(active: jax.Array, i: jax.Array) -> jax.Array:
    """Index of the i-th active partition (i < num active)."""
    cum = jnp.cumsum(active.astype(jnp.int32)) - 1
    return jnp.argmax((cum == i) & active).astype(jnp.int32)


def masked_argmin(x: jax.Array, mask: jax.Array) -> jax.Array:
    return jnp.argmin(jnp.where(mask, x, _BIG)).astype(jnp.int32)


def load_stats(state: PartitionState):
    """(avg_d, load_dev) over active partitions — Eqs. 2 & 10."""
    act = state.active
    load = state.edge_load.astype(jnp.float32)
    p = jnp.maximum(state.num_partitions.astype(jnp.float32), 1.0)
    maxl = jnp.max(jnp.where(act, load, -jnp.inf))
    minl = jnp.min(jnp.where(act, load, jnp.inf))
    avg_d = (maxl - minl) / p
    mean = jnp.sum(jnp.where(act, load, 0.0)) / p
    var = jnp.sum(jnp.where(act, (load - mean) ** 2, 0.0)) / p
    return avg_d, jnp.sqrt(var)


# ---------------------------------------------------------------------------
# policies: choose a partition for an arriving vertex
# ---------------------------------------------------------------------------

def _affinity_choice(state: PartitionState, scores: jax.Array, key: jax.Array):
    """Paper Alg. 3: argmax affinity; tie → min load; no overlap → random."""
    act = state.active
    s = jnp.where(act, scores, -1)
    best = jnp.max(s)
    tied = act & (s == best)
    p_tie = masked_argmin(state.edge_load, tied)          # tie → min load
    ridx = jax.random.randint(key, (), 0, jnp.maximum(state.num_partitions, 1))
    p_rand = nth_active(act, ridx)                        # no overlap → random
    return jnp.where(best > 0, p_tie, p_rand)


def _choose_sdp(state, scores, deg, v, key, cfg: EngineConfig, n: int):
    """§4.2.2 communication-aware balance guard wrapped around Alg. 3."""
    avg_d, load_dev = load_stats(state)
    cut = jnp.maximum(state.cut_edges.astype(jnp.float32), 1.0)
    w_dev = (state.total_edges.astype(jnp.float32) / cut) * load_dev  # Eq. 4
    th = w_dev - load_dev                                             # Eq. 3
    p_min = masked_argmin(state.edge_load, state.active)
    p_aff = _affinity_choice(state, scores, key)
    multi = state.num_partitions > 1
    if cfg.balance_guard == "text":
        guard = multi & (avg_d > th)          # §4.2.2: imbalance ⇒ least-loaded
        return jnp.where(guard, p_min, p_aff)
    sigma = load_dev                          # Alg. 1 listing: σ > TH ⇒ affinity
    guard = multi & (sigma > th)
    return jnp.where(guard, p_aff, p_min)


def _choose_ldg(state, scores, deg, v, key, cfg: EngineConfig, n: int):
    k = jnp.maximum(state.num_partitions.astype(jnp.float32), 1.0)
    cap = cfg.ldg_slack * n / k
    w = 1.0 - state.vertex_count.astype(jnp.float32) / cap
    h = scores.astype(jnp.float32) * jnp.maximum(w, 0.0)
    h = jnp.where(state.active, h, -jnp.inf)
    best = jnp.max(h)
    tied = state.active & (h >= best - 1e-6)
    return masked_argmin(state.vertex_count, tied)


def _choose_fennel(state, scores, deg, v, key, cfg: EngineConfig, n: int):
    g = cfg.fennel_gamma
    m = state.total_edges.astype(jnp.float32) + deg.astype(jnp.float32)
    nt = jnp.maximum(jnp.sum(state.vertex_count).astype(jnp.float32), 1.0)
    k = jnp.maximum(state.num_partitions.astype(jnp.float32), 1.0)
    alpha = cfg.fennel_alpha_scale * jnp.sqrt(k) * m / (nt**1.5)
    cost = alpha * g * state.vertex_count.astype(jnp.float32) ** (g - 1.0)
    h = jnp.where(state.active, scores.astype(jnp.float32) - cost, -jnp.inf)
    best = jnp.max(h)
    tied = state.active & (h >= best - 1e-6)
    return masked_argmin(state.vertex_count, tied)


def _choose_hash(state, scores, deg, v, key, cfg: EngineConfig, n: int):
    idx = jnp.mod(v, jnp.maximum(state.num_partitions, 1))
    return nth_active(state.active, idx)


def _choose_random(state, scores, deg, v, key, cfg: EngineConfig, n: int):
    idx = jax.random.randint(key, (), 0, jnp.maximum(state.num_partitions, 1))
    return nth_active(state.active, idx)


def _choose_greedy(state, scores, deg, v, key, cfg: EngineConfig, n: int):
    return _affinity_choice(state, scores, key)


_POLICY_FNS = {
    "sdp": _choose_sdp,
    "ldg": _choose_ldg,
    "fennel": _choose_fennel,
    "hash": _choose_hash,
    "random": _choose_random,
    "greedy": _choose_greedy,
}


# ---------------------------------------------------------------------------
# scaling (§4.2.3)
# ---------------------------------------------------------------------------

def scale_out(state: PartitionState, cfg: EngineConfig) -> PartitionState:
    """Eq. 5: if MAXCAP ≤ |E|/|P|, activate one more partition."""
    p = jnp.maximum(state.num_partitions.astype(jnp.float32), 1.0)
    adding_threshold = state.total_edges.astype(jnp.float32) / p
    want = cfg.max_cap <= adding_threshold
    slot_free = ~jnp.all(state.active)
    do = want & slot_free
    slot = jnp.argmax(~state.active).astype(jnp.int32)  # first inactive slot
    return state._replace(
        active=state.active.at[slot].set(jnp.where(do, True, state.active[slot])),
        num_partitions=state.num_partitions + do.astype(jnp.int32),
        scale_events=state.scale_events + do.astype(jnp.int32),
        denied_scaleout=state.denied_scaleout + (want & ~slot_free).astype(jnp.int32),
    )


def _recompute_cut(assignment, present, adj) -> jax.Array:
    """Exact cut count (each undirected edge stored twice in adj)."""
    valid = adj >= 0
    safe = jnp.where(valid, adj, 0)
    nb_present = valid & present[safe]
    both = nb_present & present[:, None]
    diff = assignment[:, None] != assignment[safe]
    return (jnp.sum(both & diff, dtype=jnp.int32) // 2).astype(jnp.int32)


def scale_in(state: PartitionState, cfg: EngineConfig) -> PartitionState:
    """Eqs. 6–8: if ≥2 machines under l, migrate min-load machine into the
    next-least-loaded one (if it fits under destinationThreshold)."""
    l = cfg.tolerance_param * cfg.max_cap / 100.0
    dest_threshold = cfg.max_cap - cfg.dest_param * cfg.max_cap / 100.0
    under = state.active & (state.edge_load.astype(jnp.float32) < l)
    n_under = jnp.sum(under, dtype=jnp.int32)
    src = masked_argmin(state.edge_load, state.active)
    mask2 = state.active.at[src].set(False)
    dst = masked_argmin(state.edge_load, mask2)
    fits = (state.edge_load[src] + state.edge_load[dst]).astype(jnp.float32) <= dest_threshold
    do = (state.num_partitions > 1) & (n_under >= 2) & fits

    def migrate(s: PartitionState) -> PartitionState:
        assignment = jnp.where(s.assignment == src, dst, s.assignment)
        edge_load = s.edge_load.at[dst].add(s.edge_load[src]).at[src].set(0)
        vertex_count = s.vertex_count.at[dst].add(s.vertex_count[src]).at[src].set(0)
        cut = _recompute_cut(assignment, s.present, s.adj)
        return s._replace(
            assignment=assignment, edge_load=edge_load, vertex_count=vertex_count,
            active=s.active.at[src].set(False),
            num_partitions=s.num_partitions - 1,
            cut_edges=cut,
            scale_events=s.scale_events + 1,
        )

    return jax.lax.cond(do, migrate, lambda s: s, state)


# ---------------------------------------------------------------------------
# event branches
# ---------------------------------------------------------------------------

def _apply_add(state: PartitionState, v, row, key, policy: str, cfg: EngineConfig):
    if policy == "sdp" and cfg.autoscale:
        state = scale_out(state, cfg)
    scores, deg, nb_present, safe_row = neighbor_stats(state, row)
    n = state.assignment.shape[0]
    p = _POLICY_FNS[policy](state, scores, deg, v, key, cfg, n)
    fresh = ~state.present[v]  # ignore duplicate adds
    d = jnp.where(fresh, deg, 0)
    sc = jnp.where(fresh, scores, 0)
    return state._replace(
        assignment=jnp.where(fresh, state.assignment.at[v].set(p), state.assignment),
        present=state.present.at[v].set(True),
        adj=jnp.where(fresh, state.adj.at[v].set(row), state.adj),
        vertex_count=state.vertex_count.at[p].add(fresh.astype(jnp.int32)),
        edge_load=(state.edge_load + sc).at[p].add(d),
        total_edges=state.total_edges + d,
        cut_edges=state.cut_edges + d - sc[p],
    )


def _apply_del_vertex(state: PartitionState, v, row, key, policy: str, cfg: EngineConfig):
    was = state.present[v]
    own_row = state.adj[v]
    scores, deg, _, _ = neighbor_stats(state, own_row)
    p = jnp.maximum(state.assignment[v], 0)
    d = jnp.where(was, deg, 0)
    sc = jnp.where(was, scores, 0)
    state = state._replace(
        assignment=jnp.where(was, state.assignment.at[v].set(-1), state.assignment),
        present=state.present.at[v].set(False),
        vertex_count=state.vertex_count.at[p].add(-was.astype(jnp.int32)),
        edge_load=(state.edge_load - sc).at[p].add(-d),
        total_edges=state.total_edges - d,
        cut_edges=state.cut_edges - (d - sc[p]),
    )
    if policy == "sdp" and cfg.autoscale:
        state = scale_in(state, cfg)
    return state


def _apply_del_edge(state: PartitionState, v, row, key, policy: str, cfg: EngineConfig):
    u = row[0]
    safe_u = jnp.maximum(u, 0)
    in_adj = jnp.any(state.adj[v] == u) & (u >= 0)
    exists = state.present[v] & state.present[safe_u] & in_adj
    pv = jnp.maximum(state.assignment[v], 0)
    pu = jnp.maximum(state.assignment[safe_u], 0)
    e = exists.astype(jnp.int32)
    cutdec = (exists & (pv != pu)).astype(jnp.int32)
    adj = state.adj.at[v].set(jnp.where(state.adj[v] == u, -1, state.adj[v]))
    adj = adj.at[safe_u].set(jnp.where(adj[safe_u] == v, -1, adj[safe_u]))
    return state._replace(
        adj=jnp.where(u >= 0, adj, state.adj),
        edge_load=state.edge_load.at[pv].add(-e).at[pu].add(-e),
        total_edges=state.total_edges - e,
        cut_edges=state.cut_edges - cutdec,
    )


def _apply_pad(state, v, row, key, policy, cfg):
    return state


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("policy", "cfg"))
def run_events(
    state: PartitionState,
    etype: jax.Array,     # (T,)
    vertex: jax.Array,    # (T,)
    nbrs: jax.Array,      # (T, max_deg)
    t0: jax.Array,        # () global index of first event (RNG alignment)
    *,
    policy: str,
    cfg: EngineConfig,
) -> tuple[PartitionState, EventTrace]:
    """Process a chunk of events; resumable (checkpoint state between chunks)."""
    base_key = state.key

    def step(s: PartitionState, ev):
        et, v, row, i = ev
        key = jax.random.fold_in(base_key, i)
        sv = jnp.maximum(v, 0)
        branches = [_apply_add, _apply_del_vertex, _apply_del_edge, _apply_pad]
        s = jax.lax.switch(
            jnp.clip(et, 0, 3),
            [functools.partial(f, policy=policy, cfg=cfg) for f in branches],
            s, sv, row, key,
        )
        _, load_dev = load_stats(s)
        tr = EventTrace(s.total_edges, s.cut_edges, s.num_partitions, load_dev)
        return s, tr

    idx = t0 + jnp.arange(etype.shape[0], dtype=jnp.int32)
    final, trace = jax.lax.scan(step, state, (etype, vertex, nbrs, idx))
    return final, trace


def run_stream(
    stream: VertexStream,
    *,
    policy: str = "sdp",
    cfg: EngineConfig | None = None,
    seed: int = 0,
    chunk: int | None = None,
) -> tuple[PartitionState, EventTrace]:
    """Host entry: run a full stream through the faithful engine."""
    cfg = cfg or EngineConfig()
    state = init_state(stream.n, stream.max_deg, cfg.k_max, cfg.k_init, seed)
    et = jnp.asarray(stream.etype)
    vx = jnp.asarray(stream.vertex)
    nb = jnp.asarray(stream.nbrs)
    if chunk is None:
        return run_events(state, et, vx, nb, jnp.int32(0), policy=policy, cfg=cfg)
    traces = []
    t = 0
    while t < stream.num_events:
        sl = slice(t, min(t + chunk, stream.num_events))
        state, tr = run_events(
            state, et[sl], vx[sl], nb[sl], jnp.int32(t), policy=policy, cfg=cfg
        )
        traces.append(tr)
        t = sl.stop
    trace = EventTrace(*(jnp.concatenate([getattr(tr, f) for tr in traces])
                         for f in EventTrace._fields))
    return state, trace


def trace_at(trace: EventTrace, indices) -> dict[str, np.ndarray]:
    """Sample the trace at interval boundaries (paper's capture points)."""
    idx = np.asarray(indices, dtype=np.int64) - 1
    idx = np.clip(idx, 0, np.asarray(trace.total_edges).shape[0] - 1)
    tot = np.asarray(trace.total_edges)[idx]
    cut = np.asarray(trace.cut_edges)[idx]
    return {
        "total_edges": tot,
        "cut_edges": cut,
        "edge_cut_ratio": cut / np.maximum(tot, 1),
        "num_partitions": np.asarray(trace.num_partitions)[idx],
        "load_std": np.asarray(trace.load_std)[idx],
    }
