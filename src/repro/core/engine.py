"""Faithful one-pass streaming engine (paper Algorithm 1) as a lax.scan.

Every event (add vertex / delete vertex / delete edge) is processed in
arrival order, exactly one pass, with the partition decision taken from the
state as of that event — the TPU-native equivalent of the paper's Java
event loop. Policies: SDP (Alg. 1 + §4.2.2 balance guard + §4.2.3 scaling)
and the streaming baselines (LDG, Fennel, hash, random, pure greedy).

The windowed engine (repro.core.windowed) is bit-identical to this one but
restructures the hot affinity scoring into a batched kernel; this module is
the semantic reference.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import EngineConfig, POLICIES
from repro.core.state import PartitionState, init_state
from repro.graph.stream import VertexStream

_BIG = jnp.int32(2**30)


class EventTrace(NamedTuple):
    """Per-event metric trace (paper captures these at interval boundaries)."""
    total_edges: jax.Array
    cut_edges: jax.Array
    num_partitions: jax.Array
    load_std: jax.Array


# ---------------------------------------------------------------------------
# engine knobs
# ---------------------------------------------------------------------------

class Knobs(NamedTuple):
    """Numeric policy/scaling knobs derived from EngineConfig on the host.

    All Python-level arithmetic (products, percentages) happens in
    ``make_knobs`` so that the static path (fields are weak Python scalars,
    embedded as f32 constants at trace time) and the dynamic sweep path
    (fields are traced f32 scalars, see repro.runtime.sweep) perform
    bit-identical f32 operations.
    """
    max_cap: jax.Array | float            # Eq. 5 MAXCAP
    scale_in_l: jax.Array | float         # Eq. 6 l = tolerance*MAXCAP/100
    scale_in_dest: jax.Array | float      # Eq. 7 destinationThreshold
    ldg_cap_num: jax.Array | float        # ldg_slack * n (cap = this / k)
    fennel_gamma: jax.Array | float
    fennel_gm1: jax.Array | float         # gamma - 1
    fennel_alpha_scale: jax.Array | float


def make_knobs(cfg: EngineConfig, n: int) -> Knobs:
    """Host-side knob derivation shared by every engine path."""
    return Knobs(
        max_cap=cfg.max_cap,
        scale_in_l=cfg.tolerance_param * cfg.max_cap / 100.0,
        scale_in_dest=cfg.max_cap - cfg.dest_param * cfg.max_cap / 100.0,
        ldg_cap_num=cfg.ldg_slack * n,
        fennel_gamma=cfg.fennel_gamma,
        fennel_gm1=cfg.fennel_gamma - 1.0,
        fennel_alpha_scale=cfg.fennel_alpha_scale,
    )


def knobs_arrays(cfg: EngineConfig, n: int) -> Knobs:
    """Knobs as f32 scalars — the traced/vmapped form for the sweep runtime."""
    kn = make_knobs(cfg, n)
    return Knobs(*(jnp.float32(x) for x in kn))


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------

def neighbor_stats(state: PartitionState, row: jax.Array):
    """(scores[k], deg, nb_present, safe_row): affinity of one vertex row.

    scores[k] = |E(v) ∩ P_k| over *present* neighbours (paper Eq. 1).
    """
    valid = row >= 0
    safe_row = jnp.where(valid, row, 0)
    nb_present = valid & state.present[safe_row]
    nb_assign = jnp.where(nb_present, state.assignment[safe_row], -1)
    k_max = state.edge_load.shape[0]
    onehot = (nb_assign[:, None] == jnp.arange(k_max, dtype=jnp.int32)[None, :])
    scores = jnp.sum(onehot, axis=0, dtype=jnp.int32)
    deg = jnp.sum(nb_present, dtype=jnp.int32)
    return scores, deg, nb_present, safe_row


def nth_active(active: jax.Array, i: jax.Array) -> jax.Array:
    """Index of the i-th active partition (i < num active)."""
    cum = jnp.cumsum(active.astype(jnp.int32)) - 1
    return jnp.argmax((cum == i) & active).astype(jnp.int32)


def masked_argmin(x: jax.Array, mask: jax.Array) -> jax.Array:
    return jnp.argmin(jnp.where(mask, x, _BIG)).astype(jnp.int32)


def load_stats(state: PartitionState):
    """(avg_d, load_dev) over active partitions — Eqs. 2 & 10."""
    act = state.active
    load = state.edge_load.astype(jnp.float32)
    p = jnp.maximum(state.num_partitions.astype(jnp.float32), 1.0)
    maxl = jnp.max(jnp.where(act, load, -jnp.inf))
    minl = jnp.min(jnp.where(act, load, jnp.inf))
    avg_d = (maxl - minl) / p
    mean = jnp.sum(jnp.where(act, load, 0.0)) / p
    var = jnp.sum(jnp.where(act, (load - mean) ** 2, 0.0)) / p
    return avg_d, jnp.sqrt(var)


# ---------------------------------------------------------------------------
# policies: choose a partition for an arriving vertex
# ---------------------------------------------------------------------------

def _affinity_choice(state: PartitionState, scores: jax.Array, key: jax.Array):
    """Paper Alg. 3: argmax affinity; tie → min load; no overlap → random."""
    act = state.active
    s = jnp.where(act, scores, -1)
    best = jnp.max(s)
    tied = act & (s == best)
    p_tie = masked_argmin(state.edge_load, tied)          # tie → min load
    ridx = jax.random.randint(key, (), 0, jnp.maximum(state.num_partitions, 1))
    p_rand = nth_active(act, ridx)                        # no overlap → random
    return jnp.where(best > 0, p_tie, p_rand)


def _sdp_guard_inputs(state):
    avg_d, load_dev = load_stats(state)
    cut = jnp.maximum(state.cut_edges.astype(jnp.float32), 1.0)
    w_dev = (state.total_edges.astype(jnp.float32) / cut) * load_dev  # Eq. 4
    th = w_dev - load_dev                                             # Eq. 3
    return avg_d, load_dev, th


def _choose_sdp_text(state, scores, deg, v, key, kn: Knobs, n: int):
    """§4.2.2 text semantics: imbalance (AVG_d > TH) ⇒ least-loaded."""
    avg_d, _, th = _sdp_guard_inputs(state)
    p_min = masked_argmin(state.edge_load, state.active)
    p_aff = _affinity_choice(state, scores, key)
    guard = (state.num_partitions > 1) & (avg_d > th)
    return jnp.where(guard, p_min, p_aff)


def _choose_sdp_alg1(state, scores, deg, v, key, kn: Knobs, n: int):
    """Alg. 1 listing semantics: σ > TH ⇒ affinity path, else least-loaded."""
    _, load_dev, th = _sdp_guard_inputs(state)
    p_min = masked_argmin(state.edge_load, state.active)
    p_aff = _affinity_choice(state, scores, key)
    guard = (state.num_partitions > 1) & (load_dev > th)
    return jnp.where(guard, p_aff, p_min)


def _choose_ldg(state, scores, deg, v, key, kn: Knobs, n: int):
    k = jnp.maximum(state.num_partitions.astype(jnp.float32), 1.0)
    cap = kn.ldg_cap_num / k
    w = 1.0 - state.vertex_count.astype(jnp.float32) / cap
    h = scores.astype(jnp.float32) * jnp.maximum(w, 0.0)
    h = jnp.where(state.active, h, -jnp.inf)
    best = jnp.max(h)
    tied = state.active & (h >= best - 1e-6)
    return masked_argmin(state.vertex_count, tied)


def _choose_fennel(state, scores, deg, v, key, kn: Knobs, n: int):
    m = state.total_edges.astype(jnp.float32) + deg.astype(jnp.float32)
    nt = jnp.maximum(jnp.sum(state.vertex_count).astype(jnp.float32), 1.0)
    k = jnp.maximum(state.num_partitions.astype(jnp.float32), 1.0)
    alpha = kn.fennel_alpha_scale * jnp.sqrt(k) * m / (nt**1.5)
    cost = alpha * kn.fennel_gamma * \
        state.vertex_count.astype(jnp.float32) ** kn.fennel_gm1
    h = jnp.where(state.active, scores.astype(jnp.float32) - cost, -jnp.inf)
    best = jnp.max(h)
    tied = state.active & (h >= best - 1e-6)
    return masked_argmin(state.vertex_count, tied)


def _choose_hash(state, scores, deg, v, key, kn: Knobs, n: int):
    idx = jnp.mod(v, jnp.maximum(state.num_partitions, 1))
    return nth_active(state.active, idx)


def _choose_random(state, scores, deg, v, key, kn: Knobs, n: int):
    idx = jax.random.randint(key, (), 0, jnp.maximum(state.num_partitions, 1))
    return nth_active(state.active, idx)


def _choose_greedy(state, scores, deg, v, key, kn: Knobs, n: int):
    return _affinity_choice(state, scores, key)


POLICY_INDEX = {p: i for i, p in enumerate(POLICIES)}


def policy_fns(balance_guard: str):
    """Policy table in POLICIES order — indexable by POLICY_INDEX for the
    static engines or by a traced lax.switch index in the sweep runtime."""
    sdp = _choose_sdp_text if balance_guard == "text" else _choose_sdp_alg1
    return (sdp, _choose_ldg, _choose_fennel, _choose_hash, _choose_random,
            _choose_greedy)


# ---------------------------------------------------------------------------
# scaling (§4.2.3)
# ---------------------------------------------------------------------------

def scale_out(state: PartitionState, kn: Knobs) -> PartitionState:
    """Eq. 5: if MAXCAP ≤ |E|/|P|, activate one more partition."""
    p = jnp.maximum(state.num_partitions.astype(jnp.float32), 1.0)
    adding_threshold = state.total_edges.astype(jnp.float32) / p
    want = kn.max_cap <= adding_threshold
    slot_free = ~jnp.all(state.active)
    do = want & slot_free
    slot = jnp.argmax(~state.active).astype(jnp.int32)  # first inactive slot
    return state._replace(
        active=state.active.at[slot].set(jnp.where(do, True, state.active[slot])),
        num_partitions=state.num_partitions + do.astype(jnp.int32),
        scale_events=state.scale_events + do.astype(jnp.int32),
        denied_scaleout=state.denied_scaleout + (want & ~slot_free).astype(jnp.int32),
    )


def _recompute_cut(assignment, present, adj) -> jax.Array:
    """Exact cut count (each undirected edge stored twice in adj)."""
    valid = adj >= 0
    safe = jnp.where(valid, adj, 0)
    nb_present = valid & present[safe]
    both = nb_present & present[:, None]
    diff = assignment[:, None] != assignment[safe]
    return (jnp.sum(both & diff, dtype=jnp.int32) // 2).astype(jnp.int32)


def scale_in_trigger(small, kn: Knobs):
    """Eqs. 6–8 trigger: (src, dst, do). `small` is any state carrying
    active/edge_load/num_partitions — shared with the windowed journal."""
    under = small.active & (small.edge_load.astype(jnp.float32) < kn.scale_in_l)
    n_under = jnp.sum(under, dtype=jnp.int32)
    src = masked_argmin(small.edge_load, small.active)
    mask2 = small.active.at[src].set(False)
    dst = masked_argmin(small.edge_load, mask2)
    fits = (small.edge_load[src] + small.edge_load[dst]).astype(
        jnp.float32) <= kn.scale_in_dest
    do = (small.num_partitions > 1) & (n_under >= 2) & fits
    return src, dst, do


def scale_in(state: PartitionState, kn: Knobs) -> PartitionState:
    """Eqs. 6–8: if ≥2 machines under l, migrate min-load machine into the
    next-least-loaded one (if it fits under destinationThreshold)."""
    src, dst, do = scale_in_trigger(state, kn)

    def migrate(s: PartitionState) -> PartitionState:
        assignment = jnp.where(s.assignment == src, dst, s.assignment)
        edge_load = s.edge_load.at[dst].add(s.edge_load[src]).at[src].set(0)
        vertex_count = s.vertex_count.at[dst].add(s.vertex_count[src]).at[src].set(0)
        cut = _recompute_cut(assignment, s.present, s.adj)
        return s._replace(
            assignment=assignment, edge_load=edge_load, vertex_count=vertex_count,
            active=s.active.at[src].set(False),
            num_partitions=s.num_partitions - 1,
            cut_edges=cut,
            scale_events=s.scale_events + 1,
        )

    return jax.lax.cond(do, migrate, lambda s: s, state)


# ---------------------------------------------------------------------------
# event branches
# ---------------------------------------------------------------------------

def _commit_add(state: PartitionState, v, row, p, scores, deg):
    """Apply an ADD decision (partition p, scores vs current presence).
    Shared by the faithful, mixed-window, and sweep engines.

    Non-fresh (duplicate) adds scatter to the out-of-bounds row n, which
    drop-mode scatters skip — cheaper inside a scan than a full-array
    select, and identical values."""
    n = state.assignment.shape[0]
    fresh = ~state.present[v]  # ignore duplicate adds
    tgt = jnp.where(fresh, v, n)
    d = jnp.where(fresh, deg, 0)
    sc = jnp.where(fresh, scores, 0)
    return state._replace(
        assignment=state.assignment.at[tgt].set(p, mode="drop"),
        present=state.present.at[v].set(True),
        adj=state.adj.at[tgt].set(row, mode="drop"),
        vertex_count=state.vertex_count.at[p].add(fresh.astype(jnp.int32)),
        edge_load=(state.edge_load + sc).at[p].add(d),
        total_edges=state.total_edges + d,
        cut_edges=state.cut_edges + d - sc[p],
    )


def _apply_add(state: PartitionState, v, row, key, policy: str, cfg: EngineConfig):
    n = state.assignment.shape[0]
    kn = make_knobs(cfg, n)
    if policy == "sdp" and cfg.autoscale:
        state = scale_out(state, kn)
    scores, deg, _, _ = neighbor_stats(state, row)
    choose = policy_fns(cfg.balance_guard)[POLICY_INDEX[policy]]
    p = choose(state, scores, deg, v, key, kn, n)
    return _commit_add(state, v, row, p, scores, deg)


def _del_vertex_core(state: PartitionState, v):
    """Remove vertex v and its incident edges (no scale-in)."""
    n = state.assignment.shape[0]
    was = state.present[v]
    own_row = state.adj[v]
    scores, deg, _, _ = neighbor_stats(state, own_row)
    p = jnp.maximum(state.assignment[v], 0)
    d = jnp.where(was, deg, 0)
    sc = jnp.where(was, scores, 0)
    return state._replace(
        assignment=state.assignment.at[jnp.where(was, v, n)].set(
            -1, mode="drop"),
        present=state.present.at[v].set(False),
        vertex_count=state.vertex_count.at[p].add(-was.astype(jnp.int32)),
        edge_load=(state.edge_load - sc).at[p].add(-d),
        total_edges=state.total_edges - d,
        cut_edges=state.cut_edges - (d - sc[p]),
    )


def _apply_del_vertex(state: PartitionState, v, row, key, policy: str, cfg: EngineConfig):
    state = _del_vertex_core(state, v)
    if policy == "sdp" and cfg.autoscale:
        state = scale_in(state, make_knobs(cfg, state.assignment.shape[0]))
    return state


def _del_edge_core(state: PartitionState, v, row):
    """Remove edge (v, row[0]) if it exists (no config dependence)."""
    u = row[0]
    safe_u = jnp.maximum(u, 0)
    in_adj = jnp.any(state.adj[v] == u) & (u >= 0)
    exists = state.present[v] & state.present[safe_u] & in_adj
    pv = jnp.maximum(state.assignment[v], 0)
    pu = jnp.maximum(state.assignment[safe_u], 0)
    e = exists.astype(jnp.int32)
    cutdec = (exists & (pv != pu)).astype(jnp.int32)
    # row-wise edits only (u < 0 rewrites the rows with themselves) — a
    # full-array select here is a per-event O(n·max_deg) copy in the scan
    row_v = jnp.where((state.adj[v] == u) & (u >= 0), -1, state.adj[v])
    adj = state.adj.at[v].set(row_v)
    row_u = jnp.where((adj[safe_u] == v) & (u >= 0), -1, adj[safe_u])
    adj = adj.at[safe_u].set(row_u)
    return state._replace(
        adj=adj,
        edge_load=state.edge_load.at[pv].add(-e).at[pu].add(-e),
        total_edges=state.total_edges - e,
        cut_edges=state.cut_edges - cutdec,
    )


def _apply_del_edge(state: PartitionState, v, row, key, policy: str, cfg: EngineConfig):
    return _del_edge_core(state, v, row)


def _apply_pad(state, v, row, key, policy, cfg):
    return state


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("policy", "cfg"))
def run_events(
    state: PartitionState,
    etype: jax.Array,     # (T,)
    vertex: jax.Array,    # (T,)
    nbrs: jax.Array,      # (T, max_deg)
    t0: jax.Array,        # () global index of first event (RNG alignment)
    *,
    policy: str,
    cfg: EngineConfig,
) -> tuple[PartitionState, EventTrace]:
    """Process a chunk of events; resumable (checkpoint state between chunks)."""
    base_key = state.key

    def step(s: PartitionState, ev):
        et, v, row, i = ev
        key = jax.random.fold_in(base_key, i)
        sv = jnp.maximum(v, 0)
        branches = [_apply_add, _apply_del_vertex, _apply_del_edge, _apply_pad]
        s = jax.lax.switch(
            jnp.clip(et, 0, 3),
            [functools.partial(f, policy=policy, cfg=cfg) for f in branches],
            s, sv, row, key,
        )
        _, load_dev = load_stats(s)
        tr = EventTrace(s.total_edges, s.cut_edges, s.num_partitions, load_dev)
        return s, tr

    idx = t0 + jnp.arange(etype.shape[0], dtype=jnp.int32)
    final, trace = jax.lax.scan(step, state, (etype, vertex, nbrs, idx))
    return final, trace


def run_stream(
    stream: VertexStream,
    *,
    policy: str = "sdp",
    cfg: EngineConfig | None = None,
    seed: int = 0,
    chunk: int | None = None,
) -> tuple[PartitionState, EventTrace]:
    """Host entry: run a full stream through the faithful engine."""
    cfg = cfg or EngineConfig()
    state = init_state(stream.n, stream.max_deg, cfg.k_max, cfg.k_init, seed)
    et = jnp.asarray(stream.etype)
    vx = jnp.asarray(stream.vertex)
    nb = jnp.asarray(stream.nbrs)
    if chunk is None:
        return run_events(state, et, vx, nb, jnp.int32(0), policy=policy, cfg=cfg)
    traces = []
    t = 0
    while t < stream.num_events:
        sl = slice(t, min(t + chunk, stream.num_events))
        state, tr = run_events(
            state, et[sl], vx[sl], nb[sl], jnp.int32(t), policy=policy, cfg=cfg
        )
        traces.append(tr)
        t = sl.stop
    trace = EventTrace(*(jnp.concatenate([getattr(tr, f) for tr in traces])
                         for f in EventTrace._fields))
    return state, trace


def trace_at(trace: EventTrace, indices) -> dict[str, np.ndarray]:
    """Sample the trace at interval boundaries (paper's capture points)."""
    idx = np.asarray(indices, dtype=np.int64) - 1
    idx = np.clip(idx, 0, np.asarray(trace.total_edges).shape[0] - 1)
    tot = np.asarray(trace.total_edges)[idx]
    cut = np.asarray(trace.cut_edges)[idx]
    return {
        "total_edges": tot,
        "cut_edges": cut,
        "edge_cut_ratio": cut / np.maximum(tot, 1),
        "num_partitions": np.asarray(trace.num_partitions)[idx],
        "load_std": np.asarray(trace.load_std)[idx],
    }
