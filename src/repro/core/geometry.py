"""Elastic state geometry: the (n, max_deg, k_max) shape triple as a value.

SDP's premise is partitioning a graph whose size is not known up front
("streaming manner to overcome the memory bottleneck"), but XLA arrays
are fixed-shape: every engine runs at SOME concrete ``(n, max_deg,
k_max)``. This module makes that triple an explicit, comparable value —
a :class:`Geometry` — so shapes can *flow* through the stack instead of
being frozen at construction:

* ``repro.core.state.grow_state(state, geom)`` pads a live state to a
  larger geometry (new rows absent, wider rows -1-padded) — a semantics
  no-op, see below;
* ``repro.api.Partitioner`` auto-grows its session geometry in
  ``feed()`` along power-of-two tiers (:func:`grow_tier`);
* checkpoints record their geometry in metadata and ``restore()`` grows
  or validates on mismatch;
* the sweep runtime pads lanes of heterogeneous geometry to the union
  geometry before stacking.

Geometry-neutrality
-------------------
Growing ``n``/``max_deg`` never changes a single decision: every
transition core scores absent-padded rows as empty (``present`` is False
on new slots, ``-1`` neighbour entries are masked), the drop-mode
scatter sentinel row ``n`` is semantics-free, and the RNG folds
``(base_key, global_event_index)`` — none of it reads the array sizes.
A state grown mid-stream is therefore **bit-identical** (original slots
plus all counters, including ``cut_matrix``) to one that ran at the
larger geometry from the start. The single exception is the LDG
baseline: its capacity knob is derived from the live ``n``
(``ldg_slack * n`` in ``transition.make_knobs``), so LDG runs are
bit-comparable only at matching geometry — grow-vs-presized identity
holds for every other policy and for LDG lanes compared at the same
final geometry.

Growing ``k_max`` adds *inactive* partition slots. Past decisions are
unchanged (inactive slots are masked everywhere), but future scale-outs
that would have been denied at the old ``k_max`` may now succeed — that
is the point of growing it, and why auto-grow never touches ``k_max``
(it is pinned by the session's ``EngineConfig``; only an explicit
restore-into-larger-``cfg.k_max`` grows it).

Tier policy
-----------
Auto-growth re-jits every kernel the state flows through (shapes are
trace-time statics), so :func:`grow_tier` doubles at minimum: each grown
dimension jumps to ``next_pow2(max(required, 2 * current))``. A session
fed a stream of unknown size therefore re-jits O(log n) times total, and
donation keeps reusing buffers within a tier. Explicit pre-sizing
(``Partitioner.grow_to``) is exact — the caller knows the size.

Shrinking is the inverse move with deliberate asymmetry
(:func:`shrink_tier`): a dimension shrinks only when the live content
occupies at most ``1 / (2 * hysteresis)`` of the current allocation
(default hysteresis=4 → below ¼ of the next tier down), and the target
``next_pow2(2 * required)`` lands at most half-full. Re-growing out of
the new tier needs the content to more than double; re-shrinking out of
it needs the content to fall below an eighth — growth and shrink bands
never overlap, so churn around a tier boundary cannot thrash re-jits.
``k_max`` never auto-shrinks (config-pinned, like growth). The state
move itself is ``repro.core.state.shrink_state`` /
``compact_state``.
"""
from __future__ import annotations

from typing import NamedTuple


def next_pow2(x: int) -> int:
    """Smallest power of two >= max(x, 1)."""
    x = int(x)
    return 1 if x <= 1 else 1 << (x - 1).bit_length()


class Geometry(NamedTuple):
    """The shape triple every dense partition state is allocated at.

    ``k_max=None`` means "no requirement" — streams know the vertex
    universe and row width they need but have no opinion on the
    partition-slot count (that is the config's job).
    """
    n: int
    max_deg: int
    k_max: int | None = None

    def covers(self, other: "Geometry") -> bool:
        """True iff a state at this geometry can ingest work requiring
        ``other`` (componentwise >=; a ``None`` requirement is free)."""
        return (self.n >= other.n and self.max_deg >= other.max_deg
                and (other.k_max is None or (self.k_max or 0) >= other.k_max))

    def union(self, other: "Geometry") -> "Geometry":
        """Componentwise max — the smallest geometry covering both."""
        ks = [k for k in (self.k_max, other.k_max) if k is not None]
        return Geometry(max(self.n, other.n),
                        max(self.max_deg, other.max_deg),
                        max(ks) if ks else None)

    def tiered(self) -> "Geometry":
        """This geometry rounded up to its power-of-two tier (``k_max``
        is never tiered — it is config-pinned, see module docstring)."""
        return self._replace(n=next_pow2(self.n),
                             max_deg=next_pow2(self.max_deg))


def geometry_of(state) -> Geometry:
    """The geometry a live ``PartitionState`` is allocated at."""
    return Geometry(int(state.assignment.shape[0]),
                    int(state.adj.shape[1]),
                    int(state.edge_load.shape[0]))


def grow_tier(current: Geometry, required: Geometry) -> Geometry:
    """The tier-doubling growth policy (see module docstring): every
    dimension that ``required`` exceeds jumps to
    ``next_pow2(max(required, 2 * current))``; satisfied dimensions keep
    their current size. ``k_max`` grows exactly (config-driven), never
    tiered."""
    def dim(cur: int, req: int) -> int:
        return cur if req <= cur else next_pow2(max(req, 2 * cur))

    k = current.k_max
    if required.k_max is not None and (k or 0) < required.k_max:
        k = required.k_max
    return Geometry(dim(current.n, required.n),
                    dim(current.max_deg, required.max_deg), k)


def shrink_tier(current: Geometry, required: Geometry, *,
                hysteresis: int = 4) -> Geometry:
    """The hysteretic shrink policy — the inverse of :func:`grow_tier`
    (see module docstring). Each dimension whose live requirement has
    fallen to ``1 / (2 * hysteresis)`` of the current allocation drops to
    ``next_pow2(2 * required)`` (at most half-full at the new tier);
    everything else keeps its current size. ``k_max`` is config-pinned
    and never auto-shrinks. Returns a geometry ``current`` covers, equal
    to ``current`` when nothing qualifies."""
    if hysteresis < 2:
        raise ValueError(
            f"hysteresis={hysteresis} must be >= 2: at 1 the shrink "
            "target is exactly the growth trigger, so a stream oscillating"
            " around a tier boundary would re-jit every window")

    def dim(cur: int, req: int) -> int:
        req = max(int(req), 1)
        if req * 2 * hysteresis > cur:
            return cur
        return next_pow2(2 * req)

    return Geometry(dim(current.n, required.n),
                    dim(current.max_deg, required.max_deg),
                    current.k_max)


def check_row_width(state, nbrs) -> None:
    """Geometry guard at the engine boundaries (scan, window kernels,
    sweep lanes): event rows must match the state's allocated row width
    exactly — a mismatch would otherwise surface as an opaque XLA
    scatter shape error deep inside the scan. Shape-only, so it runs at
    trace time for free."""
    if nbrs.shape[-1] != state.adj.shape[-1]:
        raise ValueError(
            f"event neighbour rows are {nbrs.shape[-1]} wide but the state "
            f"geometry is max_deg={state.adj.shape[-1]} — normalize the rows "
            "(repro.graph.stream.normalize_rows) or grow the state "
            "(repro.core.state.grow_state)")


def resolve_geometry(stream, cfg, geometry: Geometry | None) -> Geometry:
    """Geometry an engine entry point should run ``stream`` at: the
    stream's declared geometry by default, or the caller's ``geometry``
    (validated to cover the stream's requirement; ``k_max`` defaults to
    the config's). Shared by ``run_stream`` and ``run_stream_windowed``
    so a grown session can be replayed against the batch engines at its
    final geometry."""
    if geometry is None:
        return Geometry(int(stream.n), int(stream.max_deg), int(cfg.k_max))
    geom = Geometry(int(geometry.n), int(geometry.max_deg),
                    int(geometry.k_max) if geometry.k_max else int(cfg.k_max))
    req = stream.required_geometry()
    if not geom.covers(req):
        raise ValueError(
            f"geometry=(n={geom.n}, max_deg={geom.max_deg}) cannot ingest "
            f"this stream: it requires at least (n={req.n}, "
            f"max_deg={req.max_deg})")
    if geom.k_max < cfg.k_init:
        raise ValueError(
            f"geometry k_max={geom.k_max} is smaller than cfg.k_init="
            f"{cfg.k_init}: the initial partitions would not fit")
    return geom
