from repro.optim.optimizers import (
    adamw, sgd_momentum, cosine_schedule, linear_warmup_cosine,
    clip_by_global_norm, apply_updates, Optimizer,
)
from repro.optim.compression import (
    int8_compress, int8_decompress, compressed_allreduce_grads,
    init_error_feedback,
)

__all__ = [
    "adamw", "sgd_momentum", "cosine_schedule", "linear_warmup_cosine",
    "clip_by_global_norm", "apply_updates", "Optimizer",
    "int8_compress", "int8_decompress", "compressed_allreduce_grads",
    "init_error_feedback",
]
