"""Error-feedback int8 gradient compression for data-parallel all-reduce.

At 1000+ nodes the DP gradient all-reduce dominates the step for small
models/large meshes; int8 with per-tensor scale cuts collective bytes 4×
(fp32) / 2× (bf16). Error feedback keeps the quantisation bias out of the
long-run trajectory (Karimireddy et al., arXiv:1901.09847).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def int8_compress(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decompress(q, scale):
    return q.astype(jnp.float32) * scale


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_allreduce_grads(grads, err, axis_name: str):
    """Quantise (grad + error), all-reduce int32-accumulated int8 payloads,
    keep the residual. Returns (mean_grads, new_err).

    Inside shard_map/pmap with `axis_name` bound. The int8 payload is what
    crosses ICI; accumulation upcasts to int32 (no overflow for ≤2^23 ranks).
    """
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, scale = int8_compress(gf)
        new_e = gf - int8_decompress(q, scale)
        tot = jax.lax.psum(q.astype(jnp.int32), axis_name)
        tot_scale = jax.lax.psum(scale, axis_name)
        n = jax.lax.psum(1, axis_name)
        # per-rank scales differ: decode with the mean scale (bias captured
        # by error feedback next step)
        return (tot.astype(jnp.float32) * (tot_scale / n) / n).astype(g.dtype), new_e

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(tdef, [o[0] for o in out]),
            jax.tree.unflatten(tdef, [o[1] for o in out]))
