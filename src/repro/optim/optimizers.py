"""Optimizers from scratch (no optax offline): AdamW, SGD+momentum,
schedules, global-norm clipping. Functional optax-like API:

    opt = adamw(schedule, ...)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

Optimizer state dtype is fp32 regardless of param dtype (bf16 training).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable


def cosine_schedule(base_lr: float, total_steps: int, final_frac: float = 0.1):
    def lr(step):
        t = jnp.minimum(step, total_steps) / max(total_steps, 1)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
        return base_lr * (final_frac + (1 - final_frac) * cos)
    return lr


def linear_warmup_cosine(base_lr: float, warmup: int, total_steps: int,
                         final_frac: float = 0.1):
    cos = cosine_schedule(base_lr, max(total_steps - warmup, 1), final_frac)
    def lr(step):
        w = jnp.minimum(step / max(warmup, 1), 1.0)
        return jnp.where(step < warmup, base_lr * w, cos(step - warmup))
    return lr


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gn


def adamw(lr: Callable | float, b1=0.9, b2=0.95, eps=1e-8,
          weight_decay=0.1, clip_norm: float = 1.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32),
            "gnorm": jnp.zeros((), jnp.float32),
        }

    def update(grads, state, params):
        if clip_norm > 0:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        else:
            _, gnorm = clip_by_global_norm(grads, 1e30)
        c = state["count"] + 1
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                          state["mu"], grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g.astype(jnp.float32) ** 2,
                          state["nu"], grads)
        mh = jax.tree.map(lambda m: m / (1 - b1 ** c), mu)
        vh = jax.tree.map(lambda v: v / (1 - b2 ** c), nu)
        step_lr = lr_fn(c)
        upd = jax.tree.map(
            lambda m, v, p: (-step_lr * (m / (jnp.sqrt(v) + eps)
                                         + weight_decay * p.astype(jnp.float32))
                             ).astype(p.dtype),
            mh, vh, params)
        return upd, {"mu": mu, "nu": nu, "count": c, "gnorm": gnorm}

    return Optimizer(init, update)


def sgd_momentum(lr: Callable | float, momentum=0.9,
                 clip_norm: float = 0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return {
            "mom": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        if clip_norm > 0:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        c = state["count"] + 1
        mom = jax.tree.map(
            lambda m, g: momentum * m + g.astype(jnp.float32),
            state["mom"], grads)
        upd = jax.tree.map(lambda m, p: (-lr_fn(c) * m).astype(p.dtype),
                           mom, params)
        return upd, {"mom": mom, "count": c}

    return Optimizer(init, update)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u, params, updates)
