"""Fused Pallas window chooser: gather→score→argmax→commit in ONE kernel.

The mixed-window engine (`repro.core.windowed._window_mixed_lane`) walks a
window of W events with a lax.scan whose carry includes a dense O(n) label
journal: every slot re-gathers neighbour labels through HBM, scores them,
runs the policy chooser, and scatters the decision back into the journal.
That per-slot HBM round-trip is the remaining hot-path cost (ROADMAP "fuse
the chooser").

This kernel keeps the whole window resident in VMEM instead. The insight
making that possible: *which* labels a slot can observe is choice-
independent — presence, adjacency, freshness, and "which earlier slot last
touched this vertex" depend only on the event structure, never on the
partition decisions. So a cheap choice-independent prep pass
(`ops._prepare_window`, batched XLA outside the kernel) reduces the O(n)
journal to three window-local **touch tables**:

* ``src_lbl[i, d]`` — the *committed* label of slot i's d-th score-source
  vertex (−1 if absent/padded), gathered once;
* ``touch[i, d]`` — the index of the last earlier slot that re-labelled
  that vertex (−1 if none): the in-window label is then
  ``w_label[touch[i, d]]``, a (W,) VMEM lookup;
* a per-slot scalar row (event code, subject vertex, fresh/was/exists
  flags, the subject's and deletion-peer's committed label + touch index).

Inside the kernel a ``fori_loop`` carries only O(K) counters plus the
(W,) ``w_label`` decision vector and a (K,) ``remap`` composing scale-in
merges over committed labels — the score tile never leaves VMEM, and the
policy chooser is the *same table* as the engines
(``transition.make_table_chooser``: the ``make_chooser`` bodies with the
single random draw precomputed by ``transition.rand_index_table``). Both
knob bindings exist: static policy string (single runs) and traced
policy_idx via lax.switch on a kernel scalar (sweep lanes, vmapped over
the pallas_call).

Bit-identity with `run_stream` (all policies, autoscale on, interleaved
churn) is the contract — tests/test_fused_chooser.py; `ref.py` is the
same slot step driven by lax.scan for kernel-vs-oracle triangulation.
Interpret-mode policy and histogram masking come from
`repro.kernels.common` (shared with `partition_affinity`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import transition as tx
from repro.core.windowed import SmallState
from repro.graph.stream import EVENT_ADD, EVENT_DEL_VERTEX
from repro.kernels.common import label_histogram, resolve_interpret

# per-slot scalar row layout (ops._prepare_window packs, the kernel unpacks)
EV_ET, EV_V, EV_FRESH, EV_WAS, EV_EXISTS = 0, 1, 2, 3, 4
EV_VLBL, EV_VTOUCH, EV_ULBL, EV_UTOUCH = 5, 6, 7, 8
EV_COLS = 9

# scalar-counter vector layout (window in/out)
SCAL_NP, SCAL_TOTAL, SCAL_CUT, SCAL_DENIED, SCAL_SCALE = 0, 1, 2, 3, 4
SCAL_N = 5


def _scale_in_touch(small: SmallState, w_label, remap, kn):
    """transition.scale_in on the touch-table representation: the trigger
    and counter merges are shared with the faithful engine
    (`windowed._scale_in_journal`); only the relabel target differs — the
    (W,) in-window decisions and the (K,) committed-label remap instead of
    the O(n) journal. Future slots' w_label entries are −1 and src is
    always a valid partition id, so the select cannot corrupt them."""
    src, dst, do = tx.scale_in_trigger(small, kn)

    def migrate(args):
        sm, wl, rm = args
        sm2 = sm._replace(
            edge_load=sm.edge_load.at[dst].add(
                sm.edge_load[src]).at[src].set(0),
            vertex_count=sm.vertex_count.at[dst].add(
                sm.vertex_count[src]).at[src].set(0),
            active=sm.active.at[src].set(False),
            num_partitions=sm.num_partitions - 1,
            cut_edges=sm.cut_edges - sm.cut_matrix[src, dst],
            cut_matrix=tx.merge_cut_matrix(sm.cut_matrix, src, dst),
            scale_events=sm.scale_events + 1,
        )
        return sm2, jnp.where(wl == src, dst, wl), jnp.where(rm == src, dst, rm)

    return jax.lax.cond(do, migrate, lambda a: a, (small, w_label, remap))


def make_slot_step(*, k_max: int, n: int, choose, autoscaling: bool,
                   dynamic: bool):
    """One window slot on the touch-table representation.

    ``choose`` is a ``transition.make_table_chooser`` chooser. The body
    mirrors ``windowed._window_mixed_lane``'s scan step op-for-op (same
    cores, same masked counter merge, same scale gates) with the journal
    gathers replaced by touch-table lookups — the seam both the Pallas
    kernel and the `ref.py` lax.scan oracle drive, so they cannot drift.
    """

    def slot_step(small: SmallState, w_label, remap, kn, do_scale, i,
                  ev, src_lbl, touch, rand_row):
        et = ev[EV_ET]
        v = ev[EV_V]
        fresh = ev[EV_FRESH] != 0
        was = ev[EV_WAS] != 0
        exists = ev[EV_EXISTS] != 0
        add_i = et == EVENT_ADD
        dv_i = et == EVENT_DEL_VERTEX

        # --- scale-out before the ADD decision (faithful apply_add) ---
        if autoscaling:
            gate = add_i if not dynamic else add_i & do_scale
            scaled = tx.scale_out(small, kn)
            small = jax.tree_util.tree_map(
                lambda a, b: jnp.where(gate, a, b), scaled, small)

        def label_at(lbl_c, touch_i):
            """Current label: last in-window decision if touched, else the
            committed label pushed through the scale-in remap."""
            in_win = w_label[jnp.maximum(touch_i, 0)]
            committed = jnp.where(lbl_c >= 0,
                                  remap[jnp.maximum(lbl_c, 0)], -1)
            return jnp.where(touch_i >= 0, in_win, committed)

        # --- effective neighbour labels + affinity (paper Eq. 1) ---
        eff = label_at(src_lbl, touch)                       # (D,)
        sc_eff, deg_k = label_histogram(eff, k_max)
        deg_eff = deg_k[0]
        ridx = rand_row[jnp.maximum(small.num_partitions, 1) - 1]
        p = choose(small, sc_eff, deg_eff, v, ridx, kn, n)
        d_add = jnp.where(fresh, deg_eff, 0)
        sc_a = jnp.where(fresh, sc_eff, 0)

        # --- DEL_VERTEX / DEL_EDGE quantities (faithful cores) ---
        vl = label_at(ev[EV_VLBL], ev[EV_VTOUCH])
        ul = label_at(ev[EV_ULBL], ev[EV_UTOUCH])
        p_dv = jnp.maximum(vl, 0)
        d_dv = jnp.where(was, deg_eff, 0)
        sc_d = jnp.where(was, sc_eff, 0)
        pu = jnp.maximum(ul, 0)
        e = exists.astype(jnp.int32)
        cutdec = (exists & (p_dv != pu)).astype(jnp.int32)

        # --- masked counter merge (one event type per slot ⇒ exact) ---
        small = small._replace(
            vertex_count=(small.vertex_count
                          .at[p].add(fresh.astype(jnp.int32))
                          .at[p_dv].add(-was.astype(jnp.int32))),
            edge_load=((small.edge_load + sc_a - sc_d)
                       .at[p].add(d_add).at[p_dv].add(-d_dv)
                       .at[p_dv].add(-e).at[pu].add(-e)),
            total_edges=small.total_edges + d_add - d_dv - e,
            cut_edges=(small.cut_edges + (d_add - sc_a[p])
                       - (d_dv - sc_d[p_dv]) - cutdec),
            cut_matrix=(small.cut_matrix
                        .at[p, :].add(sc_a).at[:, p].add(sc_a)
                        .at[p_dv, :].add(-sc_d).at[:, p_dv].add(-sc_d)
                        .at[p_dv, pu].add(-e).at[pu, p_dv].add(-e)),
        )

        # --- record the slot's label decision (add/dv touch the subject;
        # del_edge leaves labels unchanged, so its slot stays -1 and no
        # later touch index ever points at it) ---
        new_lbl = jnp.where(add_i, jnp.where(fresh, p, vl),
                            jnp.where(dv_i, -1, vl))
        w_label = w_label.at[i].set(jnp.where(add_i | dv_i, new_lbl, -1))

        # --- scale-in after DEL_VERTEX (faithful apply_del_vertex) ---
        if autoscaling:
            gate_dv = dv_i if not dynamic else dv_i & do_scale
            small, w_label, remap = jax.lax.cond(
                gate_dv,
                lambda args: _scale_in_touch(args[0], args[1], args[2], kn),
                lambda args: args,
                (small, w_label, remap),
            )
        return small, w_label, remap, p

    return slot_step


def _read_small(active_ref, loads_ref, cutmat_ref, scal_ref) -> SmallState:
    return SmallState(
        active=active_ref[...] != 0,
        edge_load=loads_ref[0, :],
        vertex_count=loads_ref[1, :],
        num_partitions=scal_ref[SCAL_NP],
        total_edges=scal_ref[SCAL_TOTAL],
        cut_edges=scal_ref[SCAL_CUT],
        denied_scaleout=scal_ref[SCAL_DENIED],
        scale_events=scal_ref[SCAL_SCALE],
        cut_matrix=cutmat_ref[...],
    )


def _fused_kernel(ev_ref, srclbl_ref, touch_ref, rand_ref, active_ref,
                  loads_ref, cutmat_ref, scal_ref, knobs_ref, flags_ref,
                  wlabel_ref, psel_ref, remap_ref, active_o_ref, loads_o_ref,
                  cutmat_o_ref, scal_o_ref, *, w: int, k_max: int, n: int,
                  policy: str | None, balance_guard: str, autoscaling: bool,
                  dynamic: bool):
    """Single-program kernel: the whole window's refs live in VMEM; a
    fori_loop walks the W slots carrying only O(K)+O(W) values. Policy
    dispatch is static (trace-time table pick) when ``policy`` is a
    string, else a lax.switch over the table on the ``flags`` scalar."""
    kn = tx.Knobs(*(knobs_ref[j] for j in range(7)))
    if policy is not None:
        choose = tx.make_table_chooser(balance_guard, policy=policy)
    else:
        choose = tx.make_table_chooser(balance_guard,
                                       policy_idx=flags_ref[0])
    do_scale = flags_ref[1] != 0
    slot_step = make_slot_step(k_max=k_max, n=n, choose=choose,
                               autoscaling=autoscaling, dynamic=dynamic)

    small0 = _read_small(active_ref, loads_ref, cutmat_ref, scal_ref)
    w_label0 = jnp.full((w,), -1, jnp.int32)
    remap0 = jnp.arange(k_max, dtype=jnp.int32)
    psel0 = jnp.zeros((w,), jnp.int32)

    def body(i, carry):
        small, w_label, remap, psel = carry
        small, w_label, remap, p = slot_step(
            small, w_label, remap, kn, do_scale, i,
            ev_ref[i, :], srclbl_ref[i, :], touch_ref[i, :], rand_ref[i, :])
        return small, w_label, remap, psel.at[i].set(p)

    small, w_label, remap, psel = jax.lax.fori_loop(
        0, w, body, (small0, w_label0, remap0, psel0))

    wlabel_ref[...] = w_label
    psel_ref[...] = psel
    remap_ref[...] = remap
    active_o_ref[...] = small.active.astype(jnp.int32)
    loads_o_ref[...] = jnp.stack([small.edge_load, small.vertex_count])
    cutmat_o_ref[...] = small.cut_matrix
    scal_o_ref[...] = jnp.stack([
        small.num_partitions, small.total_edges, small.cut_edges,
        small.denied_scaleout, small.scale_events])


def fused_window_choose(ev, src_lbl, touch, rand_tab, active, edge_load,
                        vertex_count, cut_matrix, scalars, knobs, flags, *,
                        n: int, policy: str | None, balance_guard: str,
                        autoscaling: bool, dynamic: bool,
                        interpret: bool | None = None):
    """One pallas_call for one whole window.

    Inputs are the prep tables (`ops._prepare_window`), the per-slot random
    table (`transition.rand_index_table`), and the O(K) counter slice;
    outputs are (w_label, p_sel, remap, active, loads, cut_matrix,
    scalars). ``interpret=None`` defers to
    ``repro.kernels.common.default_interpret``. vmap over this call is the
    sweep's lane batching (pallas_call lifts the batch to a grid axis).
    """
    interpret = resolve_interpret(interpret)
    w = ev.shape[0]
    k_max = int(rand_tab.shape[-1])
    loads = jnp.stack([edge_load, vertex_count])
    kernel = functools.partial(
        _fused_kernel, w=w, k_max=k_max, n=n, policy=policy,
        balance_guard=balance_guard, autoscaling=autoscaling, dynamic=dynamic)
    return pl.pallas_call(
        kernel,
        out_shape=[
            jax.ShapeDtypeStruct((w,), jnp.int32),            # w_label
            jax.ShapeDtypeStruct((w,), jnp.int32),            # p_sel
            jax.ShapeDtypeStruct((k_max,), jnp.int32),        # remap
            jax.ShapeDtypeStruct((k_max,), jnp.int32),        # active
            jax.ShapeDtypeStruct((2, k_max), jnp.int32),      # loads
            jax.ShapeDtypeStruct((k_max, k_max), jnp.int32),  # cut_matrix
            jax.ShapeDtypeStruct((SCAL_N,), jnp.int32),       # scalars
        ],
        interpret=interpret,
    )(ev, src_lbl, touch, rand_tab, active.astype(jnp.int32), loads,
      cut_matrix, scalars, knobs, flags)
