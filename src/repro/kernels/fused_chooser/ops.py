"""Wrappers wiring the fused chooser kernel into the engines.

Pipeline per mixed window (see fused_chooser.py for the design):

  1. `_prepare_window` — choice-independent prep: a lean lax.scan over the
     W slots carrying (adj, present, last_touch) that emits the per-slot
     scalar rows and the (W, D) committed-label / touch-index tables, and
     performs the faithful adjacency row writes (adjacency evolution never
     depends on partition choices). Batched XLA, outside the kernel.
  2. `transition.rand_index_table` — the per-slot random draw precomputed
     for every possible partition count (bit-identical to the engines'
     fold_in/randint scheme).
  3. ONE `fused_window_choose` pallas_call — gather (from VMEM-resident
     touch tables) → score → policy argmax → counter/cut_matrix commit
     for all W slots.
  4. `_apply` — two O(n) gathers rebuild the final journal from
     (w_label, remap): ``label = w_label[last_touch]`` where touched,
     else ``remap[committed]``.

`run_window_mixed_fused` is the static-knob drop-in for
`windowed.run_window_mixed`; `sweep_window_mixed_fused` is the traced-knob
lane-batched drop-in for `windowed.sweep_window_mixed` (vmapped
pallas_call). ``variant="ref"`` swaps the kernel for the `ref.py` oracle.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import transition as tx
from repro.core.config import EngineConfig
from repro.core.geometry import check_row_width
from repro.core.state import PartitionState
from repro.graph.stream import (
    EVENT_ADD, EVENT_DEL_EDGE, EVENT_DEL_VERTEX, EVENT_PAD,
)
from repro.kernels.fused_chooser import fused_chooser as fk
from repro.kernels.fused_chooser.fused_chooser import fused_window_choose
from repro.kernels.fused_chooser.ref import fused_window_choose_ref


class WindowPrep(NamedTuple):
    """Choice-independent window tables (see module docstring)."""
    ev: jax.Array          # (W, EV_COLS) per-slot scalars
    src_lbl: jax.Array     # (W, D) committed labels of score sources
    touch: jax.Array       # (W, D) last label-touching slot (< i), -1 none
    label0: jax.Array      # (n,) committed journal (present ? label : -1)
    last_touch: jax.Array  # (n,) final label-touching slot per vertex
    adj: jax.Array         # (n, D) post-window adjacency


def _prepare_window(state: PartitionState, ets, vs, rows) -> WindowPrep:
    """The prep scan. Presence, adjacency, freshness, and touch indices
    depend only on the event structure — never on partition choices — so
    this runs as plain batched XLA and the kernel's slot loop needs no
    O(n) state at all. The adjacency row writes replicate
    `_window_mixed_lane` op-for-op (incl. the self-loop aliasing order of
    the two DEL_EDGE row writes)."""
    n = state.assignment.shape[0]
    w = vs.shape[0]
    ets = jnp.where(vs >= 0, ets, EVENT_PAD)
    is_add = ets == EVENT_ADD
    is_dv = ets == EVENT_DEL_VERTEX
    is_de = ets == EVENT_DEL_EDGE
    safe_vs = jnp.where(vs >= 0, vs, 0)
    label0 = jnp.where(state.present, state.assignment, -1)
    rows_add = jnp.where(is_add[:, None], rows, -1)

    def step(carry, i):
        adj, present, last_touch = carry
        v = safe_vs[i]
        row = rows[i]
        add_i, dv_i, de_i = is_add[i], is_dv[i], is_de[i]
        own_row = adj[v]
        u = row[0]
        safe_u = jnp.maximum(u, 0)

        fresh = add_i & ~present[v]
        was = dv_i & present[v]
        in_adj = jnp.any(own_row == u) & (u >= 0)
        exists = de_i & present[v] & present[safe_u] & in_adj

        src_row = jnp.where(add_i, rows_add[i], jnp.where(dv_i, own_row, -1))
        src_safe = jnp.maximum(src_row, 0)
        src_lbl = jnp.where(src_row >= 0, label0[src_safe], -1)
        touch = jnp.where(src_row >= 0, last_touch[src_safe], -1)

        ev = jnp.stack([
            ets[i], v, fresh.astype(jnp.int32), was.astype(jnp.int32),
            exists.astype(jnp.int32), label0[v], last_touch[v],
            label0[safe_u], last_touch[safe_u],
        ])

        # presence / touch updates (add and del_vertex touch the subject)
        tgt = jnp.where(add_i | dv_i, v, n)
        present = present.at[tgt].set(add_i, mode="drop")
        last_touch = last_touch.at[tgt].set(i, mode="drop")

        # faithful adjacency row writes (windowed._window_mixed_lane)
        row_v_de = jnp.where((own_row == u) & (u >= 0), -1, own_row)
        w1_val = jnp.where(add_i, row, jnp.where(de_i, row_v_de, own_row))
        w1_tgt = jnp.where(fresh | de_i, v, n)
        adj = adj.at[w1_tgt].set(w1_val, mode="drop")
        row_u = adj[safe_u]                   # after write 1 (self-loops)
        row_u_de = jnp.where((row_u == v) & (u >= 0), -1, row_u)
        adj = adj.at[jnp.where(de_i, safe_u, n)].set(row_u_de, mode="drop")
        return (adj, present, last_touch), (ev, src_lbl, touch)

    last_touch0 = jnp.full((n,), -1, jnp.int32)
    (adj, _, last_touch), (ev, src_lbl, touch) = jax.lax.scan(
        step, (state.adj, state.present, last_touch0),
        jnp.arange(w, dtype=jnp.int32))
    return WindowPrep(ev, src_lbl, touch, label0, last_touch, adj)


def _fused_lane(
    state: PartitionState,
    ets, vs, rows, t0,
    knobs,               # (7,) f32 (transition.Knobs field order)
    flags,               # (2,) int32 [policy_idx, do_scale]
    *,
    policy: str | None,
    balance_guard: str,
    autoscaling: bool,
    dynamic: bool,
    interpret: bool | None = None,
    variant: str = "pallas",
) -> PartitionState:
    """One mixed window through prep → rand table → kernel → apply."""
    n = state.assignment.shape[0]
    w = vs.shape[0]
    k_max = state.edge_load.shape[0]
    prep = _prepare_window(state, ets, vs, rows)
    rand_tab = tx.rand_index_table(state.key, t0, w, k_max)
    scalars = jnp.stack([
        state.num_partitions, state.total_edges, state.cut_edges,
        state.denied_scaleout, state.scale_events])
    call = fused_window_choose if variant == "pallas" else \
        fused_window_choose_ref
    kwargs = {} if variant == "ref" else {"interpret": interpret}
    w_label, _psel, remap, active, loads, cut_matrix, scal = call(
        prep.ev, prep.src_lbl, prep.touch, rand_tab,
        state.active, state.edge_load, state.vertex_count, state.cut_matrix,
        scalars, knobs, flags, n=n, policy=policy,
        balance_guard=balance_guard, autoscaling=autoscaling,
        dynamic=dynamic, **kwargs)

    # apply: rebuild the journal from the window-local decisions — two
    # O(n) gathers, no scatter ordering to get wrong
    lbl_touched = w_label[jnp.clip(prep.last_touch, 0, w - 1)]
    lbl_kept = jnp.where(prep.label0 >= 0,
                         remap[jnp.maximum(prep.label0, 0)], -1)
    label_final = jnp.where(prep.last_touch >= 0, lbl_touched, lbl_kept)
    return state._replace(
        assignment=label_final, present=label_final >= 0, adj=prep.adj,
        active=active != 0, edge_load=loads[0], vertex_count=loads[1],
        num_partitions=scal[fk.SCAL_NP], total_edges=scal[fk.SCAL_TOTAL],
        cut_edges=scal[fk.SCAL_CUT], denied_scaleout=scal[fk.SCAL_DENIED],
        scale_events=scal[fk.SCAL_SCALE], cut_matrix=cut_matrix,
    )


def _run_window_mixed_fused(
    state: PartitionState,
    ets, vs, rows, t0,
    *,
    policy: str,
    cfg: EngineConfig,
    interpret: bool | None = None,
    variant: str = "pallas",
) -> PartitionState:
    """Drop-in for `windowed._run_window_mixed` under the static knob,
    bit-identical to the faithful engine. Unjitted body —
    `run_window_mixed_fused` is the plain jitted binding;
    repro.api.partitioner re-jits it with the carried state donated."""
    check_row_width(state, rows)
    n = state.assignment.shape[0]
    kn = tx.make_knobs(cfg, n)
    knobs = jnp.stack([jnp.float32(x) for x in kn])
    flags = jnp.array([0, 1], jnp.int32)
    return _fused_lane(
        state, ets, vs, rows, t0, knobs, flags,
        policy=policy, balance_guard=cfg.balance_guard,
        autoscaling=policy == "sdp" and cfg.autoscale,
        dynamic=False, interpret=interpret, variant=variant)


run_window_mixed_fused = functools.partial(
    jax.jit, static_argnames=("policy", "cfg", "interpret", "variant"),
)(_run_window_mixed_fused)


def sweep_window_mixed_fused(
    states: PartitionState,   # stacked (L, ...) lanes
    kns: tx.Knobs,            # stacked (L,) f32 knobs
    policy_idx: jax.Array,    # (L,) int32 into POLICIES order
    autoscale: jax.Array,     # (L,) bool (cfg.autoscale per lane)
    ets, vs, rows,            # (L, T) per-lane — or (T,) shared — events
    t0,
    *,
    balance_guard: str,
    autoscale_mode: str,      # "off" | "dynamic"
    window: int = 256,
    shared_stream: bool = False,
    interpret: bool | None = None,
    variant: str = "pallas",
) -> PartitionState:
    """Drop-in for `windowed.sweep_window_mixed` with the slot loop fused
    into the Pallas chooser: per lane, lax.scan over windows whose body
    dynamic-slices the next window and runs `_fused_lane` under the traced
    knob (policy via lax.switch on a kernel scalar, autoscale via the
    per-lane runtime gate). The vmap over lanes lifts the pallas_call's
    batch to a grid axis — one kernel launch per window step covering all
    lanes. Same contract as the XLA version: T a multiple of ``window``,
    ``shared_stream`` broadcast semantics, not jitted here (the sweep
    runtime wraps it)."""
    check_row_width(states, rows)
    dynamic = autoscale_mode == "dynamic"
    sdp_idx = tx.POLICY_INDEX["sdp"]

    def one_lane(state, kn, pidx, auto, ets_l, vs_l, rows_l):
        do = auto & (pidx == sdp_idx)
        knobs = jnp.stack([jnp.float32(x) for x in kn])
        gate = do if dynamic else jnp.bool_(True)
        flags = jnp.stack([pidx, gate.astype(jnp.int32)])
        n_windows = ets_l.shape[0] // window

        def body(s, wdx):
            i0 = wdx * window
            s = _fused_lane(
                s,
                jax.lax.dynamic_slice_in_dim(ets_l, i0, window),
                jax.lax.dynamic_slice_in_dim(vs_l, i0, window),
                jax.lax.dynamic_slice_in_dim(rows_l, i0, window),
                t0 + i0, knobs, flags,
                policy=None, balance_guard=balance_guard,
                autoscaling=dynamic, dynamic=dynamic,
                interpret=interpret, variant=variant)
            return s, None

        s, _ = jax.lax.scan(body, state,
                            jnp.arange(n_windows, dtype=jnp.int32))
        return s

    ax = None if shared_stream else 0
    if shared_stream:
        lanes = states.assignment.shape[0]
        ets = jnp.broadcast_to(ets, (lanes,) + ets.shape)
        vs = jnp.broadcast_to(vs, (lanes,) + vs.shape)
    return jax.vmap(one_lane, in_axes=(0, 0, 0, 0, 0, 0, ax))(
        states, kns, policy_idx, autoscale, ets, vs, rows)
