"""Oracle for the fused window chooser: the SAME slot step
(`fused_chooser.make_slot_step`) driven by a plain lax.scan instead of the
Pallas fori_loop, with no pallas_call anywhere. Used to triangulate
failures — kernel vs ref isolates Pallas lowering issues, ref vs the
faithful `_window_mixed_lane` isolates touch-table prep issues."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import transition as tx
from repro.core.windowed import SmallState
from repro.kernels.fused_chooser.fused_chooser import (
    SCAL_CUT, SCAL_DENIED, SCAL_NP, SCAL_SCALE, SCAL_TOTAL, make_slot_step,
)


def fused_window_choose_ref(ev, src_lbl, touch, rand_tab, active, edge_load,
                            vertex_count, cut_matrix, scalars, knobs, flags,
                            *, n: int, policy: str | None, balance_guard: str,
                            autoscaling: bool, dynamic: bool):
    """Same signature and outputs as `fused_chooser.fused_window_choose`
    (minus ``interpret``), pure XLA."""
    w = ev.shape[0]
    k_max = int(rand_tab.shape[-1])
    kn = tx.Knobs(*(knobs[j] for j in range(7)))
    if policy is not None:
        choose = tx.make_table_chooser(balance_guard, policy=policy)
    else:
        choose = tx.make_table_chooser(balance_guard, policy_idx=flags[0])
    do_scale = flags[1] != 0
    slot_step = make_slot_step(k_max=k_max, n=n, choose=choose,
                               autoscaling=autoscaling, dynamic=dynamic)

    small0 = SmallState(
        active=active != 0, edge_load=edge_load, vertex_count=vertex_count,
        num_partitions=scalars[SCAL_NP], total_edges=scalars[SCAL_TOTAL],
        cut_edges=scalars[SCAL_CUT], denied_scaleout=scalars[SCAL_DENIED],
        scale_events=scalars[SCAL_SCALE], cut_matrix=cut_matrix)
    w_label0 = jnp.full((w,), -1, jnp.int32)
    remap0 = jnp.arange(k_max, dtype=jnp.int32)

    def body(carry, xs):
        small, w_label, remap = carry
        i, ev_i, src_i, touch_i, rand_i = xs
        small, w_label, remap, p = slot_step(
            small, w_label, remap, kn, do_scale, i, ev_i, src_i, touch_i,
            rand_i)
        return (small, w_label, remap), p

    (small, w_label, remap), psel = jax.lax.scan(
        body, (small0, w_label0, remap0),
        (jnp.arange(w, dtype=jnp.int32), ev, src_lbl, touch, rand_tab))
    return (w_label, psel, remap, small.active.astype(jnp.int32),
            jnp.stack([small.edge_load, small.vertex_count]),
            small.cut_matrix,
            jnp.stack([small.num_partitions, small.total_edges,
                       small.cut_edges, small.denied_scaleout,
                       small.scale_events]))
