"""Pure-jnp oracle for segment_spmm (take + masked reduce over ELL rows)."""
from __future__ import annotations

import jax.numpy as jnp


def segment_spmm_ref(x, adj_ell, *, mode: str = "sum"):
    valid = adj_ell >= 0
    safe = jnp.where(valid, adj_ell, 0)
    rows = jnp.take(x, safe, axis=0)                      # (N, Dmax, F)
    rows = jnp.where(valid[..., None], rows.astype(jnp.float32), 0.0)
    out = rows.sum(axis=1)
    if mode == "mean":
        cnt = jnp.maximum(valid.sum(axis=1, keepdims=True), 1)
        out = out / cnt
    return out
