"""Differentiable ELL aggregation: kernel forward, gather-transpose backward."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.segment_spmm.segment_spmm import segment_spmm
from repro.kernels.segment_spmm.ref import segment_spmm_ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def ell_aggregate(x, adj_ell, mode="sum", use_kernel=False):
    if use_kernel:
        return segment_spmm(x, adj_ell, mode=mode)
    return segment_spmm_ref(x, adj_ell, mode=mode)


def _fwd(x, adj_ell, mode, use_kernel):
    return ell_aggregate(x, adj_ell, mode, use_kernel), (x.shape, adj_ell)


def _bwd(mode, use_kernel, res, g):
    (n, f), adj_ell = res
    valid = adj_ell >= 0
    if mode == "mean":
        cnt = jnp.maximum(valid.sum(axis=1, keepdims=True), 1)
        g = g / cnt
    gl = jnp.broadcast_to(g[:, None, :], adj_ell.shape + (f,))
    gl = jnp.where(valid[..., None], gl, 0.0)
    safe = jnp.where(valid, adj_ell, 0)
    dx = jnp.zeros((n, f), g.dtype).at[safe.reshape(-1)].add(gl.reshape(-1, f))
    return dx, None


ell_aggregate.defvjp(_fwd, _bwd)
