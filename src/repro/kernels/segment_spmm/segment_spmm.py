"""Pallas TPU kernel: ELL-padded sparse aggregation (GNN message passing).

Computes ``out[v] = reduce_d x[adj[v, d]]`` over an ELL (row-padded)
adjacency — the SpMM at the heart of GCN/PNA/MeshGraphNet aggregation.

TPU adaptation: scatter-free. Instead of the GPU scatter-add over an edge
list, rows are processed in blocks; the neighbour ids are scalar-prefetched
and the BlockSpec index_map streams exactly the needed (1, block_f) feature
tiles HBM→VMEM (same gather-by-index_map pattern as embedding_bag — on TPU
the pipelined DMA is the analogue of the GPU's gather warp). The output
row tile accumulates in VMEM across the innermost neighbour-slot axis.

Grid: (N, F/block_f, Dmax) — Dmax innermost for accumulation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import resolve_interpret


def _spmm_kernel(adj_ref, x_ref, out_ref, *, n_slots: int, mean: bool):
    i = pl.program_id(0)
    sl = pl.program_id(2)

    @pl.when(sl == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    valid = adj_ref[i, sl] >= 0
    out_ref[...] += jnp.where(valid, x_ref[...].astype(jnp.float32), 0.0)

    if mean:
        @pl.when(sl == n_slots - 1)
        def _finalize():
            cnt = jnp.zeros((), jnp.float32)
            for j in range(n_slots):
                cnt += (adj_ref[i, j] >= 0).astype(jnp.float32)
            out_ref[...] /= jnp.maximum(cnt, 1.0)


@functools.partial(jax.jit, static_argnames=("mode", "block_f", "interpret"))
def segment_spmm(
    x: jax.Array,        # (N, F) float — node features
    adj_ell: jax.Array,  # (N, Dmax) int32, -1 padded — neighbour ids
    *,
    mode: str = "sum",   # 'sum' | 'mean'
    block_f: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """(N, F) aggregated neighbour features."""
    interpret = resolve_interpret(interpret)
    n, f = x.shape
    _, dmax = adj_ell.shape
    bf = min(block_f, f)
    pad_f = (-f) % bf
    if pad_f:
        x = jnp.pad(x, ((0, 0), (0, pad_f)))
    fp = x.shape[1]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n, fp // bf, dmax),
        in_specs=[
            pl.BlockSpec(
                (1, bf),
                lambda i, jf, sl, adj_ref: (jnp.maximum(adj_ref[i, sl], 0), jf),
            ),
        ],
        out_specs=pl.BlockSpec((1, bf), lambda i, jf, sl, adj_ref: (i, jf)),
    )
    out = pl.pallas_call(
        functools.partial(_spmm_kernel, n_slots=dmax, mean=(mode == "mean")),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, fp), jnp.float32),
        interpret=interpret,
    )(adj_ell.astype(jnp.int32), x)
    return out[:, :f]
