"""Pure-jnp oracle for flash_attention (also the differentiable train path)."""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(
    q, k, v, *, causal: bool = True, window: int = 0, softcap: float = 0.0,
    q_offset: int = 0,
):
    """Dense attention with GQA / sliding window / softcap / position offset.

    q: (B, H, Sq, D); k, v: (B, Hkv, Sk, D). Returns (B, H, Sq, D).
    """
    b, h, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    group = h // hkv
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * (d ** -0.5)
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = q_offset + jnp.arange(sq)[:, None]
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, -1e30)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32)).astype(q.dtype)
