"""Differentiable wrapper: Pallas forward, reference-recompute backward.

On TPU the backward pass would be a second Pallas kernel; on this CPU
container the custom_vjp recomputes through the jnp reference, which is
mathematically identical (tested to 1e-5).
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7)
)
def attention(q, k, v, causal=True, window=0, softcap=0.0, q_offset=0,
              use_kernel=False):
    if use_kernel:
        return flash_attention(
            q, k, v, causal=causal, window=window, softcap=softcap,
            q_offset=q_offset,
        )
    return attention_ref(
        q, k, v, causal=causal, window=window, softcap=softcap,
        q_offset=q_offset,
    )


def _fwd(q, k, v, causal, window, softcap, q_offset, use_kernel):
    out = attention(q, k, v, causal, window, softcap, q_offset, use_kernel)
    return out, (q, k, v)


def _bwd(causal, window, softcap, q_offset, use_kernel, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q, k, v: attention_ref(
            q, k, v, causal=causal, window=window, softcap=softcap,
            q_offset=q_offset),
        q, k, v,
    )
    return vjp(g)


attention.defvjp(_fwd, _bwd)
