"""Pallas TPU kernel: tiled online-softmax attention (flash attention).

Covers the attention variants of the assigned LM archs:
  * causal LM training / prefill,
  * sliding-window local attention (gemma2 alternating local/global,
    llama4-scout chunked-local — window == chunk),
  * logit soft-capping (gemma2),
  * GQA (q-head → kv-head folding via BlockSpec index_map),
  * decode with a long KV cache (q_offset = cache position).

TPU adaptation: HBM→VMEM tiles of (block_q × d) and (block_k × d); the
running max/denominator/accumulator live in VMEM scratch across the
innermost (kv) grid axis; the two matmuls hit the MXU with d and block
sizes kept multiples of 128 on real hardware (interpret mode off TPU,
resolved by repro.kernels.common.default_interpret).

Forward only: training uses the XLA-differentiable reference path
(``ref.py``), serving and the dry-run use this kernel's semantics. A
custom-vjp wrapper in ops.py recomputes through the reference for autodiff.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import resolve_interpret

_NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, scale: float, causal: bool, window: int, softcap: float,
    q_offset: int, block_q: int, block_k: int, num_k_blocks: int,
    kv_len: int,
):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                   # (bq, d)
    k = k_ref[0]                                   # (bk, d)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale                                      # (bq, bk)
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = q_offset + iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = k_pos < kv_len          # kv padding (always)
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_ref[...]                            # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                         # (bq, bk)
    alpha = jnp.exp(m_prev - m_new)                # (bq, 1)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(ik == num_k_blocks - 1)
    def _finalize():
        l = jnp.where(l_ref[...] == 0.0, 1.0, l_ref[...])
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "q_offset", "block_q", "block_k",
    "interpret"))
def flash_attention(
    q: jax.Array,   # (B, H, Sq, D)
    k: jax.Array,   # (B, Hkv, Sk, D)
    v: jax.Array,   # (B, Hkv, Sk, D)
    *,
    causal: bool = True,
    window: int = 0,          # 0 = unbounded; >0 = sliding window size
    softcap: float = 0.0,     # 0 = disabled
    q_offset: int = 0,        # absolute position of q[0] (decode)
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    interpret = resolve_interpret(interpret)
    b, h, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert h % hkv == 0, "GQA requires H % Hkv == 0"
    group = h // hkv
    scale = d ** -0.5

    bq = min(block_q, sq)
    bk = min(block_k, sk)
    pad_q = (-sq) % bq
    pad_k = (-sk) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        # padded kv positions are masked out because (causal ∨ window) only
        # *shrinks* coverage; for the pure-bidirectional case we pad with the
        # causal mask disabled but rely on k_pos >= sk masking below.
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    sqp, skp = q.shape[2], k.shape[2]

    qf = q.reshape(b * h, sqp, d)
    kf = k.reshape(b * hkv, skp, d)
    vf = v.reshape(b * hkv, skp, d)

    def kv_index(bh, iq, ik):
        return ((bh // h) * hkv + (bh % h) // group, ik, 0)

    grid = (b * h, sqp // bq, skp // bk)
    from jax.experimental.pallas import tpu as pltpu
    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, scale=scale, causal=causal, window=window,
            softcap=softcap, q_offset=q_offset, block_q=bq, block_k=bk,
            num_k_blocks=grid[2], kv_len=sk,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, bk, d), kv_index),
            pl.BlockSpec((1, bk, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sqp, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # running max m
            pltpu.VMEM((bq, 1), jnp.float32),    # running denominator l
            pltpu.VMEM((bq, d), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, sqp, d)[:, :, :sq, :]
