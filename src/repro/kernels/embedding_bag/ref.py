"""Pure-jnp oracle for embedding_bag: jnp.take + masked segment reduce."""
from __future__ import annotations

import jax.numpy as jnp


def embedding_bag_ref(table, indices, *, mode: str = "sum"):
    valid = indices >= 0
    safe = jnp.where(valid, indices, 0)
    rows = jnp.take(table, safe, axis=0)                    # (B, L, D)
    rows = jnp.where(valid[..., None], rows.astype(jnp.float32), 0.0)
    out = rows.sum(axis=1)
    if mode == "mean":
        cnt = jnp.maximum(valid.sum(axis=1, keepdims=True), 1)
        out = out / cnt
    return out
