"""Differentiable EmbeddingBag: kernel forward, segment-sum backward."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.embedding_bag.embedding_bag import embedding_bag
from repro.kernels.embedding_bag.ref import embedding_bag_ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def bag_lookup(table, indices, mode="sum", use_kernel=False):
    if use_kernel:
        return embedding_bag(table, indices, mode=mode)
    return embedding_bag_ref(table, indices, mode=mode)


def _fwd(table, indices, mode, use_kernel):
    return bag_lookup(table, indices, mode, use_kernel), (table.shape, indices)


def _bwd(mode, use_kernel, res, g):
    (v, d), indices = res
    valid = indices >= 0
    if mode == "mean":
        cnt = jnp.maximum(valid.sum(axis=1, keepdims=True), 1)
        g = g / cnt
    gl = jnp.broadcast_to(g[:, None, :], indices.shape + (d,))
    gl = jnp.where(valid[..., None], gl, 0.0)
    safe = jnp.where(valid, indices, 0)
    dtable = jnp.zeros((v, d), g.dtype).at[safe.reshape(-1)].add(
        gl.reshape(-1, d))
    return dtable, None


bag_lookup.defvjp(_fwd, _bwd)
