"""Pallas TPU kernel: EmbeddingBag (gather + bag-reduce).

JAX has no native EmbeddingBag; the recsys tower needs
``out[b] = reduce_l table[idx[b, l]]`` over huge tables. TPU adaptation:
the bag indices are *scalar-prefetched* into SMEM, and the BlockSpec
index_map performs the row gather — the pipeline itself streams exactly
the needed (1, block_d) table tiles HBM→VMEM, no megagather materialised.
Accumulation runs across the innermost (bag-slot) grid axis in the output
VMEM tile. Padding idx = -1 contributes zero via a mask read from SMEM.

Grid: (B, D/block_d, L) — L innermost for accumulation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import resolve_interpret


def _bag_kernel(idx_ref, table_ref, out_ref, *, n_slots: int, mean: bool):
    b = pl.program_id(0)
    l = pl.program_id(2)

    @pl.when(l == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    valid = idx_ref[b, l] >= 0
    row = table_ref[...]                        # (1, bd) gathered by index_map
    out_ref[...] += jnp.where(valid, row.astype(jnp.float32), 0.0)

    if mean:
        @pl.when(l == n_slots - 1)
        def _finalize():
            cnt = jnp.zeros((), jnp.float32)
            for j in range(n_slots):          # n_slots is static
                cnt += (idx_ref[b, j] >= 0).astype(jnp.float32)
            out_ref[...] /= jnp.maximum(cnt, 1.0)


@functools.partial(
    jax.jit, static_argnames=("mode", "block_d", "interpret")
)
def embedding_bag(
    table: jax.Array,     # (V, D) float
    indices: jax.Array,   # (B, L) int32, -1 padded
    *,
    mode: str = "sum",    # 'sum' | 'mean'
    block_d: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """(B, D) bag-reduced embeddings."""
    interpret = resolve_interpret(interpret)
    v, d = table.shape
    bsz, l = indices.shape
    bd = min(block_d, d)
    pad_d = (-d) % bd
    if pad_d:
        table = jnp.pad(table, ((0, 0), (0, pad_d)))
    dp = table.shape[1]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(bsz, dp // bd, l),
        in_specs=[
            pl.BlockSpec(
                (1, bd),
                lambda b, jd, sl, idx_ref: (jnp.maximum(idx_ref[b, sl], 0), jd),
            ),
        ],
        out_specs=pl.BlockSpec((1, bd), lambda b, jd, sl, idx_ref: (b, jd)),
    )
    out = pl.pallas_call(
        functools.partial(_bag_kernel, n_slots=l, mean=(mode == "mean")),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, dp), jnp.float32),
        interpret=interpret,
    )(indices.astype(jnp.int32), table)
    return out[:, :d]
