"""Pallas TPU kernel: partition-affinity scoring (paper Eq. 1, batched).

Computes, for a window of W streaming vertices with (already gathered)
neighbour partition labels ``labels[w, d] ∈ {-1, 0..K-1}``:

    scores[w, k] = |{d : labels[w, d] == k}|      (|E(v) ∩ P_k|)
    deg[w]       = |{d : labels[w, d] >= 0}|

TPU adaptation (DESIGN.md §2): the paper's Java hash-probe becomes a
VMEM-tiled compare+reduce. The (W, D) label block is compared against the
K partition ids broadcast in VREGs — an 8×128-lane-friendly elementwise
compare — and reduced over the neighbour axis D, accumulating the (bW, K)
score tile in VMEM across the D grid dimension. The arbitrary HBM gather
``assignment[rows]`` stays outside the kernel (XLA's native gather), which
is the right split on TPU: gathers don't use the MXU/VPU, histograms do.

Grid: (W/bW, D/bD); the D axis is the reduction/accumulation axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import label_histogram, resolve_interpret


def _affinity_kernel(labels_ref, scores_ref, deg_ref, *, k_max: int):
    d_idx = pl.program_id(1)

    @pl.when(d_idx == 0)
    def _init():
        scores_ref[...] = jnp.zeros_like(scores_ref)
        deg_ref[...] = jnp.zeros_like(deg_ref)

    labels = labels_ref[...]                                  # (bW, bD) int32
    scores, deg = label_histogram(labels, k_max)              # shared masking
    scores_ref[...] += scores                                 # (bW, K)
    deg_ref[...] += deg                                       # (bW, 1)


@functools.partial(
    jax.jit, static_argnames=("k_max", "block_w", "block_d", "interpret")
)
def partition_affinity(
    labels: jax.Array,
    *,
    k_max: int,
    block_w: int = 128,
    block_d: int = 128,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """(scores (W, K), deg (W,)) from neighbour partition labels (W, D).

    ``interpret=None`` defers to ``repro.kernels.common.default_interpret``
    — real Mosaic compile on a TPU backend, interpret mode elsewhere,
    ``REPRO_PALLAS_INTERPRET`` overriding for debugging.
    """
    interpret = resolve_interpret(interpret)
    w, d = labels.shape
    bw = min(block_w, w)
    bd = min(block_d, d)
    pad_w = (-w) % bw
    pad_d = (-d) % bd
    if pad_w or pad_d:
        labels = jnp.pad(labels, ((0, pad_w), (0, pad_d)), constant_values=-1)
    wp, dp = labels.shape

    scores, deg = pl.pallas_call(
        functools.partial(_affinity_kernel, k_max=k_max),
        grid=(wp // bw, dp // bd),
        in_specs=[pl.BlockSpec((bw, bd), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((bw, k_max), lambda i, j: (i, 0)),
            pl.BlockSpec((bw, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((wp, k_max), jnp.int32),
            jax.ShapeDtypeStruct((wp, 1), jnp.int32),
        ],
        interpret=interpret,
    )(labels)
    return scores[:w], deg[:w, 0]
