"""Jit'd wrappers wiring the partition_affinity kernel into the engines."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.partition_affinity.partition_affinity import partition_affinity


def gather_labels(assignment, present, rows):
    """HBM gather half of the scoring op (stays outside the kernel)."""
    valid = rows >= 0
    safe = jnp.where(valid, rows, 0)
    nb_present = valid & present[safe]
    return jnp.where(nb_present, assignment[safe], -1).astype(jnp.int32)


def scores_for_state(state, rows, *, interpret: bool | None = None):
    """Drop-in for repro.core.windowed.committed_scores using the kernel.

    Tolerates in-window deletions: on churn streams the windowed driver
    still routes its pure-ADD windows here, so the committed state may
    carry deletion holes — vertices with present=False but stale
    assignment entries. ``gather_labels`` masks those to -1 (scored as
    empty), matching the faithful engine's presence semantics.

    ``interpret=None`` defers to ``repro.kernels.common.default_interpret``
    (interpret mode off-TPU, real compile on TPU).
    """
    labels = gather_labels(state.assignment, state.present, rows)
    k_max = state.edge_load.shape[0]
    return partition_affinity(labels, k_max=k_max, interpret=interpret)
