"""Pure-jnp oracle for the partition_affinity kernel."""
from __future__ import annotations

import jax.numpy as jnp


def partition_affinity_ref(labels, *, k_max: int):
    """scores[w,k] = #{d: labels[w,d]==k};  deg[w] = #{d: labels[w,d]>=0}."""
    onehot = labels[..., None] == jnp.arange(k_max, dtype=jnp.int32)
    scores = jnp.sum(onehot, axis=1, dtype=jnp.int32)
    deg = jnp.sum(labels >= 0, axis=1, dtype=jnp.int32)
    return scores, deg
