"""Shared Pallas plumbing — ONE definition site for two conventions every
repro kernel must agree on:

* **interpret-mode policy** (``default_interpret``): kernels run in Pallas
  interpret mode everywhere except a real TPU backend, so the same
  ``use_kernel=True`` call sites exercise the kernel logic bit-identically
  in CPU CI and compile to real Mosaic on TPU. ``REPRO_PALLAS_INTERPRET``
  overrides for debugging (``=1`` forces interpret on TPU, ``=0`` forces a
  real compile elsewhere — which will fail off-TPU; that is the point of
  the override).

* **label-histogram masking** (``label_histogram``): affinity scoring is a
  compare+reduce one-hot histogram over neighbour labels where ``-1``
  means "no neighbour here" (absent vertex, padded slot, or padded tile)
  and matches no partition id. Both the batched committed-scores kernel
  (``partition_affinity``) and the fused window chooser score through this
  helper, so their tiling/masking semantics cannot drift.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

# TPU VPU lane tiling — kernels pad the (window/vertex, k) trailing dims to
# multiples of this when compiled for real hardware (interpret mode accepts
# any geometry; see docs/ARCHITECTURE.md "Kernels").
TILE = (8, 128)

_ENV = "REPRO_PALLAS_INTERPRET"


def default_interpret() -> bool:
    """True ⇔ Pallas kernels should run in interpret mode.

    Derived from the backend (`jax.default_backend() != "tpu"`) so the one
    ``use_kernel=True`` flag means "real kernel on TPU, interpreted
    elsewhere"; the ``REPRO_PALLAS_INTERPRET`` env var overrides both ways
    for debugging.
    """
    override = os.environ.get(_ENV)
    if override is not None:
        return override.strip().lower() not in ("0", "false", "no", "off")
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """Resolve a kernel call's ``interpret=None`` default to the policy."""
    return default_interpret() if interpret is None else bool(interpret)


def label_histogram(labels: jax.Array, k_max: int):
    """(…, D) int32 labels → ((…, K) scores, (…, 1) degree).

    ``scores[..., k] = |{j : labels[..., j] == k}|`` and ``degree`` counts
    labels ``>= 0``. Labels ``-1`` (absent / padding) match no k — THE
    masking convention shared by every scoring path; integer compare+sum,
    so results are exact and bit-identical across engines.
    """
    ks = jax.lax.broadcasted_iota(
        jnp.int32, (1,) * labels.ndim + (k_max,), labels.ndim)
    onehot = (labels[..., None] == ks).astype(jnp.int32)
    scores = jnp.sum(onehot, axis=-2)
    deg = jnp.sum((labels >= 0).astype(jnp.int32), axis=-1, keepdims=True)
    return scores, deg
