"""Shared neural layers (no flax/optax offline — built from jnp directly).

Conventions:
  * params are nested dicts of jnp arrays; init fns take a PRNG key;
  * layer-stack params are vmap-stacked with a leading (L,) axis and
    consumed by lax.scan (keeps HLO small for 40–60 layer models);
  * per-layer heterogeneity (local/global attention windows) is passed as
    scanned per-layer scalars, not Python branches, so the stack stays
    homogeneous.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _normal(key, shape, scale, dtype):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32):
    return {"w": _normal(key, (d_in, d_out), d_in ** -0.5, dtype)}


def dense(p, x):
    return x @ p["w"]


def mlp_init(key, dims: list[int], dtype=jnp.float32):
    keys = jax.random.split(key, len(dims) - 1)
    return {f"l{i}": dense_init(k, dims[i], dims[i + 1], dtype)
            for i, k in enumerate(keys)}


def mlp_apply(p, x, act=jax.nn.relu, final_act=False):
    n = len(p)
    for i in range(n):
        x = dense(p[f"l{i}"], x)
        if i < n - 1 or final_act:
            x = act(x)
    return x


def layernorm_init(d: int, dtype=jnp.float32):
    return {"g": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def layernorm(p, x, eps=1e-5):
    m = x.mean(-1, keepdims=True)
    v = ((x - m) ** 2).mean(-1, keepdims=True)
    return (x - m) * jax.lax.rsqrt(v + eps) * p["g"] + p["b"]


def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"g": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps=1e-6):
    v = jnp.mean(x.astype(jnp.float32) ** 2, -1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(v + eps) * p["g"]).astype(x.dtype)


# --------------------------------------------------------------------------
# rotary embeddings
# --------------------------------------------------------------------------

def rope(x, positions, theta: float = 10000.0):
    """x: (..., S, H, D). positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


# --------------------------------------------------------------------------
# attention (traced window/softcap so layer stacks stay scannable)
# --------------------------------------------------------------------------

def attention_traced(q, k, v, *, q_positions, k_positions, window, softcap,
                     causal: bool = True):
    """Dense attention with traced per-layer window (0 ⇒ unbounded).

    q: (B, Sq, H, D); k/v: (B, Sk, Hkv, D). window/softcap are traced
    scalars so gemma2's local/global alternation runs under one lax.scan.
    The Pallas `flash_attention` kernel implements the identical math for
    static configs (serving path); tests assert both agree.
    """
    b, sq, h, d = q.shape
    _, sk, hkv, _ = k.shape
    group = h // hkv
    qg = q.reshape(b, sq, hkv, group, d)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * (d ** -0.5)
    softcap = jnp.asarray(softcap, jnp.float32)
    s = jnp.where(softcap > 0, jnp.tanh(s / jnp.where(softcap > 0, softcap, 1.0))
                  * softcap, s)
    qp = q_positions[:, None, None, :, None]
    kp = k_positions[:, None, None, None, :]
    mask = jnp.ones((b, 1, 1, sq, sk), dtype=bool)
    if causal:
        mask &= qp >= kp
    w = jnp.asarray(window, jnp.int32)
    mask &= jnp.where(w > 0, (qp - kp) < w, True)
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, h, d).astype(q.dtype)


def attention_chunked(q, k, v, *, q_positions, k_positions, window, softcap,
                      causal: bool = True, chunk: int = 512):
    """Exact attention computed in query chunks (each chunk sees all of K).

    Memory per step is O(B·H·chunk·Sk) instead of O(B·H·Sq·Sk); each chunk
    is rematerialised in the backward pass (jax.checkpoint), so long-context
    training/prefill never materialises the full score matrix. Numerics are
    identical to attention_traced (same per-row softmax).
    """
    b, sq, h, d = q.shape
    if sq <= chunk or sq % chunk != 0:
        return attention_traced(q, k, v, q_positions=q_positions,
                                k_positions=k_positions, window=window,
                                softcap=softcap, causal=causal)
    nc = sq // chunk
    qc = jnp.moveaxis(q.reshape(b, nc, chunk, h, d), 1, 0)
    qp = jnp.moveaxis(q_positions.reshape(b, nc, chunk), 1, 0)

    @jax.checkpoint
    def one(args):
        qi, qpi = args
        return attention_traced(qi, k, v, q_positions=qpi,
                                k_positions=k_positions, window=window,
                                softcap=softcap, causal=causal)

    out = jax.lax.map(one, (qc, qp))                  # (nc, b, chunk, h, d)
    return jnp.moveaxis(out, 0, 1).reshape(b, sq, h, d)


def attention_kv_chunked(q, k, v, *, q_positions, k_positions, window,
                         softcap, causal: bool = True, kv_chunk: int = 1024):
    """Exact attention with online softmax over KV chunks (flash-style).

    The jnp analogue of kernels/flash_attention (which is the TPU VMEM
    codepath): running (max, denom, acc) carried over KV blocks via
    lax.scan, each block rematerialised in the backward pass. Score memory
    is O(B·H·Sq·kv_chunk); no full (Sq, Sk) matrix ever exists. Used by
    the sequence-parallel training scheme where Sq is already sharded but
    the gathered K/V span the full sequence (§Perf iteration 4).
    """
    b, sq, h, d = q.shape
    _, sk, hkv, _ = k.shape
    if sk <= kv_chunk or sk % kv_chunk != 0:
        return attention_traced(q, k, v, q_positions=q_positions,
                                k_positions=k_positions, window=window,
                                softcap=softcap, causal=causal)
    group = h // hkv
    nc = sk // kv_chunk
    scale = d ** -0.5
    qg = q.reshape(b, sq, hkv, group, d).astype(jnp.float32)
    kc = jnp.moveaxis(k.reshape(b, nc, kv_chunk, hkv, d), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, nc, kv_chunk, hkv, d), 1, 0)
    kpc = jnp.moveaxis(k_positions.reshape(b, nc, kv_chunk), 1, 0)
    qp = q_positions[:, None, None, :, None]
    softcap_t = jnp.asarray(softcap, jnp.float32)
    w = jnp.asarray(window, jnp.int32)

    @jax.checkpoint
    def block(carry, xs):
        m, l, acc = carry
        ki, vi, kpi = xs
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg,
                       ki.astype(jnp.float32)) * scale
        s = jnp.where(softcap_t > 0,
                      jnp.tanh(s / jnp.where(softcap_t > 0, softcap_t, 1.0))
                      * softcap_t, s)
        kp = kpi[:, None, None, None, :]
        mask = jnp.ones(s.shape, bool)
        if causal:
            mask &= qp >= kp
        mask &= jnp.where(w > 0, (qp - kp) < w, True)
        s = jnp.where(mask, s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)                       # (b,hkv,g,sq)
        l = l * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhgqk,bkhd->bqhgd", p, vi.astype(jnp.float32))
        acc = acc * jnp.moveaxis(alpha, 3, 1)[..., None] + pv
        return (m_new, l, acc), None

    m0 = jnp.full((b, hkv, group, sq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, hkv, group, sq), jnp.float32)
    a0 = jnp.zeros((b, sq, hkv, group, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(block, (m0, l0, a0), (kc, vc, kpc))
    l = jnp.where(l == 0.0, 1.0, l)
    out = acc / jnp.moveaxis(l, 3, 1)[..., None]
    return out.reshape(b, sq, h, d).astype(q.dtype)


def attn_init(key, d_model: int, n_heads: int, n_kv: int, head_dim: int,
              dtype=jnp.float32):
    """Projections stored 2D (d, H*hd): the combined head dim is divisible
    by the TP axis for every assigned arch (56 or 40 heads are not), so
    pjit boundary shardings stay even; models reshape to heads inside."""
    kq, kk, kv, ko = jax.random.split(key, 4)
    s = d_model ** -0.5
    return {
        "wq": _normal(kq, (d_model, n_heads * head_dim), s, dtype),
        "wk": _normal(kk, (d_model, n_kv * head_dim), s, dtype),
        "wv": _normal(kv, (d_model, n_kv * head_dim), s, dtype),
        "wo": _normal(ko, (n_heads * head_dim, d_model),
                      (n_heads * head_dim) ** -0.5, dtype),
    }


def gated_mlp_init(key, d_model: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": _normal(k1, (d_model, d_ff), d_model ** -0.5, dtype),
        "wg": _normal(k2, (d_model, d_ff), d_model ** -0.5, dtype),
        "wo": _normal(k3, (d_ff, d_model), d_ff ** -0.5, dtype),
    }


def gated_mlp(p, x, act=jax.nn.silu):
    """SwiGLU (silu) / GeGLU (gelu)."""
    return (act(x @ p["wg"]) * (x @ p["wi"])) @ p["wo"]


# --------------------------------------------------------------------------
# losses
# --------------------------------------------------------------------------

def softmax_xent(logits, labels, *, label_mask=None):
    """Token cross-entropy; logits (..., V) fp32-accumulated."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if label_mask is not None:
        return jnp.sum(nll * label_mask) / jnp.maximum(jnp.sum(label_mask), 1)
    return jnp.mean(nll)


def chunked_softmax_xent(x, head, labels, *, label_mask=None,
                         final_softcap: float = 0.0, chunk: int = 8192):
    """Cross-entropy over a huge vocab without materialising (T, V) logits.

    x: (T, d) final hidden states; head: (d, V); labels: (T,).
    Token chunks are scanned; each chunk's logits are rematerialised in the
    backward pass. At V=256k / T=1M this keeps live logits to chunk×V.
    """
    t, _ = x.shape
    mask = (jnp.ones((t,), jnp.float32) if label_mask is None
            else label_mask.astype(jnp.float32))
    if t <= chunk or t % chunk != 0:
        return _xent_block(x, head, labels, mask, final_softcap)
    nc = t // chunk
    xs = (x.reshape(nc, chunk, -1), labels.reshape(nc, chunk),
          mask.reshape(nc, chunk))

    @jax.checkpoint
    def one(args):
        xc, lc, mc = args
        return _xent_block(xc, head, lc, mc, final_softcap, mean=False)

    nll, cnt = jax.lax.map(one, xs)
    return jnp.sum(nll) / jnp.maximum(jnp.sum(cnt), 1.0)


def _xent_block(x, head, labels, mask, final_softcap, mean: bool = True):
    logits = (x @ head).astype(jnp.float32)
    if final_softcap > 0:
        logits = final_softcap * jnp.tanh(logits / final_softcap)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (lse - ll) * mask
    if mean:
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(nll), jnp.sum(mask)


def stack_layer_params(init_fn, key, n_layers: int):
    """vmap-stacked per-layer params with a leading (L,) axis for lax.scan."""
    keys = jax.random.split(key, n_layers)
    return jax.vmap(init_fn)(keys)
