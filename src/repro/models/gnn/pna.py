"""PNA [arXiv:2004.05718]: Principal Neighbourhood Aggregation.

Assigned config: 4 layers, hidden 75, aggregators mean/max/min/std,
scalers identity/amplification/attenuation (log-degree).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.gnn import common as C


@dataclasses.dataclass(frozen=True)
class PNAConfig:
    name: str = "pna"
    n_layers: int = 4
    d_hidden: int = 75
    aggregators: tuple = ("mean", "max", "min", "std")
    scalers: tuple = ("identity", "amplification", "attenuation")
    out_dim: int = 1
    delta: float = 2.5   # E[log(d+1)] over training graphs (paper's δ)


def init_params(key, cfg: PNAConfig, d_node: int):
    ke, kl, ko = jax.random.split(key, 3)
    h = cfg.d_hidden
    n_agg = len(cfg.aggregators) * len(cfg.scalers)

    def layer_init(k):
        k1, k2 = jax.random.split(k)
        return {
            "pre": L.mlp_init(k1, [2 * h, h]),        # M(h_i, h_j)
            "post": L.mlp_init(k2, [(n_agg + 1) * h, h]),
        }

    return {
        "enc": L.mlp_init(ke, [d_node, h]),
        "layers": L.stack_layer_params(layer_init, kl, cfg.n_layers),
        "dec": L.mlp_init(ko, [h, h, cfg.out_dim]),
    }


def apply(params, batch, cfg: PNAConfig):
    snd, rcv = batch["senders"], batch["receivers"]
    n = batch["node_feat"].shape[0]
    emask = (snd >= 0)[:, None]
    h = L.mlp_apply(params["enc"], batch["node_feat"])

    deg = C.in_degree(rcv, n)                               # (N,)
    logd = jnp.log(deg + 1.0)
    scal = {
        "identity": jnp.ones_like(logd),
        "amplification": logd / cfg.delta,
        "attenuation": cfg.delta / jnp.maximum(logd, 1e-3),
    }

    def step(h, lp):
        hs, hr = C.gather_src(h, snd), C.gather_src(h, rcv)
        msg = L.mlp_apply(lp["pre"], jnp.concatenate([hs, hr], -1))
        msg = jnp.where(emask, msg, 0.0)
        aggs = []
        mean = C.segment_mean_pad(msg, rcv, n)
        for a in cfg.aggregators:
            if a == "mean":
                agg = mean
            elif a == "max":
                agg = C.segment_max_pad(jnp.where(emask, msg, -jnp.inf),
                                        rcv, n, fill=0.0)
            elif a == "min":
                agg = C.segment_min_pad(jnp.where(emask, msg, jnp.inf),
                                        rcv, n, fill=0.0)
            elif a == "std":
                sq = C.segment_mean_pad(msg**2, rcv, n)
                agg = jnp.sqrt(jnp.maximum(sq - mean**2, 0.0) + 1e-8)
            else:
                raise ValueError(a)
            for s in cfg.scalers:
                aggs.append(agg * scal[s][:, None])
        z = jnp.concatenate([h] + aggs, axis=-1)
        return h + L.mlp_apply(lp["post"], z), None

    h, _ = jax.lax.scan(step, h, params["layers"])
    return L.mlp_apply(params["dec"], h)


def loss_fn(params, batch, cfg: PNAConfig):
    per_node = apply(params, batch, cfg)
    if "graph_id" in batch:   # batched molecules: per-graph readout
        n_mol = batch["targets"].shape[0]
        pred = C.segment_sum_pad(per_node, batch["graph_id"], n_mol)
        loss = C.mse_loss(pred, batch["targets"])
    else:
        loss = C.mse_loss(per_node, batch["targets"], batch.get("node_mask"))
    return loss, {"mse": loss}
