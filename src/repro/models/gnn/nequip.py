"""NequIP [arXiv:2101.03164]: E(3)-equivariant interatomic potential.

Assigned config: 5 layers, 32 channels, l_max=2, 8 bessel RBFs, cutoff 5.
Features are irrep channel stacks {l: (N, C, 2l+1)}; each convolution
couples features with spherical harmonics of edge unit vectors through
Clebsch–Gordan tensors (repro.models.gnn.so3 — computed from first
principles, no e3nn), modulated by a radial MLP per path, aggregated with
segment-sum, mixed channel-wise per l, and gated (scalar silu / norm gate
for l>0). Exact equivariance is property-tested.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models.gnn import common as C
from repro.models.gnn import so3


@dataclasses.dataclass(frozen=True)
class NequIPConfig:
    name: str = "nequip"
    n_layers: int = 5
    channels: int = 32
    l_max: int = 2
    n_rbf: int = 8
    cutoff: float = 5.0
    n_species: int = 100
    out_dim: int = 1


def init_params(key, cfg: NequIPConfig):
    paths = so3.paths(cfg.l_max)
    c = cfg.channels
    ke, kl, ko = jax.random.split(key, 3)

    def layer_init(k):
        kr, km = jax.random.split(k)
        p = {"radial": L.mlp_init(kr, [cfg.n_rbf, 32, len(paths) * c])}
        mix_keys = jax.random.split(km, cfg.l_max + 1)
        for l in range(cfg.l_max + 1):
            n_in = sum(1 for (_, _, l3) in paths if l3 == l)
            p[f"mix{l}"] = (jax.random.normal(mix_keys[l], (n_in * c, c))
                            * (n_in * c) ** -0.5)
        return p

    return {
        "embed": jax.random.normal(ke, (cfg.n_species, c)) * 0.5,
        "layers": L.stack_layer_params(layer_init, kl, cfg.n_layers),
        "head": L.mlp_init(ko, [c, 32, cfg.out_dim]),
    }


def apply(params, batch, cfg: NequIPConfig):
    """→ per-node invariant outputs (N, out_dim)."""
    snd, rcv = batch["senders"], batch["receivers"]
    n = batch["species"].shape[0]
    c = cfg.channels
    paths = so3.paths(cfg.l_max)

    _, dist, unit = C.edge_vectors(batch["positions"], snd, rcv)
    rbf = C.bessel_rbf(dist, cfg.n_rbf, cfg.cutoff)          # (E, R)
    emask = (snd >= 0).astype(jnp.float32)
    sh = {}  # real spherical harmonics of edge unit vectors (jnp, traced)
    for l in range(cfg.l_max + 1):
        if l == 0:
            sh[l] = jnp.ones(snd.shape + (1,))
        elif l == 1:
            x, y, z = unit[:, 0], unit[:, 1], unit[:, 2]
            sh[l] = jnp.stack([y, z, x], axis=-1)
        else:
            x, y, z = unit[:, 0], unit[:, 1], unit[:, 2]
            s3 = float(np.sqrt(3.0))
            sh[l] = jnp.stack([
                s3 * x * y, s3 * y * z, 0.5 * (3 * z**2 - 1.0),
                s3 * x * z, 0.5 * s3 * (x**2 - y**2)], axis=-1)

    cg = {p: jnp.asarray(so3.clebsch_gordan(*p), jnp.float32) for p in paths}

    feats = {0: jnp.take(params["embed"], batch["species"], axis=0)[..., None]}
    for l in range(1, cfg.l_max + 1):
        feats[l] = jnp.zeros((n, c, 2 * l + 1))

    def layer(feats, lp):
        radial = L.mlp_apply(lp["radial"], rbf, act=jax.nn.silu)   # (E, P*c)
        radial = radial.reshape(radial.shape[0], len(paths), c)
        msgs = {l: [] for l in range(cfg.l_max + 1)}
        for pi, (l1, l2, l3) in enumerate(paths):
            xj = C.gather_src(feats[l1], snd)                       # (E,c,2l1+1)
            m = jnp.einsum("eci,ej,ijn->ecn", xj, sh[l2], cg[(l1, l2, l3)])
            m = m * radial[:, pi, :, None] * emask[:, None, None]
            msgs[l3].append(C.segment_sum_pad(m, rcv, n))           # (N,c,2l3+1)
        new = {}
        for l in range(cfg.l_max + 1):
            stack = jnp.concatenate(msgs[l], axis=1)                # (N,P_l*c,d)
            mixed = jnp.einsum("npd,pc->ncd", stack, lp[f"mix{l}"])
            if l == 0:
                new[l] = feats[0] + jax.nn.silu(mixed)
            else:  # norm gate keeps equivariance
                norm = jnp.linalg.norm(mixed, axis=-1, keepdims=True)
                gate = jax.nn.sigmoid(norm - 1.0)
                new[l] = feats[l] + mixed * gate
        return new

    for i in range(cfg.n_layers):
        lp = jax.tree.map(lambda x, i=i: x[i], params["layers"])
        feats = layer(feats, lp)
    return L.mlp_apply(params["head"], feats[0][..., 0], act=jax.nn.silu)


def loss_fn(params, batch, cfg: NequIPConfig):
    per_node = apply(params, batch, cfg)
    if "graph_id" in batch:
        n_mol = batch["targets"].shape[0]
        pred = C.segment_sum_pad(per_node, batch["graph_id"], n_mol)
    else:
        pred = per_node
    loss = C.mse_loss(pred, batch["targets"],
                      None if "graph_id" in batch else batch.get("node_mask"))
    return loss, {"mse": loss}
