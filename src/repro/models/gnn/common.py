"""GNN substrate: padded edge-list message passing via segment ops.

JAX sparse is BCOO-only, so (per the assignment) message passing is built
on ``jax.ops.segment_sum``-style scatter over an edge index. Edges are
(senders, receivers) int32 arrays padded with -1; padded lanes scatter to
a dump row that is sliced off. The ELL-blocked Pallas kernel
(repro.kernels.segment_spmm) implements the same aggregation for the
full-graph hot path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.csr import Graph


def segment_sum_pad(data, seg_ids, n: int):
    """segment_sum where seg_ids == -1 rows are dropped."""
    safe = jnp.where(seg_ids >= 0, seg_ids, n)
    return jax.ops.segment_sum(data, safe, num_segments=n + 1)[:n]


def segment_max_pad(data, seg_ids, n: int, fill=-jnp.inf):
    safe = jnp.where(seg_ids >= 0, seg_ids, n)
    out = jax.ops.segment_max(data, safe, num_segments=n + 1)[:n]
    return jnp.where(jnp.isfinite(out), out, fill)


def segment_min_pad(data, seg_ids, n: int, fill=jnp.inf):
    safe = jnp.where(seg_ids >= 0, seg_ids, n)
    out = jax.ops.segment_min(data, safe, num_segments=n + 1)[:n]
    return jnp.where(jnp.isfinite(out), out, fill)


def segment_mean_pad(data, seg_ids, n: int):
    s = segment_sum_pad(data, seg_ids, n)
    cnt = segment_sum_pad(jnp.ones(data.shape[:1] + (1,), data.dtype),
                          seg_ids, n)
    return s / jnp.maximum(cnt, 1.0)


def gather_src(x, idx):
    """x[idx] with -1-safe indexing (padded rows read row 0, to be masked)."""
    return jnp.take(x, jnp.maximum(idx, 0), axis=0)


def in_degree(receivers, n: int):
    return segment_sum_pad(
        jnp.ones(receivers.shape + (1,), jnp.float32), receivers, n)[:, 0]


# --------------------------------------------------------------------------
# radial bases (schnet / nequip)
# --------------------------------------------------------------------------

def gaussian_rbf(d, n_rbf: int, cutoff: float):
    """SchNet gaussian basis on distances d (E,)."""
    mu = jnp.linspace(0.0, cutoff, n_rbf)
    gamma = (n_rbf / cutoff) ** 2
    return jnp.exp(-gamma * (d[:, None] - mu[None, :]) ** 2)


def bessel_rbf(d, n_rbf: int, cutoff: float):
    """NequIP bessel basis with polynomial cutoff envelope."""
    d = jnp.maximum(d, 1e-6)
    n = jnp.arange(1, n_rbf + 1, dtype=jnp.float32)
    basis = jnp.sqrt(2.0 / cutoff) * jnp.sin(
        n * jnp.pi * d[:, None] / cutoff) / d[:, None]
    x = jnp.clip(d / cutoff, 0.0, 1.0)
    env = 1.0 - 10.0 * x**3 + 15.0 * x**4 - 6.0 * x**5   # p=3 polynomial
    return basis * env[:, None]


def edge_vectors(positions, senders, receivers):
    """(vec (E,3), dist (E,), unit (E,3)) with -1-padded edges zeroed."""
    mask = (senders >= 0) & (receivers >= 0)
    vec = gather_src(positions, receivers) - gather_src(positions, senders)
    vec = jnp.where(mask[:, None], vec, 0.0)
    dist = jnp.linalg.norm(vec + 1e-12, axis=-1)
    unit = vec / jnp.maximum(dist, 1e-6)[:, None]
    return vec, jnp.where(mask, dist, 0.0), unit


# --------------------------------------------------------------------------
# host-side batch construction
# --------------------------------------------------------------------------

def graph_to_batch(g: Graph, d_feat: int, *, seed: int = 0,
                   with_positions: bool = False, out_dim: int = 1,
                   dtype=np.float32) -> dict:
    """Full-graph training batch with synthetic features/targets."""
    rng = np.random.default_rng(seed)
    e = g.edge_array()
    senders = np.concatenate([e[:, 0], e[:, 1]]).astype(np.int32)
    receivers = np.concatenate([e[:, 1], e[:, 0]]).astype(np.int32)
    batch = {
        "senders": senders,
        "receivers": receivers,
        "node_feat": rng.standard_normal((g.n, d_feat)).astype(dtype),
        "node_mask": np.ones(g.n, bool),
        "targets": rng.standard_normal((g.n, out_dim)).astype(dtype),
    }
    if with_positions:
        batch["positions"] = rng.standard_normal((g.n, 3)).astype(dtype)
        batch["species"] = rng.integers(0, 16, g.n).astype(np.int32)
    return batch


def batch_molecules(n_mol: int, n_nodes: int, n_edges: int, *, seed: int = 0,
                    d_feat: int = 0, out_dim: int = 1) -> dict:
    """Batched small molecules flattened into one disjoint graph."""
    rng = np.random.default_rng(seed)
    senders, receivers = [], []
    for m in range(n_mol):
        off = m * n_nodes
        u = rng.integers(0, n_nodes, n_edges)
        v = rng.integers(0, n_nodes, n_edges)
        ok = u != v
        senders.append((u[ok] + off))
        receivers.append((v[ok] + off))
    senders = np.concatenate(senders).astype(np.int32)
    receivers = np.concatenate(receivers).astype(np.int32)
    ntot = n_mol * n_nodes
    batch = {
        "senders": senders,
        "receivers": receivers,
        "positions": rng.standard_normal((ntot, 3)).astype(np.float32),
        "species": rng.integers(0, 16, ntot).astype(np.int32),
        "node_mask": np.ones(ntot, bool),
        "graph_id": np.repeat(np.arange(n_mol, dtype=np.int32), n_nodes),
        "targets": rng.standard_normal((n_mol, out_dim)).astype(np.float32),
    }
    if d_feat:
        batch["node_feat"] = rng.standard_normal((ntot, d_feat)).astype(np.float32)
    return batch


def mse_loss(pred, targets, mask=None):
    err = (pred.astype(jnp.float32) - targets.astype(jnp.float32)) ** 2
    if mask is not None:
        err = err * mask[:, None]
        return jnp.sum(err) / jnp.maximum(jnp.sum(mask) * err.shape[-1], 1)
    return jnp.mean(err)
