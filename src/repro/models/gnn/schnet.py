"""SchNet [arXiv:1706.08566]: continuous-filter convolutions over
interatomic distances. Assigned config: 3 interactions, hidden 64,
300 gaussian RBFs, cutoff 10 Å.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.gnn import common as C


@dataclasses.dataclass(frozen=True)
class SchNetConfig:
    name: str = "schnet"
    n_interactions: int = 3
    d_hidden: int = 64
    n_rbf: int = 300
    cutoff: float = 10.0
    n_species: int = 100
    out_dim: int = 1


def shifted_softplus(x):
    return jax.nn.softplus(x) - jnp.log(2.0)


def init_params(key, cfg: SchNetConfig):
    ke, ki, ko = jax.random.split(key, 3)
    h = cfg.d_hidden

    def inter_init(k):
        k1, k2, k3, k4 = jax.random.split(k, 4)
        return {
            "filter": L.mlp_init(k1, [cfg.n_rbf, h, h]),
            "in": L.dense_init(k2, h, h),
            "out1": L.dense_init(k3, h, h),
            "out2": L.dense_init(k4, h, h),
        }

    return {
        "embed": (jax.random.normal(ke, (cfg.n_species, h)) * 0.1),
        "inter": L.stack_layer_params(inter_init, ki, cfg.n_interactions),
        "head": L.mlp_init(ko, [h, h // 2, cfg.out_dim]),
    }


def apply(params, batch, cfg: SchNetConfig):
    """→ per-node outputs (N, out_dim); caller may graph-readout."""
    snd, rcv = batch["senders"], batch["receivers"]
    n = batch["species"].shape[0]
    _, dist, _ = C.edge_vectors(batch["positions"], snd, rcv)
    rbf = C.gaussian_rbf(dist, cfg.n_rbf, cfg.cutoff)        # (E, R)
    emask = (snd >= 0)[:, None]

    x = jnp.take(params["embed"], batch["species"], axis=0)  # (N, h)

    def step(x, lp):
        w = L.mlp_apply(lp["filter"], rbf, act=shifted_softplus,
                        final_act=True)                      # (E, h)
        xj = C.gather_src(L.dense(lp["in"], x), snd)
        msg = jnp.where(emask, xj * w, 0.0)
        agg = C.segment_sum_pad(msg, rcv, n)
        v = shifted_softplus(L.dense(lp["out1"], agg))
        return x + L.dense(lp["out2"], v), None

    x, _ = jax.lax.scan(step, x, params["inter"])
    return L.mlp_apply(params["head"], x, act=shifted_softplus)


def loss_fn(params, batch, cfg: SchNetConfig):
    per_node = apply(params, batch, cfg)
    if "graph_id" in batch:   # molecular: per-graph energy = Σ node energies
        n_mol = batch["targets"].shape[0]
        pred = C.segment_sum_pad(per_node, batch["graph_id"], n_mol)
    else:
        pred = per_node
    loss = C.mse_loss(pred, batch["targets"],
                      None if "graph_id" in batch else batch.get("node_mask"))
    return loss, {"mse": loss}
