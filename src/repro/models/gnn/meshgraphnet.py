"""MeshGraphNet [arXiv:2010.03409]: encode–process–decode with edge+node
MLPs and residual updates. Assigned config: 15 layers, hidden 128, sum
aggregation, 2-hidden-layer MLPs (+LayerNorm, as in the paper).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.gnn import common as C


@dataclasses.dataclass(frozen=True)
class MGNConfig:
    name: str = "meshgraphnet"
    n_layers: int = 15
    d_hidden: int = 128
    mlp_layers: int = 2
    aggregator: str = "sum"
    out_dim: int = 1
    remat: bool = True


def _mlp_dims(cfg: MGNConfig, d_in: int, d_out: int):
    return [d_in] + [cfg.d_hidden] * cfg.mlp_layers + [d_out]


def _block_init(key, cfg: MGNConfig, d_in: int, d_out: int):
    k1, k2 = jax.random.split(key)
    return {"mlp": L.mlp_init(k1, _mlp_dims(cfg, d_in, d_out)),
            "ln": L.layernorm_init(d_out)}


def _block(p, x):
    return L.layernorm(p["ln"], L.mlp_apply(p["mlp"], x))


def init_params(key, cfg: MGNConfig, d_node: int, d_edge: int = 4):
    ke, kv, kp, kd = jax.random.split(key, 4)
    h = cfg.d_hidden

    def proc_init(k):
        k1, k2 = jax.random.split(k)
        return {
            "edge": _block_init(k1, cfg, 3 * h, h),
            "node": _block_init(k2, cfg, 2 * h, h),
        }

    return {
        "enc_node": _block_init(kv, cfg, d_node, h),
        "enc_edge": _block_init(ke, cfg, d_edge, h),
        "proc": L.stack_layer_params(proc_init, kp, cfg.n_layers),
        "dec": {"mlp": L.mlp_init(kd, _mlp_dims(cfg, h, cfg.out_dim))},
    }


def apply(params, batch, cfg: MGNConfig):
    """→ node outputs (N, out_dim)."""
    snd, rcv = batch["senders"], batch["receivers"]
    n = batch["node_feat"].shape[0]
    emask = (snd >= 0)[:, None]

    h = _block(params["enc_node"], batch["node_feat"])
    if "edge_feat" in batch:
        efeat = batch["edge_feat"]
    else:  # mesh edge features: relative position + length if available
        if "positions" in batch:
            vec, dist, _ = C.edge_vectors(batch["positions"], snd, rcv)
            efeat = jnp.concatenate([vec, dist[:, None]], axis=-1)
        else:
            efeat = jnp.ones(snd.shape + (4,), h.dtype)
    e = _block(params["enc_edge"], efeat)

    def step(carry, lp):
        h, e = carry
        hs, hr = C.gather_src(h, snd), C.gather_src(h, rcv)
        e_new = _block(lp["edge"], jnp.concatenate([e, hs, hr], -1))
        e = e + jnp.where(emask, e_new, 0.0)
        agg = C.segment_sum_pad(e, rcv, n)
        h_new = _block(lp["node"], jnp.concatenate([h, agg], -1))
        h = h + h_new
        return (h, e), None

    step_fn = jax.checkpoint(step) if cfg.remat else step
    (h, e), _ = jax.lax.scan(step_fn, (h, e), params["proc"])
    return L.mlp_apply(params["dec"]["mlp"], h)


def loss_fn(params, batch, cfg: MGNConfig):
    per_node = apply(params, batch, cfg)
    if "graph_id" in batch:   # batched molecules: per-graph readout
        n_mol = batch["targets"].shape[0]
        pred = C.segment_sum_pad(per_node, batch["graph_id"], n_mol)
        loss = C.mse_loss(pred, batch["targets"])
    else:
        loss = C.mse_loss(per_node, batch["targets"], batch.get("node_mask"))
    return loss, {"mse": loss}
