"""Self-contained SO(3) machinery for the equivariant GNN (NequIP).

No e3nn offline — real spherical harmonics are written as explicit
polynomials (l ≤ 2), Wigner-D matrices are fit from them by least squares,
and Clebsch–Gordan coupling tensors are obtained as the rotation-averaged
fixed-point projector of D3 ⊗ (D1 ⊗ D2)ᵀ. Everything is computed once in
numpy at import, cached, and verified by the equivariance property tests
(residuals ~1e-12).

Convention: component order m = -l..l with the e3nn-style l=1 ordering
(y, z, x) so that D¹ equals the rotation matrix in that basis.
"""
from __future__ import annotations

import functools

import numpy as np

L_MAX = 2
DIMS = {0: 1, 1: 3, 2: 5}


def sh_np(l: int, r: np.ndarray) -> np.ndarray:
    """Real spherical harmonics of unit vectors r (..., 3), unnormalised
    (component normalisation ||Y_l||² = const per l, e3nn 'integral' not
    needed — any fixed scale is equivariance-preserving)."""
    x, y, z = r[..., 0], r[..., 1], r[..., 2]
    if l == 0:
        return np.ones(r.shape[:-1] + (1,))
    if l == 1:
        return np.stack([y, z, x], axis=-1)
    if l == 2:
        s3 = np.sqrt(3.0)
        return np.stack([
            s3 * x * y,
            s3 * y * z,
            0.5 * (3 * z**2 - 1.0),
            s3 * x * z,
            0.5 * s3 * (x**2 - y**2),
        ], axis=-1)
    raise NotImplementedError(l)


def _rand_rotations(n: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    q = rng.standard_normal((n, 4))
    q /= np.linalg.norm(q, axis=1, keepdims=True)
    w, x, y, z = q.T
    return np.stack([
        np.stack([1 - 2 * (y**2 + z**2), 2 * (x * y - z * w), 2 * (x * z + y * w)], -1),
        np.stack([2 * (x * y + z * w), 1 - 2 * (x**2 + z**2), 2 * (y * z - x * w)], -1),
        np.stack([2 * (x * z - y * w), 2 * (y * z + x * w), 1 - 2 * (x**2 + y**2)], -1),
    ], axis=1)


def wigner_d(l: int, R: np.ndarray) -> np.ndarray:
    """D^l(R) (dim, dim): fit Y_l(R r) = D Y_l(r) by least squares over
    random unit vectors. Exact for polynomial SH (system is overdetermined
    and consistent)."""
    dim = DIMS[l]
    rng = np.random.default_rng(1)
    pts = rng.standard_normal((4 * dim * dim, 3))
    pts /= np.linalg.norm(pts, axis=1, keepdims=True)
    a = sh_np(l, pts)                       # (P, dim)   Y(r)
    b = sh_np(l, pts @ R.T)                 # (P, dim)   Y(Rr)
    d, *_ = np.linalg.lstsq(a, b, rcond=None)
    return d.T                               # b = Y(Rr) = D @ Y(r)


@functools.lru_cache(maxsize=None)
def clebsch_gordan(l1: int, l2: int, l3: int) -> np.ndarray:
    """Coupling tensor C (d1, d2, d3) with
    D3[n,n'] C[i',j',n'] = C[i,j,n] D1[i,i'] D2[j,j'] for all rotations —
    i.e. contracting two covariant inputs against C yields an l3-covariant
    output. Computed as the dominant fixed vector of the rotation average of
    the combined representation; normalised to ||C|| = 1."""
    if not (abs(l1 - l2) <= l3 <= l1 + l2):
        return np.zeros((DIMS[l1], DIMS[l2], DIMS[l3]))
    d1, d2, d3 = DIMS[l1], DIMS[l2], DIMS[l3]
    dim = d1 * d2 * d3
    rots = _rand_rotations(8, seed=7)
    # C (as a vector of V1⊗V2⊗V3) is rotation-invariant:
    # (D1⊗D2⊗D3) c = c for every rotation — exact linear constraints.
    rows = []
    for R in rots:
        m1, m2, m3 = wigner_d(l1, R), wigner_d(l2, R), wigner_d(l3, R)
        big = np.einsum("ia,jb,nc->ijnabc", m1, m2, m3).reshape(dim, dim)
        rows.append(big - np.eye(dim))
    m = np.concatenate(rows, axis=0)
    _, sv, vt = np.linalg.svd(m)
    null = vt[sv.size - 1:] if sv[-1] < 1e-8 else vt[len(sv):]
    if null.shape[0] != 1:
        raise RuntimeError(
            f"CG null space for ({l1},{l2},{l3}) has dim {null.shape[0]}")
    c = null[0].reshape(d1, d2, d3)
    return c / np.linalg.norm(c)


def paths(l_max: int = L_MAX):
    """All (l_in, l_edge, l_out) couplings with every l ≤ l_max."""
    out = []
    for l1 in range(l_max + 1):
        for l2 in range(l_max + 1):
            for l3 in range(abs(l1 - l2), min(l1 + l2, l_max) + 1):
                out.append((l1, l2, l3))
    return out
