"""Mixture-of-Experts FFN: top-k routing, capacity-based dispatch (GShard
style, scatter formulation — no (T, E, C) one-hot dispatch tensor).

SDP tie-in (DESIGN.md §3): token→expert dispatch is the same
affinity-vs-load assignment problem the paper solves for vertices. The
optional ``balance_bias`` implements the paper's communication-aware
balance guard as an aux-loss-free router bias (DeepSeek-style): experts
over mean load get their logits pushed down before top-k.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp



@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    balance_bias: float = 0.0   # >0 ⇒ SDP-style load-bias routing
    aux_loss_coef: float = 0.01
    dispatch_groups: int = 1    # >1 ⇒ group-local dispatch (per-DP-shard
    #   capacity): the cumsum/scatter stays inside each token group, so a
    #   data-sharded step never all-reduces the (E, C, d) dispatch buffer.
    #   Real systems dispatch per device (GShard/MegaBlocks); set this to
    #   the DP world size in distributed steps.
    buf_pspec: tuple = ()       # optional PartitionSpec entries for the
    #   (G, E, C, d) dispatch buffer, e.g. (("data",), "model", None, None)
    #   — groups stay data-sharded, experts expert-parallel on model, so
    #   the expert GEMMs are local (no d-contraction psum). §Perf 4.2.


def moe_init(key, d_model: int, cfg: MoEConfig, dtype=jnp.float32):
    kr, k1, k2, k3 = jax.random.split(key, 4)
    e, f = cfg.n_experts, cfg.d_ff_expert
    s = d_model ** -0.5
    return {
        "router": (jax.random.normal(kr, (d_model, e)) * s).astype(jnp.float32),
        "wi": (jax.random.normal(k1, (e, d_model, f)) * s).astype(dtype),
        "wg": (jax.random.normal(k2, (e, d_model, f)) * s).astype(dtype),
        "wo": (jax.random.normal(k3, (e, f, d_model)) * f ** -0.5).astype(dtype),
    }


def moe_apply(p, x, cfg: MoEConfig, *, expert_load=None):
    """x: (B, S, d) → (y (B, S, d), aux_loss, new_expert_load).

    Dispatch is scatter-based (no (T, E, C) one-hot) and *group-local* when
    cfg.dispatch_groups > 1: tokens are split into G contiguous groups with
    per-group capacity, so the running-count cumsum and the dispatch scatter
    never cross a data shard — the buffer stays G-sharded and the only
    cross-shard traffic is the expert-weight gather the partitioner owns.
    """
    import math
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    # group-local dispatch degrades gracefully for tiny token counts
    # (single-token decode): use the largest group count dividing T
    g = max(1, math.gcd(t, max(1, cfg.dispatch_groups)))
    tg = t // g
    xf = x.reshape(t, d)

    logits = xf.astype(jnp.float32) @ p["router"]          # (T, E)
    if cfg.balance_bias > 0.0 and expert_load is not None:
        mean = jnp.mean(expert_load) + 1e-6
        logits = logits - cfg.balance_bias * (expert_load - mean) / mean
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert = jax.lax.top_k(probs, k)                 # (T, k)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)

    # Switch-style load-balancing aux loss (computed pre-capacity).
    density = jnp.mean(jax.nn.one_hot(expert[:, 0], e), axis=0)
    router_mean = jnp.mean(probs, axis=0)
    aux = cfg.aux_loss_coef * e * jnp.sum(density * router_mean)

    cap = int(cfg.capacity_factor * tg * k / e) + 1
    fe = expert.reshape(g, tg * k)                         # token-major/group
    oh = jax.nn.one_hot(fe, e, dtype=jnp.int32)            # (G, Tg*k, E)
    pos = jnp.cumsum(oh, axis=1) - 1                       # running count
    pos = jnp.take_along_axis(pos, fe[..., None], axis=2)[..., 0]
    keep = pos < cap
    idx_e = jnp.where(keep, fe, e)                         # drop row → e
    idx_c = jnp.where(keep, pos, 0)

    xr = jnp.repeat(xf, k, axis=0).reshape(g, tg * k, d)   # (G, Tg*k, d)
    buf = jnp.zeros((g, e + 1, cap, d), x.dtype)
    buf = jax.vmap(lambda bu, ie, ic, xv: bu.at[ie, ic].add(xv))(
        buf, idx_e, idx_c, xr)
    h = buf[:, :e]                                         # (G, E, C, d)
    if cfg.buf_pspec:
        from jax.sharding import PartitionSpec as P
        h = jax.lax.with_sharding_constraint(h, P(*cfg.buf_pspec))
    act = jax.nn.silu(jnp.einsum("gecd,edf->gecf", h, p["wg"]))
    h = act * jnp.einsum("gecd,edf->gecf", h, p["wi"])
    out = jnp.einsum("gecf,efd->gecd", h, p["wo"])         # (G, E, C, d)

    y = jax.vmap(lambda o, ie, ic: o[jnp.minimum(ie, e - 1), ic])(
        out, idx_e, idx_c)                                 # (G, Tg*k, d)
    y = y * keep[..., None] * gate.reshape(g, tg * k)[..., None]
    y = y.reshape(t, k, d).sum(axis=1).reshape(b, s, d).astype(x.dtype)

    load = jnp.sum(oh * keep[..., None], axis=(0, 1)).astype(jnp.float32)
    return y, aux, load
