"""Two-tower retrieval [Yi et al., RecSys'19]: sampled-softmax retrieval.

Assigned config: embed_dim 256, tower MLPs 1024-512-256, dot interaction.
Embedding tables are the hot path (built on jnp.take + segment-sum —
repro.kernels.embedding_bag provides the TPU kernel). Training uses
in-batch sampled softmax with logQ correction; SDP partitions the
user–item co-access graph to place hot rows (DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.kernels.embedding_bag.ops import bag_lookup


@dataclasses.dataclass(frozen=True)
class TwoTowerConfig:
    name: str = "two-tower-retrieval"
    embed_dim: int = 256
    tower_mlp: tuple = (1024, 512, 256)
    user_vocab: int = 50_331_648   # ≈50M, multiple of 512 (even row shards)
    item_vocab: int = 50_331_648
    user_fields: int = 8     # multi-hot categorical fields per user
    item_fields: int = 4
    field_slots: int = 8     # ids per field (bag size, -1 padded)
    temperature: float = 0.05
    dtype: str = "float32"


def init_params(key, cfg: TwoTowerConfig):
    ku, ki, kum, kim = jax.random.split(key, 4)
    d = cfg.embed_dim
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    return {
        "user_table": (jax.random.normal(ku, (cfg.user_vocab, d)) * 0.01).astype(dt),
        "item_table": (jax.random.normal(ki, (cfg.item_vocab, d)) * 0.01).astype(dt),
        "user_tower": L.mlp_init(kum, [cfg.user_fields * d, *cfg.tower_mlp]),
        "item_tower": L.mlp_init(kim, [cfg.item_fields * d, *cfg.tower_mlp]),
    }


def _tower(table, tower_p, ids, n_fields: int, use_kernel: bool):
    """ids (B, F, S) multi-hot → (B, out) L2-normalised tower embedding."""
    b = ids.shape[0]
    flat = ids.reshape(b * n_fields, -1)
    bags = bag_lookup(table, flat, "mean", use_kernel)       # (B*F, d)
    x = bags.reshape(b, -1)                                  # (B, F*d)
    x = L.mlp_apply(tower_p, x, act=jax.nn.relu)
    return x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-6)


def user_embed(params, batch, cfg: TwoTowerConfig, use_kernel=False):
    return _tower(params["user_table"], params["user_tower"],
                  batch["user_ids"], cfg.user_fields, use_kernel)


def item_embed(params, batch, cfg: TwoTowerConfig, use_kernel=False):
    return _tower(params["item_table"], params["item_tower"],
                  batch["item_ids"], cfg.item_fields, use_kernel)


def loss_fn(params, batch, cfg: TwoTowerConfig):
    """In-batch sampled softmax with logQ correction (RecSys'19 eq. 5)."""
    u = user_embed(params, batch, cfg)                       # (B, d)
    v = item_embed(params, batch, cfg)                       # (B, d)
    logits = (u @ v.T) / cfg.temperature                     # (B, B)
    logits = logits - batch["log_q"][None, :]                # logQ correction
    labels = jnp.arange(u.shape[0])
    loss = L.softmax_xent(logits, labels)
    acc = jnp.mean(jnp.argmax(logits, -1) == labels)
    return loss, {"xent": loss, "in_batch_acc": acc}


def score_candidates(params, batch, cfg: TwoTowerConfig):
    """Retrieval scoring: one/many queries × many candidate items.

    batch: user_ids (B, F, S), cand_item_emb (Nc, d) [precomputed corpus
    embeddings, the standard serving layout]. → (B, Nc) scores."""
    u = user_embed(params, batch, cfg)
    return u @ batch["cand_item_emb"].T / cfg.temperature


def serve_score(params, batch, cfg: TwoTowerConfig):
    """Online inference: score B (user, item) pairs."""
    u = user_embed(params, batch, cfg)
    v = item_embed(params, batch, cfg)
    return jnp.sum(u * v, axis=-1) / cfg.temperature


def make_batch(cfg: TwoTowerConfig, batch: int, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    return {
        "user_ids": rng.integers(
            -1, cfg.user_vocab, (batch, cfg.user_fields, cfg.field_slots)
        ).astype(np.int32),
        "item_ids": rng.integers(
            -1, cfg.item_vocab, (batch, cfg.item_fields, cfg.field_slots)
        ).astype(np.int32),
        "log_q": rng.standard_normal(batch).astype(np.float32) * 0.1,
    }
