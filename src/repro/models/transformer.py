"""Decoder-only LM covering the 5 assigned architectures.

One homogeneous layer stack under lax.scan; per-layer heterogeneity
(gemma2 local/global alternation, llama4 chunked-local) rides through the
scan as a per-layer window array. Supports:
  * GQA + RoPE (+ per-arch theta), SwiGLU/GeGLU,
  * attention & final logit soft-capping (gemma2),
  * sliding-window layers (gemma2 local-4096, llama4 chunked-8192),
  * MoE FFN (moonshot 64e/top-6, llama4 16e/top-1),
  * train forward, prefill (returns KV cache), and single-token decode.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models.moe import MoEConfig, moe_apply, moe_init


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    rope_theta: float = 10000.0
    act: str = "silu"              # 'silu' (SwiGLU) | 'gelu' (GeGLU)
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    window_pattern: tuple[int, ...] = (0,)   # cycled; 0 = global
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    moe: Optional[MoEConfig] = None
    dtype: str = "bfloat16"
    remat: bool = True
    attn_chunk: int = 512     # query-chunk size for long-context attention
    xent_chunk: int = 8192    # token-chunk size for vocab cross-entropy
    # Optional PartitionSpec entries for the (B, S, d) residual stream,
    # e.g. (("pod","data"), "model", None) — sequence parallelism. Applied
    # as with_sharding_constraint at every layer boundary; () disables.
    # Needs an ambient mesh (the dry-run/launcher provide one).
    act_pspec: tuple = ()
    # Optional PartitionSpec for K/V (B, S, Hkv, hd) inside attention.
    # With sequence parallelism, constraining K/V to (da, None, None, None)
    # forces ONE all-gather of K/V per layer instead of psum-ing f32
    # attention outputs over the sharded KV sequence (§Perf iteration 3).
    kv_pspec: tuple = ()
    # >0 ⇒ online-softmax attention over KV chunks of this size (exact,
    # flash-style; the jnp analogue of kernels/flash_attention). Bounds
    # score memory when q-chunking is disabled (§Perf iteration 4).
    kv_chunk: int = 0

    @property
    def jdtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    @property
    def windows(self) -> np.ndarray:
        pat = self.window_pattern
        return np.asarray([pat[i % len(pat)] for i in range(self.n_layers)],
                          np.int32)

    def param_count(self) -> int:
        d, h, kv, hd, f, v = (self.d_model, self.n_heads, self.n_kv_heads,
                              self.head_dim, self.d_ff, self.vocab)
        attn = d * hd * (h + 2 * kv) + h * hd * d
        if self.moe is not None:
            m = self.moe
            ffn = d * m.n_experts + 3 * m.n_experts * d * m.d_ff_expert
        else:
            ffn = 3 * d * f
        per_layer = attn + ffn + 2 * d
        emb = v * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + emb + d

    def active_param_count(self) -> int:
        """6·N_active·D convention for MoE rooflines."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        m = self.moe
        attn = d * self.head_dim * (self.n_heads + 2 * self.n_kv_heads) \
            + self.n_heads * self.head_dim * d
        ffn = d * m.n_experts + 3 * m.top_k * d * m.d_ff_expert
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * (attn + ffn + 2 * d) + emb + d


def init_params(key, cfg: LMConfig):
    dt = cfg.jdtype
    ke, kl, kh = jax.random.split(key, 3)

    def layer_init(k):
        ka, km, kn = jax.random.split(k, 3)
        p = {
            "attn": L.attn_init(ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                cfg.head_dim, dt),
            "ln1": L.rmsnorm_init(cfg.d_model),
            "ln2": L.rmsnorm_init(cfg.d_model),
        }
        if cfg.moe is not None:
            p["moe"] = moe_init(km, cfg.d_model, cfg.moe, dt)
        else:
            p["mlp"] = L.gated_mlp_init(km, cfg.d_model, cfg.d_ff, dt)
        return p

    params = {
        "embed": (jax.random.normal(ke, (cfg.vocab, cfg.d_model))
                  * cfg.d_model ** -0.5).astype(dt),
        "layers": L.stack_layer_params(layer_init, kl, cfg.n_layers),
        "ln_f": L.rmsnorm_init(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(kh, (cfg.d_model, cfg.vocab))
                             * cfg.d_model ** -0.5).astype(dt)
    return params


def _act(cfg):
    return jax.nn.silu if cfg.act == "silu" else jax.nn.gelu


def _layer_fwd(cfg: LMConfig, lp, x, window, *, q_positions, k_positions,
               kv=None):
    """One block. kv=(k_cache, v_cache) for decode (cache already includes
    positions < len(k_positions)-1; the new kv is appended here)."""
    h = L.rmsnorm(lp["ln1"], x, cfg.norm_eps)
    b, s, _ = h.shape
    hd = cfg.head_dim
    q = (h @ lp["attn"]["wq"]).reshape(b, s, cfg.n_heads, hd)
    k = (h @ lp["attn"]["wk"]).reshape(b, s, cfg.n_kv_heads, hd)
    v = (h @ lp["attn"]["wv"]).reshape(b, s, cfg.n_kv_heads, hd)
    q = L.rope(q, q_positions, cfg.rope_theta)
    k = L.rope(k, q_positions, cfg.rope_theta)
    if cfg.kv_pspec and kv is None:
        from jax.sharding import PartitionSpec as P
        k = jax.lax.with_sharding_constraint(k, P(*cfg.kv_pspec))
        v = jax.lax.with_sharding_constraint(v, P(*cfg.kv_pspec))
    new_kv = (k, v)
    if kv is not None:
        k = jnp.concatenate([kv[0], k], axis=1)
        v = jnp.concatenate([kv[1], v], axis=1)
    if kv is None and cfg.kv_chunk > 0 and k.shape[1] > cfg.kv_chunk:
        o = L.attention_kv_chunked(
            q, k, v, q_positions=q_positions, k_positions=k_positions,
            window=window, softcap=cfg.attn_softcap, kv_chunk=cfg.kv_chunk,
        )
    elif kv is None and q.shape[1] > cfg.attn_chunk:
        o = L.attention_chunked(
            q, k, v, q_positions=q_positions, k_positions=k_positions,
            window=window, softcap=cfg.attn_softcap, chunk=cfg.attn_chunk,
        )
    else:
        o = L.attention_traced(
            q, k, v, q_positions=q_positions, k_positions=k_positions,
            window=window, softcap=cfg.attn_softcap,
        )
    x = x + o.reshape(b, s, cfg.n_heads * hd) @ lp["attn"]["wo"]
    h = L.rmsnorm(lp["ln2"], x, cfg.norm_eps)
    if cfg.moe is not None:
        y, aux, load = moe_apply(lp["moe"], h, cfg.moe)
    else:
        y = L.gated_mlp(lp["mlp"], h, _act(cfg))
        aux = jnp.zeros((), jnp.float32)
        load = None
    return x + y, aux, new_kv


def _constrain(x, cfg: LMConfig):
    """Sequence-parallel sharding constraint on the residual stream."""
    if not cfg.act_pspec:
        return x
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(x, P(*cfg.act_pspec))


def backbone(params, tokens, cfg: LMConfig, *, collect_cache: bool = False):
    """Shared trunk. tokens (B, S) → (x (B, S, d) post-ln_f, extra), where
    extra is the stacked KV cache (L, B, S, Hkv, hd)×2 if collect_cache
    else the summed MoE aux loss."""
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    x = _constrain(x, cfg)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    windows = jnp.asarray(cfg.windows)

    def body(x, xs):
        lp, w = xs
        x = _constrain(x, cfg)
        y, aux, kvs = _layer_fwd(cfg, lp, x, w, q_positions=pos,
                                 k_positions=pos)
        y = _constrain(y, cfg)
        out = kvs if collect_cache else aux
        return y, out

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, extra = jax.lax.scan(body_fn, x, (params["layers"], windows))
    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    if not collect_cache:
        extra = jnp.sum(extra)
    return x, extra


def _head(params, cfg: LMConfig):
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


def forward(params, tokens, cfg: LMConfig, *, collect_cache: bool = False):
    """Train / prefill forward. tokens (B, S) → logits (B, S, V)
    [+ stacked KV cache (L, B, S, Hkv, hd) if collect_cache]."""
    x, extra = backbone(params, tokens, cfg, collect_cache=collect_cache)
    logits = (x @ _head(params, cfg)).astype(jnp.float32)
    if cfg.final_softcap > 0:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    return logits, extra


def loss_fn(params, batch, cfg: LMConfig):
    """Token cross-entropy via the vocab-chunked head (never materialises
    the (T, V) logits — required at V=256k, T=1M)."""
    x, aux = backbone(params, batch["tokens"], cfg)
    b, s, d = x.shape
    mask = batch.get("mask")
    loss = L.chunked_softmax_xent(
        x.reshape(b * s, d), _head(params, cfg),
        batch["labels"].reshape(b * s),
        label_mask=None if mask is None else mask.reshape(b * s),
        final_softcap=cfg.final_softcap, chunk=cfg.xent_chunk,
    )
    return loss + aux, {"xent": loss, "aux": aux}


def prefill_step(params, tokens, cfg: LMConfig):
    """Serving prefill: returns last-position logits (B, V) + KV cache
    (L, B, S, Hkv, hd)×2 — the full-sequence logits are never needed."""
    x, cache = backbone(params, tokens, cfg, collect_cache=True)
    logits = (x[:, -1] @ _head(params, cfg)).astype(jnp.float32)
    if cfg.final_softcap > 0:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    return logits, cache[0], cache[1]


def decode_step(params, token, cache_k, cache_v, cfg: LMConfig):
    """One-token decode. token (B, 1); cache_[kv] (L, B, S, Hkv, hd) holds
    positions 0..S-1; the new token sits at position S.

    Returns (logits (B, V), new_k (L, B, 1, Hkv, hd), new_v)."""
    b, _ = token.shape
    s_cache = cache_k.shape[2]
    x = jnp.take(params["embed"], token, axis=0)
    qpos = jnp.full((b, 1), s_cache, jnp.int32)
    kpos = jnp.broadcast_to(jnp.arange(s_cache + 1, dtype=jnp.int32)[None],
                            (b, s_cache + 1))
    windows = jnp.asarray(cfg.windows)

    def body(x, xs):
        lp, w, ck, cv = xs
        y, _, new_kv = _layer_fwd(cfg, lp, x, w, q_positions=qpos,
                                  k_positions=kpos, kv=(ck, cv))
        return y, new_kv

    x, new_kv = jax.lax.scan(body, x, (params["layers"], windows,
                                       cache_k, cache_v))
    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x[:, 0] @ head).astype(jnp.float32)
    if cfg.final_softcap > 0:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    return logits, new_kv[0], new_kv[1]


def decode_step_inplace(params, token, cache_k, cache_v, cache_len,
                        cfg: LMConfig):
    """Production decode: preallocated cache, in-place slot write.

    token (B, 1); cache_[kv] (L, B, S_max, Hkv, hd) with positions
    0..cache_len-1 valid; the new token is written at slot ``cache_len``
    (traced scalar) via dynamic_update_slice — no buffer growth, the cache
    layout/sharding is step-invariant (vLLM-style slot write). Causal
    masking at q_pos == cache_len hides the garbage beyond the write point.

    The caches ride through the layer scan as part of the CARRY (not as
    stacked xs/ys): XLA aliases carry buffers in place, so the step's live
    memory is one cache copy, not two — this is what lets the 32k-context
    decode cells fit a 16 GB HBM chip (EXPERIMENTS.md §Perf).

    Returns (logits (B, V), cache_k, cache_v) with the slot written.
    """
    b, _ = token.shape
    n_l, _, s_max = cache_k.shape[:3]
    x = jnp.take(params["embed"], token, axis=0)
    qpos = jnp.full((b, 1), cache_len, jnp.int32)
    kpos = jnp.broadcast_to(jnp.arange(s_max, dtype=jnp.int32)[None],
                            (b, s_max))
    windows = jnp.asarray(cfg.windows)
    hd = cfg.head_dim

    def body(carry, xs):
        x, ck_all, cv_all = carry
        lp, w, li = xs
        h = L.rmsnorm(lp["ln1"], x, cfg.norm_eps)
        q = (h @ lp["attn"]["wq"]).reshape(b, 1, cfg.n_heads, hd)
        k1 = (h @ lp["attn"]["wk"]).reshape(b, 1, cfg.n_kv_heads, hd)
        v1 = (h @ lp["attn"]["wv"]).reshape(b, 1, cfg.n_kv_heads, hd)
        q = L.rope(q, qpos, cfg.rope_theta)
        k1 = L.rope(k1, qpos, cfg.rope_theta)
        ck_all = jax.lax.dynamic_update_slice(
            ck_all, k1.astype(ck_all.dtype)[None],
            (li, 0, cache_len, 0, 0))
        cv_all = jax.lax.dynamic_update_slice(
            cv_all, v1.astype(cv_all.dtype)[None],
            (li, 0, cache_len, 0, 0))
        ck = jax.lax.dynamic_index_in_dim(ck_all, li, 0, keepdims=False)
        cv = jax.lax.dynamic_index_in_dim(cv_all, li, 0, keepdims=False)
        o = L.attention_traced(q, ck, cv, q_positions=qpos,
                               k_positions=kpos, window=w,
                               softcap=cfg.attn_softcap)
        x = x + o.reshape(b, 1, cfg.n_heads * hd) @ lp["attn"]["wo"]
        h = L.rmsnorm(lp["ln2"], x, cfg.norm_eps)
        if cfg.moe is not None:
            y, _, _ = moe_apply(lp["moe"], h, cfg.moe)
        else:
            y = L.gated_mlp(lp["mlp"], h, _act(cfg))
        return (x + y, ck_all, cv_all), None

    (x, new_k, new_v), _ = jax.lax.scan(
        body, (x, cache_k, cache_v),
        (params["layers"], windows, jnp.arange(n_l, dtype=jnp.int32)))
    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = (x[:, 0] @ _head(params, cfg)).astype(jnp.float32)
    if cfg.final_softcap > 0:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    return logits, new_k, new_v
