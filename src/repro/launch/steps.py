"""Step builders: one jit-able step per (arch × shape), with shardings.

This is the layer the dry-run, the trainer and the server all share. For
every assigned cell it produces a ``StepBundle``:

  * ``fn``            — the pure step function (train / prefill / decode /
                        serve), ready for jax.jit;
  * ``specs``         — ShapeDtypeStruct stand-ins for every argument
                        (weak-type-correct, shardable, no allocation);
  * ``in_shardings`` / ``out_shardings`` — NamedShardings matching specs;
  * ``donate``        — argument indices donated (params/opt/caches);
  * ``meta``          — MODEL_FLOPS + family info for the roofline.

Sharding scheme (DESIGN.md §6): FSDP on data(×pod) + TP on model for LMs
(EP for MoE experts), graph parallelism over the flattened mesh for GNNs,
row-sharded embedding tables for recsys.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_arch
from repro.configs.base import ArchDef
from repro.configs.shapes import GNNShape, LMShape, RecSysShape
from repro.graph.sampler import subgraph_sizes
from repro.models import recsys as RS
from repro.models import transformer as T
from repro.models.gnn import (meshgraphnet as MGN, nequip as NQ, pna as PNA,
                              schnet as SCH)
from repro.optim.optimizers import adamw, apply_updates
from repro.runtime import sharding as SHR


@dataclasses.dataclass
class StepBundle:
    name: str
    kind: str                      # 'train' | 'prefill' | 'decode' | 'serve'
    fn: Callable
    specs: tuple
    in_shardings: tuple
    out_shardings: Any
    donate: tuple
    meta: dict


def _rep(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def _sh(mesh: Mesh, *spec) -> NamedSharding:
    return NamedSharding(mesh, P(*spec))


def _all_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)


def _n_dp(mesh: Mesh) -> int:
    n = 1
    for a in SHR.batch_axes(mesh):
        n *= mesh.shape[a]
    return n


def round_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# ---------------------------------------------------------------------------
# optimizer plumbing shared by the train steps
# ---------------------------------------------------------------------------

def make_opt():
    return adamw(1e-4, weight_decay=0.1, clip_norm=1.0)


def _opt_shardings(param_sh, mesh: Mesh):
    return {"mu": param_sh, "nu": param_sh,
            "count": _rep(mesh), "gnorm": _rep(mesh)}


def _train_step_fn(loss_fn, cfg, micro: int = 1):
    """micro > 1 ⇒ gradient accumulation over microbatches (halves live
    activation temps per pass at the cost of re-gathering weights —
    §Perf iteration 6). Grads accumulate in f32."""
    opt = make_opt()

    def step(params, opt_state, batch):
        if micro > 1:
            mb_batch = jax.tree.map(
                lambda x: x.reshape(micro, x.shape[0] // micro,
                                    *x.shape[1:]), batch)

            def one(carry, mb):
                gsum, lsum = carry
                (loss, _), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb, cfg)
                gsum = jax.tree.map(
                    lambda a, b_: a + b_.astype(jnp.float32), gsum, g)
                return (gsum, lsum + loss), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(
                one, (zeros, jnp.zeros((), jnp.float32)), mb_batch)
            grads = jax.tree.map(lambda g: g / micro, gsum)
            loss = lsum / micro
            metrics = {}
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch, cfg)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        metrics = dict(metrics, loss=loss, gnorm=opt_state["gnorm"])
        return params, opt_state, metrics

    return step, opt


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

def _lm_flops_fwd(cfg: T.LMConfig, tokens: int, kv_len: int | None = None):
    """2·N_active·tokens + attention score/AV flops."""
    n = cfg.active_param_count()
    kv = kv_len if kv_len is not None else 0
    attn = 0.0
    for w in cfg.windows:
        span = kv if kv else 0
        if w > 0 and span:
            span = min(span, int(w))
        # train/prefill: causal ≈ S/2 per query; decode: full span
        attn += 4.0 * cfg.n_heads * cfg.head_dim * tokens * (span or 0)
    return 2.0 * n * tokens + attn


def _lm_cfg_for_mesh(arch: ArchDef, mesh: Mesh) -> T.LMConfig:
    cfg = arch.config
    if cfg.moe is not None and cfg.moe.dispatch_groups == 1:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, dispatch_groups=_n_dp(mesh)))
    return cfg


def _lm_param_specs(cfg: T.LMConfig):
    return jax.eval_shape(
        functools.partial(T.init_params, cfg=cfg), jax.random.PRNGKey(0))


def _lm_shardings(params_like, mesh: Mesh):
    return SHR.shardings_from_rules(params_like, SHR.lm_param_rules(mesh),
                                    mesh)


def _lm_cache_sharding(mesh: Mesh, batch: int, n_kv_heads: int = 0):
    da = SHR.batch_axes(mesh)
    if batch % _n_dp(mesh) == 0 and batch >= _n_dp(mesh):
        if n_kv_heads and n_kv_heads % mesh.shape["model"] == 0:
            # KV heads divide the TP axis (phi3 MHA=32, moonshot 16):
            # shard heads instead of sequence — the per-layer cache slice
            # temps shrink by TP× (§Perf 4.4)
            return _sh(mesh, None, da, None, "model", None)
        return _sh(mesh, None, da, "model", None, None)
    # tiny batch (long-context): shard the sequence over every axis
    return _sh(mesh, None, None, da + ("model",), None, None)


def build_lm(arch: ArchDef, shape: LMShape, mesh: Mesh,
             scheme: str = "baseline") -> StepBundle:
    cfg = _lm_cfg_for_mesh(arch, mesh)
    da = SHR.batch_axes(mesh)
    tp = mesh.shape["model"]
    if scheme == "opt" and shape.kind == "train":
        # Beyond-paper scheme (EXPERIMENTS.md §Perf): sequence parallelism —
        # the residual stream is sharded (batch over data(,pod), seq over
        # model) at every layer boundary, so activations are never
        # replicated over the TP axis; XLA then gathers *weights* (ZeRO-3
        # pattern) instead of all-reducing activations. MoE dispatch groups
        # match the total activation shards.
        cfg = dataclasses.replace(
            cfg, act_pspec=(tuple(da), "model", None),
            kv_pspec=(tuple(da), None, None, None),
            # q-chunking would cut across the S/TP shard boundary → off;
            # score memory is bounded by online-softmax KV chunking instead
            attn_chunk=max(cfg.attn_chunk, shape.seq_len),
            kv_chunk=512,
            moe=None if cfg.moe is None else dataclasses.replace(
                cfg.moe, dispatch_groups=_n_dp(mesh) * tp,
                buf_pspec=(tuple(da), "model", None, None)))
    params_like = _lm_param_specs(cfg)
    if scheme == "opt" and shape.kind == "train":
        param_sh = SHR.shardings_from_rules(
            params_like, SHR.lm_param_rules_zero(mesh), mesh)
    else:
        param_sh = _lm_shardings(params_like, mesh)
    b, s = shape.global_batch, shape.seq_len
    bspec = _sh(mesh, da) if b % _n_dp(mesh) == 0 else _rep(mesh)

    if shape.kind == "train":
        if scheme == "opt" and s % tp == 0:
            bspec = _sh(mesh, da, "model")
        step, opt = _train_step_fn(T.loss_fn, cfg,
                                   micro=2 if scheme == "opt" else 1)
        opt_like = jax.eval_shape(opt.init, params_like)
        opt_sh = _opt_shardings(param_sh, mesh)
        batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        batch_sh = {"tokens": bspec, "labels": bspec}
        flops = 3.0 * _lm_flops_fwd(cfg, b * s, kv_len=s // 2)
        return StepBundle(
            name=f"{arch.arch_id}:{shape.name}", kind="train",
            fn=step, specs=(params_like, opt_like, batch),
            in_shardings=(param_sh, opt_sh, batch_sh),
            out_shardings=(param_sh, opt_sh, _rep(mesh)),
            donate=(0, 1),
            meta={"family": "lm", "model_flops": flops,
                  "params": cfg.param_count(),
                  "active_params": cfg.active_param_count(),
                  "tokens": b * s},
        )

    if shape.kind == "prefill":
        def step(params, tokens):
            return T.prefill_step(params, tokens, cfg)
        cache_sh = _lm_cache_sharding(mesh, b)
        tokens = jax.ShapeDtypeStruct((b, s), jnp.int32)
        logits_sh = (_sh(mesh, da, "model") if b % _n_dp(mesh) == 0
                     else _sh(mesh, None, "model"))
        flops = _lm_flops_fwd(cfg, b * s, kv_len=s // 2)
        return StepBundle(
            name=f"{arch.arch_id}:{shape.name}", kind="prefill",
            fn=step, specs=(params_like, tokens),
            in_shardings=(param_sh, bspec),
            out_shardings=(logits_sh, cache_sh, cache_sh),
            donate=(),
            meta={"family": "lm", "model_flops": flops,
                  "params": cfg.param_count(),
                  "active_params": cfg.active_param_count(),
                  "tokens": b * s},
        )

    # decode (decode_32k / long_500k): one new token, S_max-slot cache
    def step(params, token, cache_k, cache_v, cache_len):
        return T.decode_step_inplace(params, token, cache_k, cache_v,
                                     cache_len, cfg)

    cache_sh = _lm_cache_sharding(mesh, b, cfg.n_kv_heads)
    cache = jax.ShapeDtypeStruct(
        (cfg.n_layers, b, s, cfg.n_kv_heads, cfg.head_dim), cfg.jdtype)
    token = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    clen = jax.ShapeDtypeStruct((), jnp.int32)
    logits_sh = (_sh(mesh, da, "model") if b % _n_dp(mesh) == 0
                 else _sh(mesh, None, "model"))
    flops = _lm_flops_fwd(cfg, b, kv_len=s)
    return StepBundle(
        name=f"{arch.arch_id}:{shape.name}", kind="decode",
        fn=step, specs=(params_like, token, cache, cache, clen),
        in_shardings=(param_sh, bspec if b > 1 else _rep(mesh),
                      cache_sh, cache_sh, _rep(mesh)),
        out_shardings=(logits_sh, cache_sh, cache_sh),
        donate=(2, 3),
        meta={"family": "lm", "model_flops": flops,
              "params": cfg.param_count(),
              "active_params": cfg.active_param_count(),
              "tokens": b},
    )


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------

_GNN_MODELS = {
    "meshgraphnet": MGN,
    "schnet": SCH,
    "nequip": NQ,
    "pna": PNA,
}


def _gnn_init_like(arch: ArchDef, d_feat: int):
    mod = _GNN_MODELS[arch.arch_id]
    cfg = arch.config
    key = jax.random.PRNGKey(0)
    if arch.arch_id in ("meshgraphnet", "pna"):
        return mod, cfg, jax.eval_shape(
            functools.partial(mod.init_params, cfg=cfg, d_node=d_feat), key)
    return mod, cfg, jax.eval_shape(
        functools.partial(mod.init_params, cfg=cfg), key)


def _gnn_batch_specs(arch: ArchDef, n: int, e: int, d_feat: int,
                     mol_batch: int = 0) -> dict:
    f32, i32 = jnp.float32, jnp.int32
    specs = {
        "senders": jax.ShapeDtypeStruct((e,), i32),
        "receivers": jax.ShapeDtypeStruct((e,), i32),
        "node_mask": jax.ShapeDtypeStruct((n,), jnp.bool_),
    }
    needs_feat = arch.arch_id in ("meshgraphnet", "pna")
    if needs_feat:
        specs["node_feat"] = jax.ShapeDtypeStruct((n, d_feat), f32)
    if "pos" in arch.gnn_inputs or arch.arch_id in ("schnet", "nequip"):
        specs["positions"] = jax.ShapeDtypeStruct((n, 3), f32)
    if arch.arch_id in ("schnet", "nequip"):
        specs["species"] = jax.ShapeDtypeStruct((n,), i32)
    if mol_batch:
        specs["graph_id"] = jax.ShapeDtypeStruct((n,), i32)
        specs["targets"] = jax.ShapeDtypeStruct((mol_batch, 1), f32)
    else:
        specs["targets"] = jax.ShapeDtypeStruct((n, 1), f32)
    return specs


def _gnn_flops_fwd(arch: ArchDef, n: int, e: int, d_feat: int) -> float:
    cfg = arch.config
    if arch.arch_id == "meshgraphnet":
        h = cfg.d_hidden
        per = e * (3 * h * h + h * h) + n * (2 * h * h + h * h)
        enc = n * d_feat * h + e * 4 * h + n * h * cfg.out_dim
        return 2.0 * (cfg.n_layers * per + enc)
    if arch.arch_id == "pna":
        h = cfg.d_hidden
        n_agg = len(cfg.aggregators) * len(cfg.scalers)
        per = e * (2 * h * h) + n * ((n_agg + 1) * h * h)
        return 2.0 * (cfg.n_layers * per + n * d_feat * h)
    if arch.arch_id == "schnet":
        h, r = cfg.d_hidden, cfg.n_rbf
        per = e * (r * h + h * h + h) + n * (2 * h * h)
        return 2.0 * (cfg.n_interactions * per + n * h * h)
    if arch.arch_id == "nequip":
        c = cfg.channels
        # paths for l_max=2: (l1,l2,l3) with |l1-l2|<=l3<=min(l1+l2,lmax)
        import repro.models.gnn.so3 as so3
        paths = so3.paths(cfg.l_max)
        tp = sum((2 * l1 + 1) * (2 * l2 + 1) * (2 * l3 + 1)
                 for (l1, l2, l3) in paths)
        per = e * (cfg.n_rbf * 32 + 32 * len(paths) * c + c * tp) \
            + n * (len(paths) * c * c * 9)
        return 2.0 * cfg.n_layers * per
    raise ValueError(arch.arch_id)


def build_gnn(arch: ArchDef, shape: GNNShape, mesh: Mesh,
              scheme: str = "baseline") -> StepBundle:
    if scheme == "halo":
        return build_gnn_halo(arch, shape, mesh)
    ax = _all_axes(mesh)
    n_dev = 1
    for a in ax:
        n_dev *= mesh.shape[a]

    if shape.kind == "molecule":
        n_mol = shape.mol_batch
        n = n_mol * shape.n_nodes
        e = round_to(2 * shape.n_edges * n_mol, n_dev)
        n = round_to(n, n_dev)
        d_feat = 16
        mol = n_mol
    elif shape.kind == "minibatch":
        n_sub, e_sub = subgraph_sizes(shape.batch_nodes, shape.fanout)
        n = round_to(n_sub, n_dev)
        e = round_to(e_sub, n_dev)
        d_feat = shape.d_feat
        mol = 0
    else:
        n = round_to(shape.n_nodes, n_dev)
        e = round_to(2 * shape.n_edges, n_dev)
        d_feat = shape.d_feat
        mol = 0

    mod, cfg, params_like = _gnn_init_like(arch, d_feat)
    param_sh = jax.tree.map(lambda _: _rep(mesh), params_like)
    step, opt = _train_step_fn(mod.loss_fn, cfg)
    opt_like = jax.eval_shape(opt.init, params_like)
    opt_sh = _opt_shardings(param_sh, mesh)

    batch = _gnn_batch_specs(arch, n, e, d_feat, mol)
    node_sh = _sh(mesh, ax)
    nodef_sh = _sh(mesh, ax, None)
    batch_sh = {}
    for k_, v in batch.items():
        if k_ in ("senders", "receivers"):
            batch_sh[k_] = _sh(mesh, ax)
        elif k_ == "graph_id":
            batch_sh[k_] = node_sh
        elif k_ == "targets" and mol:
            batch_sh[k_] = _sh(mesh, ax, None) if mol % n_dev == 0 \
                else _rep(mesh)
        elif v.ndim == 1:
            batch_sh[k_] = node_sh
        else:
            batch_sh[k_] = nodef_sh

    flops = 3.0 * _gnn_flops_fwd(arch, n, e, d_feat)
    return StepBundle(
        name=f"{arch.arch_id}:{shape.name}", kind="train",
        fn=step, specs=(params_like, opt_like, batch),
        in_shardings=(param_sh, opt_sh, batch_sh),
        out_shardings=(param_sh, opt_sh, _rep(mesh)),
        donate=(0, 1),
        meta={"family": "gnn", "model_flops": flops,
              "n_nodes": n, "n_edges": e},
    )


def build_gnn_halo(arch: ArchDef, shape: GNNShape, mesh: Mesh) -> StepBundle:
    """§Perf 'halo' scheme: SDP-blocked layout + boundary-only exchange.

    B_max (published boundary rows per shard) is sized from the measured
    SDP boundary fraction on a scaled proxy graph (artifacts/halo_frac.json,
    produced by benchmarks/measure_halo.py); the hash-partition baseline
    corresponds to halo_frac ≈ 1.
    """
    import json
    import os
    assert arch.arch_id in ("meshgraphnet",), \
        "halo scheme is implemented for the meshgraphnet hillclimb cell"
    assert shape.kind == "full"
    from repro.runtime.gnn_halo_train import make_mgn_halo_loss

    ax = _all_axes(mesh)
    n_dev = 1
    for a in ax:
        n_dev *= mesh.shape[a]
    n = round_to(shape.n_nodes, n_dev)
    e2 = round_to(2 * shape.n_edges, n_dev)
    nb = n // n_dev
    e_max = round_to(int(1.25 * e2 / n_dev), 8)

    frac = 0.5
    path = "artifacts/halo_frac.json"
    if os.path.exists(path):
        with open(path) as f:
            frac = json.load(f).get(shape.name, {}).get("sdp", frac)
    b_max = min(nb, round_to(max(8, int(frac * nb)), 8))
    h_max = min(8 * b_max, round_to(max(8, int(frac * nb * 4)), 8))

    cfg = arch.config
    d_feat = shape.d_feat
    params_like = jax.eval_shape(
        functools.partial(_GNN_MODELS["meshgraphnet"].init_params,
                          cfg=cfg, d_node=d_feat), jax.random.PRNGKey(0))
    param_sh = jax.tree.map(lambda _: _rep(mesh), params_like)
    loss_fn = make_mgn_halo_loss(mesh, cfg, nb)
    step, opt = _train_step_fn(loss_fn, cfg)
    opt_like = jax.eval_shape(opt.init, params_like)
    opt_sh = _opt_shardings(param_sh, mesh)

    f32, i32 = jnp.float32, jnp.int32
    batch = {
        "node_feat": jax.ShapeDtypeStruct((n_dev, nb, d_feat), f32),
        "targets": jax.ShapeDtypeStruct((n_dev, nb, 1), f32),
        "node_mask": jax.ShapeDtypeStruct((n_dev, nb), jnp.bool_),
        "publish_idx": jax.ShapeDtypeStruct((n_dev, b_max), i32),
        "halo_map": jax.ShapeDtypeStruct((n_dev, h_max, 2), i32),
        "senders": jax.ShapeDtypeStruct((n_dev, e_max), i32),
        "receivers": jax.ShapeDtypeStruct((n_dev, e_max), i32),
    }
    batch_sh = {k: _sh(mesh, ax) for k in batch}
    flops = 3.0 * _gnn_flops_fwd(arch, n, e2, d_feat)
    return StepBundle(
        name=f"{arch.arch_id}:{shape.name}:halo", kind="train",
        fn=step, specs=(params_like, opt_like, batch),
        in_shardings=(param_sh, opt_sh, batch_sh),
        out_shardings=(param_sh, opt_sh, _rep(mesh)),
        donate=(0, 1),
        meta={"family": "gnn", "model_flops": flops, "n_nodes": n,
              "n_edges": e2, "halo_frac": frac, "b_max": b_max},
    )


# ---------------------------------------------------------------------------
# RecSys cells
# ---------------------------------------------------------------------------

def _recsys_param_shardings(params_like, mesh: Mesh,
                            all_axes: bool = False):
    """Tables row-sharded (model axis, or the whole mesh when all_axes —
    the §Perf 'opt' scheme); towers replicated."""
    rows = _all_axes(mesh) if all_axes else "model"

    def rule(path, _):
        if "table" in path:
            return _sh(mesh, rows, None)
        return _rep(mesh)
    paths, vals, treedef = SHR.tree_paths(params_like)
    return jax.tree_util.tree_unflatten(
        treedef, [rule(p, v) for p, v in zip(paths, vals)])


def _recsys_flops_fwd(cfg: RS.TwoTowerConfig, b: int) -> float:
    d = cfg.embed_dim
    tower = 0.0
    dims_u = [cfg.user_fields * d, *cfg.tower_mlp]
    dims_i = [cfg.item_fields * d, *cfg.tower_mlp]
    for a, bb in zip(dims_u[:-1], dims_u[1:]):
        tower += a * bb
    for a, bb in zip(dims_i[:-1], dims_i[1:]):
        tower += a * bb
    lookups = (cfg.user_fields + cfg.item_fields) * cfg.field_slots * d
    return 2.0 * b * (tower + lookups)


def build_recsys(arch: ArchDef, shape: RecSysShape, mesh: Mesh,
                 scheme: str = "baseline") -> StepBundle:
    cfg: RS.TwoTowerConfig = arch.config
    params_like = jax.eval_shape(
        functools.partial(RS.init_params, cfg=cfg), jax.random.PRNGKey(0))
    param_sh = _recsys_param_shardings(params_like, mesh,
                                       all_axes=scheme == "opt")
    da = SHR.batch_axes(mesh)
    ax = _all_axes(mesh)
    n_dev = 1
    for a in ax:
        n_dev *= mesh.shape[a]
    b = shape.batch
    i32, f32 = jnp.int32, jnp.float32

    def ids_spec(bb, fields):
        return jax.ShapeDtypeStruct((bb, fields, cfg.field_slots), i32)

    if shape.kind == "train":
        step, opt = _train_step_fn(RS.loss_fn, cfg)
        opt_like = jax.eval_shape(opt.init, params_like)
        opt_sh = _opt_shardings(param_sh, mesh)
        batch = {"user_ids": ids_spec(b, cfg.user_fields),
                 "item_ids": ids_spec(b, cfg.item_fields),
                 "log_q": jax.ShapeDtypeStruct((b,), f32)}
        bsh = _sh(mesh, da)
        batch_sh = {"user_ids": _sh(mesh, da, None, None),
                    "item_ids": _sh(mesh, da, None, None),
                    "log_q": bsh}
        flops = 3.0 * (_recsys_flops_fwd(cfg, b)
                       + 2.0 * b * b * cfg.tower_mlp[-1])
        return StepBundle(
            name=f"{arch.arch_id}:{shape.name}", kind="train",
            fn=step, specs=(params_like, opt_like, batch),
            in_shardings=(param_sh, opt_sh, batch_sh),
            out_shardings=(param_sh, opt_sh, _rep(mesh)),
            donate=(0, 1),
            meta={"family": "recsys", "model_flops": flops, "batch": b},
        )

    if shape.kind == "retrieval":
        nc = shape.n_candidates

        def step(params, batch):
            return RS.score_candidates(params, batch, cfg)

        batch = {"user_ids": ids_spec(b, cfg.user_fields),
                 "cand_item_emb": jax.ShapeDtypeStruct(
                     (nc, cfg.tower_mlp[-1]), f32)}
        batch_sh = {"user_ids": _rep(mesh),
                    "cand_item_emb": _sh(mesh, ax, None)}
        flops = _recsys_flops_fwd(cfg, b) + 2.0 * b * nc * cfg.tower_mlp[-1]
        return StepBundle(
            name=f"{arch.arch_id}:{shape.name}", kind="serve",
            fn=step, specs=(params_like, batch),
            in_shardings=(param_sh, batch_sh),
            out_shardings=_sh(mesh, None, ax),
            donate=(),
            meta={"family": "recsys", "model_flops": flops, "batch": b},
        )

    # serve_p99 / serve_bulk: pairwise scores
    def step(params, batch):
        return RS.serve_score(params, batch, cfg)

    wide = b % n_dev == 0
    bsh = _sh(mesh, ax) if wide else (_sh(mesh, da) if b % _n_dp(mesh) == 0
                                      else _rep(mesh))
    id_sh_axes = ax if wide else (da if b % _n_dp(mesh) == 0 else None)
    id_sh = (_sh(mesh, id_sh_axes, None, None) if id_sh_axes
             else _rep(mesh))
    batch = {"user_ids": ids_spec(b, cfg.user_fields),
             "item_ids": ids_spec(b, cfg.item_fields)}
    batch_sh = {"user_ids": id_sh, "item_ids": id_sh}
    flops = _recsys_flops_fwd(cfg, b)
    return StepBundle(
        name=f"{arch.arch_id}:{shape.name}", kind="serve",
        fn=step, specs=(params_like, batch),
        in_shardings=(param_sh, batch_sh),
        out_shardings=bsh,
        donate=(),
        meta={"family": "recsys", "model_flops": flops, "batch": b},
    )


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------

def build_step(arch_id: str, shape_name: str, mesh: Mesh,
               scheme: str = "baseline") -> StepBundle:
    arch = get_arch(arch_id)
    shape = arch.shapes[shape_name]
    if shape_name in arch.skip_shapes:
        raise ValueError(
            f"{arch_id}:{shape_name} is skip-marked: "
            f"{arch.skip_shapes[shape_name]}")
    if arch.family == "lm":
        return build_lm(arch, shape, mesh, scheme)
    if arch.family == "gnn":
        return build_gnn(arch, shape, mesh, scheme)
    return build_recsys(arch, shape, mesh, scheme)
