import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) on the production
meshes, prove memory fits, and extract the roofline terms.

MUST be run as its own process (the two lines above lock the device count
before jax initialises):

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-9b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Per cell it records to artifacts/dryrun/<arch>__<shape>__<mesh>.json:
  * memory_analysis()   — per-device argument/temp/output bytes (fits HBM?)
  * cost_analysis()     — per-device HLO FLOPs + bytes accessed
  * collective bytes    — parsed from compiled.as_text(): per-op-type wire
                          bytes per device (ring-model) for all-gather /
                          all-reduce / reduce-scatter / all-to-all /
                          collective-permute
  * roofline terms      — seconds, vs 197 TFLOP/s bf16, 819 GB/s HBM,
                          50 GB/s/link ICI (TPU v5e-class constants)
"""
import argparse
import json
import re
import subprocess
import sys
import time
import traceback

# hardware constants (v5e-class chip; assignment-specified)
PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link (per chip, ring model)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0,
}

_COLL_RE = re.compile(
    r"=\s*(?P<shapes>.*?)\s*"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<start>-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[\d+\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(shapes_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shapes_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, n_devices: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return n_devices


def parse_collectives(hlo: str, n_devices: int) -> dict:
    """Per-device wire bytes by op type (ring model), from optimized HLO."""
    out: dict[str, dict] = {}
    total = 0.0
    for line in hlo.splitlines():
        m = _COLL_RE.search(line)
        if m is None:
            continue
        op = m.group("op")
        nbytes = _shape_bytes(m.group("shapes"))
        g = max(_group_size(line, n_devices), 1)
        if op == "all-gather":
            wire = nbytes * (g - 1) / g
        elif op == "all-reduce":
            wire = 2.0 * nbytes * (g - 1) / g
        elif op == "reduce-scatter":
            wire = nbytes * (g - 1)          # result shape is the shard
        elif op == "all-to-all":
            wire = nbytes * (g - 1) / g
        else:                                 # collective-permute
            wire = float(nbytes)
        rec = out.setdefault(op, {"count": 0, "bytes": 0.0, "wire": 0.0})
        rec["count"] += 1
        rec["bytes"] += nbytes
        rec["wire"] += wire
        total += wire
    return {"per_op": out, "wire_bytes_per_device": total}


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             scan_hlo: bool = True, scheme: str = "baseline") -> dict:
    import jax
    from repro.configs import get_arch
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_step

    arch = get_arch(arch_id)
    mesh_name = "multi" if multi_pod else "single"
    rec = {"arch": arch_id, "shape": shape_name, "mesh": mesh_name,
           "scheme": scheme, "status": "ok"}
    if shape_name in arch.skip_shapes:
        rec["status"] = "skip"
        rec["reason"] = arch.skip_shapes[shape_name]
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_devices = mesh.devices.size
    bundle = build_step(arch_id, shape_name, mesh, scheme)
    with mesh:
        jitted = jax.jit(
            bundle.fn,
            in_shardings=bundle.in_shardings,
            out_shardings=bundle.out_shardings,
            donate_argnums=bundle.donate,
        )
        lowered = jitted.lower(*bundle.specs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    rec["lower_s"] = round(t_lower, 2)
    rec["compile_s"] = round(t_compile, 2)
    rec["memory"] = {
        "argument_bytes": int(mem.argument_size_in_bytes),
        "output_bytes": int(mem.output_size_in_bytes),
        "temp_bytes": int(mem.temp_size_in_bytes),
        "alias_bytes": int(mem.alias_size_in_bytes),
        "code_bytes": int(mem.generated_code_size_in_bytes),
    }
    live = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
            + mem.output_size_in_bytes - mem.alias_size_in_bytes)
    rec["memory"]["live_bytes_per_device"] = int(live)
    # raw XLA numbers (loop bodies counted ONCE — reference only)
    rec["xla_cost"] = {
        "flops_per_device": float(cost.get("flops", 0.0)),
        "bytes_per_device": float(cost.get("bytes accessed", 0.0)),
    }
    # loop-aware HLO analysis (trip-count-correct; the roofline source)
    from repro.launch.hlo_stats import analyze
    st = analyze(compiled.as_text(), n_devices)
    rec["cost"] = {
        "flops_per_device": st["flops_per_device"],
        "bytes_per_device": st["hbm_bytes_per_device"],
    }
    colls = st["collectives"]
    rec["collectives"] = colls

    flops_dev = rec["cost"]["flops_per_device"]
    bytes_dev = rec["cost"]["bytes_per_device"]
    wire_dev = colls["wire_bytes_per_device"]
    t_c = flops_dev / PEAK_FLOPS
    t_m = bytes_dev / HBM_BW
    t_n = wire_dev / ICI_BW
    dom = max((t_c, "compute"), (t_m, "memory"), (t_n, "collective"))[1]
    model_flops = float(bundle.meta.get("model_flops", 0.0))
    rec["roofline"] = {
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_n,
        "dominant": dom,
        "model_flops": model_flops,
        "hlo_flops_global": flops_dev * n_devices,
        "useful_flops_ratio": (model_flops / (flops_dev * n_devices)
                               if flops_dev else 0.0),
        "n_devices": n_devices,
        "step_time_bound_s": max(t_c, t_m, t_n),
    }
    rec["meta"] = {k: (float(v) if isinstance(v, (int, float)) else v)
                   for k, v in bundle.meta.items()}
    return rec


def _out_path(out_dir: str, arch: str, shape: str, mesh: str,
              scheme: str = "baseline") -> str:
    safe = arch.replace("/", "_")
    suffix = "" if scheme == "baseline" else f"__{scheme}"
    return os.path.join(out_dir, f"{safe}__{shape}__{mesh}{suffix}.json")


def main() -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", type=str, default=None)
    p.add_argument("--shape", type=str, default=None)
    p.add_argument("--mesh", choices=("single", "multi", "both"),
                   default="single")
    p.add_argument("--all", action="store_true",
                   help="run every (arch × shape) cell in subprocesses")
    p.add_argument("--out", type=str, default="artifacts/dryrun")
    p.add_argument("--jobs", type=int, default=2,
                   help="parallel subprocesses for --all")
    p.add_argument("--force", action="store_true",
                   help="re-run cells that already have artifacts")
    p.add_argument("--no-hlo-scan", action="store_true")
    p.add_argument("--scheme", type=str, default="baseline",
                   help="sharding scheme: baseline | opt | halo (§Perf)")
    args = p.parse_args()
    os.makedirs(args.out, exist_ok=True)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    if args.all:
        from repro.configs import ARCHS
        cells = []
        for arch_id, arch in ARCHS.items():
            for shape in arch.shapes:
                for mp in meshes:
                    mesh_name = "multi" if mp else "single"
                    path = _out_path(args.out, arch_id, shape, mesh_name)
                    if not args.force and os.path.exists(path):
                        with open(path) as f:
                            prior = json.load(f)
                        if prior.get("status") in ("ok", "skip"):
                            continue   # re-run only errored cells
                    cells.append((arch_id, shape, mesh_name))
        print(f"dry-run: {len(cells)} cells to compile", flush=True)
        procs: list[tuple[tuple, subprocess.Popen]] = []
        failures = 0
        while cells or procs:
            while cells and len(procs) < args.jobs:
                cell = cells.pop(0)
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", cell[0], "--shape", cell[1],
                       "--mesh", "multi" if cell[2] == "multi" else "single",
                       "--out", args.out]
                if args.no_hlo_scan:
                    cmd.append("--no-hlo-scan")
                procs.append((cell, subprocess.Popen(cmd)))
                print(f"  launch {cell}", flush=True)
            done = [(c, pr) for c, pr in procs if pr.poll() is not None]
            procs = [(c, pr) for c, pr in procs if pr.poll() is None]
            for cell, pr in done:
                st = "ok" if pr.returncode == 0 else f"RC={pr.returncode}"
                failures += pr.returncode != 0
                print(f"  done   {cell}: {st}", flush=True)
            if procs:
                time.sleep(2.0)
        print(f"dry-run complete; {failures} failures", flush=True)
        return 1 if failures else 0

    assert args.arch and args.shape, "--arch and --shape (or --all) required"
    for mp in meshes:
        mesh_name = "multi" if mp else "single"
        try:
            rec = run_cell(args.arch, args.shape, mp,
                           scan_hlo=not args.no_hlo_scan,
                           scheme=args.scheme)
        except Exception:
            rec = {"arch": args.arch, "shape": args.shape,
                   "mesh": mesh_name, "scheme": args.scheme,
                   "status": "error", "traceback": traceback.format_exc()}
        path = _out_path(args.out, args.arch, args.shape, mesh_name,
                         args.scheme)
        with open(path, "w") as f:
            json.dump(rec, f, indent=2)
        if rec["status"] == "ok":
            r = rec["roofline"]
            print(f"{args.arch}:{args.shape}:{mesh_name} OK "
                  f"compile={rec['compile_s']:.0f}s "
                  f"mem={rec['memory']['live_bytes_per_device']/2**30:.2f}GiB "
                  f"compute={r['compute_s']*1e3:.2f}ms "
                  f"memory={r['memory_s']*1e3:.2f}ms "
                  f"collective={r['collective_s']*1e3:.2f}ms "
                  f"dom={r['dominant']}", flush=True)
        elif rec["status"] == "skip":
            print(f"{args.arch}:{args.shape}:{mesh_name} SKIP "
                  f"({rec['reason'][:60]}…)", flush=True)
        else:
            print(f"{args.arch}:{args.shape}:{mesh_name} ERROR", flush=True)
            print(rec["traceback"], flush=True)
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
