"""Training driver: end-to-end fault-tolerant training for any --arch.

On this CPU container it runs the reduced (smoke) configs end-to-end —
same code path the production mesh would use: config → params → sharded
jit step → data pipeline → fault-tolerant loop with async checkpoints.

    PYTHONPATH=src python -m repro.launch.train --arch gemma2-9b \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Use --full to build the full-size config instead (requires a real pod).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_arch
from repro.data import pipeline as dp
from repro.graph.generators import make_graph
from repro.models import recsys as RS
from repro.models import transformer as T
from repro.models.gnn import common as C
from repro.optim.optimizers import adamw, apply_updates, linear_warmup_cosine
from repro.runtime.fault import FaultTolerantLoop


def build_lm(arch, args):
    cfg = arch.config if args.full else arch.smoke_config
    params = T.init_params(jax.random.PRNGKey(args.seed), cfg)
    batches = dp.token_batches(cfg.vocab, args.batch, args.seq,
                               seed=args.seed)
    return cfg, T.loss_fn, params, batches


def build_gnn(arch, args):
    cfg = arch.config if args.full else arch.smoke_config
    from repro.launch.steps import _GNN_MODELS
    mod = _GNN_MODELS[arch.arch_id]
    if arch.arch_id in ("schnet", "nequip"):
        batch = C.batch_molecules(args.batch, 12, 24, seed=args.seed)
        params = mod.init_params(jax.random.PRNGKey(args.seed), cfg)
    else:
        g = make_graph("mesh", 256, 700, seed=args.seed)
        batch = C.graph_to_batch(g, 16, with_positions=True, seed=args.seed)
        params = mod.init_params(jax.random.PRNGKey(args.seed), cfg,
                                 d_node=16)

    def batches():
        while True:
            yield batch

    return cfg, mod.loss_fn, params, batches()


def build_recsys(arch, args):
    cfg = arch.config if args.full else arch.smoke_config
    params = RS.init_params(jax.random.PRNGKey(args.seed), cfg)
    batches = dp.recsys_batches(cfg, args.batch, seed=args.seed)
    return cfg, RS.loss_fn, params, batches


def main() -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", required=True)
    p.add_argument("--steps", type=int, default=20)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--full", action="store_true")
    p.add_argument("--ckpt-dir", type=str, default="/tmp/repro_ckpt")
    p.add_argument("--ckpt-interval", type=int, default=20)
    p.add_argument("--log-every", type=int, default=5)
    args = p.parse_args()

    arch = get_arch(args.arch)
    builders = {"lm": build_lm, "gnn": build_gnn, "recsys": build_recsys}
    cfg, loss_fn, params, batches = builders[arch.family](arch, args)

    opt = adamw(linear_warmup_cosine(args.lr, args.steps // 10 + 1,
                                     args.steps))
    opt_state = opt.init(params)
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    print(f"arch={args.arch} family={arch.family} params={n_params:,}")

    @jax.jit
    def step_fn(state, batch):
        params, opt_state = state
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch, cfg)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return (params, opt_state), dict(metrics, loss=loss)

    ckpt = CheckpointManager(args.ckpt_dir, interval=args.ckpt_interval)
    loop = FaultTolerantLoop(ckpt)

    losses = []
    state = (params, opt_state)
    restored, rstep = ckpt.restore(state)
    start = 0
    if restored is not None:
        state, start = restored, rstep
        print(f"resumed from checkpoint step {start}")

    def counted(it, n):
        for _ in range(n):
            yield next(it)

    t0 = time.time()
    step = start

    def stepper(state, batch):
        nonlocal step
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % args.log_every == 0:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"({time.time() - t0:.1f}s)", flush=True)
        step += 1
        return state, metrics

    state, final = loop.run(state, counted(batches, args.steps - start),
                            stepper, start_step=start)
    ckpt.maybe_save(final, state, blocking=True)
    print(f"done: {final} steps, loss {losses[0]:.4f} -> {losses[-1]:.4f}, "
          f"{(time.time()-t0):.1f}s")
    assert np.isfinite(losses[-1]), "training diverged"
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
