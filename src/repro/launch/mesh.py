"""Production mesh construction.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and only then calls these.

Mesh shapes (assignment):
  single-pod:  (16, 16)      = ("data", "model")   — 256 chips
  multi-pod:   (2, 16, 16)   = ("pod", "data", "model") — 512 chips
"""
from __future__ import annotations

import jax


def make_mesh_compat(shape, axes) -> jax.sharding.Mesh:
    """jax.make_mesh across jax versions: axis_types landed after 0.4.x."""
    try:
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
        )
    except (AttributeError, TypeError):
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    return make_mesh_compat((data, model), ("data", "model"))


def make_lane_mesh(n_devices: int | None = None) -> jax.sharding.Mesh:
    """1-D mesh over local devices for lane-parallel sweeps (axis "lanes").

    The sweep runtime (repro.runtime.sweep) shard_maps the lane axis of a
    (policy × seed × config) sweep over this mesh; lanes are embarrassingly
    parallel, so the mesh carries no collectives.
    """
    if n_devices is None:
        n_devices = jax.device_count()
    return make_mesh_compat((n_devices,), ("lanes",))


def make_vertices_mesh(n_devices: int | None = None,
                       devices=None) -> jax.sharding.Mesh:
    """1-D mesh over local devices for vertex-sharded sessions (axis
    "vertices").

    One session's per-vertex state (adjacency rows, label journal,
    presence/touch counters) is laid out as per-device row blocks along
    this axis; the K-sized loads and the O(K²) cut matrix stay replicated
    and are combined with ``lax.psum`` once per window
    (repro.runtime.shard_session).

    ``devices`` selects an explicit device subset (benchmarks sweep mesh
    widths this way — the device count cannot change in-process);
    otherwise the first ``n_devices`` local devices are used.
    """
    import numpy as np
    if devices is None:
        avail = jax.devices()
        if n_devices is None:
            n_devices = len(avail)
        if n_devices > len(avail):
            raise ValueError(
                f"make_vertices_mesh(n_devices={n_devices}) exceeds the "
                f"{len(avail)} local devices — force more with "
                "XLA_FLAGS=--xla_force_host_platform_device_count=N or "
                "pass an explicit devices= subset")
        devices = avail[:n_devices]
    return jax.sharding.Mesh(np.asarray(devices), ("vertices",))


def make_grid_mesh(n_lanes: int, n_vertices: int,
                   devices=None) -> jax.sharding.Mesh:
    """2-D (lanes × vertices) mesh: sweep lanes on the first axis, each
    lane's vertex blocks on the second.

    This is the composition guard for the two 1-D meshes: asking for
    ``make_lane_mesh()`` (which claims every local device) *and* a
    vertices mesh used to silently oversubscribe the device pool. Build
    the grid explicitly instead; the product must fit the device budget
    or this raises with the arithmetic spelled out.
    """
    import numpy as np
    if n_lanes < 1 or n_vertices < 1:
        raise ValueError(
            f"make_grid_mesh(n_lanes={n_lanes}, n_vertices={n_vertices}): "
            "both axis sizes must be >= 1")
    if devices is None:
        devices = jax.devices()
    need = n_lanes * n_vertices
    if need > len(devices):
        raise ValueError(
            f"make_grid_mesh(n_lanes={n_lanes}, n_vertices={n_vertices}) "
            f"needs {need} devices but only {len(devices)} are available — "
            "shrink one axis, force more host devices with "
            "XLA_FLAGS=--xla_force_host_platform_device_count=N, or run "
            "lane-sharded and vertex-sharded work as separate sweeps")
    grid = np.asarray(devices[:need]).reshape(n_lanes, n_vertices)
    return jax.sharding.Mesh(grid, ("lanes", "vertices"))


def shard_map_compat(f, mesh, in_specs, out_specs, check_rep=True):
    """jax.shard_map across jax versions (experimental until ~0.6).

    ``check_rep=False`` disables the replication checker — required when
    the mapped body contains a ``pallas_call`` (the sweep runtime's fused
    chooser lanes), which has no replication rule; lanes are
    embarrassingly parallel so the check is vacuous there anyway.
    """
    kw = {} if check_rep else {"check_rep": False}
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     **kw)


def mesh_devices(mesh: jax.sharding.Mesh) -> int:
    n = 1
    for s in mesh.shape.values():
        n *= s
    return n
