"""Production mesh construction.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and only then calls these.

Mesh shapes (assignment):
  single-pod:  (16, 16)      = ("data", "model")   — 256 chips
  multi-pod:   (2, 16, 16)   = ("pod", "data", "model") — 512 chips
"""
from __future__ import annotations

import jax


def make_mesh_compat(shape, axes) -> jax.sharding.Mesh:
    """jax.make_mesh across jax versions: axis_types landed after 0.4.x."""
    try:
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
        )
    except (AttributeError, TypeError):
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    return make_mesh_compat((data, model), ("data", "model"))


def make_lane_mesh(n_devices: int | None = None) -> jax.sharding.Mesh:
    """1-D mesh over local devices for lane-parallel sweeps (axis "lanes").

    The sweep runtime (repro.runtime.sweep) shard_maps the lane axis of a
    (policy × seed × config) sweep over this mesh; lanes are embarrassingly
    parallel, so the mesh carries no collectives.
    """
    if n_devices is None:
        n_devices = jax.device_count()
    return make_mesh_compat((n_devices,), ("lanes",))


def shard_map_compat(f, mesh, in_specs, out_specs, check_rep=True):
    """jax.shard_map across jax versions (experimental until ~0.6).

    ``check_rep=False`` disables the replication checker — required when
    the mapped body contains a ``pallas_call`` (the sweep runtime's fused
    chooser lanes), which has no replication rule; lanes are
    embarrassingly parallel so the check is vacuous there anyway.
    """
    kw = {} if check_rep else {"check_rep": False}
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     **kw)


def mesh_devices(mesh: jax.sharding.Mesh) -> int:
    n = 1
    for s in mesh.shape.values():
        n *= s
    return n
