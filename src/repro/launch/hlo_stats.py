"""Loop-aware HLO cost extraction for the roofline analysis.

XLA's ``compiled.cost_analysis()`` counts each while-loop body ONCE, which
undercounts a scan-over-layers model by ~n_layers×. This module parses the
optimized HLO text instead:

  * builds the computation call graph (fusions, calls, while bodies) with
    multipliers from each while's ``known_trip_count`` backend config;
  * FLOPs  — every ``dot`` (2 × result_elems × contraction_size), scaled by
    the product of enclosing trip counts;
  * HBM traffic — per *sequential* instruction: result bytes + operand
    bytes (fusion internals excluded: a fusion is one read per operand and
    one write per result, the TPU/CPU memory model);
  * collective wire bytes — ring-model per-device bytes for all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute, scaled
    by trip counts.

Validated against cost_analysis() on loop-free modules (tests).
"""
from __future__ import annotations

import dataclasses
import json
import re

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP = re.compile(r"^\s*(?:\(.*?\)|\S+)\s+([\w\-]+)\(")
_OPERANDS = re.compile(r"%([\w\.\-]+)")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLED = re.compile(
    r"(?:calls=|to_apply=|body=|condition=|branch_computations=\{|"
    r"called_computations=\{)%?([\w\.\-]+(?:,\s*%[\w\.\-]+)*)")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[\d+\]")
_GROUPS_LIST = re.compile(r"replica_groups=\{\{([^}]*)\}")

_SKIP_BYTES_OPS = {
    "tuple", "get-tuple-element", "parameter", "bitcast", "constant",
    "after-all", "while", "conditional", "call", "custom-call", "fusion2",
}
COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute", "all-gather-start", "all-reduce-start",
               "collective-permute-start")


def _shape_elems_bytes(type_str: str) -> tuple[int, int]:
    """Total (elems, bytes) over all array shapes in a type string."""
    elems = 0
    nbytes = 0
    for dt, dims in _SHAPE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dt]
    return elems, nbytes


@dataclasses.dataclass
class Instr:
    name: str
    op: str
    type_str: str       # result type portion
    rest: str           # full rhs text
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list
    shapes: dict        # instr name -> result type string


def _split_type_op(rhs: str) -> tuple[str, str]:
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    rest = rhs[i + 1:].lstrip()
                    om = re.match(r"([\w\-]+)\(", rest)
                    return rhs[:i + 1], (om.group(1) if om else "unknown")
        return rhs, "unknown"
    parts = rhs.split(None, 1)
    if len(parts) > 1:
        om = re.match(r"([\w\-]+)\(", parts[1])
        if om:
            return parts[0], om.group(1)
    return parts[0], "unknown"


def parse_computations(hlo: str) -> tuple[dict[str, Computation], str]:
    comps: dict[str, Computation] = {}
    entry = ""
    cur: Computation | None = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if (not line.startswith(" ") and line.endswith("{")
                and "->" in line and not line.startswith("HloModule")):
            m = _COMP_HDR.match(line.strip())
            if m:
                cur = Computation(m.group(2), [], {})
                comps[cur.name] = cur
                if m.group(1):
                    entry = cur.name
                continue
        if line.strip() == "}":
            continue
        if cur is None:
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        # rhs looks like: TYPE op(...), attrs...  — TYPE may be a tuple
        # containing parens and /*index=N*/ comments, so scan for balance.
        type_str, op = _split_type_op(rhs)
        # parameters: "%p = f32[...] parameter(0)"
        cur.instrs.append(Instr(name, op, type_str, rhs, line))
        cur.shapes[name] = type_str
    return comps, entry


def _dot_flops(instr: Instr, shapes: dict) -> float:
    res_elems, _ = _shape_elems_bytes(instr.type_str)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.rest)
    if not m:
        return 2.0 * res_elems  # degenerate
    cdims = [int(x) for x in m.group(1).split(",") if x]
    ops = _OPERANDS.findall(instr.rest.split("(", 1)[1])
    if not ops:
        return 0.0
    lhs = shapes.get(ops[0])
    if lhs is None:
        return 2.0 * res_elems
    sm = _SHAPE.search(lhs)
    if sm is None:
        return 2.0 * res_elems
    dims = [int(d) for d in sm.group(2).split(",") if d]
    csize = 1
    for c in cdims:
        if c < len(dims):
            csize *= dims[c]
    return 2.0 * res_elems * csize


def _group_size(line: str, n_devices: int) -> int:
    m = _GROUPS_IOTA.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return n_devices


def _collective_wire(instr: Instr, n_devices: int) -> tuple[str, float, float]:
    op = instr.op.replace("-start", "")
    _, nbytes = _shape_elems_bytes(instr.type_str)
    g = max(_group_size(instr.line, n_devices), 1)
    if op == "all-gather":
        wire = nbytes * (g - 1) / g
    elif op == "all-reduce":
        wire = 2.0 * nbytes * (g - 1) / g
    elif op == "reduce-scatter":
        wire = nbytes * (g - 1)
    elif op == "all-to-all":
        wire = nbytes * (g - 1) / g
    else:  # collective-permute
        wire = float(nbytes)
    return op, float(nbytes), wire


def _instr_bytes(instr: Instr, shapes: dict) -> float:
    """HBM traffic proxy: result bytes + operand bytes."""
    if instr.op in _SKIP_BYTES_OPS or instr.op.endswith("-done"):
        return 0.0
    _, wbytes = _shape_elems_bytes(instr.type_str)
    rbytes = 0
    arg_str = instr.rest.split("(", 1)[1] if "(" in instr.rest else ""
    # strip attribute tail (operands come before the first "),")
    arg_str = arg_str.split(")", 1)[0]
    for op_name in _OPERANDS.findall(arg_str):
        t = shapes.get(op_name)
        if t is not None:
            rbytes += _shape_elems_bytes(t)[1]
    return float(wbytes + rbytes)


def analyze(hlo: str, n_devices: int) -> dict:
    comps, entry_name = parse_computations(hlo)

    # ---- call graph with trip multipliers --------------------------------
    edges: dict[str, list[tuple[str, float]]] = {c: [] for c in comps}
    fusion_comps: set[str] = set()
    for cname, comp in comps.items():
        for ins in comp.instrs:
            trip = 1.0
            if ins.op == "while":
                tm = _TRIP.search(ins.line)
                trip = float(tm.group(1)) if tm else 1.0
            for m in _CALLED.finditer(ins.line):
                for callee in re.split(r",\s*", m.group(1)):
                    callee = callee.lstrip("%")
                    if callee in comps:
                        mult = trip if ins.op == "while" else 1.0
                        edges[cname].append((callee, mult))
                        if ins.op == "fusion":
                            fusion_comps.add(callee)

    # ---- propagate multipliers from ENTRY --------------------------------
    entry = entry_name if entry_name in comps else None
    if entry is None:  # fallback: computation that nobody calls
        called = {c for outs in edges.values() for c, _ in outs}
        roots = [c for c in comps if c not in called]
        entry = roots[-1] if roots else next(iter(comps))

    mult: dict[str, float] = {c: 0.0 for c in comps}
    mult[entry] = 1.0
    order = [entry]
    seen = {entry}
    i = 0
    while i < len(order):
        c = order[i]
        i += 1
        for callee, m in edges[c]:
            nm = mult[c] * m
            if nm > mult[callee] + 1e-9:
                mult[callee] = nm
                if callee not in seen:
                    seen.add(callee)
                    order.append(callee)
                elif callee in order[i:]:
                    pass
                else:
                    order.append(callee)
    # (monotone relaxation; call graphs are DAGs so this converges)

    # ---- accumulate -------------------------------------------------------
    flops = 0.0
    hbm_bytes = 0.0
    coll: dict[str, dict] = {}
    wire_total = 0.0
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0.0:
            continue
        in_fusion = cname in fusion_comps
        for ins in comp.instrs:
            if ins.op == "dot":
                flops += m * _dot_flops(ins, comp.shapes)
            elif ins.op in ("convolution",):
                res_elems, _ = _shape_elems_bytes(ins.type_str)
                flops += m * 2.0 * res_elems  # lower bound; no convs used
            if ins.op.replace("-start", "") in (
                    "all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute") \
                    and not ins.op.endswith("-done"):
                op, nbytes, wire = _collective_wire(ins, n_devices)
                rec = coll.setdefault(op, {"count": 0.0, "bytes": 0.0,
                                           "wire": 0.0})
                rec["count"] += m
                rec["bytes"] += m * nbytes
                rec["wire"] += m * wire
                wire_total += m * wire
            if not in_fusion:
                hbm_bytes += m * _instr_bytes(ins, comp.shapes)
    return {
        "flops_per_device": flops,
        "hbm_bytes_per_device": hbm_bytes,
        "collectives": {"per_op": coll,
                        "wire_bytes_per_device": wire_total},
        "n_computations": len(comps),
    }


if __name__ == "__main__":
    import sys
    with open(sys.argv[1]) as f:
        print(json.dumps(analyze(f.read(), int(sys.argv[2])), indent=2))
