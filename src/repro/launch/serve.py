"""Serving driver: batched LM prefill+decode with slot-based continuous
batching, and recsys request scoring.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-9b \
        --requests 8 --prompt-len 32 --gen 16

LM serving keeps a fixed pool of B decode slots with a preallocated
(S_max-slot) KV cache; finished sequences free their slot and the next
queued request is prefilled into it (continuous batching). The decode step
is the same ``decode_step_inplace`` the dry-run lowers on the production
mesh.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import recsys as RS
from repro.models import transformer as T


class LMServer:
    """Slot-based continuous batching over decode_step_inplace."""

    def __init__(self, params, cfg, *, slots: int, max_len: int):
        self.params, self.cfg = params, cfg
        self.slots, self.max_len = slots, max_len
        shape = (cfg.n_layers, slots, max_len, cfg.n_kv_heads, cfg.head_dim)
        self.cache_k = jnp.zeros(shape, cfg.jdtype)
        self.cache_v = jnp.zeros(shape, cfg.jdtype)
        self.lengths = np.zeros(slots, np.int64)       # valid prefix length
        self.active = np.zeros(slots, bool)
        self.tokens = np.zeros(slots, np.int32)        # last emitted token
        self.outputs: dict[int, list[int]] = {}
        self.slot_req = -np.ones(slots, np.int64)

        self._decode = jax.jit(
            lambda p, t, ck, cv, ln: T.decode_step_inplace(
                p, t, ck, cv, ln, cfg))
        self._prefill = jax.jit(
            lambda p, t: T.prefill_step(p, t, cfg))

    def add_request(self, req_id: int, prompt: np.ndarray) -> bool:
        free = np.where(~self.active)[0]
        if free.size == 0:
            return False
        s = int(free[0])
        logits, ck, cv = self._prefill(self.params, prompt[None])
        plen = prompt.shape[0]
        # write the prefilled cache into the slot
        self.cache_k = jax.lax.dynamic_update_slice(
            self.cache_k, ck[:, 0:1].astype(self.cache_k.dtype),
            (0, s, 0, 0, 0))
        self.cache_v = jax.lax.dynamic_update_slice(
            self.cache_v, cv[:, 0:1].astype(self.cache_v.dtype),
            (0, s, 0, 0, 0))
        tok = int(jnp.argmax(logits[0]))
        self.lengths[s] = plen
        self.tokens[s] = tok
        self.active[s] = True
        self.slot_req[s] = req_id
        self.outputs[req_id] = [tok]
        return True

    def decode_round(self):
        """One synchronous decode step for every active slot.

        All slots share one cache_len per step in the jitted kernel, so we
        decode per-unique-length groups (slot lengths diverge slowly; in
        production the Pallas decode kernel takes a per-slot length vector).
        """
        for ln in np.unique(self.lengths[self.active]):
            toks = jnp.asarray(self.tokens[None, :].T)     # (slots, 1)
            logits, ck, cv = self._decode(
                self.params, toks, self.cache_k, self.cache_v,
                jnp.int32(ln))
            sel = self.active & (self.lengths == ln)
            self.cache_k, self.cache_v = ck, cv
            nxt = np.asarray(jnp.argmax(logits, -1))
            for s in np.where(sel)[0]:
                tok = int(nxt[s])
                self.tokens[s] = tok
                self.outputs[int(self.slot_req[s])].append(tok)
                self.lengths[s] = ln + 1

    def finish(self, req_id: int):
        s = np.where(self.slot_req == req_id)[0]
        if s.size:
            self.active[s[0]] = False
            self.slot_req[s[0]] = -1


def serve_lm(args) -> int:
    arch = get_arch(args.arch)
    cfg = arch.config if args.full else arch.smoke_config
    params = T.init_params(jax.random.PRNGKey(args.seed), cfg)
    rng = np.random.default_rng(args.seed)
    server = LMServer(params, cfg, slots=args.slots,
                      max_len=args.prompt_len + args.gen + 1)
    t0 = time.time()
    pending = list(range(args.requests))
    done = 0
    while done < args.requests:
        while pending and server.add_request(
                pending[0],
                rng.integers(0, cfg.vocab, args.prompt_len).astype(np.int32)):
            pending.pop(0)
        server.decode_round()
        for req_id, out in list(server.outputs.items()):
            if len(out) >= args.gen and req_id in server.slot_req:
                server.finish(req_id)
                done += 1
    dt = time.time() - t0
    total_tokens = sum(len(v) for v in server.outputs.values())
    print(f"served {args.requests} requests, {total_tokens} tokens "
          f"in {dt:.1f}s ({total_tokens/dt:.1f} tok/s)")
    return 0


def serve_recsys(args) -> int:
    arch = get_arch(args.arch)
    cfg = arch.config if args.full else arch.smoke_config
    params = RS.init_params(jax.random.PRNGKey(args.seed), cfg)
    score = jax.jit(lambda p, b: RS.serve_score(p, b, cfg))
    t0 = time.time()
    n = 0
    for i in range(args.requests):
        batch = {k: jnp.asarray(v) for k, v in
                 RS.make_batch(cfg, args.slots, seed=args.seed + i).items()
                 if k != "log_q"}
        s = score(params, batch)
        n += s.shape[0]
    s.block_until_ready()
    dt = time.time() - t0
    print(f"scored {n} (user,item) pairs in {dt:.2f}s ({n/dt:.0f}/s)")
    return 0


def main() -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", required=True)
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--gen", type=int, default=16)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--full", action="store_true")
    args = p.parse_args()
    arch = get_arch(args.arch)
    if arch.family == "lm":
        return serve_lm(args)
    if arch.family == "recsys":
        return serve_recsys(args)
    raise SystemExit("serving supports lm and recsys archs")


if __name__ == "__main__":
    raise SystemExit(main())
