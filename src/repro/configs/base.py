"""Arch registry plumbing: every assigned architecture is an ArchDef."""
from __future__ import annotations

import dataclasses
from typing import Any

from repro.configs.shapes import (GNN_SHAPES, LM_SHAPES, RECSYS_SHAPES)


@dataclasses.dataclass(frozen=True)
class ArchDef:
    arch_id: str
    family: str                 # 'lm' | 'gnn' | 'recsys'
    config: Any                 # full-size model config (assigned numbers)
    smoke_config: Any           # reduced same-family config for CPU tests
    source: str                 # public citation tag from the assignment
    gnn_inputs: tuple = ()      # ('feat',) and/or ('pos', 'species')
    skip_shapes: dict = dataclasses.field(default_factory=dict)

    @property
    def shapes(self) -> dict:
        return {"lm": LM_SHAPES, "gnn": GNN_SHAPES,
                "recsys": RECSYS_SHAPES}[self.family]

    def runnable_shapes(self) -> list[str]:
        return [s for s in self.shapes if s not in self.skip_shapes]
