"""two-tower-retrieval [Yi et al., RecSys'19 (YouTube)]: embed_dim 256,
tower MLPs 1024-512-256, dot interaction, sampled softmax with logQ
correction. SDP applicability: DIRECT — the user-item co-access graph is
partitioned to place embedding rows (DESIGN.md §3)."""
from repro.configs.base import ArchDef
from repro.models.recsys import TwoTowerConfig

CONFIG = TwoTowerConfig(
    embed_dim=256, tower_mlp=(1024, 512, 256),
    user_vocab=50_331_648, item_vocab=50_331_648,
    user_fields=8, item_fields=4, field_slots=8,
)

SMOKE_CONFIG = TwoTowerConfig(
    embed_dim=16, tower_mlp=(32, 16),
    user_vocab=4096, item_vocab=4096,
    user_fields=4, item_fields=2, field_slots=4,
)

ARCH = ArchDef("two-tower-retrieval", "recsys", CONFIG, SMOKE_CONFIG,
               source="RecSys'19 (YouTube); unverified")
