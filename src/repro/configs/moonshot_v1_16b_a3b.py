"""moonshot-v1-16b-a3b [hf:moonshotai/Moonlight-16B-A3B]: 48L d2048 16H
(kv=16) v163840, MoE 64 experts top-6, expert ff 1408. Pure full attention
→ long_500k skipped."""
from repro.configs.base import ArchDef
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="moonshot-v1-16b-a3b", n_layers=48, d_model=2048, n_heads=16,
    n_kv_heads=16, head_dim=128, d_ff=1408, vocab=163840, act="silu",
    rope_theta=50000.0,
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408),
)

SMOKE_CONFIG = LMConfig(
    name="moonshot-smoke", n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
    head_dim=16, d_ff=64, vocab=256, act="silu", dtype="float32",
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=64),
)

ARCH = ArchDef(
    "moonshot-v1-16b-a3b", "lm", CONFIG, SMOKE_CONFIG,
    source="hf:moonshotai/Moonlight-16B-A3B; hf",
    skip_shapes={"long_500k": "pure full attention (no sub-quadratic path); "
                              "skip per assignment rule, see DESIGN.md §4"},
)
