"""phi3-mini-3.8b [arXiv:2404.14219]: 32L d3072 32H (kv=32 ⇒ MHA) ff8192
v32064, RoPE+SwiGLU. Pure full attention → long_500k skipped."""
from repro.configs.base import ArchDef
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="phi3-mini-3.8b", n_layers=32, d_model=3072, n_heads=32,
    n_kv_heads=32, head_dim=96, d_ff=8192, vocab=32064, act="silu",
    rope_theta=10000.0,
)

SMOKE_CONFIG = LMConfig(
    name="phi3-smoke", n_layers=3, d_model=48, n_heads=4, n_kv_heads=4,
    head_dim=12, d_ff=96, vocab=128, act="silu", dtype="float32",
)

ARCH = ArchDef(
    "phi3-mini-3.8b", "lm", CONFIG, SMOKE_CONFIG,
    source="arXiv:2404.14219; unverified",
    skip_shapes={"long_500k": "pure full attention (no sub-quadratic path); "
                              "skip per assignment rule, see DESIGN.md §4"},
)
