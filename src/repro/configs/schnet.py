"""schnet [arXiv:1706.08566]: 3 interactions, hidden 64, 300 gaussian RBFs,
cutoff 10. Molecular graphs carry positions+species; the large citation/
product graphs use synthetic positions (documented in DESIGN.md §4)."""
from repro.configs.base import ArchDef
from repro.models.gnn.schnet import SchNetConfig

CONFIG = SchNetConfig(n_interactions=3, d_hidden=64, n_rbf=300, cutoff=10.0)

SMOKE_CONFIG = SchNetConfig(n_interactions=2, d_hidden=16, n_rbf=16,
                            cutoff=10.0)

ARCH = ArchDef("schnet", "gnn", CONFIG, SMOKE_CONFIG,
               source="arXiv:1706.08566; paper",
               gnn_inputs=("pos", "species"))
