"""llama4-scout-17b-a16e [hf:meta-llama/Llama-4-Scout-17B-16E]: 48L d5120
40H (GQA kv=8) v202048, MoE 16 experts top-1, expert ff 8192. Chunked local
attention (8192) with every 4th layer global (iRoPE-style) → runs
long_500k. Multimodal early fusion: the vision frontend is a stub per the
assignment ([vlm] rule); this is the text backbone."""
from repro.configs.base import ArchDef
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="llama4-scout-17b-a16e", n_layers=48, d_model=5120, n_heads=40,
    n_kv_heads=8, head_dim=128, d_ff=8192, vocab=202048, act="silu",
    rope_theta=500000.0, window_pattern=(8192, 8192, 8192, 0),
    moe=MoEConfig(n_experts=16, top_k=1, d_ff_expert=8192),
)

SMOKE_CONFIG = LMConfig(
    name="llama4-smoke", n_layers=4, d_model=40, n_heads=5, n_kv_heads=1,
    head_dim=8, d_ff=64, vocab=128, act="silu", dtype="float32",
    window_pattern=(8, 8, 8, 0),
    moe=MoEConfig(n_experts=4, top_k=1, d_ff_expert=64),
)

ARCH = ArchDef("llama4-scout-17b-a16e", "lm", CONFIG, SMOKE_CONFIG,
               source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified")
