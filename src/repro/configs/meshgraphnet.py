"""meshgraphnet [arXiv:2010.03409]: 15 layers, hidden 128, sum aggregation,
2-hidden-layer MLPs. SDP applicability: DIRECT — node partitioning + halo
exchange drive the distributed full-graph layout (DESIGN.md §3)."""
from repro.configs.base import ArchDef
from repro.models.gnn.meshgraphnet import MGNConfig

CONFIG = MGNConfig(n_layers=15, d_hidden=128, mlp_layers=2, aggregator="sum")

SMOKE_CONFIG = MGNConfig(n_layers=2, d_hidden=16, mlp_layers=2,
                         aggregator="sum", remat=False)

ARCH = ArchDef("meshgraphnet", "gnn", CONFIG, SMOKE_CONFIG,
               source="arXiv:2010.03409; unverified",
               gnn_inputs=("feat", "pos"))
