"""pna [arXiv:2004.05718]: 4 layers, hidden 75, aggregators
mean/max/min/std, scalers identity/amplification/attenuation."""
from repro.configs.base import ArchDef
from repro.models.gnn.pna import PNAConfig

CONFIG = PNAConfig(n_layers=4, d_hidden=75,
                   aggregators=("mean", "max", "min", "std"),
                   scalers=("identity", "amplification", "attenuation"))

SMOKE_CONFIG = PNAConfig(n_layers=2, d_hidden=16,
                         aggregators=("mean", "max", "min", "std"),
                         scalers=("identity", "amplification", "attenuation"))

ARCH = ArchDef("pna", "gnn", CONFIG, SMOKE_CONFIG,
               source="arXiv:2004.05718; paper",
               gnn_inputs=("feat",))
