"""deepseek-coder-33b [arXiv:2401.14196]: llama-arch 62L d7168 56H (GQA
kv=8) ff19200 v32256. Pure full attention → long_500k skipped (DESIGN §4)."""
from repro.configs.base import ArchDef
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="deepseek-coder-33b", n_layers=62, d_model=7168, n_heads=56,
    n_kv_heads=8, head_dim=128, d_ff=19200, vocab=32256, act="silu",
    rope_theta=100000.0,
)

SMOKE_CONFIG = LMConfig(
    name="deepseek-smoke", n_layers=3, d_model=56, n_heads=7, n_kv_heads=1,
    head_dim=8, d_ff=96, vocab=256, act="silu", dtype="float32",
)

ARCH = ArchDef(
    "deepseek-coder-33b", "lm", CONFIG, SMOKE_CONFIG,
    source="arXiv:2401.14196; hf",
    skip_shapes={"long_500k": "pure full attention (no sub-quadratic path); "
                              "skip per assignment rule, see DESIGN.md §4"},
)
