"""Assigned input-shape sets, one per architecture family.

Sizes are padded up front to multiples of 64 so every pjit-boundary
sharding divides the (pod×data×model) mesh axes evenly; models mask
padding. `requires_subquadratic` marks long_500k (skip rule: DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses


def round_to(x: int, m: int = 64) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class LMShape:
    name: str
    kind: str          # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int
    requires_subquadratic: bool = False


LM_SHAPES = {
    "train_4k": LMShape("train_4k", "train", 4096, 256),
    "prefill_32k": LMShape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": LMShape("decode_32k", "decode", 32768, 128),
    "long_500k": LMShape("long_500k", "decode", 524288, 1,
                         requires_subquadratic=True),
}


@dataclasses.dataclass(frozen=True)
class GNNShape:
    name: str
    kind: str          # 'full' | 'minibatch' | 'molecule'
    n_nodes: int       # graph-level (paper numbers)
    n_edges: int       # undirected count
    d_feat: int = 0
    batch_nodes: int = 0
    fanout: tuple = ()
    mol_batch: int = 0

    @property
    def n_pad(self) -> int:
        return round_to(self.n_nodes)

    @property
    def e_pad(self) -> int:
        """Directed (2×) padded edge count."""
        return round_to(2 * self.n_edges)


GNN_SHAPES = {
    "full_graph_sm": GNNShape("full_graph_sm", "full", 2708, 10556,
                              d_feat=1433),
    "minibatch_lg": GNNShape("minibatch_lg", "minibatch", 232965, 114615892,
                             d_feat=602, batch_nodes=1024, fanout=(15, 10)),
    "ogb_products": GNNShape("ogb_products", "full", 2449029, 61859140,
                             d_feat=100),
    "molecule": GNNShape("molecule", "molecule", 30, 64, mol_batch=128),
}


@dataclasses.dataclass(frozen=True)
class RecSysShape:
    name: str
    kind: str          # 'train' | 'serve' | 'bulk' | 'retrieval'
    batch: int
    n_candidates: int = 0


RECSYS_SHAPES = {
    "train_batch": RecSysShape("train_batch", "train", 65536),
    "serve_p99": RecSysShape("serve_p99", "serve", 512),
    "serve_bulk": RecSysShape("serve_bulk", "serve", 262144),
    "retrieval_cand": RecSysShape("retrieval_cand", "retrieval", 1,
                                  n_candidates=1_048_576),
}
