"""nequip [arXiv:2101.03164]: 5 layers, 32 channels, l_max=2, 8 bessel RBFs,
cutoff 5, E(3)-equivariant tensor products (repro.models.gnn.so3 — CG
coefficients derived from first principles, equivariance property-tested)."""
from repro.configs.base import ArchDef
from repro.models.gnn.nequip import NequIPConfig

CONFIG = NequIPConfig(n_layers=5, channels=32, l_max=2, n_rbf=8, cutoff=5.0)

SMOKE_CONFIG = NequIPConfig(n_layers=2, channels=8, l_max=2, n_rbf=4,
                            cutoff=5.0)

ARCH = ArchDef("nequip", "gnn", CONFIG, SMOKE_CONFIG,
               source="arXiv:2101.03164; paper",
               gnn_inputs=("pos", "species"))
