"""Architecture registry: all 10 assigned archs, selectable by --arch id."""
from __future__ import annotations

from repro.configs.base import ArchDef
from repro.configs.shapes import (GNN_SHAPES, LM_SHAPES, RECSYS_SHAPES,
                                  GNNShape, LMShape, RecSysShape)

from repro.configs import (  # noqa: E402
    deepseek_coder_33b,
    gemma2_9b,
    llama4_scout_17b_a16e,
    meshgraphnet,
    moonshot_v1_16b_a3b,
    nequip,
    phi3_mini_3p8b,
    pna,
    schnet,
    two_tower_retrieval,
)

ARCHS: dict[str, ArchDef] = {
    m.ARCH.arch_id: m.ARCH
    for m in (
        gemma2_9b, deepseek_coder_33b, phi3_mini_3p8b,
        moonshot_v1_16b_a3b, llama4_scout_17b_a16e,
        meshgraphnet, schnet, nequip, pna,
        two_tower_retrieval,
    )
}


def get_arch(arch_id: str) -> ArchDef:
    if arch_id not in ARCHS:
        raise KeyError(
            f"unknown arch '{arch_id}'; available: {sorted(ARCHS)}")
    return ARCHS[arch_id]


def all_cells() -> list[tuple[str, str]]:
    """Every (arch, shape) cell — 40 total, including skip-marked ones."""
    return [(a, s) for a, arch in ARCHS.items() for s in arch.shapes]


__all__ = ["ARCHS", "get_arch", "all_cells", "ArchDef",
           "LM_SHAPES", "GNN_SHAPES", "RECSYS_SHAPES",
           "LMShape", "GNNShape", "RecSysShape"]
