"""gemma2-9b [arXiv:2408.00118]: 42L d3584 16H (GQA kv=8) ff14336 v256000,
alternating local(4096)/global attention, attn softcap 50, final softcap 30,
GeGLU, tied embeddings. Runs long_500k (half the layers are windowed)."""
from repro.configs.base import ArchDef
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="gemma2-9b", n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8,
    head_dim=256, d_ff=14336, vocab=256000, act="gelu",
    attn_softcap=50.0, final_softcap=30.0, window_pattern=(4096, 0),
    tie_embeddings=True, rope_theta=10000.0,
)

SMOKE_CONFIG = LMConfig(
    name="gemma2-smoke", n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
    head_dim=16, d_ff=128, vocab=512, act="gelu",
    attn_softcap=50.0, final_softcap=30.0, window_pattern=(8, 0),
    tie_embeddings=True, dtype="float32",
)

ARCH = ArchDef("gemma2-9b", "lm", CONFIG, SMOKE_CONFIG,
               source="arXiv:2408.00118; hf")
