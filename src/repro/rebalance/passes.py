"""The two rebalance passes over ``PartitionState``.

Both passes are pure functions of ``(state, cursor)`` — no host
round-trips, no mutation of ``state.key`` (the event RNG stream is
untouched, so a rebalanced session stays bit-identical to an
unrebalanced one on every *event* decision). Both maintain the PR 3
cut-matrix invariant exactly:

* **greedy migration** (xDGP-style): score every present vertex by its
  move gain — the affinity delta from the per-vertex label histogram —
  under an Eq. 10 capacity guard, take the top-m worst offenders, and
  commit them one by one through ``transition.migrate_core``. Scores
  are *recomputed at commit time* (earlier commits in the same pass
  change the histograms), so every committed move has fresh gain > 0:
  the cut is monotone non-increasing and the counters stay exact.

* **LPA refinement** (Spinner-style): a fixed-iteration synchronous
  label-propagation sweep. Each vertex scores labels by neighbour
  fraction minus a load penalty, movers are admitted probabilistically
  by remaining capacity (Spinner's acceptance rule), and the counters
  are rebuilt from scratch on device via one one-hot matmul — exact by
  construction, and the rebuild is itself the recount gate.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import transition as tx
from repro.core.state import PartitionState

# fold_in salt for the LPA acceptance draws: event keys are derived as
# fold_in(base, t0 + i) with non-negative cursors, so one fixed salt up
# front keeps the rebalance stream disjoint from every event stream
_SALT = 0x5EBA1A7C


def _histograms(state: PartitionState):
    """Per-vertex label histogram ``(n, k)`` and live degree ``(n,)``.

    Counts only edges whose both endpoints are present (rows of absent
    vertices are zeroed) — the same edge-counting rule as
    ``metrics.recompute_counters``."""
    k = state.edge_load.shape[0]
    valid = state.adj >= 0
    safe = jnp.where(valid, state.adj, 0)
    nbp = valid & state.present[safe] & state.present[:, None]
    nba = jnp.where(nbp, state.assignment[safe], -1)
    hist = jnp.sum(nba[..., None] == jnp.arange(k, dtype=jnp.int32)[None, None],
                   axis=1, dtype=jnp.int32)
    deg = jnp.sum(nbp, axis=1, dtype=jnp.int32)
    return hist, deg


def _dest_cap(state: PartitionState, slack, max_cap):
    """Eq. 10 capacity guard: a destination may not exceed the mean
    active edge load by more than ``slack`` (and never ``max_cap``)."""
    act = state.active
    load = state.edge_load.astype(jnp.float32)
    cnt = jnp.maximum(jnp.sum(act.astype(jnp.int32)), 1).astype(jnp.float32)
    mean = jnp.sum(jnp.where(act, load, 0.0)) / cnt
    return jnp.minimum(jnp.maximum(mean * (1.0 + slack), 1.0), max_cap)


def _rebuild_counters(state: PartitionState) -> PartitionState:
    """From-scratch device recount of every derived counter after a bulk
    relabel (presence/adjacency unchanged, so ``total_edges`` is too).
    ``cut_matrix = Eᵀ·hist`` with E the present-masked one-hot of the
    assignment — one (k, n)×(n, k) int32 matmul."""
    k = state.edge_load.shape[0]
    hist, deg = _histograms(state)
    onehot = ((state.assignment[:, None] == jnp.arange(k, dtype=jnp.int32))
              & state.present[:, None]).astype(jnp.int32)
    cut_matrix = jnp.matmul(onehot.T, hist,
                            preferred_element_type=jnp.int32)
    total = jnp.sum(cut_matrix)
    internal = jnp.trace(cut_matrix)
    return state._replace(
        vertex_count=jnp.sum(onehot, axis=0, dtype=jnp.int32),
        edge_load=jnp.sum(onehot * deg[:, None], axis=0, dtype=jnp.int32),
        cut_edges=(total - internal) // 2,
        cut_matrix=cut_matrix,
    )


def migration_pass(state: PartitionState, *, m: int, slack, max_cap,
                   enabled=True):
    """Greedy top-m migration. Selection ranks stale gains (one batched
    histogram pass); each commit recomputes scores, target, and the
    capacity guard against the *current* state and skips unless the
    fresh gain is strictly positive. Returns ``(state, moved)``."""
    k = state.edge_load.shape[0]
    hist, deg = _histograms(state)
    cur = jnp.clip(state.assignment, 0, k - 1)
    cur_aff = jnp.take_along_axis(hist, cur[:, None], axis=1)[:, 0]
    cap = _dest_cap(state, slack, max_cap)
    fits = (state.active[None, :]
            & (state.edge_load.astype(jnp.float32)[None, :]
               + deg[:, None].astype(jnp.float32) <= cap))
    h = jnp.where(fits & (jnp.arange(k)[None, :] != cur[:, None]),
                  hist, -tx._BIG)
    gain = jnp.where(state.present & (state.assignment >= 0),
                     jnp.max(h, axis=1) - cur_aff, -tx._BIG)
    _, picks = jax.lax.top_k(gain, m)

    def commit(s, v):
        scores, dv, _, _ = tx.neighbor_stats(s, s.adj[v])
        curv = jnp.clip(s.assignment[v], 0, k - 1)
        ok = (s.active
              & (s.edge_load.astype(jnp.float32)
                 + dv.astype(jnp.float32) <= _dest_cap(s, slack, max_cap))
              & (jnp.arange(k) != curv))
        hq = jnp.where(ok, scores, -tx._BIG)
        q = jnp.argmax(hq).astype(jnp.int32)
        do = enabled & (jnp.max(hq) > scores[curv])
        s, did = tx.migrate_core(s, v, q, gate=do)
        return s, did.astype(jnp.int32)

    state, moved = jax.lax.scan(commit, state, picks.astype(jnp.int32))
    return state, jnp.sum(moved)


def lpa_pass(state: PartitionState, t0, *, passes: int, slack, max_cap,
             balance_weight=0.1, enabled=True):
    """Spinner-style synchronous LPA: ``passes`` fixed iterations of
    score → candidate → probabilistic capacity acceptance → full device
    recount. Acceptance draws come from ``fold_in(fold_in(key, salt),
    t0 + i)`` — ``state.key`` itself is never advanced.

    ``balance_weight`` is Spinner's small additive load-penalty
    coefficient: the affinity term ``hist/deg`` lives in [0, 1], so a
    weight near 1 lets the penalty dominate and trades the cut away
    wholesale for balance; 0.1 nudges ties toward lighter labels while
    the capacity acceptance rule does the hard balance enforcement."""
    k = state.edge_load.shape[0]
    n = state.assignment.shape[0]
    base = jax.random.fold_in(state.key, _SALT)

    def sweep(i, s):
        hist, deg = _histograms(s)
        degf = jnp.maximum(deg.astype(jnp.float32), 1.0)
        load = s.edge_load.astype(jnp.float32)
        cap = _dest_cap(s, slack, max_cap)
        score = (hist.astype(jnp.float32) / degf[:, None]
                 - balance_weight * (load / cap)[None, :])
        score = jnp.where(s.active[None, :], score, -jnp.inf)
        cur = jnp.clip(s.assignment, 0, k - 1)
        cand = jnp.argmax(score, axis=1).astype(jnp.int32)
        best = jnp.max(score, axis=1)
        cur_sc = jnp.take_along_axis(score, cur[:, None], axis=1)[:, 0]
        want = (s.present & (s.assignment >= 0) & (cand != cur)
                & (best > cur_sc + 1e-6))
        # Spinner's acceptance: movers into label q are admitted with
        # probability remaining(q) / demand(q) so no label overshoots
        # its capacity in expectation
        wdeg = jnp.where(want, degf, 0.0)
        demand = jnp.zeros(k, jnp.float32).at[cand].add(wdeg)
        remaining = jnp.maximum(cap - load, 0.0)
        p_acc = jnp.clip(remaining / jnp.maximum(demand, 1.0), 0.0, 1.0)
        u = jax.random.uniform(jax.random.fold_in(base, t0 + i), (n,))
        move = want & (u < p_acc[cand]) & enabled
        return _rebuild_counters(
            s._replace(assignment=jnp.where(move, cand, s.assignment)))

    return jax.lax.fori_loop(0, passes, sweep, state)


class RebalanceStats(NamedTuple):
    moved: jax.Array       # () int32 — committed greedy migrations
    cut_before: jax.Array  # () int32
    cut_after: jax.Array   # () int32


def rebalance_state(state: PartitionState, t0, slack, max_cap,
                    enabled=True, *, m: int, passes: int):
    """One full rebalance: greedy migration (if ``m > 0``) then LPA
    refinement (if ``passes > 0``). ``t0`` is the session cursor —
    rebalances at different stream positions draw distinct acceptance
    randomness, and a recovered session replaying the same cursor draws
    the same. ``enabled`` is a traced gate so vmapped sweep lanes can
    switch the whole pass off per lane bit-identically."""
    cut0 = state.cut_edges
    moved = jnp.int32(0)
    if m > 0:
        state, moved = migration_pass(state, m=m, slack=slack,
                                      max_cap=max_cap, enabled=enabled)
    if passes > 0:
        state = lpa_pass(state, t0, passes=passes, slack=slack,
                         max_cap=max_cap, enabled=enabled)
    return state, RebalanceStats(moved, cut0, state.cut_edges)


rebalance_jit = jax.jit(rebalance_state, static_argnames=("m", "passes"),
                        donate_argnums=(0,))


@functools.lru_cache(maxsize=None)
def lane_rebalance(m: int, passes: int):
    """Vmapped rebalance over stacked sweep-lane states (lane axis on
    state, per-lane max_cap and enabled mask; shared cursor and slack).
    Cached so repeated ``Sweep.run()`` calls reuse the compiled fn."""
    fn = functools.partial(rebalance_state, m=m, passes=passes)
    return jax.jit(jax.vmap(fn, in_axes=(0, None, None, 0, 0)),
                   donate_argnums=(0,))
