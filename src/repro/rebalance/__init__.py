"""Online rebalancing: between-windows vertex migration + LPA refinement.

SDP assigns each vertex once; on a drifting stream (hub arrivals,
community merges, flash crowds) the one-shot choices rot the cut and
the balance. This package repairs both *between* ingest windows, in
the spirit of xDGP's adaptive vertex migration and Spinner's iterative
label propagation (see PAPERS.md), as pure jitted passes over
``PartitionState`` that preserve every counter invariant exactly.
"""
from repro.rebalance.passes import (RebalanceStats, lane_rebalance,
                                    lpa_pass, migration_pass,
                                    rebalance_jit, rebalance_state)

__all__ = ["RebalanceStats", "lane_rebalance", "lpa_pass",
           "migration_pass", "rebalance_jit", "rebalance_state"]
