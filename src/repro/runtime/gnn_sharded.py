"""Partition-aware sharded GNN aggregation (shard_map + halo exchange).

Baseline distribution (pjit, edge-sharded segment-sum) all-reduces the full
(N, F) node tensor every layer. With an SDP HaloSpec, each layer instead
all-gathers only the published boundary rows — collective bytes scale with
the edge-cut the paper minimises. See EXPERIMENTS.md §Perf (GNN hillclimb).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.graph.halo import HaloSpec
from repro.models.gnn import common as C


def halo_aggregate(x_blk, publish_idx, halo_map, senders, receivers,
                   *, axis: str, block_size: int):
    """Per-device body: x_blk (Nb, F) local block → aggregated (Nb, F).

    One all-gather of the published boundary rows replaces the full-tensor
    all-reduce of the naive layout.
    """
    pub = jnp.take(x_blk, jnp.maximum(publish_idx, 0), axis=0)
    pub = jnp.where((publish_idx >= 0)[:, None], pub, 0.0)      # (B_max, F)
    allpub = jax.lax.all_gather(pub, axis)                      # (P, B_max, F)
    hs, hp = halo_map[:, 0], halo_map[:, 1]
    halo = allpub[jnp.maximum(hs, 0), jnp.maximum(hp, 0)]       # (H_max, F)
    halo = jnp.where((hs >= 0)[:, None], halo, 0.0)
    buf = jnp.concatenate([x_blk, halo], axis=0)                # (Nb+H, F)
    msg = jnp.take(buf, jnp.maximum(senders, 0), axis=0)
    msg = jnp.where((senders >= 0)[:, None], msg, 0.0)
    return C.segment_sum_pad(msg, receivers, block_size)


def make_sharded_aggregate(mesh, spec: HaloSpec, axis: str = "data"):
    """Returns agg(x_blocks (P, Nb, F)) -> (P, Nb, F) running under
    shard_map with the halo exchange on `axis`."""

    def agg(x_blocks, publish_idx, halo_map, senders, receivers):
        body = functools.partial(halo_aggregate, axis=axis,
                                 block_size=spec.block_size)

        def shard_body(x, pi, hm, sn, rc):
            return body(x[0], pi[0], hm[0], sn[0], rc[0])[None]

        return shard_map(
            shard_body, mesh=mesh,
            in_specs=(P(axis), P(axis), P(axis), P(axis), P(axis)),
            out_specs=P(axis),
        )(x_blocks, publish_idx, halo_map, senders, receivers)

    return agg


def naive_aggregate(x, senders, receivers):
    """Baseline: global-id segment-sum; under pjit the node tensor is
    replicated/all-reduced every layer (the thing SDP avoids)."""
    n = x.shape[0]
    msg = jnp.take(x, jnp.maximum(senders, 0), axis=0)
    msg = jnp.where((senders >= 0)[:, None], msg, 0.0)
    return C.segment_sum_pad(msg, receivers, n)
