"""Sharding rules: param/input PartitionSpecs per workload family.

Scheme (DESIGN.md §6): FSDP over the data axis (params+optimizer state
sharded on a non-contracting dim), Megatron TP over the model axis
(attention combined head dim, FFN inner dim, vocab), EP for MoE experts,
sequence sharding for long-context KV caches. The pod axis composes with
data for cross-pod DP.

All pjit-boundary shardings are even: attention projections are stored 2D
(d, H·hd) precisely so the TP dim divides 16 for every assigned arch.
"""
from __future__ import annotations

import re

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _match(rules, path: str):
    for pat, spec in rules:
        if re.search(pat, path):
            return spec
    return P()


def tree_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    return paths, [v for _, v in flat], treedef


def specs_from_rules(tree, rules) -> object:
    """Pytree of PartitionSpec, matched by /-joined param path."""
    paths, vals, treedef = tree_paths(tree)
    return jax.tree_util.tree_unflatten(
        treedef, [_match(rules, p) for p in paths])


def shardings_from_rules(tree, rules, mesh: Mesh):
    specs = specs_from_rules(tree, rules)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# --------------------------------------------------------------------------
# LM rules. Layer-stacked params carry a leading (L,) axis → specs start
# with None. fsdp = the data axis (or ("pod","data") multi-pod).
# --------------------------------------------------------------------------

def lm_param_rules(mesh: Mesh, *, fsdp: bool = True) -> list:
    da = batch_axes(mesh)
    d = da if fsdp else None
    return [
        (r"embed$",            P("model", d)),       # (V, d)
        (r"lm_head$",          P(d, "model")),       # (d, V)
        (r"attn/wq$",          P(None, d, "model")),  # (L, d, H·hd)
        (r"attn/wk$",          P(None, d, "model")),
        (r"attn/wv$",          P(None, d, "model")),
        (r"attn/wo$",          P(None, "model", d)),  # (L, H·hd, d)
        (r"mlp/w[ig]$",        P(None, d, "model")),  # (L, d, ff)
        (r"mlp/wo$",           P(None, "model", d)),  # (L, ff, d)
        (r"moe/router$",       P(None, d, None)),     # (L, d, E)
        (r"moe/w[ig]$",        P(None, "model", d, None)),  # (L, E, d, f) EP
        (r"moe/wo$",           P(None, "model", d, None)),  # (L, E, f, d) EP
        (r"ln", P()),
    ]


def lm_param_rules_zero(mesh: Mesh) -> list:
    """ZeRO-3 rules for the §Perf 'opt' scheme: dense layer weights are
    sharded on ONE dim over the WHOLE mesh, so the forward all-gathers each
    layer's weights once (cheap: weights ≪ activations at these batch
    sizes) and the backward reduce-scatters the gradients — no
    activation-sized all-reduces remain. Embedding/head keep the vocab-TP
    layout (the chunked xent contracts d over data with a small psum).
    MoE experts keep EP on model."""
    da = batch_axes(mesh)
    allax = da + ("model",)
    return [
        (r"embed$",            P("model", da)),
        (r"lm_head$",          P(da, "model")),
        (r"attn/w[qkvo]$",     P(None, allax, None)),
        (r"mlp/w[ig]$",        P(None, allax, None)),   # (L, d, ff)
        (r"mlp/wo$",           P(None, None, allax)),   # (L, ff, d): ff may
        # not divide 512 (deepseek 19200), d always does
        (r"moe/router$",       P(None, da, None)),
        # experts expert-parallel on model + ff sharded on data: the first
        # expert GEMM contracts unsharded d (no psum); the second contracts
        # ff/data, which reduce-scatters onto the data-sharded group dim —
        # and opt-state/grad-accum memory for the 96B expert params stays
        # 256-way sharded (§Perf 4.2 iterations 2-3)
        (r"moe/w[ig]$",        P(None, "model", None, da)),  # (L,E,d,f)
        (r"moe/wo$",           P(None, "model", da, None)),  # (L,E,f,d)
        (r"ln", P()),
    ]


def lm_input_specs(mesh: Mesh, *, batch: int) -> dict:
    da = batch_axes(mesh)
    n_dp = 1
    for a in da:
        n_dp *= mesh.shape[a]
    bspec = P(da) if batch % n_dp == 0 else (
        P("data") if batch % mesh.shape["data"] == 0 else P())
    return {"tokens": bspec, "labels": bspec}


def lm_cache_spec(mesh: Mesh, *, batch: int, seq: int) -> P:
    """KV cache (L, B, S, Hkv·hd packed as (Hkv, hd))… stored (L,B,S,H,hd):
    batch on data when divisible, else sequence over (data, model)."""
    da = batch_axes(mesh)
    n_dp = 1
    for a in da:
        n_dp *= mesh.shape[a]
    if batch % n_dp == 0:
        return P(None, da, "model", None, None)   # seq also on model
    # long-context, tiny batch: shard sequence over everything
    axes = da + ("model",)
    return P(None, None, axes, None, None)


# --------------------------------------------------------------------------
# GNN rules: node/edge arrays sharded on data(+pod); weights replicated
# (they are tiny); the SDP halo path uses shard_map (gnn_sharded.py).
# --------------------------------------------------------------------------

def gnn_param_rules(mesh: Mesh) -> list:
    return [(r".*", P())]


def gnn_input_specs(mesh: Mesh) -> dict:
    da = batch_axes(mesh)
    return {
        "senders": P(da), "receivers": P(da),
        "node_feat": P(da, None), "node_mask": P(da),
        "targets": P(da, None), "positions": P(da, None),
        "species": P(da), "graph_id": P(da),
    }


# --------------------------------------------------------------------------
# RecSys rules: embedding tables row-sharded over the whole mesh; towers
# replicated (small); batch on data(+pod).
# --------------------------------------------------------------------------

def recsys_param_rules(mesh: Mesh) -> list:
    da = batch_axes(mesh)
    rows = da + ("model",)
    return [
        (r"(user|item)_table$", P(rows, None)),
        (r"tower", P()),
    ]


def recsys_input_specs(mesh: Mesh, *, batch: int) -> dict:
    da = batch_axes(mesh)
    n_dp = 1
    for a in da:
        n_dp *= mesh.shape[a]
    bspec = P(da) if batch % n_dp == 0 else P()
    return {
        "user_ids": P(*bspec, None, None) if bspec != P() else P(),
        "item_ids": P(*bspec, None, None) if bspec != P() else P(),
        "log_q": bspec,
        "cand_item_emb": P(("data", "model"), None),
    }
