"""Fault-tolerant training loop wrapper.

Policies implemented (designed for 1000+ nodes, exercised here in-process):
  * periodic async checkpoints (never blocks the step);
  * crash recovery: any exception inside a step → restore latest
    checkpoint, skip the poisoned batch, continue;
  * straggler mitigation: steps slower than `straggler_factor` × rolling
    median are journaled; after `straggler_patience` consecutive slow
    steps the `on_straggler` hook fires (in production: re-shard away from
    the slow host — the SDP scale-in migration at the resource level);
  * a bounded retry budget so a persistently failing step aborts loudly
    instead of spinning.

This loop is training-shaped (state in, batches through a ``step_fn``).
For the *partitioning session* shape — an open-ended event stream into a
``repro.api.Partitioner`` — the same policies live in
``repro.runtime.recovery`` (journal + snapshot + bit-identical replay).
"""
from __future__ import annotations

import time
from typing import Callable

from repro.checkpoint.manager import CheckpointManager


class FaultTolerantLoop:
    def __init__(self, ckpt: CheckpointManager, *, max_retries: int = 3,
                 straggler_patience: int = 3,
                 on_straggler: Callable[[int], None] | None = None):
        self.ckpt = ckpt
        self.max_retries = max_retries
        self.straggler_patience = straggler_patience
        self.on_straggler = on_straggler
        self.retries = 0
        self.slow_streak = 0
        self.events: list[dict] = []

    def run(self, state, batches, step_fn, *, start_step: int = 0,
            like=None):
        """state: (params, opt_state) pytree; step_fn(state, batch) →
        (state, metrics). Returns (state, final_step)."""
        step = start_step
        it = iter(batches)
        while True:
            try:
                batch = next(it)
            except StopIteration:
                break
            t0 = time.monotonic()
            try:
                state, metrics = step_fn(state, batch)
                self.retries = 0
            except Exception as err:  # noqa: BLE001 — node failure analogue
                self.retries += 1
                self.events.append({"step": step, "event": "failure",
                                    "err": repr(err)})
                if self.retries > self.max_retries:
                    raise
                # join any in-flight async save first: restoring while
                # the background writer is mid-checkpoint can read a
                # payload whose sidecar meta has not landed yet
                self.ckpt.wait()
                restored, rstep = self.ckpt.restore(like or state)
                if restored is not None:
                    state, step = restored, rstep
                self.ckpt.record_step(step, 0.0, status="restored")
                continue
            dt = time.monotonic() - t0
            self.ckpt.record_step(step, dt)
            if self.ckpt.is_straggler(dt):
                self.slow_streak += 1
                self.events.append({"step": step, "event": "straggler",
                                    "t": dt})
                if (self.slow_streak >= self.straggler_patience
                        and self.on_straggler is not None):
                    self.on_straggler(step)
                    self.slow_streak = 0
            else:
                self.slow_streak = 0
            step += 1
            self.ckpt.maybe_save(step, state)
        self.ckpt.wait()
        return state, step
