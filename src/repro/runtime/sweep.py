"""Device-sharded sweep runtime: many (policy × seed × config × stream)
lanes in ONE device program, lanes sharded across devices.

The figure benchmarks previously looped over policies/configs on the host,
re-dispatching the whole stream scan per run. Here every run becomes a
*lane*: `PartitionState` is stacked along a leading axis, each lane
carries its OWN (T,)-padded event stream (per-seed stream permutations
and per-lane churn mixes), and the lane axis is sharded across local
devices with ``shard_map`` over the 1-D "lanes" mesh
(repro.launch.mesh.make_lane_mesh) — vmap inside each shard, the lane
axis padded to a multiple of the device count, with a plain vmapped
host-fallback when only one device exists (or ``shard=False``).

Static-vs-traced knob parameterization
--------------------------------------
Both sweep kernels are the *traced-knob* instantiation of the unified
transition layer (repro.core.transition): the numeric knobs
(`transition.Knobs`) enter as stacked f32 scalars, the policy as a
traced int32 dispatched with ``lax.switch`` over the full policy table,
and per-lane autoscale as a traced bool gating the scale hooks. The
single-run engines bind the same functions with *static* knobs (Python
string/bool), so XLA specializes one program per config there and one
program for ALL lanes here. Because ``transition.make_knobs`` performs
every host-side arithmetic step before values enter the graph, the two
bindings execute bit-identical f32 ops.

The bit-identity contract: every lane — vmapped or sharded, per-event
(``engine="scan"``) or mixed-window (``engine="windowed"``), whole-stream
or chunked — produces exactly the same `PartitionState` (and, for the
scan engine, `EventTrace`) as ``repro.core.engine.run_stream`` on that
lane's stream with that lane's (policy, cfg, seed). Enforced by
tests/test_sweep.py and tests/test_sweep_sharded.py (the latter also
under ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` in CI).

Static requirements across lanes: identical ``k_max`` (array shapes)
and ``balance_guard`` (trace-time branch). ``k_init``, ``seed``,
``autoscale``, the stream, and all numeric knobs vary freely per lane —
including the stream *geometry*: per-lane streams of unequal ``n`` /
``max_deg`` are padded to the union geometry (componentwise max) before
stacking, and since absent-padded rows are inert in every transition
core (repro.core.geometry), each lane stays bit-identical to
``run_stream(stream, geometry=union)`` — which equals the lane's
own-geometry ``run_stream`` for every policy except LDG (whose capacity
knob reads the live ``n``).
"""
from __future__ import annotations

import functools
import warnings
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import transition as tx
from repro.core.config import EngineConfig
from repro.core.geometry import Geometry, check_row_width
from repro.core.state import PartitionState, init_state
from repro.core.windowed import sweep_window_mixed
from repro.graph.stream import EVENT_PAD, VertexStream, normalize_rows
from repro.kernels.fused_chooser.ops import sweep_window_mixed_fused
from repro.launch.mesh import make_lane_mesh, shard_map_compat


class SweepRun(NamedTuple):
    """One lane of a sweep: a policy/config/seed triple over its stream."""
    policy: str = "sdp"
    cfg: EngineConfig = EngineConfig()
    seed: int = 0


class SweepResult(NamedTuple):
    policy: str
    cfg: EngineConfig
    seed: int
    state: PartitionState
    trace: tx.EventTrace | None   # None for engine="windowed"


def _scan_lanes(
    states: PartitionState,   # stacked (L, ...) lanes
    kns: tx.Knobs,            # stacked (L,) f32 knobs
    policy_idx: jax.Array,    # (L,) int32 into POLICIES order
    autoscale: jax.Array,     # (L,) bool (cfg.autoscale per lane)
    etype: jax.Array,         # (L, T) per-lane — or (T,) shared — streams
    vertex: jax.Array,        # (L, T) / (T,)
    nbrs: jax.Array,          # (L, T, max_deg) / (T, max_deg)
    t0: jax.Array,            # () global index of first event
    *,
    balance_guard: str,
    autoscale_mode: str,      # "off" | "dynamic"
    shared_stream: bool = False,
    cut_fn=None,              # scale-in cut override (fig12 baseline only)
):
    """One chunk of every lane's stream through the per-event scan
    (transition.scan_events under the traced knob); resumable. Lanes use
    the fused masked step: under vmap a branch switch would compute every
    branch and select over the full state per event (see
    transition.make_masked_step). ``shared_stream`` takes one (T,)-shaped
    stream for every lane: the O(T·max_deg) neighbour tensor — the bulk
    of the stream — rides vmap in_axes=None unbatched, while the O(T)
    etype/vertex columns are broadcast lane-wise on device (an unbatched
    *vertex* index against lane-batched state lowers to a pathologically
    slow batched gather/scatter on CPU; unbatched neighbour *rows* are
    fine and they are where the memory is)."""
    check_row_width(states, nbrs)
    n = states.assignment.shape[1]
    sdp_idx = tx.POLICY_INDEX["sdp"]
    dynamic = autoscale_mode == "dynamic"

    def one_lane(state, kn, pidx, auto, et, vx, nb):
        do_scale = auto & (pidx == sdp_idx)
        step = tx.make_masked_step(
            kn, n, balance_guard=balance_guard, policy_idx=pidx,
            autoscale=do_scale if dynamic else False, cut_fn=cut_fn,
        )
        return tx.scan_events(step, state, et, vx, nb, t0)

    ax = None if shared_stream else 0
    if shared_stream:
        L = states.assignment.shape[0]
        etype = jnp.broadcast_to(etype, (L,) + etype.shape)
        vertex = jnp.broadcast_to(vertex, (L,) + vertex.shape)
    return jax.vmap(one_lane, in_axes=(0, 0, 0, 0, 0, 0, ax))(
        states, kns, policy_idx, autoscale, etype, vertex, nbrs)


_STATICS = ("balance_guard", "autoscale_mode", "shared_stream")

# public resumable kernel (no donation — callers may keep their states).
# ``cut_fn`` is static (a trace-time function: None = incremental
# cut_matrix scale-in; benchmarks/fig12 passes the from-scratch baseline)
sweep_events = jax.jit(_scan_lanes, static_argnames=_STATICS + ("cut_fn",))

# run_sweep's private kernels donate the stacked states: the chunk driver
# immediately rebinds them, and donation lets XLA reuse the
# (L, n, max_deg) adjacency buffers (incl. the stacked (L, K, K)
# cut_matrix) instead of copying per re-dispatch
_JITTED = {
    "scan": jax.jit(_scan_lanes, static_argnames=_STATICS + ("cut_fn",),
                    donate_argnums=(0,)),
    "windowed": jax.jit(sweep_window_mixed,
                        static_argnames=_STATICS + ("window",),
                        donate_argnums=(0,)),
    # the fused Pallas chooser lane-batched across lanes (vmap over
    # pallas_call); bit-identical to "windowed", selected by use_kernel
    "windowed_fused": jax.jit(
        sweep_window_mixed_fused,
        static_argnames=_STATICS + ("window", "interpret", "variant"),
        donate_argnums=(0,)),
}
_KERNELS = {
    "scan": _scan_lanes,
    "windowed": sweep_window_mixed,
    "windowed_fused": sweep_window_mixed_fused,
}


@functools.lru_cache(maxsize=None)
def _sharded_kernel(kind: str, n_devices: int, balance_guard: str,
                    autoscale_mode: str, shared_stream: bool, window: int):
    """jit(shard_map(vmapped kernel)) over the "lanes" mesh. Lanes are
    embarrassingly parallel: every lane-stacked operand shards on axis 0,
    the scalar t0 (and the stream, when shared) is replicated, and no
    collective is emitted."""
    mesh = make_lane_mesh(n_devices)
    lanes = P("lanes")
    stream_spec = P() if shared_stream else lanes
    kw = {"balance_guard": balance_guard, "autoscale_mode": autoscale_mode,
          "shared_stream": shared_stream}
    if kind in ("windowed", "windowed_fused"):
        kw["window"] = window
    base = functools.partial(_KERNELS[kind], **kw)
    return jax.jit(shard_map_compat(
        base, mesh,
        in_specs=(lanes,) * 4 + (stream_spec,) * 3 + (P(),),
        out_specs=lanes,
        # pallas_call has no replication rule; lanes emit no collectives,
        # so the checker is vacuous for every kind
        check_rep=kind != "windowed_fused"),
        donate_argnums=(0,))


def _stack(trees):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def _unstack(tree, i):
    return jax.tree_util.tree_map(lambda x: x[i], tree)


def _pad_lanes(tree, pad: int):
    """Pad the leading lane axis by replicating lane 0 (sliced off after)."""
    if pad == 0:
        return tree
    return jax.tree_util.tree_map(
        lambda x: jnp.concatenate(
            [x, jnp.broadcast_to(x[:1], (pad,) + x.shape[1:])]), tree)


def _stack_streams(streams: Sequence[VertexStream], length: int):
    """Per-lane streams → dense (L, T[, D]) event tensors, EVENT_PAD-padded
    on the right so shorter lanes no-op through the shared scan. Lanes of
    heterogeneous geometry (unequal ``n`` / ``max_deg``) are padded to
    the union geometry before stacking — absent-padded rows are inert,
    so each lane stays bit-identical to ``run_stream`` at the union
    geometry (see repro.core.geometry; the per-lane union is returned as
    the (n, max_deg) the caller sizes the stacked states at)."""
    geom = functools.reduce(
        Geometry.union, (Geometry(s.n, s.max_deg) for s in streams))
    L = len(streams)
    et = np.full((L, length), EVENT_PAD, np.int32)
    vx = np.full((L, length), -1, np.int32)
    nb = np.full((L, length, geom.max_deg), -1, np.int32)
    for i, s in enumerate(streams):
        t = s.num_events
        et[i, :t] = s.etype
        vx[i, :t] = s.vertex
        nb[i, :t] = normalize_rows(s.nbrs, geom.max_deg)
    return jnp.asarray(et), jnp.asarray(vx), jnp.asarray(nb), geom.n, \
        geom.max_deg


def _shared_stream_arrays(s: VertexStream, length: int):
    """One shared stream → dense (T[, D]) tensors broadcast to every lane
    at trace time (no L-fold materialization)."""
    et = np.full(length, EVENT_PAD, np.int32)
    vx = np.full(length, -1, np.int32)
    nb = np.full((length, s.max_deg), -1, np.int32)
    t = s.num_events
    et[:t] = s.etype
    vx[:t] = s.vertex
    nb[:t] = s.nbrs
    return jnp.asarray(et), jnp.asarray(vx), jnp.asarray(nb)


def _execute_sweep(
    stream: VertexStream | Sequence[VertexStream],
    runs: Sequence[SweepRun | tuple],
    *,
    chunk: int | None = None,
    engine: str = "scan",
    window: int = 256,
    shard: bool | None = None,
    use_kernel: bool = False,
    rebalance: dict | None = None,
    shard_vertices: bool = False,
) -> list[SweepResult]:
    """Executor behind ``repro.api.Sweep`` (and the deprecated
    ``run_sweep`` shim): every (policy, cfg, seed) lane in one device
    program, each lane's result bit-identical to ``run_stream`` with the
    same arguments on that lane's stream. Lane-compatibility validation
    (shared k_max/balance_guard, chunk×engine rules, stream pairing)
    happens in ``Sweep._validate`` — go through the builder.

    stream: one shared ``VertexStream`` (broadcast to every lane at trace
      time — never materialized L-fold), or a sequence of per-lane
      streams (one per run; may differ in length, order, churn mix, and
      geometry — they are right-padded with no-op events to a common T
      and padded to the union (n, max_deg) geometry, see
      ``_stack_streams``).
    chunk: re-dispatch the scan engine every ``chunk`` events (resumable,
      bounds step count per program); traces are concatenated along the
      event axis.
    engine: "scan" — faithful per-event scan, returns per-event traces;
      "windowed" — the mixed-event window kernel vmapped across lanes
      (PR 1's batched-window speedup), returns ``trace=None``.
    shard: shard the lane axis across local devices with shard_map
      (padding lanes to a multiple of the device count). ``None`` = auto:
      shard iff more than one device exists; ``False`` forces the
      single-device vmapped path; ``True`` forces shard_map even on one
      device (exercises the padding path).
    use_kernel: with ``engine="windowed"``, run the lanes through the
      fused Pallas chooser (repro.kernels.fused_chooser) instead of the
      XLA window kernel — bit-identical by contract, interpret mode off
      TPU. Ignored for ``engine="scan"`` (the scan is the semantic
      reference and stays XLA; ``Sweep._validate`` rejects the combo).
    rebalance: ``{"m", "every", "passes", "slack", "lanes"}`` from
      ``Sweep.rebalance()`` — after every full ``every`` processed
      events the stream is segmented and one vmapped
      ``repro.rebalance.rebalance_state`` runs over the stacked lanes
      (per-lane ``max_cap``, shared slack, ``lanes`` as a traced
      enabled mask — excluded lanes pass through bit-identically).
    """
    runs = [r if isinstance(r, SweepRun) else SweepRun(*r) for r in runs]
    if not runs:
        return []
    shared = not isinstance(stream, (list, tuple))
    streams = [stream] * len(runs) if shared else list(stream)
    if shard_vertices:
        # vertex-parallel lanes: each lane is one vertex-sharded session
        # over the WHOLE local mesh (repro.runtime.shard_session), so
        # lanes run sequentially — the device budget is spent on n, not
        # L. No union-geometry stacking: every lane runs (and is checked)
        # at its own stream's geometry, bit-identical to run_stream.
        from repro.runtime.shard_session import run_stream_sharded
        return [
            SweepResult(r.policy, r.cfg, r.seed,
                        run_stream_sharded(s, policy=r.policy, cfg=r.cfg,
                                           seed=r.seed, window=window),
                        None)
            for r, s in zip(runs, streams)
        ]
    cfg0 = runs[0].cfg
    autoscale_mode = (
        "dynamic"
        if any(r.cfg.autoscale and r.policy == "sdp" for r in runs)
        else "off"
    )

    kind = ("windowed_fused" if engine == "windowed" and use_kernel
            else engine)

    L = len(runs)
    lens = [s.num_events for s in streams]
    T_ev = max(lens)   # real events: the rebalance cadence counts these
    T = T_ev
    if engine == "windowed":
        T = ((T + window - 1) // window) * window
    if shared:
        et, vx, nb = _shared_stream_arrays(streams[0], T)
        n, max_deg = streams[0].n, streams[0].max_deg
    else:
        et, vx, nb, n, max_deg = _stack_streams(streams, T)
    states = _stack([
        init_state(n, max_deg, cfg0.k_max, r.cfg.k_init, r.seed) for r in runs
    ])
    kns = _stack([tx.knobs_arrays(r.cfg, n) for r in runs])
    pidx = jnp.asarray([tx.POLICY_INDEX[r.policy] for r in runs], jnp.int32)
    auto = jnp.asarray([r.cfg.autoscale for r in runs], bool)

    ndev = jax.device_count()
    use_shard = (ndev > 1) if shard is None else bool(shard)
    if use_shard:
        lane_pad = (-L) % ndev
        states, kns, pidx, auto = (
            _pad_lanes(x, lane_pad) for x in (states, kns, pidx, auto))
        if not shared:
            et, vx, nb = (_pad_lanes(x, lane_pad) for x in (et, vx, nb))
        call = _sharded_kernel(kind, ndev, cfg0.balance_guard,
                               autoscale_mode, shared, window)
    else:
        kw = {"balance_guard": cfg0.balance_guard,
              "autoscale_mode": autoscale_mode, "shared_stream": shared}
        if engine == "windowed":
            kw["window"] = window
        call = functools.partial(_JITTED[kind], **kw)

    def ev_slice(a, sl):
        return a[sl] if shared else a[:, sl]

    reb_apply = None
    if rebalance is not None:
        from repro.rebalance import lane_rebalance
        reb_every = int(rebalance["every"])
        Lp = int(states.assignment.shape[0])  # incl. shard padding
        en = np.zeros(Lp, bool)
        if rebalance["lanes"] is None:
            en[:L] = True   # pad lanes stay gated off (sliced away after)
        else:
            en[np.asarray(rebalance["lanes"], int)] = True
        enabled = jnp.asarray(en)
        caps = np.asarray([float(r.cfg.max_cap) for r in runs], np.float32)
        caps = np.concatenate(
            [caps, np.full(Lp - L, caps[0] if L else 1.0, np.float32)])
        maxcap, slack = jnp.asarray(caps), jnp.float32(rebalance["slack"])
        reb_call = lane_rebalance(min(int(rebalance["m"]), n),
                                  int(rebalance["passes"]))

        def reb_apply(states, t):
            states, _ = reb_call(states, jnp.int32(t), slack, maxcap,
                                 enabled)
            return states

    if engine == "windowed":
        if reb_apply is None:
            # the window loop runs on device (lax.scan over windows
            # inside the kernel) — one dispatch for the whole stream,
            # like "scan"
            states = call(states, kns, pidx, auto, et, vx, nb,
                          jnp.int32(0))
        else:
            # segment the stream at the rebalance cadence (a window
            # multiple, validated) and rebalance after each full segment
            t = 0
            while t < T:
                end = min(t + reb_every, T)
                sl = slice(t, end)
                states = call(states, kns, pidx, auto, ev_slice(et, sl),
                              ev_slice(vx, sl), ev_slice(nb, sl),
                              jnp.int32(t))
                # a segment padded past the real stream end is not a full
                # cadence interval (T is window-rounded; the scan engine
                # never sees the padding, and the engines must agree)
                if end - t == reb_every and end <= T_ev:
                    states = reb_apply(states, end)
                t = end
        trace = None
    elif chunk is None and rebalance is None:
        states, trace = call(states, kns, pidx, auto, et, vx, nb,
                             jnp.int32(0))
    else:
        step = chunk if chunk is not None else T
        traces = []
        t = 0
        while t < T:
            end = min(t + step, T)
            if rebalance is not None:
                # dispatch boundaries never cross a cadence boundary, so
                # the pass lands exactly between the right two events
                end = min(end, (t // reb_every + 1) * reb_every)
            sl = slice(t, end)
            states, tr = call(states, kns, pidx, auto, ev_slice(et, sl),
                              ev_slice(vx, sl), ev_slice(nb, sl),
                              jnp.int32(t))
            traces.append(tr)
            t = end
            if rebalance is not None and t % reb_every == 0:
                states = reb_apply(states, t)
        trace = tx.EventTrace(*(
            jnp.concatenate([getattr(tr, f) for tr in traces], axis=1)
            for f in tx.EventTrace._fields
        ))

    return [
        SweepResult(
            r.policy, r.cfg, r.seed, _unstack(states, i),
            None if trace is None else jax.tree_util.tree_map(
                lambda x: x[:lens[i]], _unstack(trace, i)),
        )
        for i, r in enumerate(runs)
    ]


def run_sweep(
    stream: VertexStream | Sequence[VertexStream],
    runs: Sequence[SweepRun | tuple],
    *,
    chunk: int | None = None,
    engine: str = "scan",
    window: int = 256,
    shard: bool | None = None,
) -> list[SweepResult]:
    """Deprecated batch entry — use the fluent builder::

        from repro.api import Sweep
        Sweep(stream).lanes(runs).windowed(256).sharded().run()

    This shim builds the equivalent ``Sweep`` (so the builder's lane
    validation applies — e.g. ``engine="windowed"`` with ``chunk`` now
    raises instead of silently ignoring the chunk) and runs it.
    """
    warnings.warn(
        "run_sweep is deprecated: use repro.api.Sweep — e.g. "
        "Sweep(stream).lanes(runs).windowed().sharded().run()",
        DeprecationWarning, stacklevel=2)
    from repro.api.sweep import Sweep
    sw = Sweep(stream).lanes(runs)
    if engine == "windowed":
        sw.windowed(window)
    elif engine != "scan":
        raise ValueError(
            f"unknown engine {engine!r} (expected 'scan' or 'windowed')")
    if chunk is not None:
        sw.chunked(chunk)
    if shard is not None:
        sw.sharded(shard)
    return sw.run()
