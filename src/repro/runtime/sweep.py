"""Vmapped sweep runtime: many (policy × seed × config) streams in ONE
jitted device program.

The figure benchmarks previously looped over policies/configs on the host,
re-dispatching the whole stream scan per run. Here every run becomes a
*lane* of a vmapped engine: `PartitionState` is stacked along a leading
axis, the numeric knobs (`repro.core.engine.Knobs`) become traced f32
scalars, and the policy becomes a traced index dispatched with
``lax.switch``. Because `make_knobs` performs all host-side arithmetic
before the values enter the graph, the dynamic lanes execute bit-identical
f32 ops to the static single-run engine — verified by tests/test_sweep.py.

Static requirements across lanes: identical ``k_max`` (array shapes) and
``balance_guard`` (trace-time branch). ``k_init``, ``seed``, ``autoscale``
and all numeric knobs vary freely per lane.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.core import engine as eng
from repro.core.config import EngineConfig
from repro.core.state import PartitionState, init_state
from repro.graph.stream import VertexStream


class SweepRun(NamedTuple):
    """One lane of a sweep: a policy/config/seed triple over the stream."""
    policy: str = "sdp"
    cfg: EngineConfig = EngineConfig()
    seed: int = 0


class SweepResult(NamedTuple):
    policy: str
    cfg: EngineConfig
    seed: int
    state: PartitionState
    trace: eng.EventTrace


@functools.partial(
    jax.jit, static_argnames=("balance_guard", "autoscale_mode"))
def sweep_events(
    states: PartitionState,   # stacked (L, ...) lanes
    kns: eng.Knobs,           # stacked (L,) f32 knobs
    policy_idx: jax.Array,    # (L,) int32 into POLICIES order
    autoscale: jax.Array,     # (L,) bool (cfg.autoscale per lane)
    etype: jax.Array,         # (T,) shared stream
    vertex: jax.Array,        # (T,)
    nbrs: jax.Array,          # (T, max_deg)
    t0: jax.Array,            # () global index of first event
    *,
    balance_guard: str,
    autoscale_mode: str,      # "off" | "dynamic"
):
    """Run one chunk of the shared stream across all lanes; resumable."""
    choose_table = eng.policy_fns(balance_guard)
    n = states.assignment.shape[1]
    sdp_idx = eng.POLICY_INDEX["sdp"]

    def one_lane(state, kn, pidx, auto):
        base_key = state.key
        do_scale = auto & (pidx == sdp_idx)

        def apply_add(s, v, row, key):
            if autoscale_mode == "dynamic":
                s = jax.lax.cond(
                    do_scale, lambda x: eng.scale_out(x, kn), lambda x: x, s)
            scores, deg, _, _ = eng.neighbor_stats(s, row)
            p = jax.lax.switch(
                pidx, list(choose_table), s, scores, deg, v, key, kn, n)
            return eng._commit_add(s, v, row, p, scores, deg)

        def apply_del_vertex(s, v, row, key):
            s = eng._del_vertex_core(s, v)
            if autoscale_mode == "dynamic":
                s = jax.lax.cond(
                    do_scale, lambda x: eng.scale_in(x, kn), lambda x: x, s)
            return s

        def apply_del_edge(s, v, row, key):
            return eng._del_edge_core(s, v, row)

        def apply_pad(s, v, row, key):
            return s

        def step(s, ev):
            et, v, row, i = ev
            key = jax.random.fold_in(base_key, i)
            sv = jnp.maximum(v, 0)
            s = jax.lax.switch(
                jnp.clip(et, 0, 3),
                [apply_add, apply_del_vertex, apply_del_edge, apply_pad],
                s, sv, row, key,
            )
            _, load_dev = eng.load_stats(s)
            tr = eng.EventTrace(s.total_edges, s.cut_edges, s.num_partitions,
                                load_dev)
            return s, tr

        idx = t0 + jnp.arange(etype.shape[0], dtype=jnp.int32)
        return jax.lax.scan(step, state, (etype, vertex, nbrs, idx))

    return jax.vmap(one_lane)(states, kns, policy_idx, autoscale)


def _stack(trees):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def _unstack(tree, i):
    return jax.tree_util.tree_map(lambda x: x[i], tree)


def run_sweep(
    stream: VertexStream,
    runs: Sequence[SweepRun | tuple],
    *,
    chunk: int | None = None,
) -> list[SweepResult]:
    """Run every (policy, cfg, seed) lane over ``stream`` in one device
    program; each lane's result is bit-identical to ``run_stream`` with the
    same arguments."""
    runs = [r if isinstance(r, SweepRun) else SweepRun(*r) for r in runs]
    if not runs:
        return []
    cfg0 = runs[0].cfg
    for r in runs:
        if r.policy not in eng.POLICY_INDEX:
            raise ValueError(f"unknown policy {r.policy!r}")
        if r.cfg.k_max != cfg0.k_max:
            raise ValueError("all sweep lanes must share k_max (array shapes)")
        if r.cfg.balance_guard != cfg0.balance_guard:
            raise ValueError("all sweep lanes must share balance_guard")
    autoscale_mode = (
        "dynamic"
        if any(r.cfg.autoscale and r.policy == "sdp" for r in runs)
        else "off"
    )

    n, max_deg = stream.n, stream.max_deg
    states = _stack([
        init_state(n, max_deg, cfg0.k_max, r.cfg.k_init, r.seed) for r in runs
    ])
    kns = _stack([eng.knobs_arrays(r.cfg, n) for r in runs])
    pidx = jnp.asarray([eng.POLICY_INDEX[r.policy] for r in runs], jnp.int32)
    auto = jnp.asarray([r.cfg.autoscale for r in runs], bool)

    et = jnp.asarray(stream.etype)
    vx = jnp.asarray(stream.vertex)
    nb = jnp.asarray(stream.nbrs)
    T = stream.num_events

    if chunk is None:
        states, trace = sweep_events(
            states, kns, pidx, auto, et, vx, nb, jnp.int32(0),
            balance_guard=cfg0.balance_guard, autoscale_mode=autoscale_mode,
        )
    else:
        traces = []
        t = 0
        while t < T:
            sl = slice(t, min(t + chunk, T))
            states, tr = sweep_events(
                states, kns, pidx, auto, et[sl], vx[sl], nb[sl], jnp.int32(t),
                balance_guard=cfg0.balance_guard,
                autoscale_mode=autoscale_mode,
            )
            traces.append(tr)
            t = sl.stop
        trace = eng.EventTrace(*(
            jnp.concatenate([getattr(tr, f) for tr in traces], axis=1)
            for f in eng.EventTrace._fields
        ))

    return [
        SweepResult(r.policy, r.cfg, r.seed,
                    _unstack(states, i), _unstack(trace, i))
        for i, r in enumerate(runs)
    ]
