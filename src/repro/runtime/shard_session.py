"""Vertex-sharded session runtime: one session's (n, max_deg) state split
into per-device row blocks on a "vertices" mesh axis.

Why this shape
--------------
Event slots inside a window are sequentially dependent through the
K-sized counters (every placement shifts the loads the next slot scores
against), so the slot loop itself cannot be split across devices without
changing results. What CAN be split is everything O(n). The fused
chooser (PR 7) already factored the mixed window into exactly that
split:

    prep (O(n + W·D), choice-independent) → slot loop (O(W·K), tiny)
    → apply (O(n))

so the sharded step runs prep and apply shard-locally on (n/P)-row
blocks and runs the *identical* slot loop — `fused_window_choose_ref`,
the oracle the Pallas kernel is tested against — replicated on every
device over psum-assembled window tables. Replication of the tiny loop
makes the per-window communication exactly two `lax.psum`s of O(W·D)
payloads (one all-reduce of per-window deltas instead of per event) and
makes bit-identity to the dense engines structural: every device
executes the same f32 ops in the same order on the same values.

Round structure per window (W slots, D = max_deg, P shards):

  round 1 — shard-local prep scan over W. Each device carries only its
    (adj block, present block); per slot it applies the faithful
    adjacency/presence writes localized to its block (drop-mode
    scatters, preserving the dense scan's self-loop write order) and
    emits owner-masked scalars: the deleted vertex's adjacency row,
    freshness/presence bits, DEL_EDGE existence halves. Values are
    encoded +2 (ids/labels live in {-1} ∪ [0, n)) so 0 is the psum
    identity and exactly one owner contributes.
  psum #1 — merges the emissions; every device now holds the same (W,)
    scalars the dense `_prepare_window` scan produces.
  round 2 — the (W, D) score-source row table is now replicated (ADD
    rows come from the event stream, DEL_VERTEX rows from psum #1), so
    each device contributes the committed labels of the entries it
    owns, plus the label0[v]/label0[u] columns.
  psum #2 — merges that one-hop halo gather. Touch tables need NO
    communication: which earlier slot last relabeled a vertex is a pure
    function of the (etype, vertex) event structure, so they are
    recomputed replicatedly with O(W²·D) vectorized compares (W is the
    window size — bounded and small; this is the same
    choice-independence trick the fused chooser's prep scan exploits).
  round 3 — `fused_window_choose_ref` over the assembled tables, with
    the *semantic* n (row padding must not perturb LDG's capacity knob).
  round 4 — shard-local apply: scatter-max of touch slots per block,
    then the journal rebuild `w_label[last_touch] / remap[label0]`.

The O(K²) cut matrix, K-vector loads, and scalar counters ride the
replicated carry. State between windows is GSPMD global arrays with the
shardings of `repro.core.sharded_state`; `run_stream_sharded` is the
whole-stream entry (the bit-identity gate against `run_stream`), and
`sharded_stream_fn` is the cached jitted step the session facade feeds
windows through.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import transition as tx
from repro.core.config import EngineConfig
from repro.core.geometry import Geometry, resolve_geometry
from repro.core.sharded_state import (
    shard_state, state_specs, unshard_state,
)
from repro.core.state import PartitionState, init_state
from repro.graph.stream import (
    EVENT_ADD, EVENT_DEL_EDGE, EVENT_DEL_VERTEX, EVENT_PAD,
    VertexStream, normalize_rows, pad_stream,
)
from repro.kernels.fused_chooser import fused_chooser as fk
from repro.kernels.fused_chooser.ref import fused_window_choose_ref
from repro.launch.mesh import make_vertices_mesh, shard_map_compat

AXIS = "vertices"


def _sharded_window(state: PartitionState, ets, vs, rows, t0,
                    *, n_sem: int, policy: str, cfg: EngineConfig):
    """One mixed window, executing INSIDE shard_map: row leaves of
    ``state`` are this device's (n_loc, ...) block, everything else is
    replicated. See the module docstring for the round structure."""
    n_loc = state.assignment.shape[0]
    w = vs.shape[0]
    k_max = state.edge_load.shape[0]
    i32 = jnp.int32
    lo = jax.lax.axis_index(AXIS).astype(i32) * n_loc

    ets = jnp.where(vs >= 0, ets, EVENT_PAD)
    is_add = ets == EVENT_ADD
    is_dv = ets == EVENT_DEL_VERTEX
    is_de = ets == EVENT_DEL_EDGE
    safe_vs = jnp.where(vs >= 0, vs, 0)
    rows_add = jnp.where(is_add[:, None], rows, -1)
    label0_loc = jnp.where(state.present, state.assignment, -1)

    def owned(g):
        return (g >= lo) & (g < lo + n_loc)

    def loc(g):                      # clamped local index (gathers)
        return jnp.clip(g - lo, 0, n_loc - 1)

    def tgt(g, cond):                # local scatter target, drop unowned
        return jnp.where(cond & owned(g), g - lo, n_loc)

    # ---- round 1: shard-local prep scan -----------------------------
    # Mirrors ops._prepare_window op-for-op on this block, including the
    # self-loop aliasing order of the two DEL_EDGE row writes. All reads
    # of v/u rows are garbage off-owner; every consumer is owner-masked.
    def step(carry, i):
        adj, present = carry
        v = safe_vs[i]
        row = rows[i]
        add_i, dv_i, de_i = is_add[i], is_dv[i], is_de[i]
        own_row = adj[loc(v)]
        u = row[0]
        safe_u = jnp.maximum(u, 0)
        o_v = owned(v)
        o_u = owned(safe_u)

        pv = present[loc(v)]
        fresh = add_i & ~pv
        was = dv_i & pv
        in_adj = jnp.any(own_row == u) & (u >= 0)

        em = (
            jnp.where(dv_i & o_v, own_row + 2, 0),              # dv row
            jnp.where(o_v, fresh.astype(i32), 0),
            jnp.where(o_v, was.astype(i32), 0),
            jnp.where(o_v, (de_i & pv & in_adj).astype(i32), 0),
            jnp.where(o_u, present[loc(safe_u)].astype(i32), 0),
        )

        present = present.at[tgt(v, add_i | dv_i)].set(add_i, mode="drop")

        row_v_de = jnp.where((own_row == u) & (u >= 0), -1, own_row)
        w1_val = jnp.where(add_i, row, jnp.where(de_i, row_v_de, own_row))
        adj = adj.at[tgt(v, fresh | de_i)].set(w1_val, mode="drop")
        row_u = adj[loc(safe_u)]     # after write 1 (self-loop aliasing)
        row_u_de = jnp.where((row_u == v) & (u >= 0), -1, row_u)
        adj = adj.at[tgt(safe_u, de_i)].set(row_u_de, mode="drop")
        return (adj, present), em

    (adj_loc, _), em = jax.lax.scan(
        step, (state.adj, state.present), jnp.arange(w, dtype=i32))
    rows_dv2, fresh_c, was_c, e1_c, e2_c = jax.lax.psum(em, AXIS)
    fresh = fresh_c != 0
    was = was_c != 0
    exists = is_de & (e1_c != 0) & (e2_c != 0)
    rows_dv = rows_dv2 - 2           # the deleted vertex's row, where is_dv

    # ---- round 2: replicated source rows, one halo gather -----------
    src_row = jnp.where(is_add[:, None], rows_add,
                        jnp.where(is_dv[:, None], rows_dv, -1))
    src_safe = jnp.maximum(src_row, 0)
    us = jnp.maximum(rows[:, 0], 0)
    contrib = (
        jnp.where(owned(src_safe), label0_loc[loc(src_safe)] + 2, 0),
        jnp.where(owned(safe_vs), label0_loc[loc(safe_vs)] + 2, 0),
        jnp.where(owned(us), label0_loc[loc(us)] + 2, 0),
    )
    sl2, l0v2, l0u2 = jax.lax.psum(contrib, AXIS)
    src_lbl = jnp.where(src_row >= 0, sl2 - 2, -1)

    # touch tables: replicated recompute. The dense scan reads
    # last_touch[x] at slot i before slot i's own update lands, so the
    # value is the last j < i with (ADD_j | DEL_VERTEX_j) and vs_j == x.
    iota = jnp.arange(w, dtype=i32)
    touches = is_add | is_dv
    before = iota[None, :] < iota[:, None]                  # (W, W)

    def last_touch_of(entries):      # (W, ...) ids -> (W, ...) slot idx
        m = (entries[..., None] == safe_vs) & touches
        m = m & before.reshape((w,) + (1,) * (entries.ndim - 1) + (w,))
        return jnp.max(jnp.where(m, iota, -1), axis=-1)

    touch = jnp.where(src_row >= 0, last_touch_of(src_safe), -1)
    lt_v = last_touch_of(safe_vs)
    lt_u = last_touch_of(us)

    ev = jnp.stack([
        ets, safe_vs, fresh.astype(i32), was.astype(i32),
        exists.astype(i32), l0v2 - 2, lt_v, l0u2 - 2, lt_u], axis=1)

    # ---- round 3: the replicated slot loop (the tested oracle) ------
    kn = tx.make_knobs(cfg, n_sem)
    knobs = jnp.stack([jnp.float32(x) for x in kn])
    flags = jnp.array([0, 1], i32)
    rand_tab = tx.rand_index_table(state.key, t0, w, k_max)
    scalars = jnp.stack([
        state.num_partitions, state.total_edges, state.cut_edges,
        state.denied_scaleout, state.scale_events])
    w_label, _psel, remap, active, loads, cut_matrix, scal = \
        fused_window_choose_ref(
            ev, src_lbl, touch, rand_tab,
            state.active, state.edge_load, state.vertex_count,
            state.cut_matrix, scalars, knobs, flags, n=n_sem,
            policy=policy, balance_guard=cfg.balance_guard,
            autoscaling=policy == "sdp" and cfg.autoscale, dynamic=False)

    # ---- round 4: shard-local apply ---------------------------------
    lt_loc = jnp.full((n_loc,), -1, i32)
    lt_loc = lt_loc.at[tgt(safe_vs, touches)].max(iota, mode="drop")
    lbl_touched = w_label[jnp.clip(lt_loc, 0, w - 1)]
    lbl_kept = jnp.where(label0_loc >= 0,
                         remap[jnp.maximum(label0_loc, 0)], -1)
    label_final = jnp.where(lt_loc >= 0, lbl_touched, lbl_kept)
    return state._replace(
        assignment=label_final, present=label_final >= 0, adj=adj_loc,
        active=active != 0, edge_load=loads[0], vertex_count=loads[1],
        num_partitions=scal[fk.SCAL_NP], total_edges=scal[fk.SCAL_TOTAL],
        cut_edges=scal[fk.SCAL_CUT], denied_scaleout=scal[fk.SCAL_DENIED],
        scale_events=scal[fk.SCAL_SCALE], cut_matrix=cut_matrix)


@functools.lru_cache(maxsize=None)
def sharded_stream_fn(mesh: jax.sharding.Mesh, *, n_sem: int, policy: str,
                      cfg: EngineConfig, window: int, n_events: int,
                      donate: bool = True):
    """The jitted sharded step: ``fn(state, ets, vs, rows, t0) -> state``
    processing ``n_events`` (a multiple of ``window``) through a
    lax.scan of `_sharded_window` under one `shard_map`. ``state`` is a
    GSPMD global `PartitionState` with `sharded_state.state_specs`
    shardings (donated when ``donate``); events are replicated. Cached
    per (mesh, geometry-tier, policy, config, window, length) — the
    sharded analogue of the dense session's per-tier re-jit."""
    if n_events % window != 0:
        raise ValueError(
            f"sharded_stream_fn(n_events={n_events}, window={window}): "
            "the event tensor must be padded to a multiple of the window "
            "(graph.stream.pad_stream, or the session's tail padding)")

    def body_stream(state, ets, vs, rows, t0):
        def body(s, wdx):
            i0 = wdx * window
            s = _sharded_window(
                s,
                jax.lax.dynamic_slice_in_dim(ets, i0, window),
                jax.lax.dynamic_slice_in_dim(vs, i0, window),
                jax.lax.dynamic_slice_in_dim(rows, i0, window),
                t0 + i0, n_sem=n_sem, policy=policy, cfg=cfg)
            return s, None
        state, _ = jax.lax.scan(
            body, state, jnp.arange(n_events // window, dtype=jnp.int32))
        return state

    specs = state_specs()
    fn = shard_map_compat(
        body_stream, mesh,
        in_specs=(specs, P(), P(), P(), P()),
        out_specs=specs, check_rep=False)
    return jax.jit(fn, donate_argnums=(0,) if donate else ())


def run_stream_sharded(
    stream: VertexStream,
    *,
    policy: str = "sdp",
    cfg: EngineConfig | None = None,
    seed: int = 0,
    window: int = 256,
    geometry: Geometry | None = None,
    mesh: jax.sharding.Mesh | None = None,
    devices=None,
) -> PartitionState:
    """Whole-stream entry: run ``stream`` vertex-sharded over ``mesh``
    (default: all local devices) and gather the final state back dense —
    bit-identical to ``run_stream(stream, ...)[0]`` at the same
    geometry, for any device count. This is the correctness gate and the
    lane body of `Sweep.sharded_vertices()`."""
    cfg = cfg if cfg is not None else EngineConfig()
    geom = resolve_geometry(stream, cfg, geometry)
    if mesh is None:
        mesh = make_vertices_mesh(devices=devices)
    state = shard_state(
        init_state(geom.n, geom.max_deg, geom.k_max, cfg.k_init, seed), mesh)
    s = pad_stream(stream, window)
    ets = jnp.asarray(s.etype)
    vs = jnp.asarray(s.vertex)
    rows = jnp.asarray(normalize_rows(s.nbrs, geom.max_deg))
    fn = sharded_stream_fn(mesh, n_sem=geom.n, policy=policy, cfg=cfg,
                           window=window, n_events=s.num_events)
    state = fn(state, ets, vs, rows, jnp.int32(0))
    return unshard_state(state, n=geom.n)
