"""SDP-partitioned full-graph GNN training step (halo exchange).

The baseline full-graph layout (steps.build_gnn) lets XLA shard the global
edge list; every layer then all-gathers/all-reduces full node tensors. This
module is the §Perf 'halo' scheme: the SDP assignment blocks nodes per
shard (repro.graph.halo), and each message-passing layer exchanges ONLY the
published boundary rows — per-layer collective bytes become
P × B_max × F, proportional to the edge-cut SDP minimises.

The MeshGraphNet processor is re-expressed in the blocked layout; weights
are replicated, blocks are sharded over the flattened mesh, and the whole
loss runs in one shard_map (differentiable; grad psums are inserted by the
shard_map transpose).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.models import layers as L
from repro.models.gnn import common as C
from repro.models.gnn.meshgraphnet import MGNConfig, _block


def mgn_halo_local_loss(params, batch, cfg: MGNConfig, *, axes,
                        block_size: int):
    """Per-shard MGN loss body (inside shard_map).

    batch arrays carry a leading (1,) shard-block dim:
      node_feat (1, Nb, F), targets (1, Nb, 1), node_mask (1, Nb),
      publish_idx (1, B_max), halo_map (1, H_max, 2),
      senders/receivers (1, E_max) — senders index [own ++ halo].
    """
    feat = batch["node_feat"][0]
    publish_idx = batch["publish_idx"][0]
    hs_shard = batch["halo_map"][0, :, 0]
    hp_slot = batch["halo_map"][0, :, 1]
    snd = batch["senders"][0]
    rcv = batch["receivers"][0]
    emask = (snd >= 0)[:, None]

    h = _block(params["enc_node"], feat)
    efeat = jnp.ones(snd.shape + (4,), h.dtype)
    e = _block(params["enc_edge"], efeat)
    # e starts as an unvarying constant (ones-encoded edge features) but
    # becomes device-varying after the first exchange — mark it varying up
    # front so the scan carry types match (shard_map VMA rules)
    pcast = getattr(jax.lax, "pcast", None)
    if pcast is not None:
        e = pcast(e, axes, to="varying")
    else:  # older spelling
        e = jax.lax.pvary(e, axes)

    def exchange(h):
        pub = jnp.take(h, jnp.maximum(publish_idx, 0), axis=0)
        pub = jnp.where((publish_idx >= 0)[:, None], pub, 0.0)
        allpub = jax.lax.all_gather(pub, axes)        # (P, B_max, F)
        allpub = allpub.reshape(-1, *pub.shape)        # flatten multi-axis
        halo = allpub[jnp.maximum(hs_shard, 0), jnp.maximum(hp_slot, 0)]
        return jnp.where((hs_shard >= 0)[:, None], halo, 0.0)

    def step(carry, lp):
        h, e = carry
        buf = jnp.concatenate([h, exchange(h)], axis=0)
        hs = jnp.take(buf, jnp.maximum(snd, 0), axis=0)
        hr = jnp.take(h, jnp.maximum(rcv, 0), axis=0)
        e_new = _block(lp["edge"], jnp.concatenate([e, hs, hr], -1))
        e = e + jnp.where(emask, e_new, 0.0)
        agg = C.segment_sum_pad(e, rcv, block_size)
        h_new = _block(lp["node"], jnp.concatenate([h, agg], -1))
        return (h + h_new, e), None

    step_fn = jax.checkpoint(step) if cfg.remat else step
    (h, e), _ = jax.lax.scan(step_fn, (h, e), params["proc"])
    pred = L.mlp_apply(params["dec"]["mlp"], h)

    mask = batch["node_mask"][0].astype(jnp.float32)[:, None]
    err = ((pred - batch["targets"][0]) ** 2) * mask
    num = jax.lax.psum(jnp.sum(err), axes)
    den = jax.lax.psum(jnp.sum(mask) * pred.shape[-1], axes)
    return num / jnp.maximum(den, 1.0)


def make_mgn_halo_loss(mesh: Mesh, cfg: MGNConfig, block_size: int):
    """Returns loss_fn(params, batch, cfg) running under shard_map."""
    axes = tuple(mesh.axis_names)
    shard = P(axes)

    def loss_fn(params, batch, _cfg=None):
        body = functools.partial(mgn_halo_local_loss, cfg=cfg, axes=axes,
                                 block_size=block_size)
        batch_specs = {k: shard for k in batch}
        param_specs = jax.tree.map(lambda _: P(), params)
        loss = jax.shard_map(
            body, mesh=mesh,
            in_specs=(param_specs, batch_specs), out_specs=P(),
        )(params, batch)
        return loss, {"mse": loss}

    return loss_fn
