"""Elastic re-meshing: the paper's §4.2.3 scale-out/in at the runtime level.

SDP adds/removes partitions as load changes; the runtime analogue adds or
removes devices (pods) between steps. Because checkpoints are host-complete
(repro.checkpoint), a re-scale is: build a new mesh from the surviving
device list → re-derive shardings from the same rules → restore. Training
state is bitwise preserved; only placement changes.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import numpy as np
from jax.sharding import Mesh

from repro.checkpoint.manager import CheckpointManager


@dataclasses.dataclass
class ElasticState:
    mesh: Mesh
    params: object
    opt_state: object
    step: int


class ElasticRunner:
    """Owns mesh construction + re-scale transitions.

    mesh_factory(devices) must return a Mesh using exactly those devices;
    shardings_fn(mesh, params_like) returns the pytree of NamedShardings.
    """

    def __init__(self, mesh_factory: Callable, shardings_fn: Callable,
                 ckpt: CheckpointManager):
        self.mesh_factory = mesh_factory
        self.shardings_fn = shardings_fn
        self.ckpt = ckpt

    def place(self, devices: Sequence, params, opt_state, step: int) -> ElasticState:
        mesh = self.mesh_factory(devices)
        sh_p = self.shardings_fn(mesh, params)
        sh_o = self.shardings_fn(mesh, opt_state)
        params = jax.tree.map(jax.device_put, params, sh_p)
        opt_state = jax.tree.map(jax.device_put, opt_state, sh_o)
        return ElasticState(mesh, params, opt_state, step)

    def rescale(self, state: ElasticState, devices: Sequence) -> ElasticState:
        """Scale to a new device set (grown or shrunk), preserving state.

        Mirrors SDP scale-in: checkpoint (migrate), rebuild mesh (machine
        set), restore under new shardings (reassign load)."""
        # unconditional pre-rescale save: maybe_save is interval-gated and
        # can silently skip this step, which would leave the transient
        # host copy below as the only migration safety net
        self.ckpt.save_now(state.step, {"params": state.params,
                                        "opt": state.opt_state},
                           blocking=True)
        host = {"params": jax.tree.map(np.asarray, state.params),
                "opt": jax.tree.map(np.asarray, state.opt_state)}
        mesh = self.mesh_factory(devices)
        sh_p = self.shardings_fn(mesh, host["params"])
        sh_o = self.shardings_fn(mesh, host["opt"])
        params = jax.tree.map(jax.device_put, host["params"], sh_p)
        opt_state = jax.tree.map(jax.device_put, host["opt"], sh_o)
        return ElasticState(mesh, params, opt_state, state.step)

    def recover(self, devices: Sequence, like_params, like_opt) -> ElasticState | None:
        """Crash recovery: restore latest checkpoint onto a fresh mesh."""
        restored, step = self.ckpt.restore(
            {"params": like_params, "opt": like_opt})
        if restored is None:
            return None
        return self.place(devices, restored["params"], restored["opt"],
                          step or 0)
