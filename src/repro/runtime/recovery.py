"""Crash-safe long-lived sessions: snapshot + journal + replay recovery.

``repro.runtime.fault.FaultTolerantLoop`` and ``repro.runtime.elastic.
ElasticRunner`` carry the fault-tolerance *policies* (periodic async
checkpoints, restore-and-skip, re-mesh) in training-loop shape: state in,
batches through a ``step_fn``. A partitioning session is a different
shape — an open-ended event stream into a ``Partitioner`` — so this
module re-bases those policies onto the session API:

* :class:`EventJournal` — an append-only, atomically written log of every
  fed chunk (and every explicit compaction), keyed by the session's
  global event cursor;
* :class:`RecoverableSession` — wraps a :class:`repro.api.Partitioner`,
  journaling each feed and snapshotting every ``snapshot_every`` events
  (async, retention-bounded via the checkpoint manager's ``keep_last``
  policy);
* :meth:`RecoverableSession.recover` — restore the latest snapshot and
  replay the journaled tail. Because ``feed`` is chop-invariant and the
  RNG is keyed by the global event cursor, the recovered state is
  **bit-identical** to the uninterrupted run — a crash costs wall time,
  never fidelity (tests/test_recovery.py proves it, including a
  SIGKILLed process).

The journal records **external** vertex ids (exactly what the caller
fed). A relabeling compaction's id map rides in the snapshot's extras
channel, and replayed feeds re-translate deterministically (fresh slots
are allocated in first-appearance order), so recovery composes with
shrink/compaction.

``RecoverableSession`` exposes the ``prepare``/``feed_prepared``/
``sync`` seams, so ``repro.api.serve.PartitionService`` can wrap one
directly — a serving tier whose state survives the machine.

Device loss (the elastic re-mesh path) is orthogonal: if the device
died but the process lives, ``remesh(device)`` moves the live session
onto a surviving device via ``Partitioner.place`` (a host round-trip —
placement is not semantics), and ``remesh(devices=[...])`` re-shards a
vertex-sharded session across the surviving devices via
``Partitioner.reshard`` (the mesh may change width — the gathered state
is canonical); if the process died with it, ``recover`` rebuilds on
whatever devices the fresh process has.
"""
from __future__ import annotations

import glob
import json
import os
import re
import tempfile
from typing import NamedTuple

import numpy as np

from repro.api.partitioner import Partitioner, PreparedChunk
from repro.core.config import EngineConfig
from repro.core.geometry import Geometry


class CrashError(RuntimeError):
    """The injected mid-stream failure (``inject_crash_after``) — raised
    after the triggering chunk is journaled but before it is fed, the
    worst-ordered single point a real crash could hit."""


class JournalEntry(NamedTuple):
    cursor: int     # session cursor the entry applies at
    seq: int        # total order within a cursor (append order)
    kind: str       # "events" | "compact" | "shrink"
    path: str


class EventJournal:
    """Append-only on-disk event log, replayable from any cursor.

    Each ``append`` atomically writes one npz chunk named by the cursor
    it applies at plus a monotonic sequence number (crash mid-write
    leaves only a temp file, never a torn entry). Compactions append a
    marker entry so a replay re-applies them at the same point in the
    stream and reproduces the crashed session's geometry lifecycle, not
    just its content."""

    def __init__(self, directory: str):
        self.dir = directory
        os.makedirs(directory, exist_ok=True)
        seqs = [e.seq for e in self.entries()]
        self._seq = (max(seqs) + 1) if seqs else 0

    _PAT = re.compile(r"(ev|cp)_(\d+)_(\d+)(?:_(\w+))?\.(?:npz|marker)$")

    def entries(self) -> list[JournalEntry]:
        """All journal entries in replay order (cursor, then append
        order)."""
        out = []
        for p in glob.glob(os.path.join(self.dir, "*_*")):
            m = self._PAT.search(os.path.basename(p))
            if not m:
                continue
            kind = "events" if m.group(1) == "ev" else (m.group(4)
                                                        or "compact")
            out.append(JournalEntry(int(m.group(2)), int(m.group(3)),
                                    kind, p))
        return sorted(out, key=lambda e: (e.cursor, e.seq))

    def _write_atomic(self, name: str, payload: bytes) -> str:
        fd, tmp = tempfile.mkstemp(dir=self.dir, suffix=".tmp")
        try:
            os.write(fd, payload)
        finally:
            os.close(fd)
        final = os.path.join(self.dir, name)
        os.replace(tmp, final)
        return final

    def append(self, cursor: int, etype, vertex, nbrs) -> str:
        """Journal one fed chunk (external ids, pre-translation) applying
        at ``cursor`` (the session cursor before the feed)."""
        import io
        buf = io.BytesIO()
        np.savez(buf, etype=np.asarray(etype, np.int32),
                 vertex=np.asarray(vertex, np.int32),
                 nbrs=np.asarray(nbrs, np.int32))
        name = f"ev_{int(cursor):012d}_{self._seq:08d}.npz"
        self._seq += 1
        return self._write_atomic(name, buf.getvalue())

    def append_marker(self, cursor: int, kind: str,
                      payload: dict | None = None) -> str:
        """Journal a session action (``"compact"``, ``"shrink"``,
        ``"rebalance"``, or the ``"snap"`` bookkeeping marker) taken at
        ``cursor``, so replay re-applies it in order. ``payload`` (the
        action's arguments, e.g. a rebalance's ``m``/``passes``) is
        stored as JSON in the marker file and comes back via
        :meth:`load_marker`."""
        name = f"cp_{int(cursor):012d}_{self._seq:08d}_{kind}.marker"
        self._seq += 1
        data = json.dumps(payload).encode() if payload is not None else b""
        return self._write_atomic(name, data)

    def load(self, entry: JournalEntry):
        data = np.load(entry.path)
        return data["etype"], data["vertex"], data["nbrs"]

    def load_marker(self, entry: JournalEntry) -> dict:
        """The JSON payload of a marker entry ({} for payload-free
        markers like compact/shrink)."""
        with open(entry.path, "rb") as f:
            raw = f.read()
        return json.loads(raw) if raw else {}

    def prune_below(self, cursor: int) -> int:
        """Drop entries fully consumed before ``cursor`` — anything a
        restore from the oldest *retained* checkpoint could never need.
        Returns the number of entries removed."""
        removed = 0
        for e in self.entries():
            if e.kind == "events":
                T = int(np.load(e.path)["etype"].shape[0])
                done = e.cursor + T <= cursor
            else:
                done = e.cursor < cursor
            if done:
                os.unlink(e.path)
                removed += 1
        return removed


class RecoverableSession:
    """A :class:`Partitioner` that survives the process (see module
    docstring).

    Args:
      part: the live session to protect (or a fresh one).
      directory: snapshot + journal root. Snapshots land as the session's
        normal checkpoints; the journal lives in ``directory/journal``.
      snapshot_every: events between automatic async snapshots. Each
        snapshot host-copies the state (a sync point) — size it so the
        copy amortizes (the default trades ~1 copy per 2048 events).
      keep: snapshots retained (the manager's ``keep_last`` GC); the
        journal is pruned to what the oldest retained snapshot needs.
      inject_crash_after: TESTING ONLY — raise :class:`CrashError` on the
        first feed once the cursor reaches this value, after journaling
        but before feeding (the worst-ordered crash point).
    """

    def __init__(self, part: Partitioner, directory: str, *,
                 snapshot_every: int = 2048, keep: int = 3,
                 inject_crash_after: int | None = None):
        if snapshot_every <= 0:
            raise ValueError(
                f"snapshot_every={snapshot_every} must be > 0: it is the "
                "event spacing of the automatic snapshots")
        self.part = part
        self.dir = directory
        self.snapshot_every = int(snapshot_every)
        self.keep = int(keep)
        self.inject_crash_after = inject_crash_after
        self.journal = EventJournal(os.path.join(directory, "journal"))
        self._last_snapshot = part.cursor
        self._snapshots = 0

    # -- the Partitioner protocol (what PartitionService drives) ------------

    def prepare(self, events) -> PreparedChunk:
        return self.part.prepare(events)

    def feed_prepared(self, chunk: PreparedChunk) -> "RecoverableSession":
        if chunk.num_events:
            self.journal.append(self.part.cursor, chunk.etype,
                                chunk.vertex, chunk.nbrs)
        if self.inject_crash_after is not None \
                and self.part.cursor >= self.inject_crash_after:
            raise CrashError(
                f"injected crash at cursor {self.part.cursor} (chunk "
                "journaled, not fed — recovery must replay it)")
        self.part.feed_prepared(chunk)
        if self.part.cursor - self._last_snapshot >= self.snapshot_every:
            self.checkpoint(blocking=False)
        return self

    def feed(self, events) -> "RecoverableSession":
        return self.feed_prepared(self.prepare(events))

    def sync(self) -> "RecoverableSession":
        self.part.sync()
        return self

    def metrics(self) -> dict:
        m = self.part.metrics()
        m["snapshots"] = self._snapshots
        m["last_snapshot_cursor"] = self._last_snapshot
        return m

    @property
    def state(self):
        return self.part.state

    @property
    def cursor(self) -> int:
        return self.part.cursor

    @property
    def geometry(self) -> Geometry:
        return self.part.geometry

    def to_internal(self, ids):
        return self.part.to_internal(ids)

    def to_external(self, ids):
        return self.part.to_external(ids)

    # -- geometry actions (journaled so replay reproduces them) -------------

    def compact(self) -> "RecoverableSession":
        # marker BEFORE the action: compact() is unconditional, so a
        # crash between marker and action just replays the compaction
        self.journal.append_marker(self.part.cursor, "compact")
        self.part.compact()
        return self

    def maybe_shrink(self, **kw) -> bool:
        # marker AFTER: the shrink is conditional on live content, and a
        # replayed maybe_shrink at the same cursor decides identically
        did = self.part.maybe_shrink(**kw)
        if did:
            self.journal.append_marker(self.part.cursor, "shrink")
        return did

    def rebalance(self, m: int | None = None, passes: int | None = None,
                  slack: float | None = None) -> dict:
        """Journaled explicit rebalance (see ``Partitioner.rebalance``).
        Marker BEFORE the action, like ``compact()``: the pass is a
        deterministic function of (state, cursor), so a crash between
        marker and action just replays it. ``auto_rebalance`` cadence
        needs no marker — its mark rides the checkpoint extras and the
        replayed feeds re-fire it at the same cursors."""
        self.journal.append_marker(
            self.part.cursor, "rebalance",
            {"m": m, "passes": passes, "slack": slack})
        return self.part.rebalance(m=m, passes=passes, slack=slack)

    def remesh(self, device=None, *, devices=None) -> "RecoverableSession":
        """Re-mesh after (simulated) device loss with the process alive —
        bit-preserving either way; if the process died too, use
        :meth:`recover` instead. A single-device session moves onto
        ``device`` (``Partitioner.place``); a vertex-sharded session
        rebuilds its mesh over ``devices`` (or ``[device]``, or all
        surviving local devices when neither is given) via
        ``Partitioner.reshard`` — the gather/re-pad round-trip, so the
        mesh may change width."""
        if getattr(self.part, "_sharded", False):
            if devices is None and device is not None:
                devices = [device]
            self.part.reshard(devices)
        else:
            if device is None:
                raise ValueError(
                    "remesh() of a single-device session needs the target "
                    "device (devices= is the vertex-sharded form)")
            self.part.place(device)
        return self

    # -- snapshots ----------------------------------------------------------

    def checkpoint(self, *, blocking: bool = True) -> int:
        """Snapshot now (regardless of ``snapshot_every``); prunes the
        journal entries no retained snapshot could need. Returns the
        snapshotted cursor."""
        # "snap" marker first: it records (by sequence number) that every
        # action marker journaled at this cursor so far is contained in
        # the snapshot about to be written, so recover() does not
        # re-apply them. Written BEFORE the save: a crash between the two
        # leaves a stale marker that an older-snapshot restore ignores
        # (its cursor is ahead), never a double-applied action.
        self.journal.append_marker(self.part.cursor, "snap")
        step = self.part.snapshot(self.dir, keep=self.keep,
                                  blocking=blocking)
        self._last_snapshot = step
        self._snapshots += 1
        mgr = self.part._managers[self.dir]
        steps = mgr._steps()
        if steps:
            self.journal.prune_below(steps[0])
        return step

    def wait(self) -> None:
        """Join pending async snapshot writers (call before exit)."""
        self.part.wait()

    # -- recovery -----------------------------------------------------------

    @classmethod
    def recover(cls, directory: str, cfg: EngineConfig | None = None, *,
                snapshot_every: int = 2048, keep: int = 3,
                **kw) -> "RecoverableSession":
        """Rebuild the session after a crash: restore the latest
        snapshot under ``directory`` (``Partitioner.restore`` — geometry,
        id map and cursor come back with it), then replay the journaled
        tail in order, re-applying compaction markers at their recorded
        cursors. Chop-invariance + cursor-keyed RNG make the result
        bit-identical to the run that never crashed. ``**kw`` are the
        session knobs (policy, window, …) — they are not checkpointed."""
        part = Partitioner.restore(directory, cfg, **kw)
        sess = cls(part, directory, snapshot_every=snapshot_every,
                   keep=keep)
        entries = sess.journal.entries()
        # action markers at the restored cursor journaled at or before
        # the snapshot's own "snap" marker are already contained in the
        # snapshot — re-applying them would double-apply (harmless for
        # the idempotent compact/shrink, wrong for rebalance). Journals
        # written before snap markers existed have snap_seq == -1 and
        # replay every equal-cursor marker, the historical behavior.
        snap_seq = max((e.seq for e in entries
                        if e.kind == "snap" and e.cursor == part.cursor),
                       default=-1)
        for e in entries:
            if e.kind == "snap":
                continue
            if e.kind != "events":
                if e.cursor > part.cursor or (e.cursor == part.cursor
                                              and e.seq > snap_seq):
                    if e.kind == "rebalance":
                        part.rebalance(**sess.journal.load_marker(e))
                    else:
                        (part.compact if e.kind == "compact"
                         else part.maybe_shrink)()
                continue
            et, vx, nb = sess.journal.load(e)
            end = e.cursor + int(et.shape[0])
            if end <= part.cursor:
                continue
            off = part.cursor - e.cursor
            part.feed((et[off:], vx[off:], nb[off:]))
        sess._last_snapshot = part.cursor
        return sess

    def __repr__(self) -> str:
        return (f"RecoverableSession(dir={self.dir!r}, "
                f"cursor={self.part.cursor}, "
                f"snapshot_every={self.snapshot_every}, "
                f"snapshots={self._snapshots})")
