"""Distributed runtime: sharding rules, halo-sharded GNN, elastic re-mesh,
and the device-sharded (policy × seed × config × stream) sweep engine."""
from repro.runtime.sweep import SweepResult, SweepRun, run_sweep, sweep_events

__all__ = ["SweepResult", "SweepRun", "run_sweep", "sweep_events"]
