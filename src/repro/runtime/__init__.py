"""Distributed runtime: sharding rules, halo-sharded GNN, elastic re-mesh."""
