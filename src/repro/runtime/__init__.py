"""Distributed runtime: sharding rules, halo-sharded GNN, elastic re-mesh,
crash-safe partitioning sessions (repro.runtime.recovery), and the
device-sharded (policy × seed × config × stream) sweep engine."""
from repro.runtime.recovery import (
    CrashError, EventJournal, JournalEntry, RecoverableSession,
)
from repro.runtime.sweep import SweepResult, SweepRun, run_sweep, sweep_events

__all__ = [
    "CrashError", "EventJournal", "JournalEntry", "RecoverableSession",
    "SweepResult", "SweepRun", "run_sweep", "sweep_events",
]
