from repro.checkpoint.ckpt import save_pytree, restore_pytree
from repro.checkpoint.manager import CheckpointManager

__all__ = ["save_pytree", "restore_pytree", "CheckpointManager"]
