from repro.checkpoint.ckpt import (
    checkpoint_keys, checkpoint_step, restore_pytree, save_pytree,
)
from repro.checkpoint.manager import CheckpointManager

__all__ = ["save_pytree", "restore_pytree", "checkpoint_step",
           "checkpoint_keys", "CheckpointManager"]
