"""Checkpoint manager: periodic async saves, retention, crash recovery,
and a step journal for straggler/failure accounting.

Fault-tolerance contract (DESIGN.md §6):
  * save every `interval` steps on a background thread (training is never
    blocked by disk);
  * atomic rename ⇒ a crash mid-save never corrupts the latest checkpoint;
  * `latest()` + `restore()` resume after preemption/node failure;
  * the step journal records (step, wall_time, status) — the elastic
    runtime uses it to detect stragglers (steps slower than
    `straggler_factor` × median) and to pick the restart step.
"""
from __future__ import annotations

import glob
import json
import os
import re
import threading

import jax
import numpy as np

from repro.checkpoint.ckpt import (
    checkpoint_extras, checkpoint_geometry, checkpoint_keys, restore_pytree,
    save_pytree,
)


# Default device→host staging granularity of save_now (bytes). Large
# enough that chunking overhead is negligible, small enough that the
# synchronous staging step yields to concurrently dispatched device work
# every few MB instead of blocking a feed for the whole state transfer.
DEFAULT_CHUNK_BYTES = 16 << 20


def _stage_host(tree, chunk_bytes: int):
    """Device→host snapshot of ``tree`` in row chunks of at most
    ``chunk_bytes``. ``np.asarray`` on a large device array is one
    synchronous transfer of the whole buffer — a big session's ``feed()``
    stalls behind it. Slicing the leading axis bounds each synchronous
    step; between chunks the caller's async-dispatched device work can
    interleave. Host/numpy leaves pass through untouched; chunked leaves
    land in one preallocated host buffer (no double copy)."""
    def one(leaf):
        if not isinstance(leaf, jax.Array):
            return np.asarray(leaf)
        if leaf.ndim == 0 or leaf.nbytes <= chunk_bytes:
            return np.asarray(leaf)
        row_bytes = max(leaf.nbytes // leaf.shape[0], 1)
        rows = max(int(chunk_bytes // row_bytes), 1)
        out = np.empty(leaf.shape, leaf.dtype)
        for i0 in range(0, leaf.shape[0], rows):
            out[i0:i0 + rows] = np.asarray(leaf[i0:i0 + rows])
        return out
    return jax.tree.map(one, tree)


class CheckpointManager:
    def __init__(self, directory: str, *, interval: int = 100, keep: int = 3,
                 keep_last: int | None = None, straggler_factor: float = 3.0,
                 host_chunk_bytes: int = DEFAULT_CHUNK_BYTES):
        """``keep``/``keep_last`` (synonyms; ``keep_last`` wins when both
        are given) bound the retained snapshots: every save garbage-
        collects all but the newest N — the retention policy that stops a
        long-lived session's periodic snapshots from growing the
        directory without bound. ``host_chunk_bytes`` bounds each
        synchronous device→host staging step of ``save_now`` (see
        ``_stage_host``)."""
        self.dir = directory
        self.interval = interval
        if host_chunk_bytes <= 0:
            raise ValueError(
                f"host_chunk_bytes={host_chunk_bytes} must be > 0: it is "
                "the per-chunk bound on the synchronous device→host "
                "staging copies")
        self.host_chunk_bytes = int(host_chunk_bytes)
        self.keep = int(keep if keep_last is None else keep_last)
        if self.keep < 1:
            raise ValueError(
                f"keep_last={self.keep} must be >= 1: retaining zero "
                "checkpoints would garbage-collect the snapshot that was "
                "just written")
        self.straggler_factor = straggler_factor
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._journal_path = os.path.join(directory, "journal.jsonl")
        self._step_times: list[float] = []

    # -- journal / straggler accounting ------------------------------------
    def record_step(self, step: int, seconds: float, status: str = "ok"):
        self._step_times.append(seconds)
        with open(self._journal_path, "a") as f:
            f.write(json.dumps({"step": step, "t": seconds,
                                "status": status}) + "\n")

    def is_straggler(self, seconds: float) -> bool:
        if len(self._step_times) < 8:
            return False
        med = float(np.median(self._step_times[-64:]))
        return seconds > self.straggler_factor * med

    # -- save/restore -------------------------------------------------------
    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"ckpt_{step:08d}.npz")

    def maybe_save(self, step: int, tree, *, blocking: bool = False,
                   geometry=None, extras=None):
        """Interval-gated save: a silent no-op (returns False) unless
        ``step`` is a multiple of ``interval``. Callers that need THIS
        step on disk — pre-rescale migration, recovery snapshots at
        arbitrary event cursors — use :meth:`save_now` instead."""
        if step % self.interval != 0:
            return False
        self.save_now(step, tree, blocking=blocking, geometry=geometry,
                      extras=extras)
        return True

    def save_now(self, step: int, tree, *, blocking: bool = False,
                 geometry=None, extras=None) -> int:
        """Unconditional save of ``tree`` at ``step`` (no interval gate),
        with the same async/atomic/retention behaviour as
        ``maybe_save``. The tree is host-snapshotted synchronously before
        the call returns, so a caller may mutate (or donate) the live
        state immediately after. Returns ``step``."""
        host_tree = _stage_host(tree, self.host_chunk_bytes)

        def work():
            save_pytree(self._path(step), host_tree, step=step,
                        geometry=geometry, extras=extras)
            self._gc()

        if self._thread is not None:
            self._thread.join()
        if blocking:
            work()
            self._thread = None
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        return step

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _steps(self) -> list[int]:
        out = []
        for p in glob.glob(os.path.join(self.dir, "ckpt_*.npz")):
            m = re.search(r"ckpt_(\d+)\.npz$", p)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def _gc(self):
        for s in self._steps()[: -self.keep]:
            for suffix in ("", ".meta"):
                p = self._path(s) + suffix
                if os.path.exists(p):
                    os.unlink(p)

    def latest(self) -> int | None:
        steps = self._steps()
        return steps[-1] if steps else None

    def leaf_keys(self, step: int | None = None) -> list[str] | None:
        """Key paths of the leaves saved at ``step`` (default: latest) —
        format detection for restorers (repro.api.Partitioner.restore uses
        the leaf count to decide whether a checkpoint predates
        ``cut_matrix`` and needs a recount)."""
        step = step if step is not None else self.latest()
        if step is None:
            return None
        return checkpoint_keys(self._path(step))

    def geometry(self, step: int | None = None):
        """The ``Geometry`` recorded for (or inferred from) the
        checkpoint at ``step`` (default: latest) — lets a restorer build
        its target at the saved shape and grow from there
        (repro.api.Partitioner.restore)."""
        step = step if step is not None else self.latest()
        if step is None:
            return None
        return checkpoint_geometry(self._path(step))

    def extras(self, step: int | None = None) -> dict:
        """The ``extras`` arrays saved with the checkpoint at ``step``
        (default: latest; empty dict when none) — the side channel a
        compacted session's id map rides in (see
        repro.checkpoint.ckpt.save_pytree)."""
        step = step if step is not None else self.latest()
        if step is None:
            return {}
        return checkpoint_extras(self._path(step))

    def restore(self, like, *, step: int | None = None, shardings=None,
                fill_missing=False):
        """``fill_missing=True`` restores checkpoints whose tree predates
        trailing fields added to ``like`` (missing leaves keep ``like``'s
        value) — e.g. pre-cut_matrix PartitionState checkpoints, where the
        caller fills the matrix via repro.core.state.recount_cut_matrix."""
        step = step if step is not None else self.latest()
        if step is None:
            return None, None
        return restore_pytree(self._path(step), like, shardings=shardings,
                              fill_missing=fill_missing), step
