"""Pytree checkpointing: npz payload + msgpack treedef, atomic rename.

Arrays are written host-resident and unsharded; restore re-shards under
the *current* mesh (put with the target sharding), which is what makes
elastic re-scale (repro.runtime.elastic) a restore with a different mesh.
"""
from __future__ import annotations

import os
import tempfile
import zipfile

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path) for path, _ in flat]
    vals = [np.asarray(v) for _, v in flat]
    return keys, vals, treedef


def save_pytree(path: str, tree, *, step: int | None = None,
                geometry=None, extras=None) -> str:
    """Atomic save. Returns the final path.

    ``geometry`` (a ``repro.core.geometry.Geometry`` or mapping with
    n/max_deg/k_max) is recorded in the metadata so a restorer can size
    its target — and grow it — without loading the payload.

    ``extras`` is an optional ``{name: array}`` of session-side arrays
    that ride along OUTSIDE the pytree (so the restore-into-``like``
    contract is untouched) — e.g. the external→internal id map a
    compacted ``Partitioner`` needs to keep answering queries in
    original vertex ids. Read back with :func:`checkpoint_extras`."""
    keys, vals, _ = _flatten(tree)
    if geometry is not None and hasattr(geometry, "_asdict"):
        geometry = dict(geometry._asdict())
    extras = {str(k): np.asarray(v) for k, v in (extras or {}).items()}
    meta = {"keys": keys, "step": step, "geometry": geometry,
            "extras": sorted(extras)}
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                               suffix=".tmp")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **{f"a{i}": v for i, v in enumerate(vals)},
                     **{f"x_{k}": v for k, v in extras.items()})
        with open(tmp + ".meta", "wb") as f:
            f.write(msgpack.packb(meta))
        os.replace(tmp, path)
        os.replace(tmp + ".meta", path + ".meta")
    finally:
        for t in (tmp, tmp + ".meta"):
            if os.path.exists(t):
                os.unlink(t)
    return path


def restore_pytree(path: str, like, *, shardings=None, fill_missing=False):
    """Restore into the structure of `like`; optional target shardings
    (a matching pytree of jax.sharding.Sharding) for elastic re-shard.

    ``fill_missing=True`` aligns leaves by their saved key paths instead of
    requiring an exact leaf-count match: leaves of ``like`` absent from the
    checkpoint keep ``like``'s value. This is how states that gained
    trailing fields (e.g. PartitionState.cut_matrix) restore from older
    checkpoints — pass ``like`` with the new field already filled (see
    repro.core.state.recount_cut_matrix)."""
    with open(path + ".meta", "rb") as f:
        meta = msgpack.unpackb(f.read())
    data = np.load(path)
    vals = [data[f"a{i}"] for i in range(len(meta["keys"]))]
    flat_like, treedef = jax.tree_util.tree_flatten(like)
    if len(vals) != len(flat_like):
        if not fill_missing:
            raise ValueError(
                f"checkpoint has {len(vals)} leaves, target has "
                f"{len(flat_like)} (fill_missing=True aligns by key)")
        saved = dict(zip(meta["keys"], vals))
        like_keys, like_vals, _ = _flatten(like)
        vals = [saved.get(k, lv) for k, lv in zip(like_keys, like_vals)]
    if shardings is not None:
        flat_sh = jax.tree_util.tree_flatten(shardings)[0]
        out = [jax.device_put(v.astype(l.dtype), s)
               for v, l, s in zip(vals, flat_like, flat_sh)]
    else:
        out = [jnp.asarray(v.astype(l.dtype)) for v, l in zip(vals, flat_like)]
    return jax.tree_util.tree_unflatten(treedef, out)


def checkpoint_extras(path: str) -> dict[str, np.ndarray]:
    """The ``extras`` arrays saved alongside a checkpoint (empty dict if
    none were recorded or the checkpoint predates the channel)."""
    try:
        with open(path + ".meta", "rb") as f:
            names = msgpack.unpackb(f.read()).get("extras") or []
    except FileNotFoundError:
        return {}
    if not names:
        return {}
    data = np.load(path)
    return {k: data[f"x_{k}"] for k in names}


def checkpoint_step(path: str) -> int | None:
    try:
        with open(path + ".meta", "rb") as f:
            return msgpack.unpackb(f.read()).get("step")
    except FileNotFoundError:
        return None


def _npy_header_shape(f) -> tuple:
    """Shape from an .npy member's header alone — no payload read."""
    version = np.lib.format.read_magic(f)
    read_header = (np.lib.format.read_array_header_1_0 if version == (1, 0)
                   else np.lib.format.read_array_header_2_0)
    shape, _, _ = read_header(f)
    return shape


def checkpoint_geometry(path: str):
    """The ``Geometry`` a checkpointed ``PartitionState`` was taken at:
    read from the metadata when recorded (``save_pytree(geometry=...)``),
    else inferred from the saved leaf *headers* (assignment → n, adj →
    max_deg, edge_load → k_max; only the npy headers inside the npz are
    read, never the payload) so pre-geometry checkpoints restore without
    the caller re-declaring their shapes. ``None`` if the checkpoint is
    missing or not a partition state."""
    from repro.core.geometry import Geometry
    try:
        with open(path + ".meta", "rb") as f:
            meta = msgpack.unpackb(f.read())
    except FileNotFoundError:
        return None
    g = meta.get("geometry")
    if g:
        k = g.get("k_max")
        return Geometry(int(g["n"]), int(g["max_deg"]),
                        int(k) if k is not None else None)
    # namedtuple key paths serialize as ".assignment" (GetAttrKey) —
    # normalize to bare field names before member lookup
    idx = {k.rsplit("/", 1)[-1].lstrip("."): i
           for i, k in enumerate(meta.get("keys") or [])}
    try:
        with zipfile.ZipFile(path) as zf:
            def shape(field: str) -> tuple:
                with zf.open(f"a{idx[field]}.npy") as f:
                    return _npy_header_shape(f)
            return Geometry(int(shape("assignment")[0]),
                            int(shape("adj")[1]),
                            int(shape("edge_load")[0]))
    except (KeyError, IndexError, FileNotFoundError, ValueError,
            zipfile.BadZipFile):
        return None


def checkpoint_keys(path: str) -> list[str] | None:
    """Key paths of the saved leaves — lets a restorer detect the saved
    tree's shape (e.g. a pre-cut_matrix PartitionState with fewer leaves)
    before deciding how to fill and heal it."""
    try:
        with open(path + ".meta", "rb") as f:
            return list(msgpack.unpackb(f.read())["keys"])
    except FileNotFoundError:
        return None
