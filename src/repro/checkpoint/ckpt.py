"""Pytree checkpointing: npz payload + msgpack treedef, atomic rename.

Arrays are written host-resident and unsharded; restore re-shards under
the *current* mesh (put with the target sharding), which is what makes
elastic re-scale (repro.runtime.elastic) a restore with a different mesh.
"""
from __future__ import annotations

import os
import tempfile

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path) for path, _ in flat]
    vals = [np.asarray(v) for _, v in flat]
    return keys, vals, treedef


def save_pytree(path: str, tree, *, step: int | None = None) -> str:
    """Atomic save. Returns the final path."""
    keys, vals, _ = _flatten(tree)
    meta = {"keys": keys, "step": step}
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                               suffix=".tmp")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **{f"a{i}": v for i, v in enumerate(vals)})
        with open(tmp + ".meta", "wb") as f:
            f.write(msgpack.packb(meta))
        os.replace(tmp, path)
        os.replace(tmp + ".meta", path + ".meta")
    finally:
        for t in (tmp, tmp + ".meta"):
            if os.path.exists(t):
                os.unlink(t)
    return path


def restore_pytree(path: str, like, *, shardings=None, fill_missing=False):
    """Restore into the structure of `like`; optional target shardings
    (a matching pytree of jax.sharding.Sharding) for elastic re-shard.

    ``fill_missing=True`` aligns leaves by their saved key paths instead of
    requiring an exact leaf-count match: leaves of ``like`` absent from the
    checkpoint keep ``like``'s value. This is how states that gained
    trailing fields (e.g. PartitionState.cut_matrix) restore from older
    checkpoints — pass ``like`` with the new field already filled (see
    repro.core.state.recount_cut_matrix)."""
    with open(path + ".meta", "rb") as f:
        meta = msgpack.unpackb(f.read())
    data = np.load(path)
    vals = [data[f"a{i}"] for i in range(len(meta["keys"]))]
    flat_like, treedef = jax.tree_util.tree_flatten(like)
    if len(vals) != len(flat_like):
        if not fill_missing:
            raise ValueError(
                f"checkpoint has {len(vals)} leaves, target has "
                f"{len(flat_like)} (fill_missing=True aligns by key)")
        saved = dict(zip(meta["keys"], vals))
        like_keys, like_vals, _ = _flatten(like)
        vals = [saved.get(k, lv) for k, lv in zip(like_keys, like_vals)]
    if shardings is not None:
        flat_sh = jax.tree_util.tree_flatten(shardings)[0]
        out = [jax.device_put(v.astype(l.dtype), s)
               for v, l, s in zip(vals, flat_like, flat_sh)]
    else:
        out = [jnp.asarray(v.astype(l.dtype)) for v, l in zip(vals, flat_like)]
    return jax.tree_util.tree_unflatten(treedef, out)


def checkpoint_step(path: str) -> int | None:
    try:
        with open(path + ".meta", "rb") as f:
            return msgpack.unpackb(f.read()).get("step")
    except FileNotFoundError:
        return None


def checkpoint_keys(path: str) -> list[str] | None:
    """Key paths of the saved leaves — lets a restorer detect the saved
    tree's shape (e.g. a pre-cut_matrix PartitionState with fewer leaves)
    before deciding how to fill and heal it."""
    try:
        with open(path + ".meta", "rb") as f:
            return list(msgpack.unpackb(f.read())["keys"])
    except FileNotFoundError:
        return None
