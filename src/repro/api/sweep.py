"""Fluent sweep builder — the one validated entry for multi-lane runs.

``run_sweep`` grew an engine/window/chunk/shard kwarg surface threaded
through three call layers, with compatibility rules (windowed lanes have
no ``chunk``; lanes must share ``k_max`` and ``balance_guard``) enforced
ad hoc or not at all. The builder states the run declaratively and
validates every lane-compatibility rule in ONE place before any array is
stacked:

    results = (Sweep(stream)          # one shared or per-lane streams
               .lanes(runs)           # SweepRun / (policy, cfg, seed)
               .windowed(256)         # or .scan() [default] + .chunked(n)
               .sharded()             # shard lanes across local devices
               .run())

Execution is unchanged: every lane is bit-identical to ``run_stream`` on
that lane's stream (tests/test_sweep.py, tests/test_sweep_sharded.py).
Per-lane streams may differ in geometry (``n`` / ``max_deg``) — the
runtime pads all lanes to the union geometry before stacking, which is a
semantics no-op per lane (tests/test_geometry.py; see
repro.core.geometry). The old ``repro.runtime.sweep.run_sweep`` survives
as a deprecation shim that builds a ``Sweep`` and runs it.
"""
from __future__ import annotations

from typing import Sequence

from repro.core.config import EngineConfig, POLICIES
from repro.graph.stream import VertexStream
from repro.runtime.sweep import SweepResult, SweepRun, _execute_sweep


class Sweep:
    """Builder for one multi-lane sweep over ``stream`` (a shared
    :class:`VertexStream`, or a sequence of per-lane streams). Every
    configuration method returns ``self`` for chaining; ``run()``
    validates the whole description and executes it."""

    def __init__(self, stream: VertexStream | Sequence[VertexStream]):
        self._stream = stream
        self._runs: list[SweepRun] = []
        self._engine = "scan"
        self._window = 256
        self._chunk: int | None = None
        self._shard: bool | None = None
        self._shard_vertices = False
        self._use_kernel = False
        self._rebalance: dict | None = None

    # -- lanes --------------------------------------------------------------

    def lane(self, policy: str = "sdp", cfg: EngineConfig | None = None,
             seed: int = 0) -> "Sweep":
        """Append one (policy, cfg, seed) lane."""
        self._runs.append(SweepRun(policy, cfg or EngineConfig(), seed))
        return self

    def lanes(self, runs: Sequence[SweepRun | tuple]) -> "Sweep":
        """Append many lanes (``SweepRun`` or ``(policy, cfg, seed)``)."""
        self._runs.extend(
            r if isinstance(r, SweepRun) else SweepRun(*r) for r in runs)
        return self

    # -- engine -------------------------------------------------------------

    def scan(self) -> "Sweep":
        """Per-event scan lanes (default): returns per-event traces."""
        self._engine = "scan"
        return self

    def windowed(self, window: int = 256) -> "Sweep":
        """Mixed-event window kernel vmapped across lanes — the fastest
        engine; returns ``trace=None`` per lane."""
        if window <= 0:
            raise ValueError(
                f"window={window} must be > 0: it is the number of events "
                "each lane batches per device step")
        self._engine = "windowed"
        self._window = int(window)
        return self

    def kernel(self, use_kernel: bool = True) -> "Sweep":
        """Run the windowed lanes through the fused Pallas chooser
        (repro.kernels.fused_chooser) instead of the XLA window kernel —
        bit-identical by contract, interpret mode off TPU. Windowed-engine
        only: the scan engine is the semantic reference and stays XLA
        (``run()`` raises on ``.scan().kernel()``)."""
        self._use_kernel = bool(use_kernel)
        return self

    def chunked(self, chunk: int) -> "Sweep":
        """Re-dispatch the scan engine every ``chunk`` events (resumable,
        bounds step count per program). Scan-engine only."""
        if chunk <= 0:
            raise ValueError(f"chunk={chunk} must be > 0")
        self._chunk = int(chunk)
        return self

    def rebalance(self, m: int = 32, *, every: int = 512, passes: int = 0,
                  slack: float = 0.25,
                  lanes: Sequence[int] | None = None) -> "Sweep":
        """Interleave a rebalance pass (repro.rebalance: greedy top-``m``
        migration + ``passes`` LPA iterations, Eq. 10 ``slack`` guard)
        after every ``every`` processed events, vmapped across lanes in
        one dispatch — the policy×cadence study lane. ``lanes`` restricts
        it to those lane indices (None = all): excluded lanes ride the
        same program with the pass gated off, bit-identical to a sweep
        that never rebalanced. With the windowed engine ``every`` must be
        a multiple of the window (the cadence segments the on-device
        window loop)."""
        self._rebalance = {"m": int(m), "every": int(every),
                           "passes": int(passes), "slack": float(slack),
                           "lanes": None if lanes is None
                           else tuple(int(i) for i in lanes)}
        return self

    def sharded(self, shard: bool = True) -> "Sweep":
        """Shard the lane axis across local devices with shard_map
        (lanes padded to a multiple of the device count).
        ``sharded(False)`` pins the single-device vmapped path; unset =
        auto (shard iff more than one device exists)."""
        self._shard = bool(shard)
        return self

    def sharded_vertices(self, shard: bool = True) -> "Sweep":
        """Shard each lane's VERTEX axis across the local devices instead
        of the lane axis: lanes run sequentially, each as one
        vertex-sharded session over the full device mesh
        (repro.runtime.shard_session) — the big-graph regime, where one
        lane's (n, max_deg) state does not fit a single device. Windowed
        engine only; bit-identical per lane to ``run_stream``. Mutually
        exclusive with ``.sharded()`` — one sweep's lanes either split
        the devices (lane-parallel) or share them all (vertex-parallel);
        to get both at once, build a 2-D mesh with
        ``repro.launch.mesh.make_grid_mesh`` and run lane groups as
        separate sweeps."""
        self._shard_vertices = bool(shard)
        return self

    # -- execution ----------------------------------------------------------

    def _validate(self) -> None:
        """Every lane-compatibility rule, in one place, before any array
        is stacked or any program traced."""
        if self._engine == "windowed" and self._chunk is not None:
            raise ValueError(
                f"chunk={self._chunk} is a scan-engine knob: the windowed "
                "engine processes each lane's stream as a device-resident "
                "lax.scan over windows — its window IS the chunk. Drop "
                ".chunked() (or the chunk= argument) or use the scan "
                "engine.")
        if self._shard_vertices:
            if self._shard:
                raise ValueError(
                    "sharded() and sharded_vertices() are mutually "
                    "exclusive: lane-parallel lanes each claim a device "
                    "while vertex-parallel lanes each claim the WHOLE "
                    "mesh — combining them would silently oversubscribe "
                    "the device pool. Run lane groups as separate sweeps, "
                    "or build an explicit 2-D lanes×vertices mesh with "
                    "repro.launch.mesh.make_grid_mesh and drive the "
                    "session runtime directly.")
            if self._engine != "windowed":
                raise ValueError(
                    "sharded_vertices() requires the windowed engine: the "
                    "vertex-sharded runtime processes streams as windows "
                    "with one all-reduce per window (the per-event scan "
                    "has no sharded counterpart) — chain .windowed() "
                    "before .sharded_vertices()")
            if self._use_kernel:
                raise ValueError(
                    "sharded_vertices() cannot run the Pallas kernel "
                    "lanes: the sharded window step runs the chooser "
                    "oracle replicated per device — drop .kernel()")
            if self._rebalance is not None:
                raise ValueError(
                    "sharded_vertices() does not interleave rebalance "
                    "passes (the vmapped rebalance cadence is a "
                    "lane-parallel program) — drop .rebalance(), or use "
                    "a Partitioner(sharded=True) session with "
                    "auto_rebalance/rebalance_drift")
        if self._use_kernel and self._engine != "windowed":
            raise ValueError(
                "kernel() requires the windowed engine: the fused Pallas "
                "chooser is the windowed kernel's Pallas form; the scan "
                "engine is the semantic reference and always scores with "
                "XLA gathers. Chain .windowed() before .kernel(), or drop "
                ".kernel().")
        if self._rebalance is not None:
            rb = self._rebalance
            if rb["every"] <= 0:
                raise ValueError(
                    f"rebalance every={rb['every']} must be > 0: it is "
                    "the event cadence of the interleaved passes")
            if rb["m"] < 0 or rb["passes"] < 0 or rb["slack"] < 0:
                raise ValueError(
                    f"rebalance m={rb['m']}, passes={rb['passes']} and "
                    f"slack={rb['slack']} must all be >= 0")
            if rb["m"] == 0 and rb["passes"] == 0:
                raise ValueError(
                    "rebalance(m=0, passes=0) would interleave empty "
                    "passes — give it a migration budget (m) and/or LPA "
                    "iterations (passes), or drop .rebalance()")
            if self._engine == "windowed" \
                    and rb["every"] % self._window != 0:
                raise ValueError(
                    f"rebalance every={rb['every']} must be a multiple of "
                    f"the window ({self._window}): the cadence segments "
                    "the on-device window loop at window boundaries")
            if rb["lanes"] is not None:
                bad = [i for i in rb["lanes"]
                       if not 0 <= i < len(self._runs)]
                if bad:
                    raise ValueError(
                        f"rebalance lanes={rb['lanes']} reference "
                        f"out-of-range lane indices {bad} (the sweep has "
                        f"{len(self._runs)} lanes)")
        if not isinstance(self._stream, (list, tuple)):
            streams = None
        else:
            streams = list(self._stream)
            if len(streams) != len(self._runs):
                raise ValueError(
                    f"got {len(streams)} streams for {len(self._runs)} runs"
                    " — per-lane streams must pair one stream per lane "
                    "(pass a single VertexStream to share it)")
        if not self._runs:
            return
        cfg0 = self._runs[0].cfg
        for r in self._runs:
            if r.policy not in POLICIES:
                raise ValueError(
                    f"unknown policy {r.policy!r} (expected one of "
                    f"{POLICIES})")
            if r.cfg.k_max != cfg0.k_max:
                raise ValueError(
                    "all sweep lanes must share k_max (array shapes): got "
                    f"{r.cfg.k_max} vs {cfg0.k_max}")
            if r.cfg.balance_guard != cfg0.balance_guard:
                raise ValueError(
                    "all sweep lanes must share balance_guard (trace-time "
                    f"branch): got {r.cfg.balance_guard!r} vs "
                    f"{cfg0.balance_guard!r}")

    def run(self) -> list[SweepResult]:
        """Validate and execute; lane results in lane order, each
        bit-identical to ``run_stream`` on that lane's stream."""
        self._validate()
        if not self._runs:
            return []
        return _execute_sweep(
            self._stream, self._runs, chunk=self._chunk,
            engine=self._engine, window=self._window, shard=self._shard,
            use_kernel=self._use_kernel, rebalance=self._rebalance,
            shard_vertices=self._shard_vertices)
