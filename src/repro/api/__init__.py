"""Public facade over the SDP reproduction: stateful streaming sessions
(:class:`Partitioner`), the serving tier over them
(:class:`PartitionService` — double-buffered async ingest, backpressure,
query/routing API), and fluent multi-lane sweeps (:class:`Sweep`).

This is THE surface new code should build on; the engine modules
(``repro.core.engine``/``windowed``) stay importable as the semantic
reference and for tests, and ``repro.runtime.sweep.run_sweep`` is a
deprecation shim over :class:`Sweep`.
"""
from repro.api.partitioner import Partitioner, PreparedChunk
from repro.api.serve import PartitionService, RouteResult
from repro.api.sweep import Sweep
from repro.runtime.sweep import SweepResult, SweepRun

__all__ = ["Partitioner", "PartitionService", "PreparedChunk",
           "RouteResult", "Sweep", "SweepRun", "SweepResult"]
