"""Streaming partition *service*: a double-buffered, backpressured ingest
loop plus a request/response query API over a :class:`Partitioner`.

``Partitioner.feed()`` is the session primitive, but calling it from a
request handler serializes the host and the device: the host blocks while
the device runs (if the caller syncs per chunk) and the device idles
while the host coerces the next chunk. ``PartitionService`` is the
serving tier on top (the shape of ``repro.launch.serve.LMServer``'s
slot loop, applied to graph events):

    part = Partitioner.from_stream(stream, cfg, policy="sdp")
    with PartitionService(part, max_pending_chunks=64) as svc:
        for chunk in arriving_chunks:
            svc.submit(chunk)              # cheap enqueue, backpressured
        svc.flush()                        # barrier: queue drained + device idle
        print(svc.where(17), svc.metrics())

Design
------
* **Double-buffered ingest.** A dedicated ingest thread pops arrival
  chunks from a bounded queue, runs the host-side coercion
  (``Partitioner.prepare`` — dtype coercion, ``normalize_rows``
  re-widthing, ``required_geometry_of``) for chunk *t+1* while the
  device still executes chunk *t* (JAX async dispatch), and only then
  waits for the previous batch's completion token before dispatching —
  so at most one batch is in flight and one is being coerced.
  ``jax.block_until_ready`` happens at query points and on completion
  tokens, never inside the dispatch path.
* **Continuous batching.** Everything queued when the ingest thread
  comes around is coalesced into ONE ``feed_prepared`` call (bounded by
  ``max_batch_events``). ``feed`` is bit-identical under any chopping,
  so coalescing never changes the result — it only turns per-event scan
  tails into full windows and amortizes dispatch overhead, which is
  where the fig14 throughput win comes from.
* **Backpressure.** The ingest queue holds at most
  ``max_pending_chunks``; ``policy="block"`` makes ``submit`` wait for a
  slot (optionally bounded by ``timeout``, raising ``TimeoutError``),
  ``policy="drop"`` sheds the chunk and returns ``False``. Both are
  counted and surfaced through ``metrics()``.
* **Queries snapshot, ingest continues.** ``where``/``where_many``/
  ``route`` grab a reference to the carried state under the dispatch
  lock (a consistent snapshot: every *dispatched* batch, in order, and
  nothing partial — queued-but-undispatched chunks are not included),
  enqueue a small device gather ordered after the in-flight feeds, and
  block only on that gather's result. The ingest thread never stalls.
  Call ``flush()`` first for read-your-submits semantics.
* **Reclaim in idle windows.** With ``idle_compact_s`` set, an ingest
  lull of that many seconds runs one hysteresis-gated
  ``Partitioner.maybe_shrink`` under the dispatch lock — churn-emptied
  sessions hand their peak-tier buffers back without ever stalling live
  traffic. ``drain_compact()`` is the explicit flush-then-compact seam
  for planned lulls. Queries keep speaking original vertex ids across
  any relabeling (``where_many`` routes through the session's id map).
* **Bit-identity.** The service-fed final state is bit-identical to a
  synchronous whole-stream ``run_stream``/``feed`` of the same events in
  submission order — enforced by tests/test_api_serve.py and asserted by
  benchmarks/fig14_serving.py.

See docs/SERVING.md for the lifecycle and the consistency model in
detail.
"""
from __future__ import annotations

import functools
import queue
import threading
import time
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.partitioner import Partitioner, PreparedChunk
from repro.core.geometry import Geometry
from repro.graph.stream import normalize_rows

_POLICIES = ("block", "drop")
_STOP = object()


class RouteResult(NamedTuple):
    """Partition routing for a batch of edges (see ``route``).

    ``src_part``/``dst_part`` are the current labels of each edge's
    endpoints (-1 = unassigned/absent); ``cut`` marks edges whose
    endpoints live in different partitions — the traffic a downstream
    sharded consumer must send cross-shard."""

    src_part: np.ndarray   # (E,) int32
    dst_part: np.ndarray   # (E,) int32
    cut: np.ndarray        # (E,) bool


def _merge_prepared(chunks: list[PreparedChunk]) -> PreparedChunk:
    """Coalesce prepared chunks into one (continuous batching). Feeding
    the merged chunk is bit-identical to feeding them back to back —
    ``feed`` is chop-invariant — so this only changes throughput."""
    if len(chunks) == 1:
        return chunks[0]
    width = max(c.nbrs.shape[1] for c in chunks)
    return PreparedChunk(
        np.concatenate([c.etype for c in chunks]),
        np.concatenate([c.vertex for c in chunks]),
        np.concatenate([normalize_rows(c.nbrs, width) for c in chunks]),
        functools.reduce(Geometry.union, (c.required for c in chunks)),
    )


class PartitionService:
    """Asynchronous serving loop over a :class:`Partitioner` session
    (see module docstring).

    Args:
      part: the session to serve. The service owns its feed path — do
        not call ``feed`` on it concurrently (queries and ``metrics``
        on the service are safe from any thread).
      max_pending_chunks: bound of the ingest queue; submits beyond it
        hit the backpressure ``policy``.
      policy: ``"block"`` (submit waits for a queue slot) or ``"drop"``
        (submit sheds the chunk, returns ``False``).
      max_batch_events: cap on how many events one coalesced dispatch
        may contain (None = bounded only by the queue).
      idle_compact_s: seconds of ingest silence after which the loop
        runs one opportunistic ``Partitioner.maybe_shrink`` (hysteresis-
        gated, so it is a cheap no-op unless churn left the state mostly
        empty) — the drain-compact path for long-lived sessions: reclaim
        happens in idle windows, never while traffic is arriving.
        ``None`` (default) disables it. ``drain_compact()`` is the
        explicit, unconditional counterpart.
      idle_rebalance_s: seconds of ingest silence after which the loop
        runs one ``Partitioner.rebalance()`` (the session's configured
        ``rebalance_m``/``rebalance_passes`` knobs) under the dispatch
        lock — queries answer from the repaired partition the moment it
        lands, via the same snapshot seam as any feed. At most one
        rebalance per ingest progress: an idle session is not
        re-rebalanced until new events arrive. ``None`` (default)
        disables it; ``drain_rebalance()`` is the explicit counterpart.
        Composes with ``idle_compact_s`` (rebalance first — it changes
        the loads the shrink check sees).
      autostart: start the ingest thread immediately. Tests pass
        ``False`` to stage deterministic queue states, then ``start()``.

    ``part`` may also be a ``repro.runtime.recovery.RecoverableSession``
    — anything speaking the ``prepare``/``feed_prepared``/``sync``/
    ``metrics``/``state``/``to_internal`` protocol serves identically
    (that is how a crash-safe serving tier is assembled).
    """

    def __init__(self, part: Partitioner, *, max_pending_chunks: int = 8,
                 policy: str = "block", max_batch_events: int | None = None,
                 idle_compact_s: float | None = None,
                 idle_rebalance_s: float | None = None,
                 autostart: bool = True):
        if policy not in _POLICIES:
            raise ValueError(
                f"policy={policy!r} is unknown: expected one of {_POLICIES}"
                " ('block' waits for a queue slot, 'drop' sheds the chunk)")
        if max_pending_chunks <= 0:
            raise ValueError(
                f"max_pending_chunks={max_pending_chunks} must be > 0: it "
                "bounds the ingest queue the backpressure policy acts on")
        if max_batch_events is not None and max_batch_events <= 0:
            raise ValueError(
                f"max_batch_events={max_batch_events} must be > 0 (or None "
                "to coalesce everything queued)")
        if idle_compact_s is not None and idle_compact_s <= 0:
            raise ValueError(
                f"idle_compact_s={idle_compact_s} must be > 0 (or None to "
                "disable idle-window compaction)")
        if idle_rebalance_s is not None and idle_rebalance_s <= 0:
            raise ValueError(
                f"idle_rebalance_s={idle_rebalance_s} must be > 0 (or None "
                "to disable idle-window rebalancing)")
        self._part = part
        self.policy = policy
        self.max_pending_chunks = int(max_pending_chunks)
        self.max_batch_events = max_batch_events
        self.idle_compact_s = idle_compact_s
        self.idle_rebalance_s = idle_rebalance_s
        # the queue-get timeout is the earliest idle action; the loop
        # then fires each action once its own threshold is crossed
        idles = [s for s in (idle_compact_s, idle_rebalance_s)
                 if s is not None]
        self._idle_s = min(idles) if idles else None
        self._idle_shrinks = 0
        self._idle_rebalances = 0
        self._last_idle_rebalance_cursor = -1
        self._drain_compacts = 0
        self._queue: queue.Queue = queue.Queue(maxsize=max_pending_chunks)
        # serializes ingest-thread dispatch against query-side snapshot +
        # gather dispatch (held for microseconds; never across a device
        # wait)
        self._lock = threading.Lock()
        self._cond = threading.Condition()
        self._accepted = 0           # chunks admitted past backpressure
        self._completed = 0          # chunks whose batch finished on device
        self._dropped = 0
        self._events_submitted = 0
        self._events_ingested_done = 0   # events in completed batches
        self._batches = 0
        self._max_depth = 0
        self._coercion_s = 0.0
        self._device_wait_s = 0.0
        self._device_busy_s = 0.0
        self._submit_blocked_s = 0.0
        self._latencies: list[float] = []
        self._t_start: float | None = None
        self._t_last_done: float | None = None
        self._error: BaseException | None = None
        self._closed = False
        self._started = False
        self._ingest = threading.Thread(
            target=self._ingest_loop, name="partition-ingest", daemon=True)
        self._completer = threading.Thread(
            target=self._completion_loop, name="partition-complete",
            daemon=True)
        # unbounded: holds (token, dispatch_time, [(arrival, n_events)])
        # per in-flight batch for the completion thread
        self._inflight: queue.Queue = queue.Queue()
        if autostart:
            self.start()

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> "PartitionService":
        """Start the ingest + completion threads (no-op if running)."""
        if not self._started:
            self._started = True
            self._ingest.start()
            self._completer.start()
        return self

    def __enter__(self) -> "PartitionService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self) -> None:
        """Stop accepting, drain the queue, wait for the device, and
        join the threads. Idempotent; queries remain valid after."""
        if self._closed:
            return
        self._closed = True
        if self._started:
            while True:
                try:
                    self._queue.put(_STOP, timeout=0.5)
                    break
                except queue.Full:
                    # a dead ingest loop never drains the queue — don't
                    # hang close() on it, the error surfaces below
                    if self._error is not None:
                        break
            self._ingest.join()
            self._inflight.put(_STOP)
            self._completer.join()
        self._part.sync()
        self._raise_pending()

    def _raise_pending(self) -> None:
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError(
                "the service ingest loop died — the session state is NOT "
                "guaranteed past the last completed batch") from err

    # -- ingest -------------------------------------------------------------

    def submit(self, events, *, arrival: float | None = None,
               timeout: float | None = None) -> bool:
        """Enqueue a chunk of events (``VertexStream`` or ``(etype,
        vertex, nbrs)`` triple — anything ``feed`` takes). Cheap: no
        coercion happens on the caller's thread.

        Returns ``True`` if admitted. Under ``policy="drop"`` a full
        queue sheds the chunk (returns ``False``, counted in
        ``metrics()["chunks_dropped"]``); under ``policy="block"`` the
        call waits for a slot, raising ``TimeoutError`` if ``timeout``
        (seconds) elapses first. ``arrival`` optionally stamps the
        chunk's arrival time (``time.perf_counter`` clock) for the
        latency percentiles — default: now."""
        if self._closed:
            raise RuntimeError("service is closed — no further submits")
        self._raise_pending()
        item = (events, time.perf_counter() if arrival is None else arrival)
        if self._t_start is None:
            self._t_start = item[1]
        if self.policy == "drop":
            try:
                self._queue.put_nowait(item)
            except queue.Full:
                with self._cond:
                    self._dropped += 1
                return False
        else:
            t0 = time.perf_counter()
            try:
                # poll in short slices so a dead ingest loop (queue never
                # drains) surfaces as its error, not an eternal block
                while True:
                    waited = time.perf_counter() - t0
                    if timeout is not None and waited >= timeout:
                        raise TimeoutError(
                            f"submit timed out after {timeout}s waiting for "
                            f"a queue slot ({self.max_pending_chunks} "
                            "pending chunks; drain with flush(), raise "
                            "max_pending_chunks, or use policy='drop')") \
                            from None
                    slice_ = 0.25 if timeout is None \
                        else min(0.25, timeout - waited)
                    try:
                        self._queue.put(item, timeout=slice_)
                        break
                    except queue.Full:
                        self._raise_pending()
            finally:
                self._submit_blocked_s += time.perf_counter() - t0
        with self._cond:
            self._accepted += 1
            self._max_depth = max(self._max_depth, self._queue.qsize())
        return True

    def flush(self, timeout: float | None = None) -> "PartitionService":
        """Barrier: block until every admitted chunk has been ingested
        AND executed on device (its completion token is ready). After
        ``flush()`` queries reflect every prior ``submit``."""
        self._raise_pending()
        if not self._started:
            raise RuntimeError(
                "flush() on a never-started service would never return — "
                "call start() first (autostart=False is for staging tests)")
        with self._cond:
            ok = self._cond.wait_for(
                lambda: self._completed >= self._accepted
                or self._error is not None,
                timeout=timeout)
        if not ok:
            raise TimeoutError(f"flush timed out after {timeout}s")
        self._raise_pending()
        return self

    def drain_compact(self, timeout: float | None = None) \
            -> "PartitionService":
        """Explicit drain-then-reclaim: ``flush()`` (every admitted chunk
        ingested and executed), then densely re-pack the session to its
        smallest tier (``Partitioner.compact``) under the dispatch lock.
        The operational seam for planned idle windows — nightly lulls,
        pre-snapshot right-sizing — where the hysteresis-gated automatic
        paths are too shy. Queries keep answering in original ids
        afterwards (the id map absorbs any relabeling)."""
        self.flush(timeout)
        with self._lock:
            self._part.compact()
            self._drain_compacts += 1
        return self

    def drain_rebalance(self, timeout: float | None = None) -> dict:
        """Explicit drain-then-repair: ``flush()``, then one
        ``Partitioner.rebalance()`` under the dispatch lock — the
        operational seam for planned quality maintenance (the
        ``idle_rebalance_s`` path is its opportunistic counterpart).
        Returns the recorded rebalance event."""
        self.flush(timeout)
        with self._lock:
            return self._part.rebalance()

    def _ingest_loop(self) -> None:
        try:
            prev_token = None
            idle_since: float | None = None
            while True:
                try:
                    # no idle action configured ⇒ None blocks forever —
                    # the plain path
                    item = self._queue.get(timeout=self._idle_s)
                except queue.Empty:
                    # idle window: nothing arrived for _idle_s. Let the
                    # device finish the last batch, then run whichever
                    # idle actions' thresholds the accumulated silence
                    # has crossed, under the dispatch lock (queries wait
                    # out the repair, never race it)
                    now = time.perf_counter()
                    if idle_since is None:
                        # the get() above already waited one interval
                        idle_since = now - (self._idle_s or 0.0)
                    idle_for = now - idle_since
                    if prev_token is not None:
                        jax.block_until_ready(prev_token)
                    with self._lock:
                        if (self.idle_rebalance_s is not None
                                and idle_for >= self.idle_rebalance_s):
                            # once per ingest progress: an already-idle
                            # session is not re-rebalanced until new
                            # events arrive
                            cur = self._part.cursor
                            if cur != self._last_idle_rebalance_cursor:
                                self._part.rebalance()
                                self._last_idle_rebalance_cursor = cur
                                self._idle_rebalances += 1
                        if (self.idle_compact_s is not None
                                and idle_for >= self.idle_compact_s
                                and self._part.maybe_shrink()):
                            self._idle_shrinks += 1
                    continue
                idle_since = None
                if item is _STOP:
                    break
                # double buffering: coerce the first chunk while the
                # device still executes the previous batch (async
                # dispatch keeps running under this host work)...
                t0 = time.perf_counter()
                p = self._part.prepare(item[0])
                prepared, records = [p], [(item[1], p.num_events)]
                total, stopped = p.num_events, False
                self._coercion_s += time.perf_counter() - t0
                # ...then wait for that batch's completion token — the
                # slot-loop beat during which further arrivals pile up
                # in the queue...
                if prev_token is not None:
                    t0 = time.perf_counter()
                    jax.block_until_ready(prev_token)
                    self._device_wait_s += time.perf_counter() - t0
                # ...and only now drain them: everything that accumulated
                # while the device ran coalesces into ONE dispatch
                # (continuous batching, bounded by max_batch_events).
                # Draining before the wait would sample the queue at its
                # emptiest and defeat the coalescing.
                t0 = time.perf_counter()
                while self.max_batch_events is None \
                        or total < self.max_batch_events:
                    try:
                        nxt = self._queue.get_nowait()
                    except queue.Empty:
                        break
                    if nxt is _STOP:
                        stopped = True
                        break
                    p = self._part.prepare(nxt[0])
                    prepared.append(p)
                    records.append((nxt[1], p.num_events))
                    total += p.num_events
                batch = _merge_prepared(prepared)
                self._coercion_s += time.perf_counter() - t0
                with self._lock:
                    self._part.feed_prepared(batch)
                    # completion token: a DERIVED scalar (not a raw state
                    # leaf — the next feed donates the state's buffers,
                    # and blocking on a donated buffer raises). Dispatched
                    # under the lock, so it is ordered before any later
                    # donation of its input.
                    token = jnp.add(self._part.state.cut_edges, 0)
                self._inflight.put((token, time.perf_counter(), records))
                prev_token = token
                self._batches += 1
                if stopped:
                    break
        except BaseException as e:  # noqa: BLE001 — surfaced to callers
            self._error = e
            with self._cond:
                self._cond.notify_all()

    def _completion_loop(self) -> None:
        """Blocks on each batch's completion token in dispatch order,
        stamping completion times for the latency percentiles and the
        device-busy accounting. Runs off the ingest path so waiting for
        chunk *t* never delays coercion of chunk *t+1*."""
        try:
            last_done = None
            while True:
                item = self._inflight.get()
                if item is _STOP:
                    break
                token, dispatch_t, records = item
                jax.block_until_ready(token)
                now = time.perf_counter()
                busy_from = dispatch_t if last_done is None \
                    else max(dispatch_t, last_done)
                self._device_busy_s += max(now - busy_from, 0.0)
                last_done = now
                self._t_last_done = now
                with self._cond:
                    for arrival, n_ev in records:
                        self._latencies.append(now - arrival)
                        self._completed += 1
                        self._events_ingested_done += n_ev
                    self._cond.notify_all()
        except BaseException as e:  # noqa: BLE001 — surfaced to callers
            self._error = e
            with self._cond:
                self._cond.notify_all()

    # -- queries ------------------------------------------------------------

    def _snapshot_gather(self, build):
        """Dispatch ``build(state)`` against a consistent snapshot of the
        carried state (under the dispatch lock, so it is ordered after
        every dispatched feed and before the next one), then block only
        on the small result — ingest continues meanwhile."""
        self._raise_pending()
        with self._lock:
            out = build(self._part.state)
        return jax.tree_util.tree_map(np.asarray, out)

    def where(self, v: int) -> int:
        """Current partition label of vertex ``v`` (-1 = absent /
        unassigned / outside the session geometry). Reflects every
        dispatched batch and no partial one (see module docstring)."""
        return int(self.where_many([v])[0])

    def where_many(self, vs) -> np.ndarray:
        """Bulk lookup: one device gather for a batch of vertex ids —
        (V,) int32 labels, -1 for absent/out-of-range ids. Ids are the
        caller's ORIGINAL ids: a relabeling compaction (``compact()`` /
        idle shrink) moves vertices to new internal slots, and the
        lookup routes through the session's id map (under the same lock
        as the snapshot, so the map and the state it indexes are the
        same version)."""
        vs = np.atleast_1d(np.asarray(vs, np.int32))

        def build(state):
            # external -> internal inside the locked region: unknown /
            # never-fed ids come back -1 from the map and stay -1 here
            ids = jnp.asarray(self._part.to_internal(vs))
            n = state.assignment.shape[0]
            safe = jnp.clip(ids, 0, n - 1)
            lab = state.assignment[safe]
            return jnp.where((ids >= 0) & (ids < n), lab, -1)

        return self._snapshot_gather(build)

    def route(self, edges) -> RouteResult:
        """Partition routing for ``edges`` — an (E, 2) array (or pair of
        (E,) arrays) of vertex ids. Returns each endpoint's label and a
        ``cut`` mask marking edges whose endpoints live in different
        partitions (both assigned) — what a downstream sharded consumer
        needs to place an edge or send it cross-shard. One device
        gather; consistency as ``where``."""
        e = np.asarray(edges, np.int32)
        if e.ndim == 1 and e.shape[0] == 2:        # one (u, v) edge
            e = e[None, :]
        elif e.ndim == 2 and e.shape[1] != 2 and e.shape[0] == 2:
            e = e.T                                # (src_ids, dst_ids) pair
        if e.ndim != 2 or e.shape[1] != 2:
            raise ValueError(
                "route() takes an (E, 2) edge array, one (u, v) edge, or "
                f"a (src, dst) pair of (E,) arrays — got shape {e.shape}")
        labs = self.where_many(e.reshape(-1)).reshape(e.shape)
        src, dst = labs[:, 0], labs[:, 1]
        cut = (src != dst) & (src >= 0) & (dst >= 0)
        return RouteResult(src, dst, cut)

    # -- observation --------------------------------------------------------

    def metrics(self) -> dict:
        """Serving counters + the session's ``Partitioner.metrics()``.

        Keys added by the service: ``queue_depth`` / ``max_queue_depth``,
        ``chunks_submitted`` / ``chunks_dropped`` / ``chunks_ingested``,
        ``events_ingested`` (events in completed batches),
        ``batches_dispatched`` (post-coalescing), ``coercion_s`` (host
        prepare+merge time), ``device_wait_s`` (ingest thread blocked on
        the previous batch), ``submit_blocked_s`` (callers blocked on
        backpressure), ``device_busy_fraction`` (fraction of the serving
        wall with a batch executing), ``events_per_s`` (completed events
        over the serving wall), and ``feed_p50_ms`` / ``feed_p99_ms``
        (submit-arrival → batch-completion latency percentiles). A query
        point: blocks on in-flight state scalars, never stalls ingest."""
        self._raise_pending()
        with self._lock:
            part_m = self._part.metrics()
        with self._cond:
            lat = np.asarray(self._latencies, np.float64)
            done = self._events_ingested_done
            m = {
                "queue_depth": self._queue.qsize(),
                "max_queue_depth": self._max_depth,
                "chunks_submitted": self._accepted + self._dropped,
                "chunks_dropped": self._dropped,
                "chunks_ingested": self._completed,
                "events_ingested": done,
                "batches_dispatched": self._batches,
                "coercion_s": self._coercion_s,
                "device_wait_s": self._device_wait_s,
                "submit_blocked_s": self._submit_blocked_s,
                "backpressure_policy": self.policy,
                "max_pending_chunks": self.max_pending_chunks,
                "idle_compact_s": self.idle_compact_s,
                "idle_shrinks": self._idle_shrinks,
                "idle_rebalance_s": self.idle_rebalance_s,
                "idle_rebalances": self._idle_rebalances,
                "drain_compacts": self._drain_compacts,
            }
        wall = None
        if self._t_start is not None:
            end = self._t_last_done
            wall = max((end or time.perf_counter()) - self._t_start, 1e-9)
        m["wall_s"] = wall if wall is not None else 0.0
        m["events_per_s"] = (done / wall) if wall else 0.0
        m["device_busy_fraction"] = (
            min(self._device_busy_s / wall, 1.0) if wall else 0.0)
        m["feed_p50_ms"] = float(np.percentile(lat, 50) * 1e3) \
            if lat.size else None
        m["feed_p99_ms"] = float(np.percentile(lat, 99) * 1e3) \
            if lat.size else None
        m.update(part_m)
        return m

    def latencies(self) -> np.ndarray:
        """All completed chunks' arrival→completion latencies (seconds,
        submission order) — what the fig14 percentiles are computed
        from."""
        with self._cond:
            return np.asarray(self._latencies, np.float64)

    @property
    def partitioner(self) -> Partitioner:
        """The wrapped session (the service owns its feed path — query
        and snapshot it, do not feed it while the service is open)."""
        return self._part

    def __repr__(self) -> str:
        return (f"PartitionService(policy={self.policy!r}, "
                f"max_pending_chunks={self.max_pending_chunks}, "
                f"queued={self._queue.qsize()}, closed={self._closed})")
