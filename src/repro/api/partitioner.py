"""Stateful streaming session over the SDP engines — THE public surface.

The paper's headline is *real-time* dynamic partitioning, but the engine
entry points (``run_stream``/``run_stream_windowed``) are batch shaped:
whole stream in, final state out. ``Partitioner`` is the serving shape —
a long-lived session that owns a device-resident :class:`PartitionState`
and a global event cursor, and ingests events **as they arrive**:

    part = Partitioner.from_stream(stream, cfg, policy="sdp")
    for chunk in arriving_chunks:
        part.feed(chunk)            # any number of events per call
        print(part.metrics())       # observable mid-stream
    part.snapshot("ckpts/session")  # resumable later via .restore()

Guarantees:

* **Bit-identity under any chopping.** ``feed()`` RNG-aligns every event
  via the engines' existing ``t0`` plumbing (``fold_in(key, global_index)``),
  so feeding in chunks of 1, 7, or anything else produces exactly the
  state one whole-stream ``run_stream`` produces — enforced by
  tests/test_api_partitioner.py.
* **Donated carry.** The session's state is donated to each feed call's
  jitted kernel, so XLA reuses the O(n·max_deg) adjacency buffers
  between calls instead of copying them. Corollary: a reference you took
  from ``part.state`` is invalidated by the *next* ``feed()`` — copy
  (``np.asarray``) anything you want to keep, or use ``snapshot()``.
* **Auto engine selection.** Per call, full windows of ``window`` events
  ride the batched mixed-window kernel (``run_window_mixed``, or the
  small-carry ``run_window_adds`` for pure-ADD windows) and small tails
  ride the faithful per-event scan; both are bit-identical, so the
  choice is pure throughput. ``engine="scan"``/``"windowed"`` pin one
  backend (``collect_trace=True`` implies the scan, the only backend
  that produces per-event traces).
* **Resumability.** ``snapshot()``/``Partitioner.restore()`` wrap
  ``repro.checkpoint`` (atomic renames, retention); checkpoints record
  their geometry in metadata, and checkpoints that predate
  ``PartitionState.cut_matrix`` restore via ``fill_missing`` and are
  healed with ``recount_cut_matrix``.
* **Elastic geometry — both directions.** The session's ``(n, max_deg)``
  allocation is a starting point, not a contract: ``feed()`` grows the
  state (``repro.core.state.grow_state``) along power-of-two tiers
  whenever an event references a vertex id or neighbour-row width beyond
  the current geometry — a semantics no-op, so a session started tiny
  and grown on demand stays bit-identical to one presized at the final
  geometry (see repro.core.geometry; LDG is the one knob-level
  exception). Each tier change re-jits the kernels (donation keeps
  reusing buffers within a tier); ``grow_to()`` pre-sizes explicitly to
  pay one re-jit instead of log-many. Sessions also shrink:
  ``compact()`` densely re-packs the live vertices to the smallest tier,
  ``shrink_to()`` targets an exact geometry, and ``maybe_shrink()`` (or
  ``auto_shrink=True``) applies the hysteretic ``shrink_tier`` policy so
  a session that bulk-deleted most of its graph stops paying peak-tier
  memory and compute. Every change is recorded in ``geometry_events``.

External vs internal vertex ids
-------------------------------
A compaction may *relabel* vertices (dense re-pack via a permutation).
The session hides that: callers keep using the original ("external") ids
in events and queries, and the session maintains the external→internal
map (persisted by ``snapshot()``/``restore()``), exposed as
``to_internal``/``to_external``. Until the first relabeling compaction
the map is the identity and costs nothing — pure truncation shrinks
(``shrink_state``) preserve ids and never create a map. Relabeling is a
semantics no-op for every policy except ``hash`` (which assigns by raw
vertex id — relabel-compaction refuses it) and LDG's allocated-``n``
capacity knob (the PR-5 caveat, which any geometry change already
carries).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.core import engine as eng
from repro.core import windowed as wnd
from repro.core.config import EngineConfig, POLICIES
from repro.core.geometry import (
    Geometry, geometry_of, grow_tier, next_pow2, shrink_tier,
)
from repro.core.state import (
    PartitionState, compact_state, grow_state, init_state, live_extent,
    recount_cut_matrix, shrink_state, state_bytes, state_metrics,
)
from repro.core.sharded_state import (
    gather_state, pad_rows, per_device_state_bytes, shard_state,
    unshard_state,
)
from repro.core.transition import EventTrace
from repro.core.metrics import load_imbalance, normalized_load_imbalance
from repro.graph.stream import (
    EVENT_ADD, EVENT_PAD, VertexStream, normalize_rows, required_geometry_of,
)
from repro.rebalance import rebalance_jit

_ENGINES = ("auto", "scan", "windowed")

# Donated re-jits of the engine kernels: the session immediately rebinds
# its carried state to each call's result, so donation lets XLA reuse the
# (n, max_deg) adjacency (and (k_max, k_max) cut_matrix) buffers between
# feed() calls instead of copying them per call.
_scan_donated = jax.jit(
    eng._run_events, static_argnames=("policy", "cfg"), donate_argnums=(0,))
_adds_donated = jax.jit(
    wnd._run_window_adds, static_argnames=("policy", "cfg", "score_fn"),
    donate_argnums=(0,))
_mixed_donated = jax.jit(
    wnd._run_window_mixed, static_argnames=("policy", "cfg"),
    donate_argnums=(0,))


def _mixed_fused_donated():
    """Donated re-jit of the fused Pallas mixed-window kernel — imported
    lazily so sessions that never set ``use_kernel=True`` do not import
    the kernels layer at all."""
    from repro.kernels.fused_chooser.ops import _run_window_mixed_fused
    return jax.jit(
        _run_window_mixed_fused,
        static_argnames=("policy", "cfg", "interpret", "variant"),
        donate_argnums=(0,))

def _resolve_vertices_mesh(devices):
    """Constructor/``reshard`` device selection: None = every local
    device, int = the first N, sequence = exactly those."""
    from repro.launch.mesh import make_vertices_mesh
    if devices is None:
        return make_vertices_mesh()
    if isinstance(devices, int):
        return make_vertices_mesh(devices)
    return make_vertices_mesh(devices=list(devices))


_TRACE_DTYPES = (jnp.int32, jnp.int32, jnp.int32, jnp.float32)


class PreparedChunk(NamedTuple):
    """The host-side half of a ``feed()``: validated, dtype-coerced event
    arrays plus their ingestion requirement. Produced by
    ``Partitioner.prepare`` — which touches no session state, so a
    serving thread may prepare chunk *t+1* while the device executes
    chunk *t* (repro.api.serve) — and consumed by ``feed_prepared``.
    Chunks over the same session concatenate associatively: feeding two
    merged chunks is bit-identical to feeding them back to back."""

    etype: np.ndarray    # (T,) int32 event codes
    vertex: np.ndarray   # (T,) int32 subject vertices
    nbrs: np.ndarray     # (T, width) int32 neighbour rows, -1 padded
    required: Geometry   # minimal geometry able to ingest these events

    @property
    def num_events(self) -> int:
        return int(self.etype.shape[0])


class Partitioner:
    """A stateful streaming partitioning session (see module docstring).

    Args:
      cfg: engine knobs (validated in ``EngineConfig.__post_init__``).
      n: starting vertex-universe size. Optional — the session grows its
        geometry on demand (tier-doubling, see module docstring), so a
        serving session whose stream size nobody knows can start with no
        pre-sizing at all; declare it (or use ``from_stream`` /
        ``grow_to``) to avoid the growth re-jits when the size IS known.
      max_deg: starting neighbour-row width of the padded adjacency
        (optional, grows like ``n``).
      policy: one of ``repro.core.config.POLICIES``.
      seed: PRNG seed for tie-breaking (folds with the global event index).
      engine: ``"auto"`` (default — windows when a call has them, scan for
        the tails), ``"scan"``, or ``"windowed"`` (tails are padded into a
        full window of no-op events).
      window: events per device step for the windowed backend.
      collect_trace: record the per-event :class:`EventTrace`; forces the
        scan backend (the window kernels return no trace).
      use_kernel: route full windows through the Pallas kernels —
        pure-ADD windows score with ``partition_affinity``, mixed windows
        run the whole slot loop in the fused chooser
        (``repro.kernels.fused_chooser``); both bit-identical to the XLA
        paths, interpret mode resolved per backend at one site
        (``repro.kernels.common.default_interpret``). Coverage is NOT
        total: the per-event scan backend — ``engine="scan"``,
        ``collect_trace``, and ``engine="auto"``'s small tails — always
        runs pure XLA (it is the faithful reference the kernels are
        verified against). ``metrics()`` reports the split as
        ``kernel_windows`` vs ``fallback_windows`` so a session can tell
        how much of its stream actually rode the kernels.
      auto_shrink: run the hysteretic ``maybe_shrink()`` check every
        ``shrink_every`` ingested events, so a long-lived session whose
        graph bulk-deleted drops back down the tiers without anyone
        calling ``compact()``. Off by default — serving tiers usually
        prefer the idle-window drain-compact (repro.api.serve).
      shrink_every: event spacing of the ``auto_shrink`` checks (the
        check itself syncs the device, so it is not free).
      auto_rebalance: run ``rebalance()`` every ``rebalance_every``
        ingested events (checked at feed boundaries, *before* the
        auto-shrink check), so a session on a drifting stream repairs
        its cut and balance without anyone calling ``rebalance()``.
        Off by default; see ``repro.rebalance`` for the passes.
      rebalance_every: event spacing of the ``auto_rebalance`` checks
        (the pass itself syncs the device, so it is not free).
      rebalance_m: default migration budget per ``rebalance()`` — the
        top-m worst-gain boundary vertices are moved greedily.
      rebalance_passes: default LPA refinement iterations per
        ``rebalance()`` (0 = greedy migration only).
      rebalance_slack: Eq. 10 capacity slack — no rebalance move may
        push a destination beyond mean active load × (1 + slack).
      rebalance_drift: adaptive rebalance cadence — after each feed,
        fire ``rebalance()`` when the observed cut ratio OR the load
        imbalance has drifted more than this much above its value at the
        last pass (both read from counters the engines already maintain;
        no extra device work). Independent of ``auto_rebalance``'s fixed
        event spacing; the two compose (fixed cadence is checked first).
        The drift baseline re-bases after every executed pass and rides
        checkpoint ``extras``.
      sharded: shard THIS session's vertex axis across the device mesh
        (repro.runtime.shard_session): adjacency rows, label journal and
        presence live as per-device row blocks on a "vertices" mesh,
        K-sized loads and the cut matrix stay replicated and are
        psum-combined once per window. Bit-identical to a dense session
        for any device count. Implies the windowed backend for every
        slice (tails are padded into no-op slots); incompatible with
        ``use_kernel``, ``collect_trace`` and ``engine="scan"``.
      shard_devices: device selection for ``sharded=True`` — an int
        (first N local devices), an explicit device sequence, or None
        for every local device.
    """

    def __init__(self, cfg: EngineConfig | None = None, *,
                 n: int | None = None, max_deg: int | None = None,
                 policy: str = "sdp", seed: int = 0,
                 engine: str = "auto", window: int = 256,
                 collect_trace: bool = False, use_kernel: bool = False,
                 auto_shrink: bool = False, shrink_every: int = 4096,
                 auto_rebalance: bool = False, rebalance_every: int = 2048,
                 rebalance_m: int = 32, rebalance_passes: int = 0,
                 rebalance_slack: float = 0.25,
                 rebalance_drift: float | None = None,
                 sharded: bool = False, shard_devices=None):
        cfg = cfg or EngineConfig()
        if policy not in POLICIES:
            raise ValueError(
                f"policy={policy!r} is unknown: expected one of {POLICIES}")
        if engine not in _ENGINES:
            raise ValueError(
                f"engine={engine!r} is unknown: expected one of {_ENGINES} "
                "('auto' picks windows for full windows and the per-event "
                "scan for small tails)")
        if window <= 0:
            raise ValueError(
                f"window={window} must be > 0: it is the number of events "
                "the windowed backend batches per device step")
        if (n is not None and n <= 0) or (max_deg is not None
                                          and max_deg <= 0):
            raise ValueError(
                f"n={n} and max_deg={max_deg} must be > 0 (or omitted to "
                "grow on demand): they size the dense (n, max_deg) "
                "adjacency")
        if collect_trace and engine == "windowed":
            raise ValueError(
                "collect_trace=True needs the per-event scan (the window "
                "kernels do not produce traces) — use engine='scan' or "
                "'auto'")
        if sharded:
            if use_kernel:
                raise ValueError(
                    "sharded=True routes windows through the shard_map'd "
                    "window step, which runs the chooser oracle replicated "
                    "— it cannot also run the Pallas fused kernel; drop "
                    "use_kernel")
            if collect_trace or engine == "scan":
                raise ValueError(
                    "sharded=True processes every slice as (padded) "
                    "windows on the vertices mesh — the per-event scan "
                    "backend (engine='scan' / collect_trace=True) has no "
                    "sharded counterpart")
        self.cfg = cfg
        self.policy = policy
        self.engine = engine
        self.window = int(window)
        self.collect_trace = bool(collect_trace)
        self.use_kernel = bool(use_kernel)
        if use_kernel:
            from repro.kernels.partition_affinity.ops import scores_for_state
            self._score_fn = scores_for_state
            self._mixed_fn = _mixed_fused_donated()
        else:
            self._score_fn = None
            self._mixed_fn = _mixed_donated
        if shrink_every <= 0:
            raise ValueError(
                f"shrink_every={shrink_every} must be > 0: it is the "
                "event spacing of the auto_shrink checks")
        self.auto_shrink = bool(auto_shrink)
        self.shrink_every = int(shrink_every)
        if rebalance_every <= 0:
            raise ValueError(
                f"rebalance_every={rebalance_every} must be > 0: it is "
                "the event spacing of the auto_rebalance checks")
        if rebalance_m < 0 or rebalance_passes < 0 or rebalance_slack < 0:
            raise ValueError(
                f"rebalance_m={rebalance_m}, rebalance_passes="
                f"{rebalance_passes} and rebalance_slack={rebalance_slack} "
                "must all be >= 0 (m is a move budget, passes an iteration "
                "count, slack a capacity fraction)")
        if auto_rebalance and rebalance_m == 0 and rebalance_passes == 0:
            raise ValueError(
                "auto_rebalance=True with rebalance_m=0 and "
                "rebalance_passes=0 would run empty passes forever — give "
                "it a migration budget (rebalance_m) and/or LPA "
                "iterations (rebalance_passes)")
        self.auto_rebalance = bool(auto_rebalance)
        self.rebalance_every = int(rebalance_every)
        self.rebalance_m = int(rebalance_m)
        self.rebalance_passes = int(rebalance_passes)
        self.rebalance_slack = float(rebalance_slack)
        if rebalance_drift is not None:
            if rebalance_drift <= 0:
                raise ValueError(
                    f"rebalance_drift={rebalance_drift} must be > 0: it "
                    "is the cut-ratio / imbalance increase (since the "
                    "last pass) that triggers an adaptive rebalance")
            if rebalance_m == 0 and rebalance_passes == 0:
                raise ValueError(
                    "rebalance_drift with rebalance_m=0 and "
                    "rebalance_passes=0 would fire empty passes — give "
                    "it a migration budget and/or LPA iterations")
        self.rebalance_drift = (None if rebalance_drift is None
                                else float(rebalance_drift))
        self._drift_base: tuple[float, float] | None = None
        self._drift_fires = 0
        self._last_rebalance = 0
        self._rebalances = 0
        self._rebalance_moves = 0
        self._rebalance_events: list[dict] = []
        self._kernel_windows = 0
        self._fallback_windows = 0
        self._sharded = bool(sharded)
        self._mesh = None
        self._state = init_state(int(n or 1), int(max_deg or 1), cfg.k_max,
                                 cfg.k_init, seed)
        if self._sharded:
            self._mesh = _resolve_vertices_mesh(shard_devices)
            # semantic geometry: the tier a dense session would sit at —
            # what the knobs (LDG capacity) and checkpoints see; the
            # physical row count is padded to a multiple of the mesh
            self._sem_geom = geometry_of(self._state)
            self._state = shard_state(self._state, self._mesh)
        self._regeometries = 0
        self._shrinks = 0
        self._compactions = 0
        self._last_shrink_check = 0
        self._cursor = 0
        # external→internal vertex-id map (None = identity: no relabeling
        # compaction has happened) and its dense inverse — see the module
        # docstring's "External vs internal vertex ids"
        self._ext2int: np.ndarray | None = None
        self._int2ext: np.ndarray | None = None
        self._geometry_events: list[dict] = []
        self._traces: list[EventTrace] = []
        self._managers: dict[str, CheckpointManager] = {}

    @classmethod
    def from_stream(cls, stream: VertexStream,
                    cfg: EngineConfig | None = None, **kw) -> "Partitioner":
        """Size a session for ``stream``'s vertex universe and degree cap
        — its declared geometry unioned with ``required_geometry()``, the
        same definition the feed-time auto-grow check uses (the stream
        itself is NOT ingested — call ``feed``)."""
        geom = Geometry(stream.n, stream.max_deg).union(
            stream.required_geometry())
        return cls(cfg, n=geom.n, max_deg=geom.max_deg, **kw)

    # -- properties ---------------------------------------------------------

    @property
    def state(self) -> PartitionState:
        """The live device-resident state. Invalidated (donated) by the
        next ``feed()`` — copy what you want to keep."""
        return self._state

    @property
    def n(self) -> int:
        """Current vertex-universe allocation (grows on demand, shrinks
        via ``compact``/``shrink_to``/``maybe_shrink``). Internal slots —
        after a relabeling compaction this is smaller than the external
        id space (see ``to_internal``)."""
        return int(self._state.assignment.shape[0])

    @property
    def max_deg(self) -> int:
        """Current neighbour-row width (grows on demand, shrinks via
        ``compact``/``shrink_to``/``maybe_shrink``)."""
        return int(self._state.adj.shape[1])

    @property
    def geometry(self) -> Geometry:
        """The session's current :class:`Geometry` (n, max_deg, k_max)."""
        return geometry_of(self._state)

    @property
    def regeometries(self) -> int:
        """How many times the state geometry changed (grow, shrink or
        tier-changing compact) — each one re-jits the engine kernels for
        the new tier."""
        return self._regeometries

    @property
    def geometry_events(self) -> list[dict]:
        """The session's geometry lifecycle trace: one
        ``{"cursor", "kind", "from", "to"}`` dict per change, ``kind`` in
        ``{"grow", "shrink", "compact", "restore"}`` and ``from``/``to``
        the :class:`Geometry` before/after. ``compact`` entries are
        same-tier re-packs; tier-dropping re-packs record ``shrink``."""
        return list(self._geometry_events)

    @property
    def rebalance_events(self) -> list[dict]:
        """The session's rebalance lifecycle trace (mirrors
        ``geometry_events``): one ``{"cursor", "m", "passes", "moved",
        "cut_before", "cut_after", "imbalance_before",
        "imbalance_after"}`` dict per executed ``rebalance()``."""
        return list(self._rebalance_events)

    @property
    def cursor(self) -> int:
        """Global index of the next event (== events ingested so far)."""
        return self._cursor

    def __repr__(self) -> str:
        return (f"Partitioner(policy={self.policy!r}, engine={self.engine!r},"
                f" n={self.n}, max_deg={self.max_deg}, events={self._cursor},"
                f" partitions={int(self._state.num_partitions)})")

    # -- geometry -----------------------------------------------------------

    def _record_geometry(self, kind: str, before: Geometry,
                         after: Geometry) -> None:
        self._geometry_events.append(
            {"cursor": self._cursor, "kind": kind,
             "from": before, "to": after})

    def grow_to(self, n: int | None = None,
                max_deg: int | None = None) -> "Partitioner":
        """Explicitly pre-size the session geometry (exact — no tier
        rounding: the caller knows the size). Grows the state to cover
        ``(n, max_deg)``; dimensions already covered are untouched, and
        shrinking is never performed (that is ``shrink_to``). Use before
        a large ``feed`` to pay one re-jit instead of log-many tier
        doublings."""
        cur = self._sem_geometry()
        target = cur.union(Geometry(int(n or 1), int(max_deg or 1)))
        if target != cur:
            self._grow(cur, target)
        return self

    def _sem_geometry(self) -> Geometry:
        """The session's *semantic* geometry: what a dense session would
        allocate. For a sharded session the physical row count is this,
        padded up to a multiple of the mesh; the semantic n is what the
        knobs (LDG capacity) and checkpoint metadata see, so sharded and
        dense sessions stay bit-identical and round-trip."""
        return self._sem_geom if self._sharded else geometry_of(self._state)

    def _grow(self, cur: Geometry, target: Geometry) -> None:
        if self._sharded:
            phys = Geometry(pad_rows(target.n, self._mesh.shape["vertices"]),
                            target.max_deg, target.k_max)
            self._state = shard_state(
                grow_state(unshard_state(self._state), phys), self._mesh)
            self._sem_geom = target
        else:
            self._state = grow_state(self._state, target)
        self._regeometries += 1
        self._record_geometry("grow", cur, target)

    def _ensure_geometry(self, required: Geometry) -> None:
        """Grow the state along power-of-two tiers until it covers
        ``required`` (no-op when it already does) — the feed-time
        auto-grow. Growth is a semantics no-op (repro.core.geometry), so
        donation simply resumes at the new tier after one re-jit. The
        tier trigger compares the SEMANTIC geometry, so a sharded
        session grows at exactly the cursors its dense twin would."""
        cur = self._sem_geometry()
        if not cur.covers(required):
            self._grow(cur, grow_tier(cur, required))

    def _repack_to(self, target: Geometry, kind: str) -> None:
        """Move the (synced) state to ``target``, preferring the
        id-preserving truncation (``shrink_state`` — no permutation, no
        translation overhead afterwards) and falling back to the
        relabeling dense re-pack (``compact_state``) when live content
        sits above ``target.n``. Updates the id maps and the lifecycle
        trace; callers guarantee ``target`` covers the packed extent."""
        cur = geometry_of(self._state)
        if target == cur or (self._sharded and target == self._sem_geom):
            return
        _, prefix = live_extent(self._state)
        if prefix.n <= target.n and prefix.max_deg <= target.max_deg:
            self._state = shrink_state(self._state, target)
        else:
            if self.policy == "hash":
                raise ValueError(
                    "the 'hash' policy assigns by raw vertex id, so a "
                    "relabeling compaction would change every future "
                    "decision — only id-preserving shrinks are legal "
                    "(shrink_to a geometry the current slot ids fit, or "
                    "accept the current tier)")
            self._state, perm = compact_state(self._state, target)
            self._apply_perm(perm)
        if self._sharded:
            # repacks land dense at the semantic target — pad rows back
            # to a mesh multiple and re-place on the vertices mesh
            self._sem_geom = target
            self._state = shard_state(self._state, self._mesh)
        self._regeometries += 1
        if kind == "shrink":
            self._shrinks += 1
        self._record_geometry(kind, cur, target)

    def _apply_perm(self, perm: np.ndarray) -> None:
        """Fold a relabeling permutation (old slot → new slot, -1 =
        dropped) into the external→internal id maps. First relabel:
        external ids ARE the old slots, so the map starts as ``perm``
        itself."""
        n_old = len(perm)
        keep_idx = np.flatnonzero(perm >= 0).astype(np.int32)
        if len(keep_idx) == n_old:
            return  # nothing moved or dropped — still the identity
        if self._ext2int is None:
            self._ext2int = perm.astype(np.int32).copy()
            self._int2ext = keep_idx
        else:
            self._int2ext = self._int2ext[keep_idx]
            m = self._ext2int
            valid = m >= 0
            m[valid] = perm[m[valid]]
            self._ext2int = m

    def compact(self) -> "Partitioner":
        """Densely re-pack the live vertices and drop to the smallest
        power-of-two tier that holds them — the explicit "reclaim now"
        seam (no hysteresis: the caller has decided). Prefers the
        id-preserving truncation; otherwise relabels and maintains the
        external-id map so ``feed``/``where``/``route`` keep speaking
        original ids (see the module docstring). A semantics no-op
        modulo that relabeling; counters are untouched. Syncs (it must
        read the live content). Returns ``self``."""
        self.sync()
        cur = geometry_of(self._state)
        packed, _ = live_extent(self._state)
        target = Geometry(min(next_pow2(packed.n), cur.n),
                          min(next_pow2(packed.max_deg), cur.max_deg),
                          cur.k_max)
        self._compactions += 1
        self._repack_to(target, "shrink" if (target.n < cur.n
                        or target.max_deg < cur.max_deg) else "compact")
        return self

    def shrink_to(self, n: int | None = None,
                  max_deg: int | None = None) -> "Partitioner":
        """Shrink the session geometry to exactly ``(n, max_deg)``
        (omitted dimensions keep their current size) — the precise
        counterpart of ``grow_to``. Truncates when the live slot ids
        already fit, otherwise densely re-packs (relabeling, see
        ``compact``). Raises if the live content cannot fit the target
        even packed, or if a dimension would grow (use ``grow_to``)."""
        self.sync()
        cur = geometry_of(self._state)
        target = Geometry(int(n if n is not None else cur.n),
                          int(max_deg if max_deg is not None
                              else cur.max_deg), cur.k_max)
        if target.n > cur.n or target.max_deg > cur.max_deg:
            raise ValueError(
                f"shrink_to target (n={target.n}, max_deg={target.max_deg})"
                f" exceeds the current geometry (n={cur.n}, "
                f"max_deg={cur.max_deg}) — growing is grow_to's job")
        packed, _ = live_extent(self._state)
        if not Geometry(target.n, target.max_deg).covers(
                Geometry(packed.n, packed.max_deg)):
            raise ValueError(
                f"live content needs (n={packed.n}, "
                f"max_deg={packed.max_deg}) even densely packed — "
                f"(n={target.n}, max_deg={target.max_deg}) cannot hold "
                "this session")
        self._repack_to(target, "shrink")
        return self

    def maybe_shrink(self, *, hysteresis: int = 4) -> bool:
        """The auto-shrink check: apply ``repro.core.geometry.shrink_tier``
        — shrink only when live content occupies at most
        ``1/(2*hysteresis)`` of a dimension, landing at most half-full —
        and re-pack if any dimension qualifies. Returns True iff the
        geometry changed. Cheap when there is nothing to do: a one-scalar
        device read gates the O(n·max_deg) host scan. This is what
        ``auto_shrink=True`` runs every ``shrink_every`` events, and what
        the serving tier runs in idle windows (repro.api.serve)."""
        cur = geometry_of(self._state)
        # gate on the present-count alone (an underestimate of the packed
        # extent, so it can only produce false positives for the scan
        # below, never a missed shrink of n; a max_deg-only shrink is
        # deliberately not gated in — it rides along when n qualifies or
        # when compact() is called explicitly)
        n_present = int(jnp.sum(self._state.present))
        if (n_present + 1) * 2 * hysteresis > cur.n:
            return False
        self.sync()
        packed, _ = live_extent(self._state)
        target = shrink_tier(cur, packed, hysteresis=hysteresis)
        if target == cur:
            return False
        self._repack_to(target, "shrink")
        return True

    def place(self, device) -> "Partitioner":
        """Move the session state onto ``device`` (a ``jax.Device``) via
        a host round-trip — the single-session re-mesh path: after a
        (simulated) device loss, a recovered or surviving session
        continues on the replacement device bit-identically (placement
        is not semantics). Syncs. Returns ``self``."""
        if self._sharded:
            raise ValueError(
                "this session is vertex-sharded across a device mesh — "
                "single-device place() does not apply; use "
                "reshard(devices=...) to move it onto a different mesh")
        self.sync()
        host = jax.tree_util.tree_map(np.asarray, self._state)
        self._state = jax.tree_util.tree_map(
            lambda a: jax.device_put(a, device), host)
        return self

    def reshard(self, devices=None) -> "Partitioner":
        """Re-shard a ``sharded=True`` session onto a different vertices
        mesh (``devices``: int, device sequence, or None for every local
        device) — the sharded re-mesh path after a device-count change:
        gather to the canonical dense layout, rebuild the mesh, re-pad
        the rows to the new shard count, and re-place. Placement is not
        semantics, so the session continues bit-identically. Syncs.
        Returns ``self``."""
        if not self._sharded:
            raise ValueError(
                "reshard() applies to sharded=True sessions only — a "
                "dense session moves with place(device)")
        self.sync()
        dense = unshard_state(self._state, n=self._sem_geom.n)
        self._mesh = _resolve_vertices_mesh(devices)
        self._state = shard_state(dense, self._mesh)
        return self

    # -- external ids -------------------------------------------------------

    def to_internal(self, ids) -> np.ndarray:
        """Map external (caller-facing, original) vertex ids to the
        session's internal slot ids — the identity until a relabeling
        compaction happens. Unknown or negative ids map to -1. Queries
        against ``state.assignment`` must go through this (the serving
        tier does: repro.api.serve.where_many)."""
        ids = np.atleast_1d(np.asarray(ids, np.int64))
        if self._ext2int is None:
            return ids.astype(np.int32)
        m = self._ext2int
        out = np.full(ids.shape, -1, np.int32)
        ok = (ids >= 0) & (ids < len(m))
        out[ok] = m[ids[ok]]
        return out

    def to_external(self, ids) -> np.ndarray:
        """Inverse of ``to_internal``: internal slot ids back to the
        external ids callers speak (identity until a relabeling
        compaction). Out-of-range slots map to -1."""
        ids = np.atleast_1d(np.asarray(ids, np.int64))
        if self._int2ext is None:
            return ids.astype(np.int32)
        m = self._int2ext
        out = np.full(ids.shape, -1, np.int32)
        ok = (ids >= 0) & (ids < len(m))
        out[ok] = m[ids[ok]]
        return out

    def _translate(self, chunk: PreparedChunk) -> PreparedChunk:
        """Rewrite a prepared chunk's external ids to internal slots,
        allocating fresh slots (in first-appearance order — the property
        that makes a journal replay allocate identically) for ids never
        seen since the last relabeling. No-op while the map is the
        identity."""
        if self._ext2int is None:
            return chunk
        vx, nb = chunk.vertex, chunk.nbrs
        # event-order first-appearance sequence: vertex before its row
        seq = np.concatenate([vx[:, None], nb], axis=1).ravel()
        seq = seq[seq >= 0].astype(np.int64)
        m = self._ext2int
        if seq.size:
            mx = int(seq.max())
            if mx >= len(m):
                m = np.concatenate(
                    [m, np.full(mx + 1 - len(m), -1, np.int32)])
            unmapped = seq[m[seq] < 0]
            if unmapped.size:
                uniq, first = np.unique(unmapped, return_index=True)
                order = uniq[np.argsort(first)].astype(np.int32)
                base = len(self._int2ext)
                m[order] = np.arange(base, base + len(order),
                                     dtype=np.int32)
                self._int2ext = np.concatenate([self._int2ext, order])
            self._ext2int = m
        vx_t = np.where(vx >= 0, m[np.clip(vx, 0, None)], -1).astype(np.int32)
        nb_t = np.where(nb >= 0, m[np.clip(nb, 0, None)], -1).astype(np.int32)
        return PreparedChunk(chunk.etype, vx_t, nb_t,
                             required_geometry_of(vx_t, nb_t))

    # -- ingestion ----------------------------------------------------------

    def feed(self, events) -> "Partitioner":
        """Ingest any number of events; returns ``self`` for chaining.

        ``events`` is a :class:`VertexStream` (over the same vertex
        universe) or an ``(etype, vertex, nbrs)`` triple of arrays.
        Bit-identical to one whole-stream run regardless of how the
        stream is chopped across calls. Equivalent to
        ``feed_prepared(prepare(events))``; dispatch is asynchronous
        (JAX async dispatch) — call ``sync()`` to block on completion.
        """
        return self.feed_prepared(self.prepare(events))

    def prepare(self, events) -> PreparedChunk:
        """Host-only coercion: validate ``events`` (a
        :class:`VertexStream` or ``(etype, vertex, nbrs)`` triple),
        coerce dtypes, and compute the required ingestion geometry —
        WITHOUT touching session state. The expensive O(T·max_deg) host
        work of a ``feed`` lives here, so a serving loop
        (repro.api.serve) can run it on chunk *t+1* while the device
        executes chunk *t*. Thread-safe with respect to the session."""
        if isinstance(events, VertexStream):
            et = np.asarray(events.etype, np.int32)
            vx = np.asarray(events.vertex, np.int32)
            nb = np.asarray(events.nbrs, np.int32)
            required = events.required_geometry()
        else:
            try:
                et, vx, nb = events
            except (TypeError, ValueError):
                raise TypeError(
                    "feed() takes a VertexStream or an (etype, vertex, "
                    f"nbrs) triple, got {type(events).__name__}") from None
            et = np.atleast_1d(np.asarray(et, np.int32))
            vx = np.atleast_1d(np.asarray(vx, np.int32))
            nb = np.asarray(nb, np.int32)
            if nb.ndim != 2 or et.shape != vx.shape \
                    or nb.shape[0] != et.shape[0]:
                raise ValueError(
                    f"event triple shapes disagree: etype{et.shape}, "
                    f"vertex{vx.shape}, nbrs{nb.shape} — want (T,), (T,), "
                    "(T, max_deg)")
            required = required_geometry_of(vx, nb)
        return PreparedChunk(et, vx, nb, required)

    def feed_prepared(self, chunk: PreparedChunk) -> "Partitioner":
        """Ingest a :class:`PreparedChunk` (see ``prepare``): grow the
        geometry if the chunk requires it, re-width the neighbour rows
        to the session, and dispatch the engine kernels. Dispatch is
        asynchronous — the call returns once the work is enqueued, and
        the carried state is a future until ``sync()`` (or any host
        read) blocks on it."""
        # external ids → internal slots (identity until a relabeling
        # compaction; allocates slots for first-seen ids)
        chunk = self._translate(chunk)
        # elastic: events beyond the current geometry grow the state
        # (tier-doubled) instead of raising — the session's shapes are a
        # starting point, not a contract
        self._ensure_geometry(chunk.required)
        et, vx = chunk.etype, chunk.vertex
        nb = normalize_rows(chunk.nbrs, self.max_deg)
        T = chunk.num_events
        if T == 0:
            return self
        use_scan = self.collect_trace or self.engine == "scan"
        t = 0
        while t < T:
            if use_scan:
                end = T
                self._feed_scan(et[t:], vx[t:], nb[t:])
            else:
                end = min(t + self.window, T)
                if end - t < self.window and self.engine == "auto" \
                        and not self._sharded:
                    # small/mixed tail: the per-event scan beats padding a
                    # nearly-empty window through the batched kernel
                    end = T
                    self._feed_scan(et[t:], vx[t:], nb[t:])
                else:
                    self._feed_window(et[t:end], vx[t:end], nb[t:end])
            # advance per processed slice, not per call: if a later slice
            # dies (interrupt, OOM) the cursor still matches the mutated
            # state, so re-feeding the unprocessed remainder resumes
            # exactly instead of double-applying the finished slices
            self._cursor += end - t
            t = end
        # rebalance before the shrink check: migration changes loads and
        # therefore what maybe_shrink sees — the order is part of the
        # replay contract (both cadence marks ride checkpoint extras)
        if self.auto_rebalance and (self._cursor - self._last_rebalance
                                    >= self.rebalance_every):
            self._last_rebalance = self._cursor
            self.rebalance()
        if self.rebalance_drift is not None:
            self._check_drift()
        if self.auto_shrink and (self._cursor - self._last_shrink_check
                                 >= self.shrink_every):
            self._last_shrink_check = self._cursor
            self.maybe_shrink()
        return self

    def _feed_scan(self, et, vx, nb):
        # the scan backend is outside the kernel surface (it is the
        # faithful reference) — count it as fallback coverage
        self._fallback_windows += 1
        self._state, tr = _scan_donated(
            self._state, jnp.asarray(et), jnp.asarray(vx), jnp.asarray(nb),
            jnp.int32(self._cursor), policy=self.policy, cfg=self.cfg)
        if self.collect_trace:
            self._traces.append(tr)

    def _feed_window(self, et, vx, nb):
        """One (possibly right-padded) window through the batched kernels.
        Pad slots are no-ops that still occupy RNG indices past the true
        events — the cursor advances by the true count only, so the next
        call's fold_in indices line up with an unchopped run."""
        if self._sharded:
            from repro.runtime.shard_session import sharded_stream_fn
            self._fallback_windows += 1
            w = self.window
            fn = sharded_stream_fn(
                self._mesh, n_sem=self._sem_geom.n, policy=self.policy,
                cfg=self.cfg, window=w, n_events=w)
            self._state = fn(
                self._state, wnd._pad_to(jnp.asarray(et), w, EVENT_PAD),
                wnd._pad_to(jnp.asarray(vx), w, -1),
                wnd._pad_to(jnp.asarray(nb), w, -1),
                jnp.int32(self._cursor))
            return
        if self.use_kernel:
            self._kernel_windows += 1
        else:
            self._fallback_windows += 1
        w = self.window
        vs_w = wnd._pad_to(vx, w, -1)
        rows_w = wnd._pad_to(nb, w, -1)
        t0 = jnp.int32(self._cursor)
        if np.all(et == EVENT_ADD):
            self._state = _adds_donated(
                self._state, vs_w, rows_w, t0,
                policy=self.policy, cfg=self.cfg, score_fn=self._score_fn)
        else:
            self._state = self._mixed_fn(
                self._state, wnd._pad_to(et, w, EVENT_PAD),
                vs_w, rows_w, t0, policy=self.policy, cfg=self.cfg)

    def sync(self) -> "Partitioner":
        """Block until every dispatched feed has executed (feeds are
        asynchronous — JAX async dispatch). THE explicit query point:
        after ``sync()`` the carried state is materialized and host
        reads of it are free. Returns ``self`` for chaining."""
        jax.block_until_ready(self._state)
        return self

    # -- rebalancing --------------------------------------------------------

    def _drift_signals(self) -> tuple[float, float]:
        """(cut ratio, normalized load imbalance) from counters the
        engines already maintain — a host read of the live state, no new
        device work. Both are scale-free ratios (the imbalance is the
        mean-normalized Eq. 10 std), so ONE drift threshold compares
        meaningfully against either and does not loosen as the stream
        grows."""
        tot = int(self._state.total_edges)
        ratio = int(self._state.cut_edges) / tot if tot else 0.0
        imb = normalized_load_imbalance(np.asarray(self._state.edge_load),
                                        np.asarray(self._state.active))
        return ratio, float(imb)

    def _check_drift(self) -> bool:
        """The ``rebalance_drift`` cadence check (each feed boundary):
        fire a pass when either signal rose more than the threshold
        since the last pass (or since the first check — the baseline).
        Drops in either signal re-base nothing: only an executed pass
        (which re-reads both signals afterwards) moves the baseline, so
        slow monotone drift cannot creep under the threshold."""
        ratio, imb = self._drift_signals()
        if self._drift_base is None:
            self._drift_base = (ratio, imb)
            return False
        r0, i0 = self._drift_base
        if (ratio - r0) < self.rebalance_drift \
                and (imb - i0) < self.rebalance_drift:
            return False
        self._drift_fires += 1
        self._last_rebalance = self._cursor
        self.rebalance()
        return True

    def rebalance(self, m: int | None = None, passes: int | None = None,
                  slack: float | None = None) -> dict:
        """Run one between-windows rebalance over the live state: greedy
        migration of the top-``m`` worst-gain boundary vertices, then
        ``passes`` Spinner-style LPA iterations (see ``repro.rebalance``
        for both). Defaults come from the constructor knobs. Never
        touches the event RNG (``state.key``) or the cursor, so the
        session's *event* decisions stay bit-identical to an
        unrebalanced run; with ``m=0`` and ``passes=0`` the device state
        is not touched at all. Returns the recorded rebalance event
        (also appended to ``rebalance_events``). A query point: blocks
        on in-flight feeds."""
        m = self.rebalance_m if m is None else int(m)
        passes = self.rebalance_passes if passes is None else int(passes)
        slack = self.rebalance_slack if slack is None else float(slack)
        if m <= 0 and passes <= 0:
            return {"cursor": self._cursor, "m": 0, "passes": 0, "moved": 0}
        load0 = np.asarray(self._state.edge_load)
        act0 = np.asarray(self._state.active)
        self._state, stats = rebalance_jit(
            self._state, jnp.int32(self._cursor), jnp.float32(slack),
            jnp.float32(self.cfg.max_cap), True,
            m=min(m, self.n), passes=passes)
        if self._sharded:
            # the rebalance jit runs under GSPMD over the sharded inputs
            # but commits to no particular output layout — re-pin the
            # session's canonical vertices-mesh shardings
            self._state = shard_state(self._state, self._mesh)
        ev = {"cursor": self._cursor, "m": m, "passes": passes,
              "moved": int(stats.moved),
              "cut_before": int(stats.cut_before),
              "cut_after": int(stats.cut_after),
              "imbalance_before": load_imbalance(load0, act0),
              "imbalance_after": load_imbalance(
                  np.asarray(self._state.edge_load),
                  np.asarray(self._state.active))}
        self._rebalances += 1
        self._rebalance_moves += ev["moved"]
        self._rebalance_events.append(ev)
        if self.rebalance_drift is not None:
            # re-base the drift detector on the post-pass signals — the
            # next fire needs fresh drift, not the residue of this one
            self._drift_base = self._drift_signals()
        return ev

    # -- observation --------------------------------------------------------

    def metrics(self) -> dict:
        """Paper metrics (Eq. 9 edge-cut ratio, Eq. 10 imbalance, scaling
        counters) of the state as of the last ``feed``, plus the session
        counters (``cursor`` — also under its historical name
        ``events_ingested`` — and the elastic-geometry counters), so
        observers like ``repro.api.serve.PartitionService`` report them
        without reaching into privates. Blocks on in-flight feeds (a
        query point)."""
        m = state_metrics(self._state)
        m["events_ingested"] = self._cursor
        m["cursor"] = self._cursor
        m["n"] = self.n
        m["max_deg"] = self.max_deg
        m["regeometries"] = self._regeometries
        m["shrinks"] = self._shrinks
        m["compactions"] = self._compactions
        m["state_bytes"] = state_bytes(self._state)
        # kernel coverage: window dispatches that rode the Pallas kernels
        # vs the XLA fallback (scan slices count as one fallback unit) —
        # use_kernel=True with a large fallback share means the stream is
        # mostly scan tails and the kernels barely engage
        m["kernel_windows"] = self._kernel_windows
        m["fallback_windows"] = self._fallback_windows
        m["rebalances"] = self._rebalances
        m["rebalance_moves"] = self._rebalance_moves
        m["rebalance_drift_fires"] = self._drift_fires
        # vertex-sharding split: how many devices carry this session's
        # row blocks, and the peak per-device resident bytes (each
        # device pays its blocks + a full copy of the replicated K-state;
        # degenerates to ~state_bytes on a dense session)
        m["shard_devices"] = (self._mesh.shape["vertices"]
                              if self._sharded else 1)
        m["per_device_state_bytes"] = per_device_state_bytes(self._state)
        return m

    def trace(self) -> EventTrace:
        """The per-event trace of everything ingested so far (requires
        ``collect_trace=True``)."""
        if not self.collect_trace:
            raise RuntimeError(
                "this session does not collect per-event traces — construct"
                " Partitioner(..., collect_trace=True) (forces the scan "
                "backend, which is the one producing traces)")
        if not self._traces:
            return EventTrace(*(jnp.zeros((0,), dt) for dt in _TRACE_DTYPES))
        if len(self._traces) > 1:
            merged = EventTrace(*(
                jnp.concatenate([getattr(tr, f) for tr in self._traces])
                for f in EventTrace._fields))
            self._traces = [merged]
        return self._traces[0]

    # -- persistence --------------------------------------------------------

    def snapshot(self, directory: str, *, keep: int = 3,
                 blocking: bool = True) -> int:
        """Checkpoint the session under ``directory`` (atomic rename,
        ``keep`` most recent retained) via ``repro.checkpoint``. The
        checkpoint step IS the event cursor; returns it. ``blocking=False``
        writes on a background thread (the state is host-snapshotted
        synchronously first, so a following ``feed`` cannot race it); the
        session keeps one manager per directory, so the next snapshot to
        the same directory — or ``wait()`` — joins the pending writer."""
        mgr = self._managers.get(directory)
        if mgr is None:
            mgr = CheckpointManager(directory, interval=1, keep=keep)
            self._managers[directory] = mgr
        else:
            mgr.keep = keep
        extras = {}
        if self._ext2int is not None:
            extras["ext2int"] = self._ext2int
        if self._last_shrink_check:
            # persist the auto-shrink cadence mark so a restored session
            # checks at the same cursors the original would have
            extras["shrink_mark"] = np.asarray([self._last_shrink_check],
                                               np.int64)
        if self._last_rebalance:
            # same contract for the auto-rebalance cadence: a restored
            # session rebalances at the cursors the original would have
            extras["rebalance_mark"] = np.asarray([self._last_rebalance],
                                                  np.int64)
        if self._drift_base is not None:
            # the adaptive-cadence baseline rides along, so a restored
            # session fires its next drift pass where the original would
            extras["drift_base"] = np.asarray(self._drift_base, np.float64)
        tree, geom = self._state, geometry_of(self._state)
        if self._sharded:
            # persist the gathered CANONICAL layout (row padding sliced
            # off, semantic geometry recorded) so sharded and dense
            # sessions — and different mesh widths — round-trip
            # interchangeably
            tree, geom = gather_state(
                self._state, n=self._sem_geom.n), self._sem_geom
        mgr.save_now(self._cursor, tree, blocking=blocking,
                     geometry=geom, extras=extras or None)
        return self._cursor

    def wait(self) -> None:
        """Join any background snapshot writers (no-op if none pending) —
        call before process exit when using ``snapshot(blocking=False)``."""
        for mgr in self._managers.values():
            mgr.wait()

    @classmethod
    def restore(cls, directory: str, cfg: EngineConfig | None = None, *,
                n: int | None = None, max_deg: int | None = None,
                step: int | None = None, **kw) -> "Partitioner":
        """Resume a session from ``snapshot()`` output (default: latest
        step). The checkpoint's recorded geometry sizes the restore —
        ``n``/``max_deg`` pre-size *larger* (the restored state is grown
        to cover them) or *smaller*: a peak-tier checkpoint restores
        straight into a right-sized session via ``shrink_to`` (which
        raises, with the packed extent, if the live content genuinely
        cannot fit). They are also how checkpoints so old their geometry
        cannot be inferred from the leaf shapes declare it.
        ``cfg.k_max`` larger than the checkpoint's grows the
        partition-slot headroom (smaller still raises — partition slots
        are config-pinned). Also restores bare ``PartitionState``
        checkpoints written by older code: states that predate
        ``cut_matrix`` come back via ``fill_missing`` and are healed
        with ``recount_cut_matrix``; the external-id map of a compacted
        session rides in the checkpoint's extras channel and is restored
        with it. ``cfg``/``policy``/engine knobs are not stored in the
        checkpoint — pass the ones the session ran with. Traces are not
        checkpointed; a restored session's ``trace()`` covers
        post-restore events only.
        """
        cfg = cfg or EngineConfig()
        mgr = CheckpointManager(directory, interval=1)
        step = step if step is not None else mgr.latest()
        if step is None:
            raise FileNotFoundError(
                f"no checkpoint found under {directory!r}")
        ck = mgr.geometry(step)
        if ck is None:
            if n is None or max_deg is None:
                raise ValueError(
                    f"checkpoint at step {step} records no geometry and "
                    "none could be inferred from its leaf shapes — pass "
                    "n= and max_deg= explicitly")
            ck = Geometry(int(n), int(max_deg), cfg.k_max)
        if cfg.k_max < (ck.k_max or 0):
            raise ValueError(
                f"checkpoint was taken at k_max={ck.k_max} but "
                f"cfg.k_max={cfg.k_max}: partition-slot shapes grow, "
                "never shrink — raise cfg.k_max")
        # restore at the union of the checkpoint and requested shapes,
        # then shrink to any smaller requested dimensions below — the
        # payload's leaf shapes dictate the initial restore size either
        # way
        target = Geometry(max(int(n or 0), ck.n),
                          max(int(max_deg or 0), ck.max_deg), cfg.k_max)
        # build the session tier-minimal — its placeholder state is
        # replaced below, and allocating it at the target would hold a
        # third full-size state alive during the restore
        part = cls(cfg, **kw)
        # restore into a `like` at the EXACT checkpoint geometry (the
        # payload dictates leaf shapes), then grow to the target
        like = init_state(ck.n, ck.max_deg, ck.k_max or cfg.k_max,
                          cfg.k_init, 0)
        keys = mgr.leaf_keys(step)
        state, step = mgr.restore(like, step=step, fill_missing=True)
        # the payload dictates the restored leaf shapes, so a checkpoint
        # whose recorded geometry omitted k_max (Geometry.k_max is
        # Optional) is validated here, against the real saved shape
        k_saved = int(state.edge_load.shape[0])
        if k_saved > cfg.k_max:
            raise ValueError(
                f"checkpoint was taken at k_max={k_saved} but "
                f"cfg.k_max={cfg.k_max}: partition-slot shapes grow, "
                "never shrink — raise cfg.k_max")
        if len(keys) < len(jax.tree_util.tree_leaves(like)):
            # pre-cut_matrix checkpoint: fill_missing kept `like`'s zero
            # matrix — rebuild it exactly from the restored adjacency
            state = recount_cut_matrix(state)
        part._state = grow_state(state, target)
        if part._sharded:
            # re-place the restored canonical layout on the session's
            # vertices mesh (rows re-padded to the new shard count — the
            # cross-layout round-trip: dense↔sharded, any mesh width)
            part._sem_geom = geometry_of(part._state)
            part._state = shard_state(part._state, part._mesh)
        part._cursor = int(step)
        # the external-id map of a compacted session rides in the
        # checkpoint's extras — rebuild its dense inverse
        ext = mgr.extras(step)
        if "ext2int" in ext:
            e2i = np.asarray(ext["ext2int"], np.int32)
            part._ext2int = e2i
            valid = np.flatnonzero(e2i >= 0)
            slots = int(e2i[valid].max()) + 1 if valid.size else 0
            inv = np.full(slots, -1, np.int32)
            inv[e2i[valid]] = valid.astype(np.int32)
            part._int2ext = inv
        if "shrink_mark" in ext:
            part._last_shrink_check = int(np.asarray(ext["shrink_mark"])[0])
        if "rebalance_mark" in ext:
            part._last_rebalance = int(np.asarray(ext["rebalance_mark"])[0])
        if "drift_base" in ext:
            base = np.asarray(ext["drift_base"])
            part._drift_base = (float(base[0]), float(base[1]))
        part._record_geometry("restore", ck, part._sem_geometry())
        want_n = int(n) if n is not None and n < target.n else None
        want_d = int(max_deg) if max_deg is not None \
            and max_deg < target.max_deg else None
        if want_n is not None or want_d is not None:
            # restoring into a smaller tier than the checkpoint was taken
            # at: legal whenever the live content (packed) fits — a
            # session snapshotted at its peak right-sizes on restore
            part.shrink_to(n=want_n, max_deg=want_d)
        return part
