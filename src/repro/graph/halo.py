"""SDP partition → sharded-GNN layout: block relabelling + halo indices.

This is where the paper's output becomes the distributed runtime's input
(DESIGN.md §3). Given an assignment of nodes to P partitions:

  * nodes are relabelled so each shard owns one padded block (Nb rows);
  * each shard "publishes" the boundary rows other shards need (B_max
    slots, padded);
  * every shard's halo is described as (source_shard, publish_slot) pairs;
  * per-shard local edge lists index [own block ++ halo buffer].

The per-layer collective is then ONE all-gather of (B_max, F) per shard —
its byte volume is proportional to max-boundary size, i.e. exactly the
edge-cut SDP minimises. The hash-partition baseline yields B_max ≈ all
touched nodes; SDP collapses it.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graph.csr import Graph


@dataclasses.dataclass(frozen=True)
class HaloSpec:
    perm: np.ndarray          # (n,) old → position inside its block
    block_of: np.ndarray      # (n,) owning shard
    block_size: int           # Nb (max padded block)
    n_shards: int
    publish_idx: np.ndarray   # (P, B_max) local rows each shard publishes (-1 pad)
    halo_map: np.ndarray      # (P, H_max, 2) (src_shard, publish_slot) (-1 pad)
    senders: np.ndarray       # (P, E_max) local src in [0, Nb+H_max) (-1 pad)
    receivers: np.ndarray     # (P, E_max) local dst in [0, Nb) (-1 pad)

    @property
    def halo_size(self) -> int:
        return int(self.halo_map.shape[1])

    @property
    def publish_size(self) -> int:
        return int(self.publish_idx.shape[1])

    def collective_bytes_per_layer(self, feat_dim: int,
                                   bytes_per_el: int = 4) -> int:
        """All-gather volume per message-passing layer, per device:
        every shard receives (P-1) × B_max × F remote elements."""
        return (self.n_shards - 1) * self.publish_size * feat_dim * bytes_per_el


def build_halo_spec(g: Graph, assignment: np.ndarray, p: int) -> HaloSpec:
    assignment = np.asarray(assignment)
    n = g.n
    # --- block relabelling -------------------------------------------------
    counts = np.bincount(assignment, minlength=p)
    nb = int(counts.max())
    local_idx = np.zeros(n, dtype=np.int64)
    cursor = np.zeros(p, dtype=np.int64)
    order = np.argsort(assignment, kind="stable")
    for v in order:
        a = assignment[v]
        local_idx[v] = cursor[a]
        cursor[a] += 1

    edges = g.edge_array()
    u, v = edges[:, 0], edges[:, 1]
    # both directions: aggregation dst-owned
    src = np.concatenate([u, v])
    dst = np.concatenate([v, u])
    s_own, d_own = assignment[src], assignment[dst]

    # --- publish sets: for each shard, which of its rows others need -------
    publish: list[dict[int, int]] = [dict() for _ in range(p)]   # global -> slot
    halo: list[dict[int, int]] = [dict() for _ in range(p)]      # global -> halo slot
    for e in range(src.shape[0]):
        if s_own[e] != d_own[e]:
            owner, user = int(s_own[e]), int(d_own[e])
            gsrc = int(src[e])
            if gsrc not in publish[owner]:
                publish[owner][gsrc] = len(publish[owner])
            if gsrc not in halo[user]:
                halo[user][gsrc] = len(halo[user])
    b_max = max((len(d) for d in publish), default=0) or 1
    h_max = max((len(d) for d in halo), default=0) or 1

    publish_idx = -np.ones((p, b_max), dtype=np.int32)
    for k in range(p):
        for gv, slot in publish[k].items():
            publish_idx[k, slot] = local_idx[gv]
    halo_map = -np.ones((p, h_max, 2), dtype=np.int32)
    for k in range(p):
        for gv, slot in halo[k].items():
            owner = int(assignment[gv])
            halo_map[k, slot] = (owner, publish[owner][gv])

    # --- per-shard local edge lists ----------------------------------------
    per_shard: list[list[tuple[int, int]]] = [[] for _ in range(p)]
    for e in range(src.shape[0]):
        user = int(d_own[e])
        d_loc = int(local_idx[dst[e]])
        if s_own[e] == d_own[e]:
            s_loc = int(local_idx[src[e]])
        else:
            s_loc = nb + halo[user][int(src[e])]
        per_shard[user].append((s_loc, d_loc))
    e_max = max((len(l) for l in per_shard), default=0) or 1
    senders = -np.ones((p, e_max), dtype=np.int32)
    receivers = -np.ones((p, e_max), dtype=np.int32)
    for k in range(p):
        for i, (s, d) in enumerate(per_shard[k]):
            senders[k, i] = s
            receivers[k, i] = d

    return HaloSpec(
        perm=local_idx.astype(np.int32),
        block_of=assignment.astype(np.int32),
        block_size=nb, n_shards=p,
        publish_idx=publish_idx, halo_map=halo_map,
        senders=senders, receivers=receivers,
    )


def scatter_nodes(spec: HaloSpec, x: np.ndarray, fill=0.0) -> np.ndarray:
    """(n, F) global node array → (P, Nb, F) blocked layout."""
    out = np.full((spec.n_shards, spec.block_size) + x.shape[1:], fill,
                  dtype=x.dtype)
    out[spec.block_of, spec.perm] = x
    return out


def gather_nodes(spec: HaloSpec, blocks: np.ndarray) -> np.ndarray:
    """(P, Nb, F) blocked → (n, F) global order."""
    return blocks[spec.block_of, spec.perm]
