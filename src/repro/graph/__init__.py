"""Graph substrate: structures, synthetic datasets, streams, sampling, halo."""
from repro.graph.csr import Graph, from_edge_list, to_undirected, degrees
from repro.graph.generators import (
    mesh_graph, barabasi_albert, erdos_renyi, powerlaw_cluster, make_graph,
)
from repro.graph.datasets import PAPER_DATASETS, load_dataset
from repro.graph.stream import (
    VertexStream, build_stream, dynamic_schedule, EVENT_ADD, EVENT_DEL_VERTEX,
    EVENT_DEL_EDGE, EVENT_PAD,
)

__all__ = [
    "Graph", "from_edge_list", "to_undirected", "degrees",
    "mesh_graph", "barabasi_albert", "erdos_renyi", "powerlaw_cluster",
    "make_graph", "PAPER_DATASETS", "load_dataset",
    "VertexStream", "build_stream", "dynamic_schedule",
    "EVENT_ADD", "EVENT_DEL_VERTEX", "EVENT_DEL_EDGE", "EVENT_PAD",
]
