"""Fanout neighbour sampler (GraphSAGE-style) for `minibatch_lg`.

Produces fixed-shape padded subgraphs so the jitted train step recompiles
once: seeds (B,), per-hop sampled neighbours with fanout f_h, local edge
lists, and a gathered feature matrix.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graph.csr import Graph


@dataclasses.dataclass(frozen=True)
class SampledSubgraph:
    """Local-id subgraph: row 0..B-1 are the seed nodes."""
    node_ids: np.ndarray     # (N_sub,) global ids (-1 pad)
    senders: np.ndarray      # (E_sub,) local ids (-1 pad)
    receivers: np.ndarray    # (E_sub,) local ids (-1 pad)
    seed_count: int

    @property
    def num_nodes(self) -> int:
        return int(self.node_ids.shape[0])


def sample_subgraph(
    g: Graph, seeds: np.ndarray, fanouts: tuple[int, ...],
    rng: np.random.Generator,
) -> SampledSubgraph:
    """Sample without dedup (fixed shapes): hop h draws `fanouts[h]`
    neighbours of every hop-(h-1) node; edges point child → parent so
    message passing flows toward the seeds."""
    seeds = np.asarray(seeds, dtype=np.int64)
    b = seeds.shape[0]
    frontier = seeds
    node_ids = [seeds]
    senders, receivers = [], []
    offset = 0          # local index of current frontier start
    next_offset = b
    for f in fanouts:
        nf = frontier.shape[0]
        children = -np.ones((nf, f), dtype=np.int64)
        for i, v in enumerate(frontier):
            if v < 0:
                continue
            nb = g.neighbors(int(v))
            if nb.size == 0:
                continue
            take = rng.choice(nb, size=f, replace=nb.size < f)
            children[i] = take
        child_local = next_offset + np.arange(nf * f).reshape(nf, f)
        parent_local = offset + np.repeat(np.arange(nf), f).reshape(nf, f)
        valid = children >= 0
        senders.append(np.where(valid, child_local, -1).reshape(-1))
        receivers.append(np.where(valid, parent_local, -1).reshape(-1))
        node_ids.append(children.reshape(-1))
        frontier = children.reshape(-1)
        offset = next_offset
        next_offset += nf * f
    return SampledSubgraph(
        node_ids=np.concatenate(node_ids).astype(np.int32),
        senders=np.concatenate(senders).astype(np.int32),
        receivers=np.concatenate(receivers).astype(np.int32),
        seed_count=b,
    )


def subgraph_sizes(batch_nodes: int, fanouts: tuple[int, ...]):
    """(n_nodes, n_edges) of the padded subgraph — for input_specs()."""
    n, e, layer = batch_nodes, 0, batch_nodes
    for f in fanouts:
        e += layer * f
        layer *= f
        n += layer
    return n, e


def make_minibatch(g: Graph, d_feat: int, batch_nodes: int,
                   fanouts: tuple[int, ...], *, seed: int = 0,
                   out_dim: int = 1) -> dict:
    """Host pipeline step → model batch dict (fixed shapes)."""
    rng = np.random.default_rng(seed)
    seeds = rng.integers(0, g.n, batch_nodes)
    sub = sample_subgraph(g, seeds, fanouts, rng)
    feat_rng = np.random.default_rng(seed + 1)
    valid = sub.node_ids >= 0
    feats = feat_rng.standard_normal((sub.num_nodes, d_feat)).astype(np.float32)
    feats[~valid] = 0.0
    mask = np.zeros(sub.num_nodes, bool)
    mask[: sub.seed_count] = True
    positions = feat_rng.standard_normal((sub.num_nodes, 3)).astype(np.float32)
    positions[~valid] = 0.0
    return {
        "senders": sub.senders,
        "receivers": sub.receivers,
        "node_feat": feats,
        "node_mask": mask,     # loss on seeds only
        "positions": positions,
        "species": feat_rng.integers(0, 16, sub.num_nodes).astype(np.int32),
        "targets": feat_rng.standard_normal(
            (sub.num_nodes, out_dim)).astype(np.float32),
    }
