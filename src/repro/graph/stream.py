"""Streaming event representation of a dynamic graph.

The paper's stream (§4.1, Fig. 3) delivers one event at a time:
  * add a vertex together with its associated edges,
  * delete a vertex (and all its edges),
  * delete an edge.

The TPU-native engine consumes a *padded event tensor*: dense arrays of
``(etype, vertex, nbrs[max_deg])`` with ``-1`` padding, so a one-pass
``lax.scan`` (faithful mode) or windowed kernel (optimised mode) can process
it without host round-trips. ``dynamic_schedule`` reproduces the paper's
§5.3.1 protocol: per interval add 25% of the dataset then delete 5%.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.graph.csr import Graph

EVENT_ADD = 0        # add vertex `vertex` with neighbour list `nbrs`
EVENT_DEL_VERTEX = 1  # delete vertex `vertex` and all incident edges
EVENT_DEL_EDGE = 2   # delete edge (vertex, nbrs[0])
EVENT_PAD = 3        # no-op padding


@dataclasses.dataclass(frozen=True)
class VertexStream:
    """Padded event tensor for a dynamic-graph stream.

    Attributes:
      etype:  (T,) int32 event codes (EVENT_*).
      vertex: (T,) int32 subject vertex (-1 for padding).
      nbrs:   (T, max_deg) int32 neighbour ids, -1 padded. For EVENT_ADD
              these are *all known* neighbours of the vertex in the underlying
              graph (capped at max_deg by uniform subsample); the engine only
              scores those already assigned, as in the paper.
      n:      total number of distinct vertex ids (array sizes).
      intervals: event indices at which the paper captures metrics
              (ends of the add/delete intervals).
      truncated_nbrs: count of neighbour entries dropped by the max_deg cap
              (0 ⇒ the stream is exact).
    """

    etype: np.ndarray
    vertex: np.ndarray
    nbrs: np.ndarray
    n: int
    intervals: tuple[int, ...] = ()
    truncated_nbrs: int = 0

    @property
    def num_events(self) -> int:
        return int(self.etype.shape[0])

    @property
    def max_deg(self) -> int:
        return int(self.nbrs.shape[1])

    def required_geometry(self):
        """Minimal :class:`repro.core.geometry.Geometry` able to ingest
        this stream: ``n`` covers the declared universe AND every vertex
        id the events actually reference, ``max_deg`` is the real
        content width (all-pad trailing columns don't count, so padded
        streams never force a wider state). The ONE definition shared by
        ``Partitioner.from_stream`` sizing and the feed-time auto-grow
        check."""
        return required_geometry_of(self.vertex, self.nbrs, n=self.n)


def required_geometry_of(vertex, nbrs, n: int = 0):
    """``VertexStream.required_geometry`` over bare event arrays — the
    session feed path calls this on ``(etype, vertex, nbrs)`` triples."""
    from repro.core.geometry import Geometry  # deferred: core imports us
    vertex = np.asarray(vertex)
    nbrs = np.asarray(nbrs)
    n_req = max(int(n), 1)
    if vertex.size:
        n_req = max(n_req, int(vertex.max()) + 1)
    real = nbrs >= 0
    width = 1
    if real.any():
        n_req = max(n_req, int(nbrs[real].max()) + 1)
        width = int(np.flatnonzero(real.any(axis=0)).max()) + 1
    return Geometry(n_req, width)


def normalize_rows(nbrs: np.ndarray, width: int) -> np.ndarray:
    """Pad (with -1) or losslessly trim neighbour rows to ``width``
    columns — the ONE definition of neighbour-row re-widthing, shared by
    the session feed path (repro.api.partitioner), stream concatenation,
    and the sweep runtime's lane stacking. Raises if trimming would drop
    a real neighbour id; callers grow the target geometry first (see
    repro.core.geometry) rather than widening here."""
    nbrs = np.asarray(nbrs, np.int32)
    d = nbrs.shape[1]
    if d == width:
        return nbrs
    if d < width:
        return np.concatenate(
            [nbrs, np.full((nbrs.shape[0], width - d), -1, np.int32)],
            axis=1)
    if np.any(nbrs[:, width:] >= 0):
        raise ValueError(
            f"neighbour rows carry real ids beyond column {width} (rows are "
            f"{d} wide) — grow the target geometry's max_deg instead of "
            "trimming (repro.core.state.grow_state)")
    return nbrs[:, :width]


def _neighbor_rows(
    g: Graph, order: np.ndarray, max_deg: int, rng: np.random.Generator
) -> tuple[np.ndarray, int]:
    rows = -np.ones((order.shape[0], max_deg), dtype=np.int32)
    truncated = 0
    for i, v in enumerate(order):
        nb = g.neighbors(int(v))
        if nb.size > max_deg:
            truncated += nb.size - max_deg
            nb = rng.choice(nb, size=max_deg, replace=False)
        rows[i, : nb.size] = nb
    return rows, truncated


def build_stream(
    g: Graph,
    *,
    max_deg: Optional[int] = None,
    seed: int = 0,
    order: Optional[np.ndarray] = None,
) -> VertexStream:
    """Static (insert-only) stream: every vertex arrives once, random order.

    The Graph Loader of the paper "receives input from the disk uniformly and
    at random" — the default order is a uniform shuffle.
    """
    rng = np.random.default_rng(seed)
    if order is None:
        order = rng.permutation(g.n)
    order = np.asarray(order, dtype=np.int32)
    if max_deg is None:
        max_deg = int(np.diff(g.indptr).max(initial=1))
    nbrs, truncated = _neighbor_rows(g, order, max_deg, rng)
    return VertexStream(
        etype=np.full(order.shape[0], EVENT_ADD, dtype=np.int32),
        vertex=order,
        nbrs=nbrs,
        n=g.n,
        intervals=(order.shape[0],),
        truncated_nbrs=truncated,
    )


def dynamic_schedule(
    g: Graph,
    *,
    add_pct: float = 25.0,
    del_pct: float = 5.0,
    n_intervals: int = 4,
    max_deg: Optional[int] = None,
    seed: int = 0,
    del_edges_per_interval: int = 0,
) -> VertexStream:
    """Paper §5.3.1 protocol: per interval, add `add_pct`% of the dataset's
    vertices, then delete `del_pct`% of the *currently present* vertices
    (Eqs. 11–12). Optionally also delete individual edges.
    """
    rng = np.random.default_rng(seed)
    if max_deg is None:
        max_deg = int(np.diff(g.indptr).max(initial=1))
    order = rng.permutation(g.n).astype(np.int32)
    n_add = int(round(g.n * add_pct / 100.0))
    n_del = int(round(g.n * del_pct / 100.0))

    etypes: list[np.ndarray] = []
    vertices: list[np.ndarray] = []
    nbr_rows: list[np.ndarray] = []
    intervals: list[int] = []
    truncated = 0

    present: list[int] = []
    cursor = 0
    t = 0
    for _ in range(n_intervals):
        add = order[cursor : cursor + n_add]
        cursor += add.shape[0]
        if add.size:
            rows, tr = _neighbor_rows(g, add, max_deg, rng)
            truncated += tr
            etypes.append(np.full(add.shape[0], EVENT_ADD, dtype=np.int32))
            vertices.append(add)
            nbr_rows.append(rows)
            present.extend(int(v) for v in add)
            t += add.shape[0]
        k = min(n_del, len(present))
        if k > 0:
            pick = rng.choice(len(present), size=k, replace=False)
            dels = np.array([present[i] for i in pick], dtype=np.int32)
            keep = np.ones(len(present), dtype=bool)
            keep[pick] = False
            present = [p for p, kk in zip(present, keep) if kk]
            etypes.append(np.full(k, EVENT_DEL_VERTEX, dtype=np.int32))
            vertices.append(dels)
            nbr_rows.append(-np.ones((k, max_deg), dtype=np.int32))
            t += k
        if del_edges_per_interval > 0 and present:
            evs, eus = [], []
            pres_arr = np.asarray(present, dtype=np.int64)
            for _ in range(del_edges_per_interval):
                v = int(rng.choice(present))
                # only delete edges whose BOTH endpoints are present: a
                # del-edge naming a not-yet-streamed endpoint would later be
                # resurrected one-sided by that endpoint's add row, leaving
                # the materialized adjacency asymmetric (and the engines'
                # exact incremental counters would then legitimately differ
                # from a from-scratch recount of it)
                nb = g.neighbors(v)
                nb = nb[np.isin(nb, pres_arr)]
                if nb.size:
                    evs.append(v)
                    eus.append(int(rng.choice(nb)))
            if evs:
                k = len(evs)
                etypes.append(np.full(k, EVENT_DEL_EDGE, dtype=np.int32))
                vertices.append(np.asarray(evs, dtype=np.int32))
                rows = -np.ones((k, max_deg), dtype=np.int32)
                rows[:, 0] = eus
                nbr_rows.append(rows)
                t += k
        intervals.append(t)
        if cursor >= g.n:
            break

    return VertexStream(
        etype=np.concatenate(etypes) if etypes else np.zeros(0, np.int32),
        vertex=np.concatenate(vertices) if vertices else np.zeros(0, np.int32),
        nbrs=np.concatenate(nbr_rows) if nbr_rows else np.zeros((0, max_deg), np.int32),
        n=g.n,
        intervals=tuple(intervals),
        truncated_nbrs=truncated,
    )


def interleaved_churn(
    g: Graph,
    *,
    warmup_frac: float = 0.25,
    del_every: int = 3,
    edge_del_every: int = 0,
    readd_every: int = 0,
    max_deg: Optional[int] = None,
    seed: int = 0,
) -> VertexStream:
    """Fine-grained interleaved churn stream (the xDGP-style regime).

    After a warm-up of ``warmup_frac`` of the vertices, the remaining adds
    arrive interleaved with deletions: every ``del_every`` adds a random
    *present* vertex is deleted, every ``edge_del_every`` adds a random
    present edge is deleted, and every ``readd_every`` adds a previously
    deleted vertex is re-added. Unlike ``dynamic_schedule`` (contiguous
    add/delete phases), the deletions here land inside nearly every engine
    window, which is exactly what defeated the old delete-splitting
    windowed driver.
    """
    rng = np.random.default_rng(seed)
    if max_deg is None:
        max_deg = int(np.diff(g.indptr).max(initial=1))
    order = rng.permutation(g.n).astype(np.int32)
    truncated = 0
    # edges killed by DEL_EDGE stay dead: a later re-add of an endpoint must
    # not resurrect them (its row comes from the static graph), or the
    # materialized adjacency would go asymmetric — see dynamic_schedule
    dead_edges: set[tuple[int, int]] = set()

    def row_of(v: int) -> np.ndarray:
        nonlocal truncated
        row = -np.ones(max_deg, dtype=np.int32)
        nb = g.neighbors(int(v))
        if dead_edges:
            nb = np.asarray([u for u in nb
                             if (min(int(u), int(v)), max(int(u), int(v)))
                             not in dead_edges], dtype=nb.dtype)
        if nb.size > max_deg:
            truncated += nb.size - max_deg
            nb = rng.choice(nb, size=max_deg, replace=False)
        row[: nb.size] = nb
        return row

    etypes: list[int] = []
    vertices: list[int] = []
    nbr_rows: list[np.ndarray] = []

    def emit(et: int, v: int, row: np.ndarray):
        etypes.append(et)
        vertices.append(int(v))
        nbr_rows.append(row)

    present: list[int] = []
    deleted: list[int] = []
    n_warm = int(round(g.n * warmup_frac))
    for v in order[:n_warm]:
        emit(EVENT_ADD, v, row_of(v))
        present.append(int(v))

    count = 0
    for v in order[n_warm:]:
        emit(EVENT_ADD, v, row_of(v))
        present.append(int(v))
        count += 1
        if del_every and count % del_every == 0 and present:
            i = int(rng.integers(len(present)))
            dv = present.pop(i)
            deleted.append(dv)
            emit(EVENT_DEL_VERTEX, dv, -np.ones(max_deg, np.int32))
        if edge_del_every and count % edge_del_every == 0 and present:
            ev = int(present[int(rng.integers(len(present)))])
            nb = g.neighbors(ev)
            # both endpoints present and the edge still alive (see row_of)
            nb = nb[np.isin(nb, present)]
            nb = np.asarray([u for u in nb
                             if (min(int(u), ev), max(int(u), ev))
                             not in dead_edges], dtype=nb.dtype)
            if nb.size:
                eu = int(rng.choice(nb))
                dead_edges.add((min(eu, ev), max(eu, ev)))
                row = -np.ones(max_deg, np.int32)
                row[0] = eu
                emit(EVENT_DEL_EDGE, ev, row)
        if readd_every and count % readd_every == 0 and deleted:
            rv = deleted.pop(int(rng.integers(len(deleted))))
            emit(EVENT_ADD, rv, row_of(rv))
            present.append(rv)

    return VertexStream(
        etype=np.asarray(etypes, np.int32),
        vertex=np.asarray(vertices, np.int32),
        nbrs=(np.stack(nbr_rows) if nbr_rows
              else np.zeros((0, max_deg), np.int32)),
        n=g.n,
        intervals=(len(etypes),),
        truncated_nbrs=truncated,
    )


def poisson_arrivals(
    s: VertexStream,
    *,
    rate: float,
    mean_batch: float = 24.0,
    seed: int = 0,
) -> tuple[np.ndarray, np.ndarray]:
    """Chop a stream into arrival batches with Poisson-process due times
    — the serving-workload model behind benchmarks/fig14_serving.py.

    Events arrive in bursts: batch sizes are Poisson-distributed around
    ``mean_batch`` (clamped ≥ 1, truncated at the stream end), and batch
    due times follow a Poisson process whose long-run **event** rate is
    ``rate`` events/second (inter-arrival gaps are exponential with mean
    ``batch_size / rate``, drawn per batch so bigger bursts are spaced
    proportionally further apart).

    Returns ``(bounds, due)``: ``bounds`` is (B+1,) int64 slice
    boundaries into the stream (batch ``i`` is events
    ``bounds[i]:bounds[i+1]``) and ``due`` is (B,) float64 arrival times
    in seconds from the start of the process. A driver replays the
    workload by sleeping until ``due[i]`` (when early) before
    submitting batch ``i`` — see ``PartitionService`` and fig14.
    """
    if rate <= 0:
        raise ValueError(f"rate={rate} must be > 0 events/second")
    if mean_batch <= 0:
        raise ValueError(f"mean_batch={mean_batch} must be > 0 events")
    rng = np.random.default_rng(seed)
    T = s.num_events
    sizes: list[int] = []
    total = 0
    while total < T:
        b = max(int(rng.poisson(mean_batch)), 1)
        b = min(b, T - total)
        sizes.append(b)
        total += b
    bounds = np.concatenate([[0], np.cumsum(sizes, dtype=np.int64)])
    gaps = rng.exponential(np.asarray(sizes, np.float64) / rate)
    return bounds, np.cumsum(gaps)


def pad_stream(s: VertexStream, multiple: int) -> VertexStream:
    """Pad the event tensor length to a multiple (for fixed-window engines)."""
    t = s.num_events
    target = ((t + multiple - 1) // multiple) * multiple
    if target == t:
        return s
    pad = target - t
    return VertexStream(
        etype=np.concatenate([s.etype, np.full(pad, EVENT_PAD, np.int32)]),
        vertex=np.concatenate([s.vertex, np.full(pad, -1, np.int32)]),
        nbrs=np.concatenate([s.nbrs, -np.ones((pad, s.max_deg), np.int32)]),
        n=s.n,
        intervals=s.intervals,
        truncated_nbrs=s.truncated_nbrs,
    )


def concat_streams(streams: Sequence[VertexStream]) -> VertexStream:
    """Concatenate streams over the same vertex universe."""
    max_deg = max(s.max_deg for s in streams)
    nbrs = [normalize_rows(s.nbrs, max_deg) for s in streams]
    offs, acc = [], 0
    for s in streams:
        offs.extend(i + acc for i in s.intervals)
        acc += s.num_events
    return VertexStream(
        etype=np.concatenate([s.etype for s in streams]),
        vertex=np.concatenate([s.vertex for s in streams]),
        nbrs=np.concatenate(nbrs),
        n=max(s.n for s in streams),
        intervals=tuple(offs),
        truncated_nbrs=sum(s.truncated_nbrs for s in streams),
    )


# ---------------------------------------------------------------------------
# adversarial streams — the quality scenarios (fig16, repro.rebalance)
# ---------------------------------------------------------------------------
# A one-shot streaming partitioner decides each vertex when only part of
# its neighbourhood exists. These generators arrange arrivals so the
# early decisions are maximally wrong by the end of the stream — the
# drift the rebalance subsystem is judged on. All of them obey the
# generator discipline the engine's recount invariant needs: adjacency
# rows come from one static graph built UP FRONT (so both endpoints of
# every edge list each other — rows referencing not-yet-present ids are
# inert until the partner arrives), and deletions only ever name present
# vertices.


def _append_dels(s: VertexStream, victims: np.ndarray,
                 intervals: Sequence[int]) -> VertexStream:
    """Append DEL_VERTEX events for ``victims`` (must be present — the
    callers only delete vertices their own add phase arrived)."""
    nd = victims.shape[0]
    return VertexStream(
        etype=np.concatenate(
            [s.etype, np.full(nd, EVENT_DEL_VERTEX, np.int32)]),
        vertex=np.concatenate([s.vertex, victims.astype(np.int32)]),
        nbrs=np.concatenate([s.nbrs, -np.ones((nd, s.max_deg), np.int32)]),
        n=s.n,
        intervals=tuple(intervals) + (s.num_events + nd,),
        truncated_nbrs=s.truncated_nbrs,
    )


def hub_arrivals(
    g: Graph,
    *,
    hub_frac: float = 0.02,
    warmup_frac: float = 0.3,
    del_frac: float = 0.0,
    max_deg: Optional[int] = None,
    seed: int = 0,
) -> VertexStream:
    """Power-law burst: the top-degree hubs arrive in one consecutive
    burst after only ``warmup_frac`` of the low-degree periphery exists.
    Every hub is therefore placed nearly blind (most of its neighbours
    absent), and the periphery arriving after the burst chases the
    misplaced hubs — the worst case for one-shot affinity placement.
    ``del_frac`` optionally churns that fraction of the warmup vertices
    away after the burst (they are present, so no dangling deletes).
    Intervals: (end of warmup, end of burst, end of adds[, end of dels])."""
    rng = np.random.default_rng(seed)
    deg = np.diff(g.indptr)
    n_hub = max(1, int(round(g.n * hub_frac)))
    hubs = np.argsort(deg, kind="stable")[::-1][:n_hub]
    rest = rng.permutation(np.setdiff1d(np.arange(g.n), hubs))
    n_warm = int(round(rest.size * warmup_frac))
    order = np.concatenate([rest[:n_warm], hubs, rest[n_warm:]])
    s = build_stream(g, max_deg=max_deg, seed=seed, order=order)
    intervals = (n_warm, n_warm + n_hub, g.n)
    n_del = int(round(n_warm * del_frac))
    if n_del == 0:
        return dataclasses.replace(s, intervals=intervals)
    victims = rng.choice(rest[:n_warm], size=n_del, replace=False)
    return _append_dels(s, victims, intervals)


def community_merge(
    *,
    block: int = 300,
    p_intra: float = 0.05,
    bridges: int = 60,
    bridge_deg: int = 6,
    max_deg: Optional[int] = None,
    seed: int = 0,
) -> VertexStream:
    """Two dense blocks bridged mid-stream: block A streams in full, then
    block B, then ``bridges`` bridge vertices each wired half into A and
    half into B. While the blocks stream the optimum is to keep them
    apart; once the bridges land the communities have merged and the
    early per-block placements cut every bridge edge. Mid-stream edges
    between *existing* vertices must ride new vertices (duplicate adds
    are engine no-ops), which is exactly what the bridge vertices are.
    Intervals: (end of A, end of B, end of bridges)."""
    from repro.graph.csr import from_edge_list
    rng = np.random.default_rng(seed)
    n = 2 * block + bridges
    m_intra = max(block - 1, int(round(p_intra * block * (block - 1) / 2)))
    parts = []
    for base in (0, block):
        # sampled pair list — from_edge_list dedups and drops self-loops
        pairs = rng.integers(0, block, size=(m_intra, 2)) + base
        # a spanning chain keeps each block connected (dense ≠ connected)
        chain = np.stack([np.arange(block - 1), np.arange(1, block)],
                         axis=1) + base
        parts.append(np.concatenate([pairs, chain]))
    half = max(1, bridge_deg // 2)
    for b in range(2 * block, n):
        ends = np.concatenate([rng.choice(block, half, replace=False),
                               rng.choice(block, half, replace=False)
                               + block])
        parts.append(np.stack([np.full(ends.size, b), ends], axis=1))
    g = from_edge_list(np.concatenate(parts), n=n)
    order = np.concatenate([rng.permutation(block),
                            rng.permutation(block) + block,
                            rng.permutation(np.arange(2 * block, n))])
    s = build_stream(g, max_deg=max_deg, seed=seed, order=order)
    return dataclasses.replace(s, intervals=(block, 2 * block, n))


def flash_crowd(
    g: Graph,
    *,
    crowd: int = 200,
    celebrities: int = 8,
    attach: int = 3,
    arrive_frac: float = 0.5,
    depart_frac: float = 0.5,
    max_deg: Optional[int] = None,
    seed: int = 0,
) -> VertexStream:
    """Sudden arrival-rate spike onto few vertices: after ``arrive_frac``
    of the base graph has streamed, ``crowd`` NEW vertices arrive
    back-to-back, each starring onto ``attach`` of the ``celebrities``
    highest-degree base vertices. The crowd edges exist in the static
    graph built up front (the celebrities' rows list the crowd ids from
    the start, inert until the spike), so adjacency stays symmetric.
    ``depart_frac`` of the crowd then leaves — flash crowds do.
    Intervals: (spike start, spike end, end of adds[, end of dels])."""
    from repro.graph.csr import from_edge_list
    rng = np.random.default_rng(seed)
    deg = np.diff(g.indptr)
    celebs = np.argsort(deg, kind="stable")[::-1][:max(celebrities, attach)]
    crowd_ids = np.arange(g.n, g.n + crowd)
    star = np.stack([
        np.repeat(crowd_ids, attach),
        np.concatenate([rng.choice(celebs, attach, replace=False)
                        for _ in crowd_ids]),
    ], axis=1)
    base_edges = g.edge_array()
    edges = np.concatenate([base_edges, star]) if base_edges.size else star
    g2 = from_edge_list(edges, n=g.n + crowd)
    basep = rng.permutation(g.n)
    n_pre = int(round(g.n * arrive_frac))
    order = np.concatenate([basep[:n_pre], rng.permutation(crowd_ids),
                            basep[n_pre:]])
    s = build_stream(g2, max_deg=max_deg, seed=seed, order=order)
    intervals = (n_pre, n_pre + crowd, g2.n)
    n_dep = int(round(crowd * depart_frac))
    if n_dep == 0:
        return dataclasses.replace(s, intervals=intervals)
    victims = rng.choice(crowd_ids, size=n_dep, replace=False)
    return _append_dels(s, victims, intervals)


def materialize_graph(s: VertexStream) -> Graph:
    """Host oracle: the graph a stream leaves behind — final present
    vertices and live edges under the engine's event semantics (duplicate
    adds ignored, vertex deletion drops incident edges, edge deletion is
    permanent for the pair). The offline baseline in fig16 partitions
    this graph; assumes the generator discipline above (mutual row
    listing, dead pairs never re-listed), which every in-repo generator
    obeys."""
    from repro.graph.csr import from_edge_list
    present: set[int] = set()
    rows: dict[int, set[int]] = {}
    live: set[tuple[int, int]] = set()
    dead: set[tuple[int, int]] = set()
    for t in range(s.num_events):
        et, v = int(s.etype[t]), int(s.vertex[t])
        if et == EVENT_ADD:
            if v in present:
                continue  # duplicate adds are engine no-ops
            row = {int(u) for u in s.nbrs[t] if u >= 0}
            present.add(v)
            rows[v] = row
            for u in row:
                pair = (min(v, u), max(v, u))
                if u in present and v in rows.get(u, ()) \
                        and pair not in dead:
                    live.add(pair)
        elif et == EVENT_DEL_VERTEX:
            if v not in present:
                continue
            present.discard(v)
            live = {e for e in live if v not in e}
        elif et == EVENT_DEL_EDGE:
            u = int(s.nbrs[t, 0])
            pair = (min(v, u), max(v, u))
            if v in present and u in present and pair in live:
                live.discard(pair)
                dead.add(pair)
                rows[v].discard(u)
                rows[u].discard(v)
    n = s.required_geometry().n
    edges = (np.asarray(sorted(live), np.int64)
             if live else np.zeros((0, 2), np.int64))
    return from_edge_list(edges, n=n)
