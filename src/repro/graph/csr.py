"""Compressed sparse row graph structure (numpy host-side; JAX arrays on device).

The partitioner's host-side bookkeeping uses numpy; device compute uses the
padded tensors produced by ``repro.graph.stream``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class Graph:
    """Undirected graph in CSR form.

    Attributes:
      indptr:  (n+1,) int64 — CSR row pointers.
      indices: (nnz,) int32 — neighbour ids, both directions stored.
      n:       number of vertices.
    """

    indptr: np.ndarray
    indices: np.ndarray
    n: int

    @property
    def num_edges(self) -> int:
        """Number of undirected edges (each stored twice in CSR)."""
        return int(self.indices.shape[0]) // 2

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v] : self.indptr[v + 1]]

    def degree(self, v: int) -> int:
        return int(self.indptr[v + 1] - self.indptr[v])

    def edge_array(self) -> np.ndarray:
        """(m, 2) array of undirected edges with u < v."""
        src = np.repeat(np.arange(self.n, dtype=np.int64), np.diff(self.indptr))
        dst = self.indices.astype(np.int64)
        mask = src < dst
        return np.stack([src[mask], dst[mask]], axis=1)


def from_edge_list(edges: np.ndarray, n: Optional[int] = None) -> Graph:
    """Build an undirected CSR graph from an (m, 2) edge array.

    Self-loops and duplicate edges are removed.
    """
    edges = np.asarray(edges, dtype=np.int64)
    if edges.size == 0:
        n = int(n or 0)
        return Graph(np.zeros(n + 1, dtype=np.int64), np.zeros(0, np.int32), n)
    if n is None:
        n = int(edges.max()) + 1
    u, v = edges[:, 0], edges[:, 1]
    keep = u != v
    u, v = u[keep], v[keep]
    # canonicalise + dedup
    lo, hi = np.minimum(u, v), np.maximum(u, v)
    key = lo * n + hi
    _, uniq = np.unique(key, return_index=True)
    lo, hi = lo[uniq], hi[uniq]
    # both directions
    src = np.concatenate([lo, hi])
    dst = np.concatenate([hi, lo])
    order = np.argsort(src, kind="stable")
    src, dst = src[order], dst[order]
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(indptr, src + 1, 1)
    indptr = np.cumsum(indptr)
    return Graph(indptr, dst.astype(np.int32), n)


def to_undirected(edges: np.ndarray) -> np.ndarray:
    """Canonicalise an edge list: undirected, u<v, deduped, no self loops."""
    edges = np.asarray(edges, dtype=np.int64)
    u, v = edges[:, 0], edges[:, 1]
    keep = u != v
    u, v = u[keep], v[keep]
    lo, hi = np.minimum(u, v), np.maximum(u, v)
    key = lo * (max(int(hi.max(initial=0)), int(lo.max(initial=0))) + 2) + hi
    _, uniq = np.unique(key, return_index=True)
    return np.stack([lo[uniq], hi[uniq]], axis=1)


def degrees(g: Graph) -> np.ndarray:
    return np.diff(g.indptr)


def cap_degree(g: Graph, max_deg: int, seed: int = 0) -> Graph:
    """Symmetric degree cap: drop edges so every vertex has ≤ max_deg.

    Needed so padded (n, max_deg) adjacency tensors stay exact: the stream,
    engine bookkeeping and metrics all agree on the *capped* graph. Only the
    heavy-tailed stand-ins (twitter) are affected at default caps.
    """
    rng = np.random.default_rng(seed)
    deg = np.diff(g.indptr).copy()
    if deg.size == 0 or deg.max(initial=0) <= max_deg:
        return g
    edges = g.edge_array()
    order = rng.permutation(edges.shape[0])
    kept = np.zeros(edges.shape[0], dtype=bool)
    cnt = np.zeros(g.n, dtype=np.int64)
    for i in order:
        u, v = edges[i]
        if cnt[u] < max_deg and cnt[v] < max_deg:
            kept[i] = True
            cnt[u] += 1
            cnt[v] += 1
    return from_edge_list(edges[kept], n=g.n)


def subgraph_edges(g: Graph, removed: np.ndarray) -> Graph:
    """Graph with ``removed`` vertices (bool mask) dropped (ids preserved)."""
    removed = np.asarray(removed, dtype=bool)
    edges = g.edge_array()
    keep = ~(removed[edges[:, 0]] | removed[edges[:, 1]])
    return from_edge_list(edges[keep], n=g.n)
