"""Paper Table 2 datasets, instantiated synthetically (offline container).

Each entry records the paper's |V|, |E| and family; ``load_dataset`` builds a
matched synthetic graph. ``scale`` lets tests shrink datasets uniformly.
"""
from __future__ import annotations

import dataclasses
import functools

from repro.graph.csr import Graph
from repro.graph.generators import make_graph


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    n_vertices: int
    n_edges: int
    family: str
    source: str


PAPER_DATASETS: dict[str, DatasetSpec] = {
    "3elt": DatasetSpec("3elt", 4200, 13722, "mesh", "Walshaw archive [25]"),
    "grqc": DatasetSpec("grqc", 5242, 14496, "collaboration", "SNAP [26]"),
    "wiki-vote": DatasetSpec("wiki-vote", 7115, 99291, "social", "SNAP [26]"),
    "4elt": DatasetSpec("4elt", 15606, 45878, "mesh", "Walshaw archive [25]"),
    "astroph": DatasetSpec("astroph", 18772, 198110, "citation", "SNAP [26]"),
    "email-enron": DatasetSpec("email-enron", 36692, 183831, "communication", "SNAP [26]"),
    "twitter": DatasetSpec("twitter", 81306, 1768149, "social", "SNAP [26]"),
}


@functools.lru_cache(maxsize=32)
def load_dataset(name: str, seed: int = 0, scale: float = 1.0) -> Graph:
    """Build the synthetic stand-in for a paper dataset.

    Args:
      name: key of ``PAPER_DATASETS``.
      seed: generator seed.
      scale: uniform shrink factor in (0, 1] for fast tests.
    """
    spec = PAPER_DATASETS[name.lower()]
    n = max(16, int(spec.n_vertices * scale))
    m = max(16, int(spec.n_edges * scale))
    return make_graph(spec.family, n, m, seed=seed)
