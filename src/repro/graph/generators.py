"""Synthetic graph generators matched to the paper's dataset families.

The paper (Table 2) evaluates on finite-element meshes (3elt, 4elt),
collaboration/citation networks (GrQc, AstroPh), social graphs (Wiki-vote,
Twitter) and a communication graph (Email-enron). This container has no
network access, so ``repro.graph.datasets`` instantiates synthetic graphs
from these generators with |V| and |E| matched to Table 2.
"""
from __future__ import annotations

import numpy as np

from repro.graph.csr import Graph, from_edge_list


def mesh_graph(n: int, rng: np.random.Generator) -> Graph:
    """Finite-element-mesh-like planar graph (3elt/4elt family).

    Triangulated grid: ~3 edges per vertex interior, like the Walshaw
    archive FE meshes (avg degree ~6 in CSR, |E| ≈ 3|V|).
    """
    side = int(np.ceil(np.sqrt(n)))
    ids = -np.ones((side, side), dtype=np.int64)
    flat = np.arange(side * side)
    ids.reshape(-1)[flat] = flat
    ids = np.where(ids < n, ids, -1)
    edges = []
    grid = np.arange(side * side).reshape(side, side)
    # right, down, and one diagonal -> triangulation
    for (di, dj) in ((0, 1), (1, 0), (1, 1)):
        a = grid[: side - di if di else side, : side - dj if dj else side]
        b = grid[di:, dj:]
        edges.append(np.stack([a.reshape(-1), b.reshape(-1)], axis=1))
    e = np.concatenate(edges)
    e = e[(e[:, 0] < n) & (e[:, 1] < n)]
    # jitter: drop a few edges so the mesh is irregular like 3elt
    keep = rng.random(e.shape[0]) > 0.02
    return from_edge_list(e[keep], n=n)


def barabasi_albert(n: int, m: int, rng: np.random.Generator) -> Graph:
    """Preferential-attachment graph (social / citation family)."""
    m = max(1, m)
    targets = list(range(m))
    repeated: list[int] = []
    edges = np.empty((max(0, (n - m)) * m, 2), dtype=np.int64)
    k = 0
    for v in range(m, n):
        for t in targets:
            edges[k] = (v, t)
            k += 1
        repeated.extend(targets)
        repeated.extend([v] * m)
        # sample next targets by degree (preferential attachment)
        idx = rng.integers(0, len(repeated), size=3 * m)
        cand = {repeated[i] for i in idx}
        targets = list(cand)[:m]
        while len(targets) < m:
            t = int(rng.integers(0, v + 1))
            if t not in targets:
                targets.append(t)
    return from_edge_list(edges[:k], n=n)


def erdos_renyi(n: int, m_edges: int, rng: np.random.Generator) -> Graph:
    """Uniform random graph with ~m_edges edges."""
    m_draw = int(m_edges * 1.15) + 8
    u = rng.integers(0, n, size=m_draw)
    v = rng.integers(0, n, size=m_draw)
    e = np.stack([u, v], axis=1)
    e = e[u != v][:m_edges]
    return from_edge_list(e, n=n)


def powerlaw_cluster(n: int, m: int, p: float, rng: np.random.Generator) -> Graph:
    """BA-with-triads (Holme–Kim-like): heavy tail + clustering (social)."""
    g = barabasi_albert(n, m, rng)
    # add triad-closing edges
    extra = []
    n_extra = int(p * g.num_edges)
    vs = rng.integers(0, n, size=n_extra)
    for v in vs:
        nbrs = g.neighbors(int(v))
        if nbrs.size >= 2:
            a, b = rng.choice(nbrs, size=2, replace=False)
            extra.append((int(a), int(b)))
    if extra:
        e = np.concatenate([g.edge_array(), np.array(extra, dtype=np.int64)])
        g = from_edge_list(e, n=n)
    return g


def make_graph(family: str, n: int, m_edges: int, seed: int = 0) -> Graph:
    """Dispatch by dataset family with target |V|=n, |E|≈m_edges."""
    rng = np.random.default_rng(seed)
    if family == "mesh":
        return mesh_graph(n, rng)
    if family in ("social", "citation", "collaboration"):
        m = max(1, int(round(m_edges / max(n, 1))))
        return powerlaw_cluster(n, m, 0.1, rng)
    if family == "communication":
        m = max(1, int(round(m_edges / max(n, 1))))
        return barabasi_albert(n, m, rng)
    if family == "uniform":
        return erdos_renyi(n, m_edges, rng)
    raise ValueError(f"unknown graph family: {family}")
